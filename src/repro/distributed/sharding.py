"""Path-based sharding rules -> PartitionSpec trees, with auto-legalization.

Every parameter leaf is matched by the *suffix* of its tree path against a
rule table; the rule yields logical axes for the trailing dims (leading
stacked dims — layers / groups / bank slots — are always replicated).
Logical axes map to mesh axes per run:

    tp   -> "model"
    fsdp -> "data"  (only when the run enables FSDP; else replicated)
    dp   -> ("pod", "data") on the multi-pod mesh, ("data",) single-pod

``legalize`` drops any spec entry whose dim is not divisible by the mapped
mesh-axis size (e.g. glm4's 2 kv heads over 16-way TP, smollm's 15 heads) —
GSPMD would otherwise reject the sharding.  Dropped entries are recorded so
the dry-run can report them (they are hillclimb candidates: padding the dim
recovers the sharding).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    tp_axis: str = "model"
    fsdp_axis: Optional[str] = None     # set to "data" to enable FSDP/ZeRO
    dp_axes: tuple = ("data",)          # batch axes
    style: str = "1d"                   # "1d" (baseline) | "2d" (serve:
                                        # weights shard OUTPUT dims over
                                        # (fsdp x tp); contraction dims never
                                        # shard, so no partial-sum
                                        # all-reduces of huge activations)


# rule table: (path regex, logical axes for the TRAILING dims)
# logical names: "tp", "fsdp", None
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed/embedding$",        ("tp", "fsdp")),
    (r"head/w$",                 ("fsdp", "tp")),
    (r"bank_head/w$",            (None, "fsdp", "tp")),
    (r"attn/w[qkv]$",            ("fsdp", "tp")),
    (r"attn/wo$",                ("tp", "fsdp")),
    (r"self_attn/w[qkv]$",       ("fsdp", "tp")),
    (r"self_attn/wo$",           ("tp", "fsdp")),
    (r"cross_attn/w[qkv]$",      ("fsdp", "tp")),
    (r"cross_attn/wo$",          ("tp", "fsdp")),
    (r"mlp/w[gu]$",              ("fsdp", "tp")),
    (r"mlp/wd$",                 ("tp", "fsdp")),
    (r"moe/router$",             (None, None)),
    (r"moe/w[gu]$",              ("tp", "fsdp", None)),   # experts over model
    (r"moe/wd$",                 ("tp", None, "fsdp")),
    (r"mamba/in_proj$",          ("fsdp", "tp")),
    (r"mamba/out_proj$",         ("tp", "fsdp")),
    (r"mamba/conv_w$",           (None, "tp")),
    (r"mamba/conv_b$",           ("tp",)),
    (r"adapter/a$",              (None, "tp", None)),     # (K, d@tp, r)
    (r"adapter/b$",              (None, None, "tp")),     # (K, r, out@tp)
    (r"frontend_proj/w$",        (None, "tp")),
    (r"frame_proj/w$",           (None, "tp")),
    (r"(norm|ln\d|scale)",       None),                   # norms: replicate
]


# "2d" serve style: every matrix shards only its OUTPUT dim, jointly over
# (fsdp, tp) where available.  "both" maps to the (fsdp_axis, tp_axis) tuple.
_PARAM_RULES_2D: list[tuple[str, tuple]] = [
    (r"embed/embedding$",        ("tp", "fsdp")),   # gather, not contraction
    (r"head/w$",                 (None, "both")),
    (r"bank_head/w$",            (None, None, "both")),
    (r"(attn|self_attn|cross_attn)/w[qkv]$", (None, "both")),
    (r"(attn|self_attn|cross_attn)/wo$",     (None, "both")),
    (r"mlp/w[gud]$",             (None, "both")),
    (r"moe/router$",             (None, None)),
    (r"moe/w[gud]$",             ("tp", None, "fsdp")),
    (r"mamba/in_proj$",          (None, "both")),
    (r"mamba/out_proj$",         (None, "both")),
    (r"mamba/conv_w$",           (None, "tp")),
    (r"mamba/conv_b$",           ("tp",)),
    (r"adapter/a$",              (None, "tp", None)),
    (r"adapter/b$",              (None, None, "tp")),
    (r"frontend_proj/w$",        (None, "tp")),
    (r"frame_proj/w$",           (None, "tp")),
    (r"(norm|ln\d|scale)",       None),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _logical_to_mesh(logical, rules: ShardingRules):
    if logical == "tp":
        return rules.tp_axis
    if logical == "fsdp":
        return rules.fsdp_axis
    if logical == "both":
        axes = tuple(a for a in (rules.fsdp_axis, rules.tp_axis) if a)
        return axes if len(axes) > 1 else (axes[0] if axes else None)
    return None


def spec_for_path(path_s: str, ndim: int, rules: ShardingRules) -> P:
    table = _PARAM_RULES_2D if rules.style == "2d" else _PARAM_RULES
    for pattern, trailing in table:
        if re.search(pattern, path_s):
            if trailing is None:
                return P()
            axes = [_logical_to_mesh(a, rules) for a in trailing]
            lead = [None] * max(0, ndim - len(axes))
            return P(*(lead + axes[-ndim:] if ndim < len(axes) else lead + axes))
    return P()  # default: replicate


def param_specs(params_tree, rules: ShardingRules):
    """PartitionSpec tree matching ``params_tree`` (works on ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_path(_path_str(path), np.ndim(leaf) or len(leaf.shape), rules),
        params_tree,
    )


def legalize(spec_tree, shape_tree, mesh: Mesh):
    """Drop spec entries whose dims don't divide the mesh axis size.

    Returns (legal_spec_tree, dropped: list[(path, dim, axis)]).
    """
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    dropped: list = []

    def fix(path, spec, leaf):
        shape = leaf.shape
        new = []
        for i, entry in enumerate(spec):
            if entry is None or i >= len(shape):
                new.append(None if i < len(shape) else None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = int(np.prod([axis_size[a] for a in axes]))
            if shape[i] % total == 0:
                new.append(entry)
            else:
                dropped.append((_path_str(path), i, entry))
                new.append(None)
        return P(*new[: len(shape)])

    legal = jax.tree_util.tree_map_with_path(
        lambda path, spec, leaf: fix(path, spec, leaf), spec_tree, shape_tree
    )
    return legal, dropped


def batch_specs(batch_tree, rules: ShardingRules):
    """Batch dims shard over dp axes; everything else replicated."""
    dp = tuple(a for a in rules.dp_axes if a)

    def spec(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        return P(dp if len(dp) > 1 else dp[0], *([None] * (nd - 1)))

    return jax.tree_util.tree_map(spec, batch_tree)


def cache_specs(cache_tree, rules: ShardingRules):
    """KV / SSM caches: leading stacked dims replicated, batch dim over dp.

    Cache leaves look like (L, B, G, Lc, hd) / (L, B, H, P, N) /
    (groups, L, B, ...) — the batch dim is the one right after the stacked
    layer dims.  We mark dims conservatively: shard the first dim of size
    divisible by dp product that follows the leading layer dims.
    """
    dp = tuple(a for a in rules.dp_axes if a)
    dp_entry = dp if len(dp) > 1 else dp[0]

    def spec(path, leaf):
        shape = leaf.shape
        # batch dim index: kv caches "k"/"v" -> (L, B, ...); mamba state
        # "ssm"/"conv" -> (..., n, B, ...).  Identify as the dim after all
        # leading "stack" dims; we place it by name.
        name = _path_str(path)
        nd = len(shape)
        entries = [None] * nd
        if re.search(r"(^|/)(k|v)$", name) and nd >= 2:
            entries[1] = dp_entry
        elif re.search(r"(ssm|conv)$", name) and nd >= 2:
            # batch dim: for (n, B, ...) it's 1; for (groups, n, B, ...) it's 2
            bdim = nd - 4 if name.endswith("ssm") else nd - 3
            entries[max(bdim, 0)] = dp_entry
        return P(*entries)

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def opt_state_specs(param_spec_tree, opt_state):
    """Optimizer state shards like its params (m/v/master mirror the tree)."""
    specs = {
        "m": param_spec_tree,
        "v": param_spec_tree,
        "step": P(),
    }
    if "master" in opt_state:
        specs["master"] = param_spec_tree
    return specs


def named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
