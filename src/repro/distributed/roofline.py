"""Loop-aware roofline analysis from optimized HLO text.

XLA's ``compiled.cost_analysis()`` visits every ``while`` body ONCE, so any
scan-based layer stack (all our models) under-counts FLOPs / bytes /
collectives by the trip count.  This module re-derives the three roofline
terms structurally from ``compiled.as_text()``:

  * computations are parsed into blocks with a per-op symbol table
    (op name -> shape), so operand shapes resolve by reference,
  * ``while`` ops carry ``known_trip_count`` in backend_config — the call
    tree is evaluated with multiplicities (nested loops multiply),
  * FLOPs: ``dot`` ops contribute 2 * prod(result) * prod(contracting dims)
    (elementwise flops are ignored — matmul-dominated workloads),
  * HBM bytes: per op, result + operand bytes; ops inside *fusion*
    computations are skipped (post-fusion HLO: only fusion boundaries touch
    HBM),
  * collective ICI bytes (per device):
      all-reduce          2 x result bytes          (bidirectional ring)
      all-gather          result bytes              ((n-1)/n ~ 1)
      reduce-scatter      result bytes x (gs - 1)   (input = result x gs)
      all-to-all          result bytes
      collective-permute  result bytes

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

HW = {
    "peak_flops": 197e12,   # bf16 per chip
    "hbm_bw": 819e9,        # bytes/s per chip
    "ici_bw": 50e9,         # bytes/s per link (conservative: one link)
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def xla_cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as one dict-shaped record.

    Older jaxlibs return a one-element list of per-device dicts; newer ones
    return the dict directly.  Every consumer of the analyzer expects the
    dict schema, so normalize here.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def _shape_bytes(dtype: str, dims_str: str) -> int:
    n = 1
    if dims_str:
        for d in dims_str.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _first_shapes(text: str):
    return [(m.group(1), m.group(2)) for m in _SHAPE_RE.finditer(text)]


@dataclasses.dataclass
class OpInfo:
    name: str
    result_bytes: int
    result_dims: list
    opcode: str
    rhs: str


@dataclasses.dataclass
class Computation:
    name: str
    is_fusion: bool
    ops: list            # [OpInfo]
    symbols: dict        # name -> (dtype, dims list[int])

    # lazily filled
    local_dot_flops: float = 0.0
    local_hbm_bytes: float = 0.0
    local_coll: Optional[dict] = None
    calls: Optional[list] = None  # [(callee, multiplier, kind)]


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "copy-done", "copy-start", "after-all", "iota",
    "while", "conditional", "call", "partition-id", "replica-id",
    # CPU aliasing-artifact copies: elided on TPU (donated buffers)
    "copy",
}


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        head = _COMP_HEAD_RE.match(line)
        if head and not line.lstrip().startswith("//"):
            name = head.group(2)
            cur = Computation(
                name=name,
                is_fusion=name.startswith(("fused_", "wrapped_")) or ".fused" in name,
                ops=[], symbols={},
            )
            comps[name] = cur
            if head.group(1):
                entry_name = name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        op_name, rhs = m.group(1), m.group(2)
        shapes = _first_shapes(rhs)
        if not shapes:
            continue
        dtype, dims_str = shapes[0]
        dims = [int(d) for d in dims_str.split(",")] if dims_str else []
        cur.symbols[op_name] = (dtype, dims)
        # opcode = first identifier followed by '(' after the result type
        mo = re.search(r"\)?\s*([a-z][a-z0-9\-]*)\(", rhs)
        opcode = mo.group(1) if mo else ""
        pos = rhs.find(opcode + "(") if opcode else len(rhs)
        result_bytes = sum(
            _shape_bytes(m.group(1), m.group(2))
            for m in _SHAPE_RE.finditer(rhs[:pos] if opcode else rhs)
        ) or _shape_bytes(dtype, dims_str)
        cur.ops.append(OpInfo(
            name=op_name,
            result_bytes=result_bytes,
            result_dims=dims,
            opcode=opcode,
            rhs=rhs,
        ))
    comps["__entry__"] = comps[entry_name]
    return comps


def _operand_names(rhs: str, opcode: str) -> list[str]:
    i = rhs.find(opcode + "(")
    if i < 0:
        return []
    depth, j0, out = 0, i + len(opcode) + 1, []
    j = j0
    buf = ""
    while j < len(rhs):
        ch = rhs[j]
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                out.append(buf)
                break
            depth -= 1
        elif ch == "," and depth == 0:
            out.append(buf)
            buf = ""
            j += 1
            continue
        buf += ch
        j += 1
    names = []
    for tok in out:
        mm = re.search(r"%([\w\.\-]+)", tok)
        if mm:
            names.append(mm.group(1))
    return names


def _root_indexed_update(comp: Computation, comps: dict) -> Optional[int]:
    """If ``comp``'s root is a dynamic-update-slice / scatter (an in-place
    aliased write), return the update operand's byte size, else None.
    Fusions with such roots share their output buffer with the big operand —
    only the update region moves."""
    if not comp.ops:
        return None
    by_name = {o.name: o for o in comp.ops}
    root = comp.ops[-1]
    # look through dtype/shape wrappers (CPU float-normalization inserts
    # convert(DUS(...)) round-trips that don't exist on TPU)
    for _ in range(6):
        if root.opcode in ("convert", "bitcast", "reshape", "transpose", "copy"):
            src = _operand_names(root.rhs, root.opcode)
            if src and src[0] in by_name:
                root = by_name[src[0]]
                continue
        break
    if root.opcode not in ("dynamic-update-slice", "scatter"):
        return None
    opnames = _operand_names(root.rhs, root.opcode)
    upd_ix = 2 if root.opcode == "scatter" else 1
    if len(opnames) <= upd_ix or opnames[upd_ix] not in comp.symbols:
        return None
    dt, dims = comp.symbols[opnames[upd_ix]]
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES.get(dt, 4)


def _fusion_param_reads(callee: Computation) -> dict:
    """Effective bytes read per parameter index of a fused computation.

    A parameter consumed ONLY through dynamic-slice / gather / slice ops is
    read slice-wise (e.g. one layer's weights out of a stacked (L, ...)
    buffer inside a scan body) — charging the full stacked buffer to every
    iteration would overcount by L.  Returns {param_index: bytes | None},
    None = whole-buffer read.
    """
    params = {}
    for op in callee.ops:
        if op.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", op.rhs)
            if m:
                params[op.name] = int(m.group(1))
    # propagate through shape-only aliases so `bitcast(param)` slices count
    alias = dict()
    for op in callee.ops:
        if op.opcode in ("bitcast", "reshape", "transpose", "copy",
                         "get-tuple-element"):
            src = _operand_names(op.rhs, op.opcode)
            if src:
                root = alias.get(src[0], src[0])
                if root in params:
                    alias[op.name] = root
    use: dict = {}
    for op in callee.ops:
        if op.opcode in ("", "parameter", "bitcast", "reshape", "transpose",
                         "copy", "get-tuple-element"):
            continue
        for nm in _operand_names(op.rhs, op.opcode):
            nm = alias.get(nm, nm)
            if nm in params:
                sliced = op.opcode in ("dynamic-slice", "gather", "slice")
                all_sliced, b = use.get(nm, (True, 0))
                use[nm] = (all_sliced and sliced,
                           b + (op.result_bytes if sliced else 0))
    out = {}
    for nm, idx in params.items():
        if nm not in use:
            out[idx] = 0          # dead parameter
        else:
            all_sliced, b = use[nm]
            out[idx] = b if (all_sliced and b > 0) else None
    return out


def _analyze_locals(comp: Computation, comps: dict):
    dot_flops = 0.0
    hbm = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    calls: list = []
    for op in comp.ops:
        rhs = op.rhs
        oc = op.opcode
        if oc == "while":
            trip = 1
            mt = _TRIP_RE.search(rhs)
            if mt:
                trip = int(mt.group(1))
            mw = _WHILE_RE.search(rhs)
            if mw:
                calls.append((mw.group(2), trip, "loop"))     # body
                calls.append((mw.group(1), trip, "loop"))     # cond (cheap)
            continue
        if oc == "conditional":
            mb = _BRANCH_RE.search(rhs)
            if mb:
                for tok in mb.group(1).split(","):
                    mm = re.search(r"%?([\w\.\-]+)", tok.strip())
                    if mm:
                        calls.append((mm.group(1), 1, "branch"))
        mc = _CALLS_RE.search(rhs)
        if mc:
            calls.append((mc.group(1), 1, "call"))
        if oc == "dot":
            contract = _CONTRACT_RE.search(rhs)
            lhs_ops = _operand_names(rhs, oc)
            lhs_dims = comp.symbols.get(lhs_ops[0], ("f32", []))[1] if lhs_ops else []
            cdims = (
                [int(x) for x in contract.group(1).split(",") if x]
                if contract else []
            )
            cprod = 1
            for c in cdims:
                if c < len(lhs_dims):
                    cprod *= lhs_dims[c]
            rprod = 1
            for d in op.result_dims:
                rprod *= d
            dot_flops += 2.0 * rprod * cprod
        for coll_kind in _COLLECTIVES:
            if oc == coll_kind or oc.startswith(coll_kind):
                gs = 1
                mg = _GROUPS_RE.search(rhs)
                if mg:
                    gs = int(mg.group(2))
                rb = op.result_bytes
                if coll_kind == "all-reduce":
                    coll[coll_kind] += 2.0 * rb
                elif coll_kind == "reduce-scatter":
                    coll[coll_kind] += rb * max(gs - 1, 1)
                else:
                    coll[coll_kind] += rb
                break
        # HBM bytes: fusion boundaries only.  Indexed ops are special-cased:
        # dynamic-update-slice / scatter alias their big operand in place
        # (only the update moves); gather / dynamic-slice read only the
        # slice they produce, not the whole operand.
        if oc and oc not in _SKIP_BYTES_OPS:
            opnames = _operand_names(rhs, oc)

            def obytes(name):
                if name not in comp.symbols:
                    return 0
                dt, dims = comp.symbols[name]
                n = 1
                for d in dims:
                    n *= d
                return n * _DTYPE_BYTES.get(dt, 4)

            if oc in ("dynamic-update-slice", "scatter"):
                # dynamic-update-slice(operand, update, idx...) vs
                # scatter(operand, indices, updates)
                upd_ix = 2 if oc == "scatter" else 1
                update = obytes(opnames[upd_ix]) if len(opnames) > upd_ix else 0
                hbm += 2 * update  # read update + write into aliased buffer
            elif oc in ("gather", "dynamic-slice"):
                hbm += 2 * op.result_bytes  # read slice + write result
            elif oc == "fusion":
                mc2 = _CALLS_RE.search(rhs)
                callee_name = mc2.group(1) if mc2 else ""
                callee = comps.get(callee_name)
                upd = _root_indexed_update(callee, comps) if callee else None
                if upd is not None:
                    hbm += 2 * upd  # in-place aliased write-back fusion
                elif "wrapped_convert" in callee_name:
                    # CPU float-normalization artifact: TPU keeps bf16 and
                    # fuses converts into consumers — charge the source read
                    hbm += sum(obytes(n) for n in opnames)
                elif "wrapped_broadcast" in callee_name:
                    pass  # broadcast-of-constant: fused into consumers on TPU
                else:
                    reads = _fusion_param_reads(callee) if callee else {}
                    total = op.result_bytes
                    for i, n in enumerate(opnames):
                        eff = reads.get(i, None)
                        total += obytes(n) if eff is None else eff
                    hbm += total
            else:
                hbm += op.result_bytes + sum(obytes(n) for n in opnames)
    comp.local_dot_flops = dot_flops
    comp.local_hbm_bytes = hbm
    comp.local_coll = coll
    comp.calls = calls


def analyze(text: str) -> dict:
    """Loop-aware totals from optimized HLO text (per device)."""
    comps = parse_hlo(text)
    seen = set()
    for c in comps.values():
        if c.name in seen:
            continue
        seen.add(c.name)
        _analyze_locals(c, comps)

    memo_flops: dict[str, float] = {}
    memo_bytes: dict[str, float] = {}
    memo_coll: dict[str, dict] = {}

    def total(name: str, stack=()):
        if name in memo_flops:
            return memo_flops[name], memo_bytes[name], memo_coll[name]
        if name not in comps or name in stack:
            return 0.0, 0.0, {k: 0.0 for k in _COLLECTIVES}
        c = comps[name]
        f = c.local_dot_flops
        b = 0.0 if c.is_fusion else c.local_hbm_bytes
        coll = dict(c.local_coll)
        for callee, mult, kind in c.calls:
            cf, cb, cc = total(callee, stack + (name,))
            f += mult * cf
            if kind != "call" or not comps.get(callee, c).is_fusion:
                b += mult * cb
            for k in _COLLECTIVES:
                coll[k] += mult * cc[k]
        memo_flops[name], memo_bytes[name], memo_coll[name] = f, b, coll
        return f, b, coll

    entry = comps["__entry__"].name
    f, b, coll = total(entry)
    return {
        "dot_flops": f,
        "hbm_bytes": b,
        "collective_bytes": coll,
        "collective_bytes_total": sum(coll.values()),
    }


def roofline_terms(analysis: dict, xla_cost: dict | None = None) -> dict:
    """Seconds per step for each roofline term (per chip; analysis is already
    per-device because the HLO module is the SPMD-partitioned one)."""
    compute_s = analysis["dot_flops"] / HW["peak_flops"]
    memory_s = analysis["hbm_bytes"] / HW["hbm_bw"]
    coll_s = analysis["collective_bytes_total"] / HW["ici_bw"]
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", coll_s),
        key=lambda kv: kv[1],
    )[0]
    out = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "step_s_lower_bound": max(compute_s, memory_s, coll_s),
    }
    if xla_cost:
        out["xla_flops_body_once"] = xla_cost.get("flops")
        out["xla_bytes_body_once"] = xla_cost.get("bytes accessed")
    return out


def model_flops(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS: 6*N*D (train) / 2*N*D (inference), N = active params.

    enc-dec splits the position budget (S/2 frames through the encoder,
    S/2 tokens through the decoder), so D uses seq_len/2 — each token only
    crosses its own stack.
    """
    n_active = cfg.active_param_count()
    seq = shape.seq_len // 2 if cfg.family == "encdec" else shape.seq_len
    if kind == "train":
        tokens = shape.global_batch * seq
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n_active * tokens
