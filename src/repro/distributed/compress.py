"""Gradient compression for slow (cross-pod) links.

Int8 symmetric quantization with per-leaf scale.  Two entry points:

* ``quantize`` / ``dequantize``  — the codec itself (pure, jit-safe),
* ``compressed_psum``            — shard_map'd all-reduce that moves int8
  over the wire and dequantizes after the sum: 4x less ICI traffic on the
  ``pod`` axis at <0.5% relative error on gradient-scale tensors (validated
  in tests/test_compress.py).

In the pjit train step, autodiff inserts fp32/bf16 psums automatically; the
``compress_grads`` wrapper is applied to already-reduced per-pod gradients
to model the cross-pod stage explicitly (and is exercised for real through
``compressed_psum`` in the multi-device subprocess test).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def quantize(x, axis=None):
    """x -> (int8 codes, fp32 scale).  Symmetric, saturating."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf)) if axis is None else jnp.max(
        jnp.abs(xf), axis=axis, keepdims=True
    )
    scale = jnp.maximum(amax, 1e-12) / 127.0
    codes = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def dequantize(codes, scale, dtype=jnp.float32):
    return (codes.astype(jnp.float32) * scale).astype(dtype)


def quantize_dequantize(x):
    codes, scale = quantize(x)
    return dequantize(codes, scale, x.dtype)


def compress_grads(grads):
    """Apply the int8 codec leaf-wise (models the compressed cross-pod
    reduce in single-program form)."""
    return jax.tree_util.tree_map(quantize_dequantize, grads)


def compressed_psum(x, mesh: Mesh, axis: str):
    """All-reduce ``x`` over ``axis`` moving int8 codes over the wire.

    Each participant quantizes locally; codes are summed in int32 (psum),
    scales are max-reduced; the dequantized mean uses the shared scale.
    """
    rest = tuple(a for a in mesh.axis_names if a != axis)

    def body(xs):
        codes, scale = quantize(xs)
        # share one scale so the int sum is coherent
        gscale = jax.lax.pmax(scale, axis)
        codes = jnp.clip(
            jnp.round(xs.astype(jnp.float32) / gscale), -127, 127
        ).astype(jnp.int8)
        summed = jax.lax.psum(codes.astype(jnp.int32), axis)
        return (summed.astype(jnp.float32) * gscale).astype(xs.dtype)

    spec = P(*([None] * x.ndim))
    return shard_map(
        body, mesh=mesh, in_specs=(spec,), out_specs=spec, check_rep=False
    )(x)
