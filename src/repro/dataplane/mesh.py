"""Multi-host mesh data plane: one facade over per-host runtime shards.

The paper's north star is in-network inference that scales with the
*network*, not a single box: INSIGHT frames in-network AI as inherently
topology-spanning and FENIX coordinates per-device inference engines
across a fabric.  ``MeshDataplane`` lifts the single-host
`repro.dataplane.runtime.DataplaneRuntime` to that shape — ``hosts``
runtime shards, each with its own ring set, worker fan-out (devices via
`repro.launch.mesh.make_queue_mesh`), and telemetry, behind one facade
that speaks the exact same API (``dispatch``/``tick``/``drain``/
``audit_conservation``/``snapshot``/``control``), so scenarios, policies
and benchmarks drive a mesh and a single host identically.

**Cross-host RSS.**  The 128-bucket RETA generalizes so each bucket
resolves to a ``(host, queue)`` pair, encoded as a host-major *global
queue id* (``rss.global_queue_id``): the mesh table over ``H * Q``
global ids is literally the single-host table over more queues, so the
default round-robin layout, affinity preservation, and failover remap
are the same code — ``MeshDataplane(hosts=1)`` is bit-identical to
``DataplaneRuntime`` by construction, and cross-host failover never
remaps a flow whose (host, queue) both survive.  Dispatch hashes each
burst ONCE, resolves buckets through the mesh RETA, and hands every
host its share together with the already-resolved local queue ids
(``gid % Q``); each shard also holds the *local projection* of the mesh
table (exact for the buckets it owns, in-range-but-unreachable for the
rest) so its own RETA state stays valid.

**Epoch-barrier control fan-out.**  The facade implements the runtime
protocol `repro.control.ControlPlane` drives, so ONE unmodified
``control.submit`` broadcasts an epoch to every host under a two-phase
barrier: ``_validate_command`` *stages* the epoch (mesh-scope checks
plus per-host validation of each shard's projection — any host's
rejection rejects the epoch before anything mutates), and
``_apply_command`` *commits* it to every host between the same two mesh
ticks, after ``retire_all`` has made every shard quiescent (the
barrier).  ``_control_state`` snapshots mesh-wide, so a commit that
fails on any host rolls back every host atomically.  Applied epochs are
stamped with ``host_ticks`` — the per-host apply tick, all equal — and
the epoch log, ``continuity_audit()``, and the ``RoutingPolicy`` loop
(fed by mesh-merged telemetry and global-id views) work unchanged at
mesh scale.

**Fault-tolerant barriers (DESIGN.md §10).**  The barrier above would
stall the whole mesh forever on one dead host; emergency networks make
that the normal case, not the exception.  Each host therefore holds a
tick-granularity *lease* (`repro.control.health.HealthMonitor`): serving
a tick heartbeats it, failing to — unresponsive, or blocking a pending
epoch barrier — burns it.  A straggler defers the barrier (bounded:
every deferred tick is a missed lease tick) until its lease expires and
it is declared DEAD, at which point the mesh synthesizes a ``FailQueues``
failover epoch for the dead host's global queue ids and commits pending
epochs *degraded* — a quorum of live, acked hosts instead of all hosts
(``commit_mode`` records which; losing quorum itself rolls the epoch
back atomically).  Dead hosts are re-probed with exponential backoff;
a host that answers is resynced (bank + RETA projection from a live
host, stale in-flight retired) before its queues are restored, so
packets stranded in its rings drain instead of vanishing — the
conservation audit counts them (``stranded``) while it is down.  Faults
are injected deterministically at named points by
`repro.dataplane.faults.FaultInjector`; without one armed the mesh
behaves exactly as before and the all-equal barrier stamp stays a hard
invariant.
"""

from __future__ import annotations

import math
import time

import numpy as np

from repro.control import (ControlPlane, FailQueues, HealthMonitor,
                           HostState, NonFatalControlError, ProgramReta,
                           RestoreQueues, SetPolicy, SwapSlot)
from repro.dataplane import rss
from repro.dataplane import runtime as runtime_mod
from repro.dataplane import telemetry as telemetry_mod
from repro.dataplane.runtime import DataplaneRuntime


class QuorumLost(NonFatalControlError):
    """Fewer live hosts acked a commit than the configured quorum: the
    epoch rolls back atomically and the run continues (non-fatal — a
    partitioned mesh refusing to commit is an outcome, not a bug)."""


class _MeshCounters:
    """Mesh-level control counters + live cross-host audit aggregation.

    ``slot_swaps``/``reta_updates`` count mesh *commands* (one broadcast
    = one event), while ``wrong_verdict`` sums the per-host audit
    counters live — the shape ``ControlPlane`` and ``continuity_audit``
    expect from a runtime's ``telemetry``.
    """

    def __init__(self, shards):
        self._shards = shards
        self.slot_swaps = 0
        self.reta_updates = 0
        self.degraded_commits = 0

    @property
    def wrong_verdict(self) -> int:
        return sum(s.telemetry.wrong_verdict for s in self._shards)


class MeshDataplane:
    """``hosts`` DataplaneRuntime shards behind one runtime-shaped facade.

    ``num_queues`` is *per host*; the mesh exposes ``hosts * num_queues``
    global queues (``self.num_queues``), and every queue-addressed
    control command (``ProgramReta`` / ``FailQueues`` / ``RestoreQueues``)
    speaks global ids.  Remaining keyword arguments (strategy, fanout,
    batch, ring_capacity, audit, record, pipeline_depth, ...) pass
    through to every shard; ``policy`` is held at mesh level and sees
    the merged, global-id view.

    ``megastep_ticks > 1`` puts every shard in deferred (megastep) mode:
    each host runs its staged tick windows on device in one compiled
    scan (DESIGN.md §13) *between* epoch barriers — the barrier's
    ``retire_all`` is exactly the per-shard flush point, so a committing
    epoch still observes every shard quiescent, and mesh-level fault
    injection (leases, quorum, injected stalls) keeps per-tick host
    control because it never reaches shard internals.
    """

    def __init__(self, bank, *, hosts: int, num_queues: int,
                 policy=None, fault_injector=None, lease_ticks: int = 8,
                 suspect_after: int = 2, quorum: int | None = None,
                 megastep_ticks: int = 1,
                 log_capacity: int | None = None,
                 log_spill: str | None = None, **runtime_kw):
        if hosts < 1:
            raise ValueError("need at least one host")
        self.hosts = int(hosts)
        self.num_queues_per_host = int(num_queues)
        self.num_queues = self.hosts * self.num_queues_per_host
        self.rss_key = runtime_kw.get("rss_key", rss.DEFAULT_KEY)
        # shards never get the policy: rebalancing happens once, at mesh
        # scope, over global ids — not per host over local ids
        self.shards = [
            DataplaneRuntime(bank, num_queues=self.num_queues_per_host,
                             megastep_ticks=megastep_ticks, **runtime_kw)
            for _ in range(self.hosts)
        ]
        self.reta = rss.mesh_indirection_table(
            self.hosts, self.num_queues_per_host)
        self.failed_queues: set[int] = set()     # global ids
        self.bucket_load = np.zeros(len(self.reta), np.int64)
        self.policy = policy
        self.telemetry = _MeshCounters(self.shards)
        self.control = ControlPlane(self, log_capacity=log_capacity,
                                    spill_path=log_spill)
        self._faults = fault_injector
        self.lease_ticks = int(lease_ticks)
        self.quorum = (int(quorum) if quorum is not None
                       else math.ceil(self.hosts / 2))
        if not 1 <= self.quorum <= self.hosts:
            raise ValueError(f"quorum must be in [1, {self.hosts}]")
        self.health = HealthMonitor(self.hosts, lease_ticks=self.lease_ticks,
                                    suspect_after=suspect_after)
        # hosts whose queues the mesh itself failed over (vs. operator
        # FailQueues): restored automatically when the host is healthy
        self._auto_failed: set[int] = set()
        self._participants: tuple[int, ...] = tuple(range(self.hosts))
        self._barrier_deferred = False
        self._deferred_since: int | None = None
        self.failover_epochs: list[int] = []
        self.restore_epochs: list[int] = []
        self._tick_count = 0
        self._t_start: float | None = None

    # -- liveness helpers ----------------------------------------------------

    def _responsive(self, host: int, tick: int | None = None) -> bool:
        if self._faults is None:
            return True
        return self._faults.responsive(
            host, self._tick_count if tick is None else tick)

    def _barrier_ready(self, host: int) -> bool:
        """Can this host quiesce at the barrier right now?"""
        if not self._responsive(host):
            return False
        return (self._faults is None
                or not self._faults.retire_blocked(host, self._tick_count))

    def _live_hosts(self) -> tuple[int, ...]:
        return self.health.live_hosts()

    def _host_gids(self, host: int) -> tuple[int, ...]:
        q = self.num_queues_per_host
        return tuple(range(host * q, (host + 1) * q))

    def _fault_point(self, point: str) -> None:
        """Consult the injector at a stage/apply point for every commit
        participant; an armed ``ShardError`` raises ``InjectedFault``."""
        if self._faults is not None:
            for h in self._participants:
                self._faults.check(point, h, self._tick_count)

    # -- shard-projection helpers -------------------------------------------

    @property
    def num_slots(self) -> int:
        """Resident bank size (identical on every shard)."""
        return self.shards[0].num_slots

    @property
    def pipeline_depth(self) -> int:
        """Bounded in-flight tick window (identical on every shard)."""
        return self.shards[0].pipeline_depth

    @property
    def rings(self) -> list:
        """All rings in host-major global-queue order."""
        return [r for s in self.shards for r in s.rings]

    @property
    def completed_seq(self) -> list:
        """Per-tick completed sequence numbers, concatenated shard-major
        (record mode only)."""
        return [seqs for s in self.shards for seqs in s.completed_seq]

    @property
    def completed_verdicts(self) -> list:
        """Per-tick verdict arrays, concatenated shard-major (record
        mode only) — the bit-exact replay/equivalence signal."""
        return [v for s in self.shards for v in s.completed_verdicts]

    @property
    def completed_slots(self) -> list:
        """Per-tick served-slot arrays, concatenated shard-major
        (record mode only)."""
        return [v for s in self.shards for v in s.completed_slots]

    @property
    def dropped_seq(self) -> list[int]:
        """Sequence numbers of tail-dropped packets across all shards
        (record mode only)."""
        return [x for s in self.shards for x in s.dropped_seq]

    def _shard_reta(self, reta: np.ndarray) -> np.ndarray:
        """Project the mesh RETA onto a host-local table: ``gid % Q`` is
        the exact queue for buckets the host owns and an in-range (but
        never-dispatched-to) value for buckets other hosts own.  The
        projection is host-independent, so one table serves every shard;
        mesh dispatch hands shards resolved queue ids directly, but the
        projection keeps each shard's own RETA state valid.
        """
        return (np.asarray(reta, np.int64)
                % self.num_queues_per_host).astype(np.int32)

    # -- control plane: the runtime protocol ControlPlane drives ------------

    def _validate_command(self, cmd) -> None:
        """STAGE phase of the two-phase broadcast: validate at mesh scope
        (global-id ranges), then stage the per-host projection on EVERY
        shard without mutating any — a single host's rejection rejects
        the whole epoch before any host commits.  Only the current
        barrier participants stage: a DEAD host cannot be asked, and its
        stale state is resynced wholesale when it rejoins."""
        self._fault_point("stage")
        if isinstance(cmd, SwapSlot):
            for h in self._participants:
                self.shards[h]._validate_command(cmd)
        elif isinstance(cmd, ProgramReta):
            reta = np.asarray(cmd.reta, np.int32)
            if reta.size == 0:
                raise ValueError("empty RETA")
            if reta.min() < 0 or reta.max() >= self.num_queues:
                raise ValueError("RETA entry out of global queue range")
            proj = ProgramReta(tuple(self._shard_reta(reta)))
            for h in self._participants:
                self.shards[h]._validate_command(proj)
        elif isinstance(cmd, (FailQueues, RestoreQueues)):
            if any(not 0 <= q < self.num_queues for q in cmd.queues):
                raise ValueError("queue id out of global range")
        elif isinstance(cmd, SetPolicy):
            if cmd.policy is not None and not hasattr(cmd.policy, "propose"):
                raise TypeError("policy must implement propose(view)")
        else:
            raise TypeError(f"not a control command: {cmd!r}")

    def _apply_command(self, cmd) -> None:
        """COMMIT phase: apply ONE mesh command to every host between the
        same two mesh ticks.  Only ``ControlPlane.apply_pending`` calls
        this; its mesh-wide ``_control_state`` snapshot makes a commit
        that fails on any host roll back every host."""
        self._fault_point("apply")
        if isinstance(cmd, SwapSlot):
            for h in self._participants:
                self.shards[h]._apply_command(cmd)
            self.telemetry.slot_swaps += 1
        elif isinstance(cmd, ProgramReta):
            self._install_reta(np.asarray(cmd.reta, np.int32))
        elif not runtime_mod.apply_routing_command(self, cmd):
            # the shared appliers see the mesh's global queue count and
            # its projecting _install_reta — the same audited code path
            # as the single-host runtime, over more queues
            raise TypeError(f"not a control command: {cmd!r}")

    def _install_reta(self, reta: np.ndarray) -> None:
        reta = np.asarray(reta, np.int32)
        if reta.min() < 0 or reta.max() >= self.num_queues:
            raise ValueError("RETA entry out of global queue range")
        proj = ProgramReta(tuple(self._shard_reta(reta)))
        for h in self._participants:
            self.shards[h]._apply_command(proj)
        if len(reta) != len(self.bucket_load):
            self.bucket_load = np.zeros(len(reta), np.int64)
        self.reta = reta
        self.telemetry.reta_updates += 1

    def _control_state(self) -> dict:
        """Mesh-wide snapshot: facade state plus every shard's control
        state, so a rejected epoch rolls back atomically across hosts."""
        return dict(
            reta=self.reta, failed=set(self.failed_queues),
            policy=self.policy, bucket_load=self.bucket_load,
            slot_swaps=self.telemetry.slot_swaps,
            reta_updates=self.telemetry.reta_updates,
            shards=[s._control_state() for s in self.shards],
        )

    def _rollback_control_state(self, st: dict) -> None:
        self.reta = st["reta"]
        self.failed_queues = st["failed"]
        self.policy = st["policy"]
        self.bucket_load = st["bucket_load"]
        self.telemetry.slot_swaps = st["slot_swaps"]
        self.telemetry.reta_updates = st["reta_updates"]
        for s, ss in zip(self.shards, st["shards"]):
            s._rollback_control_state(ss)

    def _apply_control(self) -> None:
        """Epoch-barrier commit: retire every in-flight tick on every
        live host (the barrier — all participating shards quiescent at
        one agreed mesh tick boundary), then apply the pending epochs.

        A live host that cannot reach the barrier right now (stalled, or
        its retire is injected-delayed) *defers* the whole commit — but
        every deferred tick burns a tick of that host's lease, so the
        deferral is bounded by ``lease_ticks``: the straggler either
        recovers or is declared DEAD at a coming ``observe``, at which
        point the epoch commits degraded over the survivors.  Each
        epoch's barrier stamp and commit mode are recorded per-epoch by
        ``_finish_epoch`` (called by ``ControlPlane.apply_pending``
        inside the transaction)."""
        if not self.control.has_pending:
            self._barrier_deferred = False
            self._deferred_since = None
            return
        tick = self._tick_count
        live = self._live_hosts()
        blocked = [h for h in live if not self._barrier_ready(h)]
        if blocked:
            self._barrier_deferred = True
            if self._deferred_since is None:
                self._deferred_since = tick
            for h in blocked:
                self.health.miss(h, tick)
            return
        self._barrier_deferred = False
        self._deferred_since = None
        self._participants = tuple(live)
        self.retire_all()
        self.control.apply_pending(tick)

    def _finish_epoch(self, rec) -> None:
        """Per-epoch commit finish, called inside the ``apply_pending``
        transaction after the last command applied: collect commit acks
        (the ``commit-ack`` injection point), enforce quorum, stamp the
        barrier proof and the commit mode.  Raising here rolls the epoch
        back on every host like any apply-time failure."""
        # barrier commit: every participant publishes its staged SwapSlot
        # params by flipping its double-buffered bank — O(1) per host, no
        # weights move (DESIGN.md §14).  A quorum failure below rolls the
        # flips back through the mesh-wide snapshot.
        for h in self._participants:
            self.shards[h]._finish_epoch(rec)
        tick = self._tick_count
        dropped = [h for h in self._participants
                   if self._faults is not None
                   and self._faults.drop_ack(h, tick)]
        acked = [h for h in self._participants if h not in dropped]
        if len(acked) < self.quorum:
            raise QuorumLost(
                f"{len(acked)}/{self.hosts} commit acks "
                f"(quorum {self.quorum}) for epoch {rec.epoch}")
        host_ticks = tuple(s._tick_count for s in self.shards)
        part_ticks = {host_ticks[h] for h in self._participants}
        if len(part_ticks) > 1 and not self.health.ever_missed:
            # on a healthy mesh the all-equal stamp is a hard invariant;
            # once hosts have missed ticks their counters lag by design
            raise RuntimeError(f"shard tick drift across hosts: {host_ticks}")
        rec.host_ticks = host_ticks
        degraded = len(self._participants) < self.hosts or bool(dropped)
        rec.commit_mode = "degraded" if degraded else "atomic"
        if degraded:
            self.telemetry.degraded_commits += 1
        for h in dropped:
            # an applied-but-unacked host cannot be trusted with traffic
            # until it proves itself again: suspect it and fail it over
            self.health.mark_suspect(h, tick, "commit ack dropped")
            self._ensure_failover(h)

    # -- host failover / rejoin ---------------------------------------------

    def _ensure_failover(self, host: int) -> None:
        """Synthesize a ``FailQueues`` epoch for the host's global queue
        ids (those not already failed).  Synthesized epochs are internal
        — like policy rebalances they are NOT recorded into traces; a
        replay's own health layer regenerates them deterministically."""
        gids = tuple(g for g in self._host_gids(host)
                     if g not in self.failed_queues)
        self._auto_failed.add(host)
        if not gids:
            return
        survivors = (set(range(self.num_queues)) - self.failed_queues
                     - set(gids))
        if not survivors:
            return   # nothing to fail over to; leave routing untouched
        self.failover_epochs.append(self.control.submit(FailQueues(gids)))

    def _restore_host(self, host: int) -> None:
        gids = tuple(g for g in self._host_gids(host)
                     if g in self.failed_queues)
        self._auto_failed.discard(host)
        if gids:
            self.restore_epochs.append(
                self.control.submit(RestoreQueues(gids)))

    def _resync_shard(self, host: int) -> None:
        """A rejoining host's shard missed every epoch committed while it
        was DEAD: copy the bank from a live reference shard, reinstall
        the current RETA projection, and retire its stale in-flight work
        (stranded pre-crash packets complete instead of vanishing)."""
        shard = self.shards[host]
        ref = next((h for h in range(self.hosts) if h != host
                    and not self.health.is_dead(h)), None)
        if ref is not None:
            # copy, never alias: under double buffering each shard owns
            # its two device buffers, and an aliased bank would be
            # donated out from under the reference shard
            shard.adopt_bank(self.shards[ref].bank)
        shard._install_reta(self._shard_reta(self.reta))
        shard.retire_all()

    @property
    def barrier_log(self) -> list[dict]:
        """The barrier history, derived from the epoch log (no second
        always-growing list to keep consistent)."""
        return [{"epoch": r.epoch, "mesh_tick": r.applied_tick,
                 "host_ticks": list(r.host_ticks)}
                for r in self.control.log
                if r.applied and r.host_ticks is not None]

    def _tick_boundary(self) -> None:
        tick = self._tick_count
        for tr in self.health.observe(tick,
                                      probe=lambda h: self._responsive(h)):
            if tr.to == HostState.DEAD.value:
                self._ensure_failover(tr.host)
            elif tr.to == HostState.RECOVERING.value:
                self._resync_shard(tr.host)
        for h in sorted(self._auto_failed):
            if self.health.state(h) is HostState.HEALTHY:
                self._restore_host(h)
        self._apply_control()
        runtime_mod.consult_policy(self, num_hosts=self.hosts)

    def flush_control(self) -> None:
        """Force-apply pending epochs now (host code runs between ticks)."""
        self._apply_control()

    def _prestage_epoch(self, rec) -> None:
        """Broadcast staging overlap (``ControlPlane.submit`` hook): fan
        the epoch's SwapSlot payloads to every live shard's shadow bank at
        submit time, so the mesh barrier commit is a pointer flip on every
        host instead of a per-host bank re-stage (DESIGN.md §14).  Dead
        hosts are skipped; they re-adopt the bank at rejoin resync."""
        for h in range(self.hosts):
            if not self.health.is_dead(h):
                self.shards[h]._prestage_epoch(rec)

    # -- data plane ---------------------------------------------------------

    def dispatch(self, packets_np: np.ndarray, now: float | None = None) -> dict:
        """RSS-dispatch one arrival burst across hosts.

        ONE Toeplitz hash resolves every flow through the mesh RETA to a
        (host, queue); each shard then admits its share through its own
        rings exactly as a single-host runtime would, taking the already-
        resolved local queue ids (the burst is never hashed twice).  The
        arrival edge is a mesh tick boundary: pending epochs commit first.
        """
        self._apply_control()
        if self._t_start is None:
            self._t_start = time.perf_counter()
        packets_np = np.asarray(packets_np)
        h = rss.toeplitz_hash(rss.flow_words_of(packets_np), self.rss_key)
        bucket = rss.bucket_index(h, len(self.reta)).astype(np.int64)
        self.bucket_load += np.bincount(bucket, minlength=len(self.reta))
        host, queue = rss.split_host_queue(self.reta[bucket],
                                           self.num_queues_per_host)
        per_host = []
        for i, s in enumerate(self.shards):
            mine = host == i
            per_host.append(
                s.dispatch(packets_np[mine], now=now, queues=queue[mine]))
        return {"per_host": per_host,
                "dropped": sum(p["dropped"] for p in per_host)}

    def tick(self) -> int:
        """One lockstep tick of every live, responsive host shard (each
        keeps its own bounded dispatch/device/retire pipeline).  Serving
        a tick heartbeats the host's lease; failing to burns it.  DEAD
        hosts are skipped entirely until a re-probe rejoins them."""
        t = self._tick_count
        self._tick_boundary()
        self._tick_count += 1
        total = 0
        for h, s in enumerate(self.shards):
            if self.health.is_dead(h):
                continue
            if not self._responsive(h, t):
                self.health.miss(h, t)
                continue
            total += s.tick()
            self.health.heartbeat(h, t)
        return total

    def retire_all(self) -> None:
        """Flush the pipeline of every shard that can flush — live,
        responsive, and not retire-blocked (the cross-host barrier
        point).  A host that cannot flush keeps its in-flight rows;
        conservation accounts them (``in_flight`` / ``stranded``)."""
        for h, s in enumerate(self.shards):
            if (not self.health.is_dead(h) and self._responsive(h)
                    and (self._faults is None or not
                         self._faults.retire_blocked(h, self._tick_count))):
                s.retire_all()

    def in_flight_rows(self) -> list[int]:
        """Rows popped but not retired, host-major global-queue order."""
        return [n for s in self.shards for n in s.in_flight_rows()]

    def drain(self, max_ticks: int = 100_000) -> int:
        """Tick until every ring on every live host is empty and no
        barrier is deferred, then flush.  Backlog on DEAD hosts does not
        block convergence — it stays stranded (and conserved) until the
        host rejoins; stalled-but-live hosts are waited for (bounded by
        their lease)."""
        done = 0
        for _ in range(max_ticks):
            n = self.tick()
            done += n
            live_rings = [r for h in range(self.hosts)
                          if not self.health.is_dead(h)
                          for r in self.shards[h].rings]
            if (n == 0 and not any(len(r) for r in live_rings)
                    and not self._barrier_deferred):
                self.retire_all()
                return done
        raise RuntimeError("drain did not converge")

    # -- audit + reporting --------------------------------------------------

    def audit_conservation(self) -> dict:
        """Mesh-wide packet conservation: per-host audits, a flattened
        per-queue view in global order, and totals summed across hosts —
        ``offered == admitted + dropped`` and ``admitted == completed +
        occupancy + in_flight`` must hold per host and in aggregate."""
        per_host = [s.audit_conservation() for s in self.shards]
        totals = {k: sum(h["totals"][k] for h in per_host)
                  for k in ("offered", "admitted", "dropped", "completed",
                            "occupancy", "in_flight")}
        dead = self.health.dead_hosts()
        stranded = sum(per_host[h]["totals"]["occupancy"]
                       + per_host[h]["totals"]["in_flight"] for h in dead)
        return {
            "per_host": per_host,
            "per_queue": [q for h in per_host for q in h["per_queue"]],
            "totals": totals,
            # packets admitted to now-DEAD hosts, conserved but parked
            # until the host rejoins (kept out of ``totals`` so a healthy
            # mesh's audit is bit-identical to the single-host runtime's)
            "stranded": {"packets": stranded, "hosts": list(dead)},
            "ok": all(h["ok"] for h in per_host),
            "wrong_verdict": self.telemetry.wrong_verdict,
        }

    def snapshot(self) -> dict:
        """One-call mesh report: aggregated telemetry, the mesh-wide
        conservation audit, health/lease state, and control stats."""
        elapsed = (time.perf_counter() - self._t_start
                   if self._t_start is not None else None)
        merged = telemetry_mod.merge([s.telemetry for s in self.shards])
        out = merged.snapshot(elapsed_s=elapsed)
        # broadcast commands count once, not once per host
        out["slot_swaps"] = self.telemetry.slot_swaps
        out["reta_updates"] = self.telemetry.reta_updates
        out["degraded_commits"] = self.telemetry.degraded_commits
        out["hosts"] = self.hosts
        out["queues_per_host"] = self.num_queues_per_host
        out["conservation"] = self.audit_conservation()
        out["health"] = self.health.snapshot()
        out["fault_events"] = (list(self._faults.events)
                               if self._faults is not None else [])
        out["fanout"] = self.shards[0].fanout
        out["strategy"] = self.shards[0].strategy
        out["pipeline_depth"] = self.pipeline_depth
        out["policy"] = getattr(self.policy, "name", None)
        out["control"] = self.control.stats()
        return out
