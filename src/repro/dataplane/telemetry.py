"""Per-queue data-plane telemetry: pps, drops, verdicts, latency histograms.

Counters mirror what a production data plane exports per hardware queue
(think ethtool -S / XDP stats): packets completed, drops at the ring edge,
per-slot verdict counts (how much traffic each resident model served and
how much of it was judged malicious), Pi action counts, and a log2 latency
histogram measured enqueue -> retire.  ``snapshot()`` freezes everything
into plain dicts per tick so benchmarks and the CLI can stream or diff
them without touching live state.

Two export paths coexist (DESIGN.md §11):

* ``snapshot()`` — the full frozen view, walked on demand.
* delta emission — when a sink is attached (``attach_sink``), the runtime
  calls ``emit_delta`` at retire boundaries and only the *increments*
  since the previous emission are pushed, computed from flat cursor
  arrays (one vector subtract per counter family, no per-queue dict
  walks).  With no sink attached the hot path pays a single attribute
  check.  Delta events are monotonic: summing a stream's deltas
  reproduces ``snapshot()`` totals exactly (tests assert this as a
  hypothesis property).
"""

from __future__ import annotations

import numpy as np

from repro.core import packet as pkt

# log2 latency bucket edges in microseconds: [1us .. ~134s] + overflow.
LATENCY_EDGES_US = np.concatenate(
    [[0.0], 2.0 ** np.arange(0, 28), [np.inf]])

#: Runtime-level event counters every ``Telemetry`` carries.  ``merge``
#: folds each of these generically, so adding a counter here is the whole
#: contract — no hand-copied list to forget (the PR-6 bug was exactly
#: that: new counters silently dropped by merge under faults).
EVENT_COUNTERS = ("slot_swaps", "reta_updates", "wrong_verdict",
                  "runtime_ticks", "dropped_total")


class QueueTelemetry:
    """Telemetry for one queue; updated once per processed tick."""

    def __init__(self, queue: int, num_slots: int):
        self.queue = queue
        self.ticks = 0
        self.completed = 0
        self.dropped = 0  # ring-edge drops charged to this queue
        self.busy_s = 0.0
        self.per_slot_total = np.zeros(num_slots, np.int64)
        self.per_slot_malicious = np.zeros(num_slots, np.int64)
        self.actions = np.zeros(3, np.int64)  # forward / drop / flag
        self.latency_hist = np.zeros(len(LATENCY_EDGES_US) - 1, np.int64)
        self.latency_sum_us = 0.0
        self.latency_max_us = 0.0

    def record(self, slots, verdicts, actions, latency_us, tick_s: float) -> None:
        slots = np.asarray(slots)
        verdicts = np.asarray(verdicts, bool)
        actions = np.asarray(actions)
        latency_us = np.asarray(latency_us, np.float64)
        self.ticks += 1
        self.completed += len(slots)
        self.busy_s += tick_s
        np.add.at(self.per_slot_total, slots, 1)
        np.add.at(self.per_slot_malicious, slots[verdicts], 1)
        for a in (pkt.ACTION_FORWARD, pkt.ACTION_DROP, pkt.ACTION_FLAG):
            self.actions[a] += int((actions == a).sum())
        if latency_us.size:
            self.latency_hist += np.histogram(latency_us, LATENCY_EDGES_US)[0]
            self.latency_sum_us += float(latency_us.sum())
            self.latency_max_us = max(self.latency_max_us, float(latency_us.max()))

    def record_bulk(self, *, ticks: int, completed: int, per_slot_total,
                    per_slot_malicious, actions, latency_us,
                    busy_s: float) -> None:
        """Fold a whole megastep window of device-accumulated counters in
        one call (DESIGN.md §13): the scan carries per-queue completed /
        served-tick / per-slot / action counters on device and the flush
        drains them here in bulk — totals are bit-identical to ``ticks``
        sequential ``record`` calls; only wall-clock attribution
        (``busy_s``, latencies) differs, measured at flush granularity.
        """
        latency_us = np.asarray(latency_us, np.float64)
        self.ticks += int(ticks)
        self.completed += int(completed)
        self.busy_s += busy_s
        self.per_slot_total += np.asarray(per_slot_total, np.int64)
        self.per_slot_malicious += np.asarray(per_slot_malicious, np.int64)
        self.actions += np.asarray(actions, np.int64)
        if latency_us.size:
            self.latency_hist += np.histogram(latency_us, LATENCY_EDGES_US)[0]
            self.latency_sum_us += float(latency_us.sum())
            self.latency_max_us = max(self.latency_max_us,
                                      float(latency_us.max()))

    def latency_quantile_us(self, q: float) -> float:
        """Histogram-resolution quantile (upper bucket edge)."""
        total = int(self.latency_hist.sum())
        if not total:
            return float("nan")
        cum = np.cumsum(self.latency_hist)
        b = int(np.searchsorted(cum, q * total))
        return float(LATENCY_EDGES_US[min(b + 1, len(LATENCY_EDGES_US) - 1)])

    def snapshot(self) -> dict:
        mean_lat = self.latency_sum_us / self.completed if self.completed else float("nan")
        return {
            "queue": self.queue,
            "ticks": self.ticks,
            "completed": self.completed,
            "dropped": self.dropped,
            "busy_s": self.busy_s,
            "pps_busy": self.completed / self.busy_s if self.busy_s else 0.0,
            "per_slot_total": self.per_slot_total.tolist(),
            "per_slot_malicious": self.per_slot_malicious.tolist(),
            "actions": {
                "forward": int(self.actions[pkt.ACTION_FORWARD]),
                "drop": int(self.actions[pkt.ACTION_DROP]),
                "flag": int(self.actions[pkt.ACTION_FLAG]),
            },
            "latency_mean_us": mean_lat,
            "latency_p50_us": self.latency_quantile_us(0.50),
            "latency_p99_us": self.latency_quantile_us(0.99),
            "latency_max_us": self.latency_max_us,
        }


class _DeltaCursor:
    """Last-emitted counter values, kept as flat arrays so each
    ``emit_delta`` is a handful of vector subtracts."""

    def __init__(self, num_queues: int, num_slots: int):
        self.completed = np.zeros(num_queues, np.int64)
        self.dropped = np.zeros(num_queues, np.int64)
        self.per_slot = np.zeros((num_queues, num_slots), np.int64)
        self.actions = np.zeros((num_queues, 3), np.int64)
        self.events = dict.fromkeys(EVENT_COUNTERS, 0)
        self.seq = 0


class Telemetry:
    """All-queue telemetry plus runtime-level event counters."""

    def __init__(self, num_queues: int, num_slots: int):
        self.num_slots = num_slots
        self.queues = [QueueTelemetry(q, num_slots) for q in range(num_queues)]
        self.slot_swaps = 0
        self.reta_updates = 0
        self.wrong_verdict = 0  # audit-mode mismatches vs the exact path
        self.runtime_ticks = 0  # ticks the runtime actually served
        self.dropped_total = 0  # ring-edge drops across all queues
        # wall-clock window this telemetry covers (first/last recorded
        # event) — merge() aligns merged pps over the UNION window so an
        # uneven-ticking host (stall/crash fault) cannot skew the rate.
        self.window_start_s: float | None = None
        self.window_last_s: float | None = None
        self._sink = None
        self._cursor: _DeltaCursor | None = None

    # -- recording -------------------------------------------------------

    def touch(self, now: float) -> None:
        """Stamp the wall-clock coverage window."""
        if self.window_start_s is None:
            self.window_start_s = now
        self.window_last_s = now

    def record_tick(self, queue: int, slots, verdicts, actions,
                    latency_us, tick_s: float) -> None:
        self.queues[queue].record(slots, verdicts, actions, latency_us, tick_s)

    def record_window(self, queue: int, **kw) -> None:
        """Bulk-fold one queue's megastep window (``QueueTelemetry.record_bulk``)."""
        self.queues[queue].record_bulk(**kw)

    def record_drops(self, queue: int, count: int, now: float | None = None) -> None:
        """Charge ``count`` ring-edge drops to ``queue``."""
        if count:
            self.queues[queue].dropped += count
            self.dropped_total += count
        if now is not None:
            self.touch(now)

    # -- delta stream ----------------------------------------------------

    @property
    def has_sink(self) -> bool:
        return self._sink is not None

    def attach_sink(self, sink) -> None:
        """Start delta emission: ``sink(event_dict)`` is called by
        ``emit_delta`` with each non-empty increment.  One sink at a
        time; cursors reset on attach, so the first delta carries the
        full counters accumulated so far."""
        self._sink = sink
        self._cursor = _DeltaCursor(len(self.queues), self.num_slots)

    def detach_sink(self) -> None:
        self._sink = None
        self._cursor = None

    def emit_delta(self, *, tick: int, now: float | None = None,
                   depths=None) -> dict | None:
        """Push the increments since the previous emission to the sink.

        ``depths`` (optional, per-queue ring occupancy) is a gauge — it
        rides along uncompared.  All-zero deltas are swallowed.  Returns
        the emitted event (or None).
        """
        if self._sink is None:
            return None
        cur = self._cursor
        n = len(self.queues)
        if len(cur.completed) != n:  # queues grew (merge targets never emit)
            grown = _DeltaCursor(n, self.num_slots)
            m = len(cur.completed)
            grown.completed[:m] = cur.completed
            grown.dropped[:m] = cur.dropped
            grown.per_slot[:m] = cur.per_slot
            grown.actions[:m] = cur.actions
            grown.events, grown.seq = cur.events, cur.seq
            cur = self._cursor = grown
        completed = np.fromiter((q.completed for q in self.queues), np.int64, n)
        dropped = np.fromiter((q.dropped for q in self.queues), np.int64, n)
        per_slot = np.stack([q.per_slot_total for q in self.queues])
        actions = np.stack([q.actions for q in self.queues])
        d_completed = completed - cur.completed
        d_dropped = dropped - cur.dropped
        d_slot = per_slot - cur.per_slot
        d_actions = actions - cur.actions
        changed = np.flatnonzero(
            d_completed | d_dropped | d_slot.any(axis=1) | d_actions.any(axis=1))
        d_events = {}
        for name in EVENT_COUNTERS:
            v = getattr(self, name)
            if v != cur.events[name]:
                d_events[name] = v - cur.events[name]
                cur.events[name] = v
        if not len(changed) and not d_events:
            return None
        cur.completed, cur.dropped = completed, dropped
        cur.per_slot, cur.actions = per_slot, actions
        event = {
            "kind": "delta",
            "seq": cur.seq,
            "tick": int(tick),
            "t_s": now,
            "queues": [
                {"queue": int(q),
                 "completed": int(d_completed[q]),
                 "dropped": int(d_dropped[q]),
                 "per_slot": d_slot[q].tolist(),
                 "actions": d_actions[q].tolist(),
                 **({"depth": int(depths[q])} if depths is not None else {})}
                for q in changed
            ],
            "events": d_events,
        }
        cur.seq += 1
        self._sink(event)
        return event

    # -- freezing --------------------------------------------------------

    def snapshot(self, *, elapsed_s: float | None = None) -> dict:
        qs = [q.snapshot() for q in self.queues]
        total = sum(q["completed"] for q in qs)
        out = {
            "queues": qs,
            "completed_total": total,
            "slot_swaps": self.slot_swaps,
            "reta_updates": self.reta_updates,
            "wrong_verdict": self.wrong_verdict,
            "runtime_ticks": self.runtime_ticks,
            "dropped_total": self.dropped_total,
        }
        if elapsed_s is None and self.window_start_s is not None:
            elapsed_s = self.window_last_s - self.window_start_s
        if elapsed_s:
            out["aggregate_pps"] = total / elapsed_s
        return out


def _copy_queue(src: QueueTelemetry, queue: int) -> QueueTelemetry:
    out = QueueTelemetry(queue, len(src.per_slot_total))
    out.ticks = src.ticks
    out.completed = src.completed
    out.dropped = src.dropped
    out.busy_s = src.busy_s
    out.per_slot_total = src.per_slot_total.copy()
    out.per_slot_malicious = src.per_slot_malicious.copy()
    out.actions = src.actions.copy()
    out.latency_hist = src.latency_hist.copy()
    out.latency_sum_us = src.latency_sum_us
    out.latency_max_us = src.latency_max_us
    return out


def merge(telemetries) -> Telemetry:
    """Aggregate per-host telemetries into one mesh-wide view.

    Queues are renumbered into host-major global order (host ``h`` queue
    ``q`` lands at ``h * Q + q``, matching ``rss.global_queue_id``) and
    every counter in ``EVENT_COUNTERS`` is summed generically, so
    policies and benchmarks read one ``Telemetry`` instead of
    hand-summing per-host dicts.  The wall-clock window is the UNION of
    the input windows (min start, max last): when hosts tick unevenly
    under faults — a stalled host covers a shorter window — the merged
    ``aggregate_pps`` divides by real elapsed time, not a sum of
    per-host windows.  The result is a deep copy: mutating it never
    touches the inputs.  Note a mesh-broadcast command counts once per
    host here; the mesh facade overrides those counters with its
    command-level counts.
    """
    tels = list(telemetries)
    if not tels:
        raise ValueError("merge needs at least one telemetry")
    if len({t.num_slots for t in tels}) != 1:
        raise ValueError("cannot merge telemetries with different slot counts")
    out = Telemetry(0, tels[0].num_slots)
    for t in tels:
        for qt in t.queues:
            out.queues.append(_copy_queue(qt, len(out.queues)))
        for name in EVENT_COUNTERS:
            setattr(out, name, getattr(out, name) + getattr(t, name))
        if t.window_start_s is not None:
            out.window_start_s = (t.window_start_s
                                  if out.window_start_s is None
                                  else min(out.window_start_s, t.window_start_s))
            out.window_last_s = (t.window_last_s
                                 if out.window_last_s is None
                                 else max(out.window_last_s, t.window_last_s))
    return out
