"""Per-queue data-plane telemetry: pps, drops, verdicts, latency histograms.

Counters mirror what a production data plane exports per hardware queue
(think ethtool -S / XDP stats): packets completed, drops at the ring edge,
per-slot verdict counts (how much traffic each resident model served and
how much of it was judged malicious), Pi action counts, and a log2 latency
histogram measured enqueue -> retire.  ``snapshot()`` freezes everything
into plain dicts per tick so benchmarks and the CLI can stream or diff
them without touching live state.
"""

from __future__ import annotations

import numpy as np

from repro.core import packet as pkt

# log2 latency bucket edges in microseconds: [1us .. ~134s] + overflow.
LATENCY_EDGES_US = np.concatenate(
    [[0.0], 2.0 ** np.arange(0, 28), [np.inf]])


class QueueTelemetry:
    """Telemetry for one queue; updated once per processed tick."""

    def __init__(self, queue: int, num_slots: int):
        self.queue = queue
        self.ticks = 0
        self.completed = 0
        self.busy_s = 0.0
        self.per_slot_total = np.zeros(num_slots, np.int64)
        self.per_slot_malicious = np.zeros(num_slots, np.int64)
        self.actions = np.zeros(3, np.int64)  # forward / drop / flag
        self.latency_hist = np.zeros(len(LATENCY_EDGES_US) - 1, np.int64)
        self.latency_sum_us = 0.0
        self.latency_max_us = 0.0

    def record(self, slots, verdicts, actions, latency_us, tick_s: float) -> None:
        slots = np.asarray(slots)
        verdicts = np.asarray(verdicts, bool)
        actions = np.asarray(actions)
        latency_us = np.asarray(latency_us, np.float64)
        self.ticks += 1
        self.completed += len(slots)
        self.busy_s += tick_s
        np.add.at(self.per_slot_total, slots, 1)
        np.add.at(self.per_slot_malicious, slots[verdicts], 1)
        for a in (pkt.ACTION_FORWARD, pkt.ACTION_DROP, pkt.ACTION_FLAG):
            self.actions[a] += int((actions == a).sum())
        if latency_us.size:
            self.latency_hist += np.histogram(latency_us, LATENCY_EDGES_US)[0]
            self.latency_sum_us += float(latency_us.sum())
            self.latency_max_us = max(self.latency_max_us, float(latency_us.max()))

    def latency_quantile_us(self, q: float) -> float:
        """Histogram-resolution quantile (upper bucket edge)."""
        total = int(self.latency_hist.sum())
        if not total:
            return float("nan")
        cum = np.cumsum(self.latency_hist)
        b = int(np.searchsorted(cum, q * total))
        return float(LATENCY_EDGES_US[min(b + 1, len(LATENCY_EDGES_US) - 1)])

    def snapshot(self) -> dict:
        mean_lat = self.latency_sum_us / self.completed if self.completed else float("nan")
        return {
            "queue": self.queue,
            "ticks": self.ticks,
            "completed": self.completed,
            "busy_s": self.busy_s,
            "pps_busy": self.completed / self.busy_s if self.busy_s else 0.0,
            "per_slot_total": self.per_slot_total.tolist(),
            "per_slot_malicious": self.per_slot_malicious.tolist(),
            "actions": {
                "forward": int(self.actions[pkt.ACTION_FORWARD]),
                "drop": int(self.actions[pkt.ACTION_DROP]),
                "flag": int(self.actions[pkt.ACTION_FLAG]),
            },
            "latency_mean_us": mean_lat,
            "latency_p50_us": self.latency_quantile_us(0.50),
            "latency_p99_us": self.latency_quantile_us(0.99),
            "latency_max_us": self.latency_max_us,
        }


class Telemetry:
    """All-queue telemetry plus runtime-level event counters."""

    def __init__(self, num_queues: int, num_slots: int):
        self.num_slots = num_slots
        self.queues = [QueueTelemetry(q, num_slots) for q in range(num_queues)]
        self.slot_swaps = 0
        self.reta_updates = 0
        self.wrong_verdict = 0  # audit-mode mismatches vs the exact path

    def record_tick(self, queue: int, slots, verdicts, actions,
                    latency_us, tick_s: float) -> None:
        self.queues[queue].record(slots, verdicts, actions, latency_us, tick_s)

    def snapshot(self, *, elapsed_s: float | None = None) -> dict:
        qs = [q.snapshot() for q in self.queues]
        total = sum(q["completed"] for q in qs)
        out = {
            "queues": qs,
            "completed_total": total,
            "slot_swaps": self.slot_swaps,
            "reta_updates": self.reta_updates,
            "wrong_verdict": self.wrong_verdict,
        }
        if elapsed_s:
            out["aggregate_pps"] = total / elapsed_s
        return out


def _copy_queue(src: QueueTelemetry, queue: int) -> QueueTelemetry:
    out = QueueTelemetry(queue, len(src.per_slot_total))
    out.ticks = src.ticks
    out.completed = src.completed
    out.busy_s = src.busy_s
    out.per_slot_total = src.per_slot_total.copy()
    out.per_slot_malicious = src.per_slot_malicious.copy()
    out.actions = src.actions.copy()
    out.latency_hist = src.latency_hist.copy()
    out.latency_sum_us = src.latency_sum_us
    out.latency_max_us = src.latency_max_us
    return out


def merge(telemetries) -> Telemetry:
    """Aggregate per-host telemetries into one mesh-wide view.

    Queues are renumbered into host-major global order (host ``h`` queue
    ``q`` lands at ``h * Q + q``, matching ``rss.global_queue_id``) and
    the runtime-level event counters — slot swaps, RETA updates, audit
    wrong-verdict mismatches — are summed, so policies and benchmarks
    read one ``Telemetry`` instead of hand-summing per-host dicts.  The
    result is a deep copy: mutating it never touches the inputs.  Note a
    mesh-broadcast command counts once per host here; the mesh facade
    overrides those counters with its command-level counts.
    """
    tels = list(telemetries)
    if not tels:
        raise ValueError("merge needs at least one telemetry")
    if len({t.num_slots for t in tels}) != 1:
        raise ValueError("cannot merge telemetries with different slot counts")
    out = Telemetry(0, tels[0].num_slots)
    for t in tels:
        for qt in t.queues:
            out.queues.append(_copy_queue(qt, len(out.queues)))
        out.slot_swaps += t.slot_swaps
        out.reta_updates += t.reta_updates
        out.wrong_verdict += t.wrong_verdict
    return out
