"""Emergency-scenario traffic engine: phased, replayable packet workloads.

Emergency communications traffic is not a steady stream — the FENIX /
Emergency-HRL line of work stresses exactly the regimes a disaster
produces: a calm baseline, a *flash crowd* when everyone transmits at
once, *link failover* when infrastructure dies and surviving queues absorb
remapped flows, and *slot churn* while operators push updated models into
the resident bank mid-event.  This module emits those regimes as
deterministic, replayable traces:

* a ``Phase`` describes one regime: ticks, burst size (arrival rate), the
  number of active flows (few elephant flows during a flash crowd, many
  mice in steady state), the slot mix the traffic selects, queues that
  fail at phase entry, and an optional resident-slot swap;
* ``render`` expands phases into per-tick packet bursts.  Every packet
  carries its flow tuple in reg0 words 4..7 (RSS input) and a globally
  monotonic sequence stamp in word 15, so conservation and per-queue
  ordering are checkable after the fact;
* ``phase_commands`` renders a phase's entry events (failover, restore,
  slot swap) as a typed control-plane command script — one atomic epoch;
* ``play`` drives a ``DataplaneRuntime`` through a rendered trace,
  submitting each phase's command script through ``runtime.control`` and
  returning per-phase reports (completed, dropped, wrong verdicts,
  throughput).

Same phases + same seed -> byte-identical trace, always.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.control import FailQueues, RestoreQueues, SwapSlot
from repro.core import executor, packet as pkt
from repro.dataplane import rss

# reg0 spare word 15: globally monotonic emission sequence number.
SEQ_WORD = 15


@dataclasses.dataclass(frozen=True)
class Phase:
    name: str
    ticks: int
    burst: int                      # packets per tick (arrival rate)
    flows: int                      # active flow count
    slot_mix: tuple[float, ...]     # per-slot selection probabilities
    failed_queues: tuple[int, ...] = ()   # queues that die at phase entry
    swap_slot: int | None = None    # resident slot replaced at phase entry
    monitor_frac: float = 0.0       # fraction sent with the monitor-only bit
    # elephant-flow skew: the first ``elephant_flows`` flows are forced
    # (by rejection-sampling their flow tuples against the default RETA)
    # to hash onto ``elephant_queue`` and carry ``elephant_frac`` of the
    # phase's packets — a few heavy flows crushing one queue.
    elephant_flows: int = 0
    elephant_queue: int | None = None
    elephant_frac: float = 0.0


def emergency_phases(num_slots: int, *, scale: int = 1) -> list[Phase]:
    """The canonical 4-phase emergency storyline (steady -> flash crowd ->
    link failover -> slot-churn recovery)."""
    uniform = tuple(1.0 / num_slots for _ in range(num_slots))
    # flash crowd: traffic collapses onto slot 0 (the triage model)
    crowd = tuple(0.7 if i == 0 else 0.3 / max(num_slots - 1, 1)
                  for i in range(num_slots))
    # recovery: the updated model (slot 1 if present) takes over
    churn_slot = 1 % num_slots
    recovery = tuple(0.6 if i == churn_slot else 0.4 / max(num_slots - 1, 1)
                     for i in range(num_slots))
    return [
        Phase("steady", ticks=8, burst=128 * scale, flows=64,
              slot_mix=uniform),
        Phase("flash_crowd", ticks=8, burst=512 * scale, flows=8,
              slot_mix=crowd, monitor_frac=0.1),
        Phase("link_failover", ticks=8, burst=256 * scale, flows=64,
              slot_mix=uniform, failed_queues=(0,)),
        Phase("slot_churn", ticks=8, burst=128 * scale, flows=64,
              slot_mix=recovery, swap_slot=churn_slot),
    ]


def elephant_skew_phases(
    num_slots: int,
    num_queues: int,
    *,
    scale: int = 1,
    ticks: int = 12,
    elephant_queue: int = 0,
) -> list[Phase]:
    """Elephant-flow skew: a few heavy flows all hash to one queue.

    A short uniform warmup, then a sustained phase where 4 elephant
    flows (rejection-sampled to land on ``elephant_queue`` under the
    default RETA) carry ~85% of a burst sized well above one queue's
    drain rate — the canonical imbalance a static RETA cannot fix and an
    adaptive policy must.  Used by the policy tests and fig9.
    """
    uniform = tuple(1.0 / num_slots for _ in range(num_slots))
    return [
        Phase("warmup", ticks=2, burst=64 * scale, flows=32,
              slot_mix=uniform),
        Phase("skew", ticks=ticks, burst=256 * scale, flows=32,
              slot_mix=uniform, elephant_flows=4,
              elephant_queue=elephant_queue, elephant_frac=0.85),
    ]


def cascading_failover_phases(
    num_slots: int,
    *,
    hosts: int,
    queues_per_host: int,
    scale: int = 1,
) -> list[Phase]:
    """Cascading host failover at mesh scale, in global queue ids.

    The mesh storyline the ROADMAP's multi-host items call for: a steady
    baseline, then an entire host dies at once (all of its queues fail,
    so its RETA buckets remap across the surviving hosts), then a second
    host *degrades* under the absorbed load (half its queues fail on
    top), then service restores with a slot swap — composed entirely
    from the existing typed commands via ``phase_commands``.  On a
    1-host mesh it degenerates to a two-queue cascade (needs >= 3
    queues so a survivor remains).
    """
    total = hosts * queues_per_host
    uniform = tuple(1.0 / num_slots for _ in range(num_slots))
    if hosts > 1:
        dead_host = tuple(range(queues_per_host))            # host 0, entirely
        degraded = tuple(queues_per_host + q                 # half of host 1
                         for q in range((queues_per_host + 1) // 2))
    else:
        dead_host, degraded = (0,), (1,)
    if total - len(dead_host) - len(degraded) < 1:
        raise ValueError(
            "cascading failover would leave zero live (host, queue) pairs; "
            "add hosts or queues")
    return [
        Phase("steady", ticks=6, burst=128 * scale, flows=64,
              slot_mix=uniform),
        Phase("host_down", ticks=6, burst=192 * scale, flows=64,
              slot_mix=uniform, failed_queues=dead_host),
        Phase("cascade", ticks=6, burst=192 * scale, flows=64,
              slot_mix=uniform, failed_queues=dead_host + degraded),
        Phase("recovery", ticks=6, burst=128 * scale, flows=64,
              slot_mix=uniform, swap_slot=1 % num_slots),
    ]


def make_scenario(name: str, *, num_slots: int, num_queues: int,
                  scale: int = 1, hosts: int = 1) -> list[Phase]:
    """CLI registry: scenario name -> phase list.

    ``num_queues`` is per host; queue-addressed phase fields (failed
    queues, elephant pinning) are in global ids over ``hosts *
    num_queues``.
    """
    total = hosts * num_queues
    if name == "emergency":
        return emergency_phases(num_slots, scale=scale)
    if name == "elephant-skew":
        return elephant_skew_phases(num_slots, total, scale=scale)
    if name == "cascading-failover":
        return cascading_failover_phases(
            num_slots, hosts=hosts, queues_per_host=num_queues, scale=scale)
    raise ValueError(
        f"unknown scenario {name!r} (known: ['emergency', 'elephant-skew', "
        "'cascading-failover'])")


@dataclasses.dataclass
class ScenarioTrace:
    phases: list[Phase]
    bursts: list[list[np.ndarray]]  # bursts[i][t] = (burst, 272) uint32
    seed: int

    @property
    def total_packets(self) -> int:
        return sum(b.shape[0] for ph in self.bursts for b in ph)


def _sample_slots(rng, mix: tuple[float, ...], n: int) -> np.ndarray:
    p = np.asarray(mix, np.float64)
    return rng.choice(len(p), size=n, p=p / p.sum())


def _elephant_flow_words(rng, n: int, num_queues: int, queue: int) -> np.ndarray:
    """Rejection-sample ``n`` flow tuples that hash to ``queue`` under the
    default RETA (deterministic in the rng state)."""
    reta = rss.indirection_table(num_queues)
    out = np.empty((n, rss.FLOW_WORDS), np.uint32)
    filled = 0
    while filled < n:
        cand = rng.integers(0, 2**32,
                            (64 * num_queues, rss.FLOW_WORDS), dtype=np.uint32)
        h = rss.toeplitz_hash(cand)
        hits = cand[reta[rss.bucket_index(h, len(reta))] == queue]
        take = min(hits.shape[0], n - filled)
        out[filled : filled + take] = hits[:take]
        filled += take
    return out


def _sample_flows(rng, phase: Phase) -> np.ndarray:
    """Per-packet flow index; elephants carry ``elephant_frac`` of them."""
    if not phase.elephant_flows or phase.elephant_frac <= 0:
        return rng.integers(0, phase.flows, phase.burst)
    heavy = rng.random(phase.burst) < phase.elephant_frac
    elephants = rng.integers(0, phase.elephant_flows, phase.burst)
    mice = rng.integers(phase.elephant_flows, phase.flows, phase.burst)
    return np.where(heavy, elephants, mice)


def render(
    phases: list[Phase],
    *,
    num_slots: int,
    seed: int = 0,
    payload_pool: np.ndarray | None = None,
    num_queues: int | None = None,
) -> ScenarioTrace:
    """Expand phases into per-tick packet bursts (deterministic in seed).

    ``payload_pool`` (N, 256) uint32 reuses real payloads round-robin per
    flow; default is random payloads drawn per flow so a flow's packets
    are self-similar (same flow tuple, correlated payloads).
    """
    rng = np.random.default_rng(seed)
    seq = 0
    bursts: list[list[np.ndarray]] = []
    for phase in phases:
        if len(phase.slot_mix) != num_slots:
            raise ValueError(
                f"phase {phase.name!r}: slot_mix has {len(phase.slot_mix)} "
                f"entries for {num_slots} slots")
        flow_words = rng.integers(
            0, 2**32, (phase.flows, rss.FLOW_WORDS), dtype=np.uint32)
        if phase.elephant_flows and phase.elephant_queue is not None:
            if num_queues is None:
                raise ValueError(
                    f"phase {phase.name!r} pins elephant flows to a queue; "
                    "render(..., num_queues=...) is required")
            if not 0 <= phase.elephant_queue < num_queues:
                raise ValueError(
                    f"phase {phase.name!r}: elephant_queue "
                    f"{phase.elephant_queue} out of range for "
                    f"{num_queues} queues")  # rejection sampling would spin
            if phase.elephant_flows >= phase.flows:
                raise ValueError(
                    f"phase {phase.name!r}: needs elephant_flows "
                    f"({phase.elephant_flows}) < flows ({phase.flows}) "
                    "so mice flows exist")
            flow_words[: phase.elephant_flows] = _elephant_flow_words(
                rng, phase.elephant_flows, num_queues, phase.elephant_queue)
        if payload_pool is None:
            flow_payload = rng.integers(
                0, 2**32, (phase.flows, pkt.PAYLOAD_WORDS), dtype=np.uint32)
        else:
            flow_payload = payload_pool[
                rng.integers(0, payload_pool.shape[0], phase.flows)]
        phase_bursts = []
        for _ in range(phase.ticks):
            fidx = _sample_flows(rng, phase)
            slots = _sample_slots(rng, phase.slot_mix, phase.burst)
            # payload: the flow's base payload with a per-packet twist so
            # verdicts are not constant within a flow
            payload = flow_payload[fidx].copy()
            payload[:, 0] ^= rng.integers(
                0, 2**32, phase.burst, dtype=np.uint32)
            control = np.where(
                rng.random(phase.burst) < phase.monitor_frac,
                int(pkt.CTRL_MONITOR_ONLY), 0)
            rows = pkt.make_packets(slots, payload)
            rows[:, pkt.CONTROL_WORD_LO] = control.astype(np.uint32)
            rows[:, rss.FLOW_WORD_LO : rss.FLOW_WORD_LO + rss.FLOW_WORDS] = \
                flow_words[fidx]
            rows[:, SEQ_WORD] = np.arange(seq, seq + phase.burst,
                                          dtype=np.uint32)
            seq += phase.burst
            phase_bursts.append(rows)
        bursts.append(phase_bursts)
    return ScenarioTrace(phases=phases, bursts=bursts, seed=seed)


def default_swap_delivery(slot: int, cfg=executor.H32):
    """Freshly 'delivered' replacement weights for ``slot`` (deterministic)."""
    return executor.init_params(jax.random.PRNGKey(10_000 + slot), cfg)


def phase_commands(
    phase: Phase,
    *,
    num_queues: int,
    swap_delivery=default_swap_delivery,
) -> list:
    """A phase's entry events as a typed control-plane command script.

    One atomic epoch: ``failed_queues`` becomes a ``FailQueues`` command
    (RETA failover remap), phases without failures restore full service
    (``RestoreQueues``), and ``swap_slot`` ships delivered weights as a
    ``SwapSlot`` command.  A failover that would leave zero live queues
    is unservable — traffic stays where it is (the 1-queue degenerate
    case), expressed as a plain restore.
    """
    failed = tuple(q for q in phase.failed_queues if q < num_queues)
    if failed and set(failed) != set(range(num_queues)):
        cmds = [FailQueues(failed)]
    else:
        cmds = [RestoreQueues()]
    if phase.swap_slot is not None:
        cmds.append(SwapSlot(phase.swap_slot, swap_delivery(phase.swap_slot)))
    return cmds


def play(
    runtime,
    trace: ScenarioTrace,
    *,
    swap_delivery=default_swap_delivery,
) -> list[dict]:
    """Drive a runtime through a rendered trace; per-phase reports.

    Each phase's entry events are submitted as one command epoch through
    ``runtime.control``; the runtime makes them effective at the next
    tick boundary (the first dispatch of the phase).  Each burst is
    dispatched then ticked once; the backlog drains inside the phase so
    phase reports are self-contained.
    """
    reports = []
    for phase, phase_bursts in zip(trace.phases, trace.bursts):
        runtime.control.submit(*phase_commands(
            phase, num_queues=runtime.num_queues,
            swap_delivery=swap_delivery))
        before = runtime.audit_conservation()["totals"]
        wrong0 = runtime.telemetry.wrong_verdict
        t0 = time.perf_counter()
        for burst in phase_bursts:
            runtime.dispatch(burst)
            runtime.tick()
        runtime.drain()
        dt = time.perf_counter() - t0
        after = runtime.audit_conservation()["totals"]
        completed = after["completed"] - before["completed"]
        reports.append({
            "phase": phase.name,
            "offered": after["offered"] - before["offered"],
            "completed": completed,
            "dropped": after["dropped"] - before["dropped"],
            "wrong_verdict": runtime.telemetry.wrong_verdict - wrong0,
            "elapsed_s": dt,
            "kpps": completed / dt / 1e3 if dt > 0 else float("nan"),
        })
    return reports
