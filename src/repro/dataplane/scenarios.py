"""Emergency-scenario traffic engine: phased, replayable packet workloads.

Emergency communications traffic is not a steady stream — the FENIX /
Emergency-HRL line of work stresses exactly the regimes a disaster
produces: a calm baseline, a *flash crowd* when everyone transmits at
once, *link failover* when infrastructure dies and surviving queues absorb
remapped flows, and *slot churn* while operators push updated models into
the resident bank mid-event.  This module emits those regimes as
deterministic, replayable traces:

* a ``Phase`` describes one regime: ticks, burst size (arrival rate), the
  number of active flows (few elephant flows during a flash crowd, many
  mice in steady state), the slot mix the traffic selects, queues that
  fail at phase entry, and an optional resident-slot swap;
* ``render`` expands phases into per-tick packet bursts.  Every packet
  carries its flow tuple in reg0 words 4..7 (RSS input) and a globally
  monotonic sequence stamp in word 15, so conservation and per-queue
  ordering are checkable after the fact;
* ``play`` drives a ``DataplaneRuntime`` through a rendered trace,
  applying failovers/swaps at phase boundaries and returning per-phase
  reports (completed, dropped, wrong verdicts, throughput).

Same phases + same seed -> byte-identical trace, always.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core import executor, packet as pkt
from repro.dataplane import rss

# reg0 spare word 15: globally monotonic emission sequence number.
SEQ_WORD = 15


@dataclasses.dataclass(frozen=True)
class Phase:
    name: str
    ticks: int
    burst: int                      # packets per tick (arrival rate)
    flows: int                      # active flow count
    slot_mix: tuple[float, ...]     # per-slot selection probabilities
    failed_queues: tuple[int, ...] = ()   # queues that die at phase entry
    swap_slot: int | None = None    # resident slot replaced at phase entry
    monitor_frac: float = 0.0       # fraction sent with the monitor-only bit


def emergency_phases(num_slots: int, *, scale: int = 1) -> list[Phase]:
    """The canonical 4-phase emergency storyline (steady -> flash crowd ->
    link failover -> slot-churn recovery)."""
    uniform = tuple(1.0 / num_slots for _ in range(num_slots))
    # flash crowd: traffic collapses onto slot 0 (the triage model)
    crowd = tuple(0.7 if i == 0 else 0.3 / max(num_slots - 1, 1)
                  for i in range(num_slots))
    # recovery: the updated model (slot 1 if present) takes over
    churn_slot = 1 % num_slots
    recovery = tuple(0.6 if i == churn_slot else 0.4 / max(num_slots - 1, 1)
                     for i in range(num_slots))
    return [
        Phase("steady", ticks=8, burst=128 * scale, flows=64,
              slot_mix=uniform),
        Phase("flash_crowd", ticks=8, burst=512 * scale, flows=8,
              slot_mix=crowd, monitor_frac=0.1),
        Phase("link_failover", ticks=8, burst=256 * scale, flows=64,
              slot_mix=uniform, failed_queues=(0,)),
        Phase("slot_churn", ticks=8, burst=128 * scale, flows=64,
              slot_mix=recovery, swap_slot=churn_slot),
    ]


@dataclasses.dataclass
class ScenarioTrace:
    phases: list[Phase]
    bursts: list[list[np.ndarray]]  # bursts[i][t] = (burst, 272) uint32
    seed: int

    @property
    def total_packets(self) -> int:
        return sum(b.shape[0] for ph in self.bursts for b in ph)


def _sample_slots(rng, mix: tuple[float, ...], n: int) -> np.ndarray:
    p = np.asarray(mix, np.float64)
    return rng.choice(len(p), size=n, p=p / p.sum())


def render(
    phases: list[Phase],
    *,
    num_slots: int,
    seed: int = 0,
    payload_pool: np.ndarray | None = None,
) -> ScenarioTrace:
    """Expand phases into per-tick packet bursts (deterministic in seed).

    ``payload_pool`` (N, 256) uint32 reuses real payloads round-robin per
    flow; default is random payloads drawn per flow so a flow's packets
    are self-similar (same flow tuple, correlated payloads).
    """
    rng = np.random.default_rng(seed)
    seq = 0
    bursts: list[list[np.ndarray]] = []
    for phase in phases:
        if len(phase.slot_mix) != num_slots:
            raise ValueError(
                f"phase {phase.name!r}: slot_mix has {len(phase.slot_mix)} "
                f"entries for {num_slots} slots")
        flow_words = rng.integers(
            0, 2**32, (phase.flows, rss.FLOW_WORDS), dtype=np.uint32)
        if payload_pool is None:
            flow_payload = rng.integers(
                0, 2**32, (phase.flows, pkt.PAYLOAD_WORDS), dtype=np.uint32)
        else:
            flow_payload = payload_pool[
                rng.integers(0, payload_pool.shape[0], phase.flows)]
        phase_bursts = []
        for _ in range(phase.ticks):
            fidx = rng.integers(0, phase.flows, phase.burst)
            slots = _sample_slots(rng, phase.slot_mix, phase.burst)
            # payload: the flow's base payload with a per-packet twist so
            # verdicts are not constant within a flow
            payload = flow_payload[fidx].copy()
            payload[:, 0] ^= rng.integers(
                0, 2**32, phase.burst, dtype=np.uint32)
            control = np.where(
                rng.random(phase.burst) < phase.monitor_frac,
                int(pkt.CTRL_MONITOR_ONLY), 0)
            rows = pkt.make_packets(slots, payload)
            rows[:, pkt.CONTROL_WORD_LO] = control.astype(np.uint32)
            rows[:, rss.FLOW_WORD_LO : rss.FLOW_WORD_LO + rss.FLOW_WORDS] = \
                flow_words[fidx]
            rows[:, SEQ_WORD] = np.arange(seq, seq + phase.burst,
                                          dtype=np.uint32)
            seq += phase.burst
            phase_bursts.append(rows)
        bursts.append(phase_bursts)
    return ScenarioTrace(phases=phases, bursts=bursts, seed=seed)


def default_swap_delivery(slot: int, cfg=executor.H32):
    """Freshly 'delivered' replacement weights for ``slot`` (deterministic)."""
    return executor.init_params(jax.random.PRNGKey(10_000 + slot), cfg)


def play(
    runtime,
    trace: ScenarioTrace,
    *,
    swap_delivery=default_swap_delivery,
) -> list[dict]:
    """Drive a runtime through a rendered trace; per-phase reports.

    Phase-entry events: ``failed_queues`` rewrites the RETA (link
    failover), ``swap_slot`` installs delivered weights into the resident
    bank while traffic is in flight.  Each burst is dispatched then
    ticked once; the backlog drains inside the phase so phase reports are
    self-contained.
    """
    reports = []
    for phase, phase_bursts in zip(trace.phases, trace.bursts):
        failed = tuple(q for q in phase.failed_queues
                       if q < runtime.num_queues)
        # a failover that would leave zero live queues is unservable —
        # traffic stays where it is (the 1-queue degenerate case)
        if failed and set(failed) != set(range(runtime.num_queues)):
            runtime.fail_queues(failed)
        else:
            runtime.reset_reta()
        if phase.swap_slot is not None:
            runtime.swap_slot(phase.swap_slot, swap_delivery(phase.swap_slot))
        before = runtime.audit_conservation()["totals"]
        wrong0 = runtime.telemetry.wrong_verdict
        t0 = time.perf_counter()
        for burst in phase_bursts:
            runtime.dispatch(burst)
            runtime.tick()
        runtime.drain()
        dt = time.perf_counter() - t0
        after = runtime.audit_conservation()["totals"]
        completed = after["completed"] - before["completed"]
        reports.append({
            "phase": phase.name,
            "offered": after["offered"] - before["offered"],
            "completed": completed,
            "dropped": after["dropped"] - before["dropped"],
            "wrong_verdict": runtime.telemetry.wrong_verdict - wrong0,
            "elapsed_s": dt,
            "kpps": completed / dt / 1e3 if dt > 0 else float("nan"),
        })
    return reports
