"""Compatibility shim: the traffic engine moved to `repro.dataplane.workloads`.

The phased-scenario core (``Phase``/``render``/``play``), the regime
generators, and the trace machinery now live in the workloads package
(DESIGN.md §9); every public name this module used to export resolves to
the same object there.  New code should import from
``repro.dataplane.workloads`` — this module exists so pre-workloads call
sites (``from repro.dataplane import scenarios``) keep working unchanged.
"""

from repro.dataplane.workloads.generators import (  # noqa: F401
    cascading_failover_phases, elephant_skew_phases, emergency_phases,
    make_scenario,
)
from repro.dataplane.workloads.phases import (  # noqa: F401
    SEQ_WORD, ChaosEvent, Phase, ScenarioTrace, default_swap_delivery,
    phase_commands, play, render,
)
