"""Phased workload core: ``Phase`` values, trace rendering, and ``play``.

Emergency communications traffic is not a steady stream — the FENIX /
Emergency-HRL line of work stresses exactly the regimes a disaster
produces: a calm baseline, a *flash crowd* when everyone transmits at
once, *link failover* when infrastructure dies and surviving queues absorb
remapped flows, and *slot churn* while operators push updated models into
the resident bank mid-event.  This module is the kernel every workload
regime is built from:

* a ``Phase`` describes one regime step: ticks, burst size (arrival
  rate), the number of active flows, the slot mix the traffic selects,
  queues that fail at phase entry, an optional resident-slot swap, and
  **chaos events** — typed command epochs injected at a tick *offset
  within the phase* (queue dies mid-surge, host drops between barrier
  ticks), not just at phase entry;
* ``render`` expands phases into per-tick packet bursts.  Every packet
  carries its flow tuple in reg0 words 4..7 (RSS input) and a globally
  monotonic sequence stamp in word 15, so conservation and per-queue
  ordering are checkable after the fact;
* ``phase_command_specs`` renders a phase's entry events (failover,
  restore, slot swap) as a typed control-plane command script — one
  atomic epoch.  ``SwapSlot`` specs carry ``params=None``; a
  ``swap_delivery`` materializes the delivered weights at play/replay
  time (so synthesized traces stay small and deterministic);
* ``play`` drives a runtime (single-host or mesh — same API) through a
  rendered trace, submitting each phase's command script and each chaos
  event's epoch through ``runtime.control``, and returning per-phase
  reports.  If the runtime exposes ``mark_phase`` (the trace recorder
  facade does), phase boundaries are forwarded to it so recorded traces
  keep the phase structure and its expected invariants.

Same phases + same seed -> byte-identical trace, always.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.control import FailQueues, RestoreQueues, SwapSlot
from repro.core import executor, packet as pkt
from repro.dataplane import rss

# reg0 spare word 15: globally monotonic emission sequence number.
SEQ_WORD = 15


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """A typed command epoch injected mid-phase, at tick offset ``at_tick``
    (0-based, before that tick's burst is dispatched).  Commands are the
    same five control-plane kinds phases compose from; ``SwapSlot`` with
    ``params=None`` is a spec materialized by ``swap_delivery``."""
    at_tick: int
    commands: tuple = ()

    def __post_init__(self):
        if self.at_tick < 0:
            raise ValueError("chaos at_tick must be >= 0")
        object.__setattr__(self, "commands", tuple(self.commands))


@dataclasses.dataclass(frozen=True)
class Phase:
    name: str
    ticks: int
    burst: int                      # packets per tick (arrival rate)
    flows: int                      # active flow count
    slot_mix: tuple[float, ...]     # per-slot selection probabilities
    failed_queues: tuple[int, ...] = ()   # queues that die at phase entry
    swap_slot: int | None = None    # resident slot replaced at phase entry
    monitor_frac: float = 0.0       # fraction sent with the monitor-only bit
    # elephant-flow skew: the first ``elephant_flows`` flows are forced
    # (by rejection-sampling their flow tuples against the default RETA)
    # to hash onto ``elephant_queue`` and carry ``elephant_frac`` of the
    # phase's packets — a few heavy flows crushing one queue.
    elephant_flows: int = 0
    elephant_queue: int | None = None
    elephant_frac: float = 0.0
    # chaos events: command epochs at tick offsets *inside* the phase
    chaos: tuple[ChaosEvent, ...] = ()


@dataclasses.dataclass
class ScenarioTrace:
    phases: list[Phase]
    bursts: list[list[np.ndarray]]  # bursts[i][t] = (burst, 272) uint32
    seed: int

    @property
    def total_packets(self) -> int:
        return sum(b.shape[0] for ph in self.bursts for b in ph)


def _sample_slots(rng, mix: tuple[float, ...], n: int) -> np.ndarray:
    p = np.asarray(mix, np.float64)
    return rng.choice(len(p), size=n, p=p / p.sum())


def _elephant_flow_words(rng, n: int, num_queues: int, queue: int) -> np.ndarray:
    """Rejection-sample ``n`` flow tuples that hash to ``queue`` under the
    default RETA (deterministic in the rng state)."""
    reta = rss.indirection_table(num_queues)
    out = np.empty((n, rss.FLOW_WORDS), np.uint32)
    filled = 0
    while filled < n:
        cand = rng.integers(0, 2**32,
                            (64 * num_queues, rss.FLOW_WORDS), dtype=np.uint32)
        h = rss.toeplitz_hash(cand)
        hits = cand[reta[rss.bucket_index(h, len(reta))] == queue]
        take = min(hits.shape[0], n - filled)
        out[filled : filled + take] = hits[:take]
        filled += take
    return out


def _sample_flows(rng, phase: Phase) -> np.ndarray:
    """Per-packet flow index; elephants carry ``elephant_frac`` of them."""
    if not phase.elephant_flows or phase.elephant_frac <= 0:
        return rng.integers(0, phase.flows, phase.burst)
    heavy = rng.random(phase.burst) < phase.elephant_frac
    elephants = rng.integers(0, phase.elephant_flows, phase.burst)
    mice = rng.integers(phase.elephant_flows, phase.flows, phase.burst)
    return np.where(heavy, elephants, mice)


def render(
    phases: list[Phase],
    *,
    num_slots: int,
    seed: int = 0,
    payload_pool: np.ndarray | None = None,
    num_queues: int | None = None,
) -> ScenarioTrace:
    """Expand phases into per-tick packet bursts (deterministic in seed).

    ``payload_pool`` (N, 256) uint32 reuses real payloads round-robin per
    flow; default is random payloads drawn per flow so a flow's packets
    are self-similar (same flow tuple, correlated payloads).
    """
    rng = np.random.default_rng(seed)
    seq = 0
    bursts: list[list[np.ndarray]] = []
    for phase in phases:
        if len(phase.slot_mix) != num_slots:
            raise ValueError(
                f"phase {phase.name!r}: slot_mix has {len(phase.slot_mix)} "
                f"entries for {num_slots} slots")
        for ev in phase.chaos:
            if ev.at_tick >= phase.ticks:
                raise ValueError(
                    f"phase {phase.name!r}: chaos event at tick "
                    f"{ev.at_tick} can never fire ({phase.ticks} ticks)")
        flow_words = rng.integers(
            0, 2**32, (phase.flows, rss.FLOW_WORDS), dtype=np.uint32)
        if phase.elephant_flows and phase.elephant_queue is not None:
            if num_queues is None:
                raise ValueError(
                    f"phase {phase.name!r} pins elephant flows to a queue; "
                    "render(..., num_queues=...) is required")
            if not 0 <= phase.elephant_queue < num_queues:
                raise ValueError(
                    f"phase {phase.name!r}: elephant_queue "
                    f"{phase.elephant_queue} out of range for "
                    f"{num_queues} queues")  # rejection sampling would spin
            if phase.elephant_flows >= phase.flows:
                raise ValueError(
                    f"phase {phase.name!r}: needs elephant_flows "
                    f"({phase.elephant_flows}) < flows ({phase.flows}) "
                    "so mice flows exist")
            flow_words[: phase.elephant_flows] = _elephant_flow_words(
                rng, phase.elephant_flows, num_queues, phase.elephant_queue)
        if payload_pool is None:
            flow_payload = rng.integers(
                0, 2**32, (phase.flows, pkt.PAYLOAD_WORDS), dtype=np.uint32)
        else:
            flow_payload = payload_pool[
                rng.integers(0, payload_pool.shape[0], phase.flows)]
        phase_bursts = []
        for _ in range(phase.ticks):
            fidx = _sample_flows(rng, phase)
            slots = _sample_slots(rng, phase.slot_mix, phase.burst)
            # payload: the flow's base payload with a per-packet twist so
            # verdicts are not constant within a flow
            payload = flow_payload[fidx].copy()
            payload[:, 0] ^= rng.integers(
                0, 2**32, phase.burst, dtype=np.uint32)
            control = np.where(
                rng.random(phase.burst) < phase.monitor_frac,
                int(pkt.CTRL_MONITOR_ONLY), 0)
            rows = pkt.make_packets(slots, payload)
            rows[:, pkt.CONTROL_WORD_LO] = control.astype(np.uint32)
            rows[:, rss.FLOW_WORD_LO : rss.FLOW_WORD_LO + rss.FLOW_WORDS] = \
                flow_words[fidx]
            rows[:, SEQ_WORD] = np.arange(seq, seq + phase.burst,
                                          dtype=np.uint32)
            seq += phase.burst
            phase_bursts.append(rows)
        bursts.append(phase_bursts)
    return ScenarioTrace(phases=phases, bursts=bursts, seed=seed)


def default_swap_delivery(slot: int, cfg=executor.H32):
    """Freshly 'delivered' replacement weights for ``slot`` (deterministic)."""
    return executor.init_params(jax.random.PRNGKey(10_000 + slot), cfg)


def materialize_command(cmd, swap_delivery=default_swap_delivery):
    """Resolve a command *spec* into a submittable command: a ``SwapSlot``
    with ``params=None`` gets its delivered weights from ``swap_delivery``;
    every other command is already a value."""
    if isinstance(cmd, SwapSlot) and cmd.params is None:
        return dataclasses.replace(
            cmd, params=swap_delivery(int(cmd.slot)))
    return cmd


def phase_command_specs(phase: Phase, *, num_queues: int) -> list:
    """A phase's entry events as typed command *specs* (one atomic epoch).

    ``failed_queues`` becomes a ``FailQueues`` command (RETA failover
    remap), phases without failures restore full service
    (``RestoreQueues``), and ``swap_slot`` becomes a ``SwapSlot`` spec
    with ``params=None`` (materialized at play/replay time).  A failover
    that would leave zero live queues is unservable — traffic stays
    where it is (the 1-queue degenerate case), expressed as a plain
    restore.
    """
    failed = tuple(q for q in phase.failed_queues if q < num_queues)
    if failed and set(failed) != set(range(num_queues)):
        cmds = [FailQueues(failed)]
    else:
        cmds = [RestoreQueues()]
    if phase.swap_slot is not None:
        cmds.append(SwapSlot(phase.swap_slot, None))
    return cmds


def phase_commands(
    phase: Phase,
    *,
    num_queues: int,
    swap_delivery=default_swap_delivery,
) -> list:
    """``phase_command_specs`` with ``SwapSlot`` payloads materialized."""
    return [materialize_command(c, swap_delivery)
            for c in phase_command_specs(phase, num_queues=num_queues)]


def chaos_by_tick(phase: Phase) -> dict[int, list[ChaosEvent]]:
    """Group a phase's chaos events by tick offset (submission order kept)."""
    out: dict[int, list[ChaosEvent]] = {}
    for ev in phase.chaos:
        out.setdefault(int(ev.at_tick), []).append(ev)
    return out


def play(
    runtime,
    trace: ScenarioTrace,
    *,
    swap_delivery=default_swap_delivery,
) -> list[dict]:
    """Drive a runtime through a rendered trace; per-phase reports.

    Each phase's entry events are submitted as one command epoch through
    ``runtime.control``; the runtime makes them effective at the next
    tick boundary (the first dispatch of the phase).  Chaos events fire
    as their own epochs at their tick offset, *before* that tick's burst
    is dispatched — on a mesh this lands between two barrier ticks.
    Each burst is dispatched then ticked once; the backlog drains inside
    the phase so phase reports are self-contained.
    """
    reports = []
    mark = getattr(runtime, "mark_phase", None)
    for phase, phase_bursts in zip(trace.phases, trace.bursts):
        runtime.control.submit(*phase_commands(
            phase, num_queues=runtime.num_queues,
            swap_delivery=swap_delivery))
        chaos = chaos_by_tick(phase)
        before = runtime.audit_conservation()["totals"]
        wrong0 = runtime.telemetry.wrong_verdict
        t0 = time.perf_counter()
        for t, burst in enumerate(phase_bursts):
            for ev in chaos.get(t, ()):
                runtime.control.submit(*(
                    materialize_command(c, swap_delivery)
                    for c in ev.commands))
            runtime.dispatch(burst)
            runtime.tick()
        runtime.drain()
        dt = time.perf_counter() - t0
        after = runtime.audit_conservation()["totals"]
        completed = after["completed"] - before["completed"]
        report = {
            "phase": phase.name,
            "offered": after["offered"] - before["offered"],
            "completed": completed,
            "dropped": after["dropped"] - before["dropped"],
            "wrong_verdict": runtime.telemetry.wrong_verdict - wrong0,
            "elapsed_s": dt,
            "kpps": completed / dt / 1e3 if dt > 0 else float("nan"),
        }
        reports.append(report)
        if mark is not None:
            mark(phase.name, report)
    return reports
