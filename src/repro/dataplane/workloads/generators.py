"""Workload generator library: parameterized regimes -> phase lists.

Every regime is a pure function from parameters to ``Phase`` values —
command scripts over the five typed control commands — so new regimes
compose with routing policies, the mesh, chaos events, and the trace
recorder for free.  The catalog (DESIGN.md §9):

* ``emergency``            — the canonical 4-phase storyline (steady ->
                             flash crowd -> link failover -> slot churn);
* ``elephant-skew``        — a few heavy flows rejection-sampled onto one
                             queue (the imbalance a static RETA cannot fix);
* ``cascading-failover``   — host dies -> buckets remap -> a second host
                             degrades under the absorbed load -> recovery;
* ``diurnal``              — a sampled sinusoidal day/night load curve;
                             the slot mix tracks the curve (day traffic
                             prefers the triage slot, night the updated
                             model), the regime the Emergency-HRL traces
                             replay;
* ``flash-crowd``          — an isolated surge: calm -> ramp -> spike
                             (x6 load collapsing onto few flows) -> decay;
* ``slot-thrash``          — adversarial control storm: a command epoch
                             EVERY tick (alternating ``SwapSlot`` and
                             rotated ``ProgramReta``) racing the epoch
                             barrier while traffic flows;
* ``chaos-queue-surge``    — a queue dies at the *peak* of a flash crowd
                             (mid-phase chaos event) and is restored two
                             ticks later;
* ``chaos-host-failover``  — an entire host's queues drop between two
                             barrier ticks mid-surge, then return;
* ``file-replay``          — the recorded-trace converter: ingests a file
                             corpus (``/root/related`` workload file sets
                             when present) and derives phases + payload
                             pools from the actual bytes;
* ``barrier-straggler``    — a host's retire is injected-delayed past its
                             lease while a command storm races the epoch
                             barrier: deferral -> lease expiry -> degraded
                             quorum commit + synthesized failover -> rejoin;
* ``crash-mid-commit``     — a host drops its commit ack and crashes the
                             next tick, mid-surge: degraded commit,
                             stranded packets conserved on the dead host.

The scripted ``chaos-*`` regimes express failures as *command* chaos
(typed ``FailQueues``/``RestoreQueues`` epochs the operator could have
sent); the two fault regimes express them as *injected* chaos — a
``Workload.fault_plan`` armed into the runtime's ``FaultInjector``
(`repro.dataplane.faults`), with the health layer synthesizing the
failover/restore epochs itself.  Same observable guarantee, opposite
detection path.

``make_workload`` is the one registry entry point; ``REGIME_NAMES`` is
what the CLI and the CI scenario matrix enumerate.
"""

from __future__ import annotations

import dataclasses
import math
import os

import numpy as np

from repro.control import FailQueues, ProgramReta, RestoreQueues, SwapSlot
from repro.core import packet as pkt
from repro.dataplane import rss
import repro.dataplane.faults as faults_mod
from repro.dataplane.workloads.phases import ChaosEvent, Phase


def _uniform(num_slots: int) -> tuple[float, ...]:
    return tuple(1.0 / num_slots for _ in range(num_slots))


def _peaked(num_slots: int, slot: int, weight: float) -> tuple[float, ...]:
    rest = (1.0 - weight) / max(num_slots - 1, 1)
    return tuple(weight if i == slot % num_slots else rest
                 for i in range(num_slots))


# ---------------------------------------------------------------------------
# the original storylines (moved verbatim from scenarios.py)
# ---------------------------------------------------------------------------

def emergency_phases(num_slots: int, *, scale: int = 1) -> list[Phase]:
    """The canonical 4-phase emergency storyline (steady -> flash crowd ->
    link failover -> slot-churn recovery)."""
    uniform = _uniform(num_slots)
    # flash crowd: traffic collapses onto slot 0 (the triage model)
    crowd = _peaked(num_slots, 0, 0.7)
    # recovery: the updated model (slot 1 if present) takes over
    churn_slot = 1 % num_slots
    recovery = _peaked(num_slots, churn_slot, 0.6)
    return [
        Phase("steady", ticks=8, burst=128 * scale, flows=64,
              slot_mix=uniform),
        Phase("flash_crowd", ticks=8, burst=512 * scale, flows=8,
              slot_mix=crowd, monitor_frac=0.1),
        Phase("link_failover", ticks=8, burst=256 * scale, flows=64,
              slot_mix=uniform, failed_queues=(0,)),
        Phase("slot_churn", ticks=8, burst=128 * scale, flows=64,
              slot_mix=recovery, swap_slot=churn_slot),
    ]


def elephant_skew_phases(
    num_slots: int,
    num_queues: int,
    *,
    scale: int = 1,
    ticks: int = 12,
    elephant_queue: int = 0,
) -> list[Phase]:
    """Elephant-flow skew: a few heavy flows all hash to one queue.

    A short uniform warmup, then a sustained phase where 4 elephant
    flows (rejection-sampled to land on ``elephant_queue`` under the
    default RETA) carry ~85% of a burst sized well above one queue's
    drain rate — the canonical imbalance a static RETA cannot fix and an
    adaptive policy must.  Used by the policy tests and fig9.
    """
    uniform = _uniform(num_slots)
    return [
        Phase("warmup", ticks=2, burst=64 * scale, flows=32,
              slot_mix=uniform),
        Phase("skew", ticks=ticks, burst=256 * scale, flows=32,
              slot_mix=uniform, elephant_flows=4,
              elephant_queue=elephant_queue, elephant_frac=0.85),
    ]


def cascading_failover_phases(
    num_slots: int,
    *,
    hosts: int,
    queues_per_host: int,
    scale: int = 1,
) -> list[Phase]:
    """Cascading host failover at mesh scale, in global queue ids.

    The mesh storyline the ROADMAP's multi-host items call for: a steady
    baseline, then an entire host dies at once (all of its queues fail,
    so its RETA buckets remap across the surviving hosts), then a second
    host *degrades* under the absorbed load (half its queues fail on
    top), then service restores with a slot swap — composed entirely
    from the existing typed commands via ``phase_commands``.  On a
    1-host mesh it degenerates to a two-queue cascade (needs >= 3
    queues so a survivor remains).
    """
    total = hosts * queues_per_host
    uniform = _uniform(num_slots)
    if hosts > 1:
        dead_host = tuple(range(queues_per_host))            # host 0, entirely
        degraded = tuple(queues_per_host + q                 # half of host 1
                         for q in range((queues_per_host + 1) // 2))
    else:
        dead_host, degraded = (0,), (1,)
    if total - len(dead_host) - len(degraded) < 1:
        raise ValueError(
            "cascading failover would leave zero live (host, queue) pairs; "
            "add hosts or queues")
    return [
        Phase("steady", ticks=6, burst=128 * scale, flows=64,
              slot_mix=uniform),
        Phase("host_down", ticks=6, burst=192 * scale, flows=64,
              slot_mix=uniform, failed_queues=dead_host),
        Phase("cascade", ticks=6, burst=192 * scale, flows=64,
              slot_mix=uniform, failed_queues=dead_host + degraded),
        Phase("recovery", ticks=6, burst=128 * scale, flows=64,
              slot_mix=uniform, swap_slot=1 % num_slots),
    ]


# ---------------------------------------------------------------------------
# new regimes (ROADMAP "Scenario corpus" open item)
# ---------------------------------------------------------------------------

def diurnal_phases(
    num_slots: int,
    *,
    scale: int = 1,
    steps: int = 8,
    ticks_per_step: int = 3,
    base: int = 96,
    amplitude: float = 0.75,
    flows: int = 48,
) -> list[Phase]:
    """A sampled diurnal (day/night) load curve.

    ``steps`` phases sample one full sinusoidal period starting at the
    nightly minimum; the slot mix tracks the curve — daytime traffic
    leans on slot 0 (triage), nighttime on slot ``1 % num_slots`` (the
    maintenance/updated model) — so load level and model demand co-vary
    the way the Emergency-HRL recorded traces do.
    """
    phases = []
    night_slot = 1 % num_slots
    for t in range(steps):
        # phase-shifted so step 0 is the minimum (deep night)
        level = 1.0 + amplitude * math.sin(
            2.0 * math.pi * t / steps - math.pi / 2.0)
        burst = max(16, int(round(base * scale * level)))
        day = 0.5 * (1.0 + math.sin(2.0 * math.pi * t / steps - math.pi / 2.0))
        mix = tuple(
            day * d + (1.0 - day) * n
            for d, n in zip(_peaked(num_slots, 0, 0.7),
                            _peaked(num_slots, night_slot, 0.7)))
        phases.append(Phase(f"diurnal_{t:02d}", ticks=ticks_per_step,
                            burst=burst, flows=flows, slot_mix=mix))
    return phases


def flash_crowd_phases(num_slots: int, *, scale: int = 1) -> list[Phase]:
    """An isolated flash-crowd surge: calm -> ramp -> spike -> decay.

    The spike collapses 6x the calm load onto 6 flows (everyone
    retransmitting the same few streams), with a heavy triage-slot mix
    and a sprinkling of monitor-only probes — the demand cliff the
    paper's switching latency argument is about.
    """
    uniform = _uniform(num_slots)
    crowd = _peaked(num_slots, 0, 0.8)
    return [
        Phase("calm", ticks=4, burst=64 * scale, flows=48, slot_mix=uniform),
        Phase("ramp", ticks=3, burst=160 * scale, flows=24, slot_mix=crowd),
        Phase("spike", ticks=5, burst=384 * scale, flows=6,
              slot_mix=crowd, monitor_frac=0.15),
        Phase("decay", ticks=3, burst=128 * scale, flows=24,
              slot_mix=uniform),
        Phase("after", ticks=3, burst=64 * scale, flows=48,
              slot_mix=uniform),
    ]


def slot_thrash_phases(
    num_slots: int,
    num_queues: int,
    *,
    scale: int = 1,
    storm_ticks: int = 8,
) -> list[Phase]:
    """Adversarial slot thrash: a command storm racing the epoch barrier.

    During the storm phase EVERY tick carries its own chaos epoch,
    alternating ``SwapSlot`` (rotating through the resident bank) and
    ``ProgramReta`` (the default table rolled by one bucket) — the
    worst-case control-plane arrival rate, submitted while packets are
    in flight.  The runtime's guarantee under test: every epoch still
    applies atomically at a tick boundary and no packet ever takes a
    wrong verdict, no matter how hard the control plane thrashes.
    """
    uniform = _uniform(num_slots)
    default = rss.indirection_table(num_queues)
    storm = []
    for t in range(storm_ticks):
        if t % 2 == 0:
            cmds: tuple = (SwapSlot(t // 2 % num_slots, None),)
        else:
            cmds = (ProgramReta(tuple(np.roll(default, 1 + t // 2))),)
        storm.append(ChaosEvent(at_tick=t, commands=cmds))
    return [
        Phase("steady", ticks=3, burst=96 * scale, flows=32,
              slot_mix=uniform),
        Phase("thrash", ticks=storm_ticks, burst=128 * scale, flows=32,
              slot_mix=uniform, chaos=tuple(storm)),
        Phase("settle", ticks=3, burst=96 * scale, flows=32,
              slot_mix=uniform),
    ]


def chaos_queue_surge_phases(
    num_slots: int,
    num_queues: int,
    *,
    scale: int = 1,
) -> list[Phase]:
    """A queue dies at the PEAK of a flash crowd (not at phase entry).

    The surge phase carries two chaos events: the highest-indexed queue
    fails mid-surge (its buckets remap onto survivors while the rings
    are at their fullest) and is restored two ticks later.  Composed
    from ``FailQueues``/``RestoreQueues`` like every other failover.
    """
    if num_queues < 2:
        raise ValueError("chaos-queue-surge needs >= 2 queues")
    uniform = _uniform(num_slots)
    victim = num_queues - 1
    surge_ticks = 8
    chaos = (
        ChaosEvent(at_tick=surge_ticks // 2,
                   commands=(FailQueues((victim,)),)),
        ChaosEvent(at_tick=surge_ticks // 2 + 2,
                   commands=(RestoreQueues((victim,)),)),
    )
    return [
        Phase("calm", ticks=3, burst=64 * scale, flows=32,
              slot_mix=uniform),
        Phase("surge", ticks=surge_ticks, burst=256 * scale, flows=12,
              slot_mix=_peaked(num_slots, 0, 0.7), chaos=chaos),
        Phase("recovery", ticks=3, burst=64 * scale, flows=32,
              slot_mix=uniform, swap_slot=1 % num_slots),
    ]


def chaos_host_failover_phases(
    num_slots: int,
    *,
    hosts: int,
    queues_per_host: int,
    scale: int = 1,
) -> list[Phase]:
    """An entire host drops between two barrier ticks, mid-surge.

    On a mesh (hosts > 1) the chaos event fails EVERY queue of the last
    host in one epoch — global ids, exactly what a host-loss event looks
    like to the control plane — and restores them three ticks later.  On
    one host it degenerates to losing the last queue (a host is its
    queues).  The epoch lands between two mesh ticks, so the barrier
    commit (stage on all hosts, apply between the same two ticks) is
    exercised while rings are loaded.
    """
    total = hosts * queues_per_host
    if total < 2:
        raise ValueError("chaos-host-failover needs >= 2 global queues")
    uniform = _uniform(num_slots)
    if hosts > 1:
        victim = tuple((hosts - 1) * queues_per_host + q
                       for q in range(queues_per_host))
    else:
        victim = (total - 1,)
    chaos = (
        ChaosEvent(at_tick=2, commands=(FailQueues(victim),)),
        ChaosEvent(at_tick=5, commands=(RestoreQueues(victim),)),
    )
    return [
        Phase("steady", ticks=3, burst=96 * scale, flows=48,
              slot_mix=uniform),
        Phase("host_loss", ticks=8, burst=192 * scale, flows=48,
              slot_mix=uniform, chaos=chaos),
        Phase("recovery", ticks=3, burst=96 * scale, flows=48,
              slot_mix=uniform, swap_slot=1 % num_slots),
    ]


# ---------------------------------------------------------------------------
# fault regimes: failures as injector plans, not command scripts (§10)
# ---------------------------------------------------------------------------

def barrier_straggler_workload(
    num_slots: int,
    *,
    hosts: int,
    queues_per_host: int,
    scale: int = 1,
    lease_ticks: int = 8,
) -> tuple[list[Phase], "faults_mod.FaultPlan"]:
    """A barrier straggler held past its lease during a command storm.

    The storm phase submits a ``SwapSlot`` chaos epoch every other tick
    while the last host's retire is injected-delayed for longer than the
    default lease: the barrier defers (bounded — every deferred tick
    burns lease), the straggler is declared DEAD, pending epochs commit
    degraded over the survivors with a synthesized failover epoch, and
    the host rejoins (resync + restore) once the delay window closes.
    On one host the plan degenerates to a short in-lease stall: the
    barrier defers and then commits atomically — the bounded-deferral
    half of the same guarantee.
    """
    uniform = _uniform(num_slots)
    storm = tuple(ChaosEvent(at_tick=t, commands=(SwapSlot(t // 2 % num_slots,
                                                           None),))
                  for t in range(0, 12, 2))
    phases = [
        Phase("steady", ticks=4, burst=96 * scale, flows=48,
              slot_mix=uniform),
        Phase("storm", ticks=12, burst=128 * scale, flows=48,
              slot_mix=uniform, chaos=storm),
        Phase("settle", ticks=8, burst=96 * scale, flows=48,
              slot_mix=uniform),
    ]
    if hosts > 1:
        plan = faults_mod.FaultPlan(
            faults=(faults_mod.DelayRetire(hosts - 1, at_tick=8,
                                           ticks=lease_ticks + 6),),
            name="barrier-straggler")
    else:
        plan = faults_mod.FaultPlan(
            faults=(faults_mod.StallHost(0, at_tick=8,
                                         ticks=max(lease_ticks - 2, 1)),),
            name="barrier-straggler")
    return phases, plan


def crash_mid_commit_workload(
    num_slots: int,
    *,
    hosts: int,
    queues_per_host: int,
    scale: int = 1,
) -> tuple[list[Phase], "faults_mod.FaultPlan"]:
    """A host loses its commit ack and crashes one tick later, mid-surge.

    The surge phase carries ``SwapSlot`` chaos epochs; the victim host
    drops the ack for one of them (degraded commit + suspect + failover)
    and then crashes outright, leaving its ring backlog stranded — the
    conservation audit must count every stranded packet while the mesh
    keeps serving on the survivors.  On one host: a short stall instead
    (crashing the only host leaves nothing to fail over to).
    """
    uniform = _uniform(num_slots)
    surge_chaos = tuple(ChaosEvent(at_tick=t,
                                   commands=(SwapSlot(t % num_slots, None),))
                        for t in (1, 3, 5, 7))
    phases = [
        Phase("steady", ticks=3, burst=96 * scale, flows=48,
              slot_mix=uniform),
        Phase("surge", ticks=10, burst=192 * scale, flows=24,
              slot_mix=_peaked(num_slots, 0, 0.7), chaos=surge_chaos),
        Phase("aftermath", ticks=5, burst=96 * scale, flows=48,
              slot_mix=uniform),
    ]
    if hosts > 1:
        victim = hosts - 1
        plan = faults_mod.FaultPlan(
            faults=(faults_mod.DropAck(victim, at_tick=6, count=1),
                    faults_mod.CrashHost(victim, at_tick=8)),
            name="crash-mid-commit")
    else:
        plan = faults_mod.FaultPlan(
            faults=(faults_mod.StallHost(0, at_tick=6, ticks=4),),
            name="crash-mid-commit")
    return phases, plan


# ---------------------------------------------------------------------------
# recorded-file converter (the /root/related workload file sets)
# ---------------------------------------------------------------------------

#: Environment override for the corpus root (the CI matrix and tests run
#: where /root/related does not exist).
CORPUS_ENV = "REPRO_WORKLOAD_CORPUS"
_DEFAULT_CORPUS_ROOTS = ("/root/related",)

#: Passing this as the corpus root skips the filesystem search and uses the
#: deterministic synthetic corpus — benchmarks pin it so BENCH baselines
#: compare across machines with different file sets.
SYNTHETIC_CORPUS = "synthetic:"


def _synthetic_corpus(n: int = 6, seed: int = 7) -> list[tuple[str, bytes]]:
    """Deterministic fallback corpus when no file set is available: byte
    blobs with realistic size spread and non-uniform byte histograms."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        size = int(2048 * (i + 1) * (1.5 if i % 2 else 1.0))
        # zipf-ish byte distribution so per-file slot mixes differ
        raw = (rng.zipf(1.3, size) % 256).astype(np.uint8)
        out.append((f"synthetic_{i}.bin", raw.tobytes()))
    return out


def file_corpus(
    root: str | None = None,
    *,
    max_files: int = 12,
    max_bytes: int = 1 << 20,
) -> list[tuple[str, bytes]]:
    """Collect (name, bytes) workload files, deterministically ordered.

    Search order: explicit ``root``, then ``$REPRO_WORKLOAD_CORPUS``,
    then ``/root/related`` (the band0 file sets retrieved for this
    paper).  When none exists, a deterministic synthetic corpus stands
    in so the regime stays runnable everywhere (CI runners included).
    """
    if root == SYNTHETIC_CORPUS:
        return _synthetic_corpus()
    candidates = [root, os.environ.get(CORPUS_ENV),
                  *_DEFAULT_CORPUS_ROOTS]
    for cand in candidates:
        if not cand or not os.path.isdir(cand):
            continue
        files = []
        for dirpath, dirnames, filenames in os.walk(cand):
            dirnames.sort()
            for fn in sorted(filenames):
                path = os.path.join(dirpath, fn)
                try:
                    size = os.path.getsize(path)
                except OSError:
                    continue
                if 0 < size:
                    files.append((os.path.relpath(path, cand), path))
        files = files[:max_files]
        if files:
            out = []
            for name, path in files:
                with open(path, "rb") as f:
                    out.append((name, f.read(max_bytes)))
            return out
    return _synthetic_corpus()


def file_replay_workload(
    num_slots: int,
    *,
    scale: int = 1,
    root: str | None = None,
    max_files: int = 12,
) -> tuple[list[Phase], np.ndarray]:
    """Convert a file corpus into (phases, payload_pool).

    Each file becomes one phase replaying its content: the payload pool
    is the corpus' actual bytes packed into 1024-B payload rows, the
    burst size tracks the file's size (bigger artifacts = heavier
    demand), the flow count tracks its distinct-1KB-block count, and the
    slot mix is derived from the file's byte histogram (each file
    exercises the resident bank differently).  Fully deterministic in
    the corpus contents.
    """
    corpus = file_corpus(root, max_files=max_files)
    blob = b"".join(data for _, data in corpus)
    row_bytes = pkt.PAYLOAD_WORDS * 4
    n_rows = max(-(-len(blob) // row_bytes), 1)
    # zero-pad the tail so any corpus size (even < one payload row) packs
    padded = blob.ljust(n_rows * row_bytes, b"\0")
    pool = np.frombuffer(padded, dtype="<u4").reshape(
        n_rows, pkt.PAYLOAD_WORDS).astype(np.uint32)
    phases = []
    for name, data in corpus:
        hist = np.bincount(np.frombuffer(data, np.uint8), minlength=256)
        per_slot = hist.reshape(num_slots, -1).sum(axis=1) if (
            256 % num_slots == 0) else np.array_split(hist, num_slots)
        weights = np.array([np.sum(s) for s in per_slot], np.float64) + 1.0
        mix = tuple(float(w) for w in weights / weights.sum())
        burst = int(np.clip(len(data) // 64, 32, 256)) * scale
        blocks = max(len(data) // 1024, 1)
        flows = int(np.clip(blocks, 4, 64))
        safe = "".join(c if c.isalnum() else "_" for c in name)[:24]
        phases.append(Phase(f"file_{safe}", ticks=2, burst=burst,
                            flows=flows, slot_mix=mix))
    return phases, pool


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Workload:
    """One generated workload: its phases, an optional payload pool
    (``None`` = per-flow random payloads), and an optional fault plan
    the driver arms into the runtime's ``FaultInjector`` (fault regimes
    only — phases stay pure traffic + command scripts either way)."""
    name: str
    phases: tuple[Phase, ...]
    payload_pool: np.ndarray | None = None
    fault_plan: "faults_mod.FaultPlan | None" = None


def _mk(name, fn):
    return name, fn


def make_workload(
    name: str,
    *,
    num_slots: int,
    num_queues: int,
    scale: int = 1,
    hosts: int = 1,
    corpus_root: str | None = None,
) -> Workload:
    """Registry entry point: regime name -> ``Workload``.

    ``num_queues`` is per host; queue-addressed phase fields (failed
    queues, elephant pinning, chaos FailQueues) are in global ids over
    ``hosts * num_queues``.
    """
    total = hosts * num_queues
    pool = None
    plan = None
    if name == "emergency":
        phases = emergency_phases(num_slots, scale=scale)
    elif name == "elephant-skew":
        phases = elephant_skew_phases(num_slots, total, scale=scale)
    elif name == "cascading-failover":
        phases = cascading_failover_phases(
            num_slots, hosts=hosts, queues_per_host=num_queues, scale=scale)
    elif name == "diurnal":
        phases = diurnal_phases(num_slots, scale=scale)
    elif name == "flash-crowd":
        phases = flash_crowd_phases(num_slots, scale=scale)
    elif name == "slot-thrash":
        phases = slot_thrash_phases(num_slots, total, scale=scale)
    elif name == "chaos-queue-surge":
        phases = chaos_queue_surge_phases(num_slots, total, scale=scale)
    elif name == "chaos-host-failover":
        phases = chaos_host_failover_phases(
            num_slots, hosts=hosts, queues_per_host=num_queues, scale=scale)
    elif name == "file-replay":
        phases, pool = file_replay_workload(
            num_slots, scale=scale, root=corpus_root)
    elif name == "barrier-straggler":
        phases, plan = barrier_straggler_workload(
            num_slots, hosts=hosts, queues_per_host=num_queues, scale=scale)
    elif name == "crash-mid-commit":
        phases, plan = crash_mid_commit_workload(
            num_slots, hosts=hosts, queues_per_host=num_queues, scale=scale)
    else:
        raise ValueError(
            f"unknown workload {name!r} (known: {list(REGIME_NAMES)})")
    return Workload(name=name, phases=tuple(phases), payload_pool=pool,
                    fault_plan=plan)


#: Every regime the registry serves — the CI scenario matrix iterates this.
REGIME_NAMES = (
    "emergency",
    "elephant-skew",
    "cascading-failover",
    "diurnal",
    "flash-crowd",
    "slot-thrash",
    "chaos-queue-surge",
    "chaos-host-failover",
    "file-replay",
    "barrier-straggler",
    "crash-mid-commit",
)


def make_scenario(name: str, *, num_slots: int, num_queues: int,
                  scale: int = 1, hosts: int = 1) -> list[Phase]:
    """Back-compat registry (pre-workloads API): name -> phase list."""
    return list(make_workload(name, num_slots=num_slots,
                              num_queues=num_queues, scale=scale,
                              hosts=hosts).phases)
