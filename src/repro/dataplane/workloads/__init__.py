"""Trace-driven workload engine (DESIGN.md §9).

Subsumes the old ``repro.dataplane.scenarios`` module: ``phases`` holds
the ``Phase``/``render``/``play`` kernel (now with first-class chaos
events), ``generators`` the parameterized regime library and its
registry, and ``trace`` the versioned recordable/replayable trace format
(``record`` from any live run, bit-exact ``replay`` through a runtime or
mesh, ``synthesize`` straight from generator phases).
"""

from repro.dataplane.workloads.generators import (  # noqa: F401
    REGIME_NAMES, Workload, barrier_straggler_workload,
    cascading_failover_phases, chaos_host_failover_phases,
    chaos_queue_surge_phases, crash_mid_commit_workload, diurnal_phases,
    elephant_skew_phases, emergency_phases, file_corpus, file_replay_workload,
    flash_crowd_phases, make_scenario, make_workload, slot_thrash_phases,
)
from repro.dataplane.workloads.phases import (  # noqa: F401
    SEQ_WORD, ChaosEvent, Phase, ScenarioTrace, chaos_by_tick,
    default_swap_delivery, materialize_command, phase_command_specs,
    phase_commands, play, render,
)
from repro.dataplane.workloads.trace import (  # noqa: F401
    INVARIANT_KEYS, TRACE_VERSION, PackedLeaves, StreamedTrace, TraceRecorder,
    WorkloadTrace, digest, load, make_runtime, record, replay, restore_bank,
    runtime_meta, save, synthesize,
)
