"""Versioned, compressed workload traces: record any run, replay bit-exactly.

A ``WorkloadTrace`` is the unit the whole workload subsystem trades in —
the recorded/replayed demand evidence the Emergency-HRL and INSIGHT
evaluations are built on, instead of synthetic phases alone.  It is an
ordered **step stream** plus expectations:

* ``{"kind": "burst"}``     — one arrival burst (B, 272) uint32 packet rows;
* ``{"kind": "tick"}``      — one runtime tick (the dispatch/tick
  interleaving is part of the recording: ring backpressure, drops, and
  pipeline behavior depend on it, so replay preserves it exactly);
* ``{"kind": "commands"}``  — one atomic control epoch of typed commands
  (the command timeline: phase entries AND chaos events, in submission
  order relative to the packet steps around them);
* ``{"kind": "drain"}``     — drain-to-empty (deterministic given the
  steps before it);
* ``{"kind": "phase"}``     — a phase boundary marker carrying the
  *expected per-phase invariants* (offered/completed/dropped/
  wrong_verdict) observed at record time, checked at replay time.

Trace-level ``expect`` adds end-of-run totals and a SHA-256 **digest**
over the completed per-queue (seq, verdict, slot) streams and the
dropped-seq stream — the bit-exactness witness: a replay that reproduces
the digest reproduced every verdict, in order, on the same queue.

On disk: ``MAGIC + version byte`` followed by (v2, current) a sequence
of independently-compressed chunks — ``tag + u32 length + zlib(msgpack
(payload))`` with step chunks (``S``) in stream order and one tail chunk
(``T``: meta + expect + bank) last — or (v1, still loadable) one
monolithic ``zlib(msgpack(doc))`` blob.  The chunked container is what
makes *streaming* recording viable: ``TraceRecorder(path=...)`` appends
each step chunk to the open file as it fills instead of buffering the
whole run and compressing it at the end (fig11 measured that at 177 ms
per save), so always-on recording costs a small bounded buffer.  Packet
arrays are raw little-endian bytes; ``SwapSlot`` weight payloads are
stored as flattened leaves and re-assembled against the replaying
runtime's bank treedef (the structures are identical by the control
plane's own validation); ``SetPolicy`` stores the policy's registry
name.  Loading rejects unknown magic/version instead of guessing.

``record()``/``TraceRecorder`` capture from ANY live run by wrapping the
runtime (single-host or mesh) in a same-API facade; ``replay()`` feeds a
trace back through a runtime and verifies the invariants.  ``synthesize``
builds a trace straight from generator phases without running anything.
"""

from __future__ import annotations

import dataclasses
import hashlib
import struct
import zlib

import jax
import msgpack
import numpy as np

from repro.control import (FailQueues, ProgramReta, RestoreQueues, SetPolicy,
                           SwapSlot, make_policy)
from repro.control import policy as policy_mod
from repro.core import executor
from repro.core import packet as pkt
from repro.dataplane.workloads.phases import (ScenarioTrace, chaos_by_tick,
                                              default_swap_delivery,
                                              materialize_command,
                                              phase_command_specs, render)

MAGIC = b"BSWTRACE"
TRACE_VERSION = 2
#: zlib level for v2 chunks: level 1 is ~5-10x faster than the old
#: monolithic level-6 blob at a modest size cost — the right trade for
#: always-on recording (packet payloads compress mostly via flow
#: repetition, which level 1 still catches)
CHUNK_ZLIB_LEVEL = 1
#: flush a step chunk once its raw payload bytes reach this bound
CHUNK_BYTES = 1 << 20

#: per-phase / end-of-run counter keys compared between record and replay
#: (timing keys like elapsed_s/kpps are machine-dependent and never stored)
INVARIANT_KEYS = ("offered", "completed", "dropped", "wrong_verdict")


@dataclasses.dataclass(frozen=True)
class PackedLeaves:
    """Flattened ``SwapSlot`` weight payload as loaded from disk; replay
    re-assembles it with the target runtime's bank treedef."""
    leaves: tuple


@dataclasses.dataclass
class WorkloadTrace:
    """Versioned step stream + expectations (+ optionally the initial bank,
    so a saved trace replays standalone, bit-exactly)."""
    meta: dict
    steps: list[dict]
    expect: dict = dataclasses.field(default_factory=dict)
    bank_leaves: tuple | None = None

    @property
    def total_packets(self) -> int:
        return sum(s["rows"].shape[0] for s in self.steps
                   if s["kind"] == "burst")

    def command_timeline(self) -> list[tuple[int, tuple]]:
        """(step index, commands) for every epoch in the trace."""
        return [(i, s["commands"]) for i, s in enumerate(self.steps)
                if s["kind"] == "commands"]


# ---------------------------------------------------------------------------
# runtime introspection helpers (single-host runtime and mesh facade)
# ---------------------------------------------------------------------------

def _bank_of(rt):
    return rt.bank if hasattr(rt, "bank") else rt.shards[0].bank


def _set_bank(rt, bank) -> None:
    """Install the recorded initial bank before replay starts (pre-run
    initialization, not a runtime mutation — no packets are in flight).
    Routed through ``adopt_bank`` so a double-buffered runtime seeds its
    device copies instead of aliasing the caller's arrays."""
    targets = [rt] if hasattr(rt, "bank") else list(rt.shards)
    for t in targets:
        if hasattr(t, "adopt_bank"):
            t.adopt_bank(bank)
        else:
            t.bank = bank


def _records(rt) -> bool:
    shard = rt if hasattr(rt, "_record") else rt.shards[0]
    return bool(shard._record)


def _template(rt):
    return rt if hasattr(rt, "batch") else rt.shards[0]


def _policy_name(policy) -> str | None:
    """Registry name of an installed policy — or raise: a policy the
    registry cannot rebuild would make the trace silently unreplayable
    (its rebalance epochs regenerate from the replaying runtime's own
    policy loop, so the replay MUST install the same policy)."""
    if policy is None:
        return None
    name = getattr(policy, "name", None)
    if name is None or name not in policy_mod.POLICIES:
        raise ValueError(
            f"cannot record a run with non-registry policy {policy!r}; "
            "give it a `name` listed in repro.control.policy.POLICIES")
    return name


def runtime_meta(rt) -> dict:
    """The runtime shape a trace was recorded against (what a replay must
    reconstruct for bit-exactness)."""
    t = _template(rt)
    meta = {
        "hosts": getattr(rt, "hosts", 1),
        "queues_per_host": (rt.num_queues_per_host
                            if hasattr(rt, "num_queues_per_host")
                            else rt.num_queues),
        "num_slots": t.num_slots,
        "strategy": t.strategy,
        "batch": t.batch,
        "ring_capacity": t.rings[0].capacity,
        "pipeline_depth": t.pipeline_depth,
        # policies live at facade scope on a mesh, runtime scope otherwise;
        # their ProgramReta epochs are NOT in the recorded command timeline
        # (they regenerate deterministically from telemetry), so the replay
        # runtime must run the same policy
        "policy": _policy_name(getattr(rt, "policy", None)),
    }
    # an armed fault plan is part of the runtime shape: like policy
    # rebalances, the failover/restore epochs the health layer
    # synthesizes are NOT recorded — the replay's own injector + health
    # monitor regenerate them deterministically, so the plan (and the
    # lease/quorum config driving detection) must ride along
    injector = getattr(rt, "_faults", None)
    meta["fault_plan"] = (injector.plan.to_dict()
                          if injector is not None else None)
    if hasattr(rt, "lease_ticks"):
        meta["lease_ticks"] = rt.lease_ticks
        meta["quorum"] = rt.quorum
    return meta


def digest(rt) -> dict:
    """SHA-256 over the completed per-queue (seq, verdict, slot) streams
    and the dropped-seq stream — requires a ``record=True`` runtime."""
    h = hashlib.sha256()
    for q in range(len(rt.completed_seq)):
        h.update(np.asarray(rt.completed_seq[q], np.int64).tobytes())
        h.update(np.asarray(rt.completed_verdicts[q], np.uint8).tobytes())
        h.update(np.asarray(rt.completed_slots[q], np.int64).tobytes())
        h.update(b"|")
    h.update(np.asarray(sorted(rt.dropped_seq), np.int64).tobytes())
    return {"sha256": h.hexdigest(),
            "completed": int(sum(len(s) for s in rt.completed_seq)),
            "dropped": int(len(rt.dropped_seq))}


# ---------------------------------------------------------------------------
# synthesize: generator phases -> trace (no runtime involved)
# ---------------------------------------------------------------------------

def synthesize(
    phases,
    *,
    num_slots: int,
    num_queues: int,
    seed: int = 0,
    name: str = "synthesized",
    payload_pool: np.ndarray | None = None,
) -> WorkloadTrace:
    """Render phases into a step-stream trace without running a runtime.

    ``num_queues`` is the *global* queue count (hosts x per-host).  The
    command timeline uses command specs (``SwapSlot`` payloads stay
    ``None`` and are materialized deterministically at replay), phase
    markers carry the statically-known invariants (offered count, zero
    wrong verdicts); completion/drop counts are runtime-shape-dependent
    and omitted.
    """
    rendered: ScenarioTrace = render(
        list(phases), num_slots=num_slots, seed=seed,
        payload_pool=payload_pool, num_queues=num_queues)
    steps: list[dict] = []
    for phase, phase_bursts in zip(rendered.phases, rendered.bursts):
        steps.append({"kind": "commands", "commands": tuple(
            phase_command_specs(phase, num_queues=num_queues))})
        chaos = chaos_by_tick(phase)
        offered = 0
        for t, burst in enumerate(phase_bursts):
            for ev in chaos.get(t, ()):
                steps.append({"kind": "commands",
                              "commands": tuple(ev.commands)})
            steps.append({"kind": "burst", "rows": burst})
            steps.append({"kind": "tick"})
            offered += int(burst.shape[0])
        steps.append({"kind": "drain"})
        steps.append({"kind": "phase", "name": phase.name,
                      "expect": {"offered": offered, "wrong_verdict": 0}})
    return WorkloadTrace(
        meta={"version": TRACE_VERSION, "name": name, "seed": seed,
              "num_slots": num_slots, "num_queues": num_queues,
              "kind": "synthesized"},
        steps=steps,
        expect={"totals": {"offered": rendered.total_packets,
                           "wrong_verdict": 0}},
    )


# ---------------------------------------------------------------------------
# record: wrap any live runtime in a same-API recording facade
# ---------------------------------------------------------------------------

class _RecordingControl:
    """``runtime.control`` proxy that logs every submitted epoch as a
    commands step at its position in the step stream."""

    def __init__(self, inner, recorder):
        self._inner = inner
        self._recorder = recorder

    def submit(self, *commands):
        self._recorder._log({"kind": "commands", "commands": tuple(commands)})
        return self._inner.submit(*commands)

    def __getattr__(self, name):
        return getattr(self._inner, name)


@dataclasses.dataclass(frozen=True)
class StreamedTrace:
    """What a streaming recording leaves behind: the finished trace file
    plus the summary a buffered ``finish()`` would have computed.  Use
    ``load(path)`` to get the replayable ``WorkloadTrace`` back."""
    path: str
    nbytes: int
    steps: int
    total_packets: int
    meta: dict
    expect: dict


class TraceRecorder:
    """Same-API facade over a runtime (or mesh) that records the step
    stream flowing through it.  Drive it with ``play`` or any custom
    loop, then ``finish()`` the trace:

        rec = TraceRecorder(runtime)
        play(rec, rendered)
        trace = rec.finish(name="emergency")
        save(trace, "emergency.bswt")

    With ``path=...`` the recorder *streams*: each step is encoded as it
    happens and appended to the open file in compressed chunks, so the
    whole run is never buffered and ``finish()`` only writes the small
    tail chunk (meta/expect/bank) — always-on recording instead of a
    O(run-length) end-of-run compression stall.  ``finish()`` then
    returns a ``StreamedTrace`` summary; the file itself is
    byte-identical to ``save()`` of the equivalent buffered trace.

    The initial bank is captured at construction (JAX arrays are
    immutable, so the reference stays the pre-run value even across
    ``SwapSlot`` epochs).
    """

    def __init__(self, runtime, *, path: str | None = None,
                 chunk_bytes: int = CHUNK_BYTES):
        self._rt = runtime
        self.steps: list[dict] = []
        self._writer = (_ChunkWriter(path, chunk_bytes=chunk_bytes)
                        if path is not None else None)
        self._stream_packets = 0
        self.control = _RecordingControl(runtime.control, self)
        # snapshot to host memory NOW: the live device buffer may be
        # donated away by later SwapSlot staging (double-buffered bank)
        self._bank0 = jax.tree_util.tree_map(np.asarray, _bank_of(runtime))
        self._mark_totals = None
        self._mark_wrong = 0

    def _log(self, step: dict) -> None:
        if self._writer is not None:
            if step["kind"] == "burst":
                self._stream_packets += int(step["rows"].shape[0])
            self._writer.add_step(step)
        else:
            self.steps.append(step)

    # -- recorded data-plane surface ----------------------------------------

    def dispatch(self, packets_np, now=None, **kw):
        self._log({"kind": "burst",
                   "rows": np.array(packets_np, np.uint32, copy=True)})
        return self._rt.dispatch(packets_np, now=now, **kw)

    def tick(self):
        self._log({"kind": "tick"})
        return self._rt.tick()

    def drain(self, *args, **kw):
        self._log({"kind": "drain"})
        return self._rt.drain(*args, **kw)

    def mark_phase(self, name: str, report: dict | None = None) -> None:
        """Record a phase boundary with the invariants observed since the
        previous mark (``play`` calls this automatically)."""
        totals = self._rt.audit_conservation()["totals"]
        wrong = self._rt.telemetry.wrong_verdict
        if report is not None:
            expect = {k: int(report[k]) for k in INVARIANT_KEYS}
        else:
            prev = self._mark_totals or {k: 0 for k in totals}
            expect = {k: int(totals[k] - prev[k])
                      for k in ("offered", "completed", "dropped")}
            expect["wrong_verdict"] = int(wrong - self._mark_wrong)
        self._mark_totals = dict(totals)
        self._mark_wrong = wrong
        self._log({"kind": "phase", "name": name, "expect": expect})

    def __getattr__(self, name):
        return getattr(self._rt, name)

    # -- finalization --------------------------------------------------------

    def finish(self, *, name: str = "recorded", seed: int | None = None,
               include_bank: bool = True) -> "WorkloadTrace | StreamedTrace":
        self._rt.retire_all()
        totals = self._rt.audit_conservation()["totals"]
        expect = {"totals": {k: int(totals[k]) for k in
                             ("offered", "completed", "dropped")}}
        expect["totals"]["wrong_verdict"] = int(
            self._rt.telemetry.wrong_verdict)
        if _records(self._rt):
            expect["digest"] = digest(self._rt)
        meta = {"version": TRACE_VERSION, "name": name, "seed": seed,
                "kind": "recorded", **runtime_meta(self._rt)}
        meta["num_queues"] = meta["hosts"] * meta["queues_per_host"]
        bank = None
        if include_bank:
            bank = tuple(np.asarray(leaf) for leaf in
                         jax.tree_util.tree_leaves(self._bank0))
        if self._writer is not None:
            nbytes = self._writer.finish(meta=meta, expect=expect,
                                         bank_leaves=bank)
            return StreamedTrace(path=self._writer.path, nbytes=nbytes,
                                 steps=self._writer.steps,
                                 total_packets=self._stream_packets,
                                 meta=meta, expect=expect)
        return WorkloadTrace(meta=meta, steps=list(self.steps),
                             expect=expect, bank_leaves=bank)

    def abort(self) -> None:
        """Close a streaming recording without writing the tail chunk
        (the partial file will be rejected by ``load``)."""
        if self._writer is not None:
            self._writer.abort()


def record(runtime, *, path: str | None = None,
           chunk_bytes: int = CHUNK_BYTES) -> TraceRecorder:
    """Wrap ``runtime`` for recording — alias kept verb-shaped so call
    sites read ``rec = record(rt); play(rec, trace); rec.finish()``.
    Pass ``path=`` to stream the recording straight to disk."""
    return TraceRecorder(runtime, path=path, chunk_bytes=chunk_bytes)


# ---------------------------------------------------------------------------
# replay: trace -> runtime, invariants checked
# ---------------------------------------------------------------------------

def _unpack_params(params, rt):
    treedef = jax.tree_util.tree_structure(_bank_of(rt))
    import jax.numpy as jnp
    return jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(leaf) for leaf in params.leaves])


def _replay_command(cmd, rt, swap_delivery):
    if isinstance(cmd, SwapSlot) and isinstance(cmd.params, PackedLeaves):
        return dataclasses.replace(cmd, params=_unpack_params(cmd.params, rt))
    if isinstance(cmd, SetPolicy) and isinstance(cmd.policy, str):
        return dataclasses.replace(cmd, policy=make_policy(cmd.policy))
    return materialize_command(cmd, swap_delivery)


def restore_bank(trace: WorkloadTrace, template_bank):
    """Re-assemble the trace's recorded initial bank against a structural
    template (any bank of the same config)."""
    if trace.bank_leaves is None:
        return None
    import jax.numpy as jnp
    treedef = jax.tree_util.tree_structure(template_bank)
    return jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(leaf) for leaf in trace.bank_leaves])


def make_runtime(trace: WorkloadTrace, *, bank=None, audit: bool = False,
                 **overrides):
    """Build the runtime a trace expects: shape from ``trace.meta``, the
    recorded initial bank when the trace carries one (else ``bank``, else
    a fresh seeded bank), ``record=True`` so the digest is checkable."""
    from repro.dataplane.mesh import MeshDataplane
    from repro.dataplane.runtime import DataplaneRuntime

    meta = trace.meta
    num_slots = int(meta.get("num_slots") or 4)
    if bank is None:
        bank = executor.init_bank(
            jax.random.PRNGKey(int(meta.get("seed") or 0)), num_slots)
    restored = restore_bank(trace, bank)
    if restored is not None:
        bank = restored
    kw = dict(strategy=meta.get("strategy", "fused"),
              batch=int(meta.get("batch", 128)),
              ring_capacity=int(meta.get("ring_capacity", 2048)),
              pipeline_depth=int(meta.get("pipeline_depth", 1)),
              policy=(make_policy(meta["policy"])
                      if meta.get("policy") else None),
              record=True, audit=audit)
    hosts = int(meta.get("hosts", 1))
    if meta.get("fault_plan") is not None:
        from repro.dataplane import faults as faults_mod
        kw["fault_injector"] = faults_mod.FaultInjector(
            faults_mod.FaultPlan.from_dict(meta["fault_plan"]))
    if hosts > 1:
        if meta.get("lease_ticks") is not None:
            kw["lease_ticks"] = int(meta["lease_ticks"])
        if meta.get("quorum") is not None:
            kw["quorum"] = int(meta["quorum"])
    kw.update(overrides)
    queues = int(meta.get("queues_per_host")
                 or meta.get("num_queues", 4) // hosts)
    if hosts > 1:
        return MeshDataplane(bank, hosts=hosts, num_queues=queues, **kw)
    return DataplaneRuntime(bank, num_queues=queues, **kw)


def replay(
    trace: WorkloadTrace,
    runtime,
    *,
    swap_delivery=default_swap_delivery,
    strict: bool = False,
    install_bank: bool = True,
) -> dict:
    """Feed a trace's step stream through ``runtime`` and verify it.

    Returns ``{"ok", "mismatches", "phases", "digest", "digest_ok"}``:
    per-phase reports with every invariant the trace carries checked,
    plus the end-of-run totals and (for recorded traces replayed on a
    ``record=True`` runtime) the bit-exactness digest.  ``strict=True``
    raises on the first mismatch instead of collecting them.
    """
    if install_bank and trace.bank_leaves is not None:
        _set_bank(runtime, restore_bank(trace, _bank_of(runtime)))
    mismatches: list[str] = []
    phases: list[dict] = []
    prev_totals: dict | None = None
    prev_wrong = runtime.telemetry.wrong_verdict

    def check(label: str, expect: dict | None, got: dict) -> None:
        for key, want in (expect or {}).items():
            if key in got and int(got[key]) != int(want):
                mismatches.append(
                    f"{label}: {key} = {got[key]} != recorded {want}")
                if strict:
                    raise AssertionError(mismatches[-1])

    for step in trace.steps:
        kind = step["kind"]
        if kind == "burst":
            runtime.dispatch(step["rows"])
        elif kind == "tick":
            runtime.tick()
        elif kind == "drain":
            runtime.drain()
        elif kind == "commands":
            runtime.control.submit(*(
                _replay_command(c, runtime, swap_delivery)
                for c in step["commands"]))
        elif kind == "phase":
            totals = runtime.audit_conservation()["totals"]
            wrong = runtime.telemetry.wrong_verdict
            prev = prev_totals or {k: 0 for k in totals}
            got = {k: int(totals[k] - prev[k])
                   for k in ("offered", "completed", "dropped")}
            got["wrong_verdict"] = int(wrong - prev_wrong)
            prev_totals, prev_wrong = dict(totals), wrong
            check(f"phase {step['name']!r}", step.get("expect"), got)
            phases.append({"phase": step["name"], **got})
        else:
            raise ValueError(f"unknown trace step kind {kind!r}")
    if not trace.steps or trace.steps[-1]["kind"] not in ("drain", "phase"):
        runtime.drain()
    runtime.retire_all()

    totals = runtime.audit_conservation()["totals"]
    got_totals = {k: int(totals[k]) for k in
                  ("offered", "completed", "dropped")}
    got_totals["wrong_verdict"] = int(runtime.telemetry.wrong_verdict)
    check("totals", trace.expect.get("totals"), got_totals)

    dig, dig_ok = None, None
    if _records(runtime):
        dig = digest(runtime)
        want = trace.expect.get("digest")
        if want is not None:
            dig_ok = dig["sha256"] == want["sha256"]
            if not dig_ok:
                mismatches.append(
                    f"digest: {dig['sha256'][:16]}... != recorded "
                    f"{want['sha256'][:16]}... (verdict streams diverged)")
                if strict:
                    raise AssertionError(mismatches[-1])
    return {"ok": not mismatches, "mismatches": mismatches,
            "phases": phases, "totals": got_totals,
            "digest": dig, "digest_ok": dig_ok}


# ---------------------------------------------------------------------------
# on-disk codec
# ---------------------------------------------------------------------------

def _enc_nd(a: np.ndarray) -> dict:
    a = np.ascontiguousarray(a)
    return {"dt": str(a.dtype), "sh": list(a.shape),
            "b": a.astype(a.dtype.newbyteorder("<"), copy=False).tobytes()}


def _dec_nd(d: dict) -> np.ndarray:
    a = np.frombuffer(d["b"], dtype=np.dtype(d["dt"]).newbyteorder("<"))
    return a.reshape(d["sh"]).astype(np.dtype(d["dt"]), copy=False)


def _enc_cmd(cmd) -> dict:
    if isinstance(cmd, SwapSlot):
        if cmd.params is None:
            leaves = None
        elif isinstance(cmd.params, PackedLeaves):
            leaves = [_enc_nd(leaf) for leaf in cmd.params.leaves]
        else:
            leaves = [_enc_nd(np.asarray(leaf)) for leaf in
                      jax.tree_util.tree_leaves(cmd.params)]
        return {"c": "swap", "slot": int(cmd.slot), "leaves": leaves}
    if isinstance(cmd, ProgramReta):
        return {"c": "reta", "reta": [int(q) for q in cmd.reta]}
    if isinstance(cmd, FailQueues):
        return {"c": "fail", "queues": [int(q) for q in cmd.queues]}
    if isinstance(cmd, RestoreQueues):
        return {"c": "restore", "queues": [int(q) for q in cmd.queues]}
    if isinstance(cmd, SetPolicy):
        name = (cmd.policy if isinstance(cmd.policy, str)
                else _policy_name(cmd.policy))
        return {"c": "policy", "name": name}
    raise TypeError(f"cannot serialize command {cmd!r}")


def _dec_cmd(d: dict):
    kind = d["c"]
    if kind == "swap":
        params = (None if d["leaves"] is None else
                  PackedLeaves(tuple(_dec_nd(x) for x in d["leaves"])))
        return SwapSlot(int(d["slot"]), params)
    if kind == "reta":
        return ProgramReta(tuple(d["reta"]))
    if kind == "fail":
        return FailQueues(tuple(d["queues"]))
    if kind == "restore":
        return RestoreQueues(tuple(d["queues"]))
    if kind == "policy":
        return SetPolicy(d["name"])
    raise ValueError(f"unknown serialized command kind {kind!r}")


def _enc_step(step: dict) -> dict:
    kind = step["kind"]
    if kind == "burst":
        return {"k": "b", "rows": _enc_nd(step["rows"])}
    if kind == "tick":
        return {"k": "t"}
    if kind == "drain":
        return {"k": "d"}
    if kind == "commands":
        return {"k": "c", "cmds": [_enc_cmd(c) for c in step["commands"]]}
    if kind == "phase":
        return {"k": "p", "name": step["name"],
                "expect": step.get("expect")}
    raise ValueError(f"unknown trace step kind {kind!r}")


def _dec_step(d: dict) -> dict:
    kind = d["k"]
    if kind == "b":
        return {"kind": "burst", "rows": _dec_nd(d["rows"])}
    if kind == "t":
        return {"kind": "tick"}
    if kind == "d":
        return {"kind": "drain"}
    if kind == "c":
        return {"kind": "commands",
                "commands": tuple(_dec_cmd(c) for c in d["cmds"])}
    if kind == "p":
        return {"kind": "phase", "name": d["name"], "expect": d["expect"]}
    raise ValueError(f"unknown serialized step kind {kind!r}")


#: v2 chunk tags: ``S`` = a batch of encoded steps (stream order),
#: ``T`` = the tail (meta + expect + bank) — exactly one, written last
_TAG_STEPS = b"S"
_TAG_TAIL = b"T"

#: first packet word eligible for payload dictionary encoding: the 16
#: meta words and payload word 0 are per-packet (seq numbers, flow
#: words, the render-time payload twist), but words 17..271 are a
#: flow's base payload repeated verbatim across every burst — the bulk
#: of a trace's bytes and the part deflate spends its time on
_PDICT_LO = pkt.META_WORDS + 1
#: sentinel index for rows whose tail is not in the dictionary
_PDICT_INLINE = 0xFFFFFFFF
#: dictionary entry cap — bounds writer/loader memory for always-on
#: recording of non-repeating traffic (overflow rows encode inline)
_PDICT_CAP = 1 << 16


def _step_nbytes(enc: dict) -> int:
    n = 64
    for v in enc.values():
        if isinstance(v, (bytes, bytearray)):
            n += len(v)
        elif isinstance(v, dict):
            n += _step_nbytes(v)
        elif isinstance(v, list):
            n += sum(_step_nbytes(x) for x in v if isinstance(x, dict))
    return n


class _ChunkWriter:
    """Appends compressed step chunks to an open file as they fill.

    Both ``save()`` and the streaming ``TraceRecorder`` write through
    this class with the same flush policy, so a buffered save and a
    streamed recording of the same run produce byte-identical files.
    """

    def __init__(self, path: str, *, level: int = CHUNK_ZLIB_LEVEL,
                 chunk_bytes: int = CHUNK_BYTES):
        self.path = path
        self._f = open(path, "wb")
        self._f.write(MAGIC + bytes([TRACE_VERSION]))
        self._f.flush()
        self._level = level
        self._chunk_bytes = chunk_bytes
        self._buf: list[dict] = []
        self._buf_bytes = 0
        self._pdict: dict[bytes, int] = {}
        self._tab_new: list[bytes] = []
        self.nbytes = len(MAGIC) + 1
        self.steps = 0

    def add_step(self, step: dict) -> None:
        if step["kind"] == "burst":
            enc = {"k": "b", "rows": self._enc_rows(step["rows"])}
        else:
            enc = _enc_step(step)
        self._buf.append(enc)
        self.steps += 1
        self._buf_bytes += _step_nbytes(enc)
        if self._buf_bytes >= self._chunk_bytes:
            self._flush_steps()

    def _enc_rows(self, rows: np.ndarray) -> dict:
        """Dictionary-encode a burst against the file-global payload
        table: per-burst ``np.unique`` collapses repeats, then only the
        per-burst uniques hit the python dict."""
        rows = np.ascontiguousarray(rows).astype("<u4", copy=False)
        B, W = rows.shape
        if W <= _PDICT_LO or B == 0:
            return _enc_nd(rows)
        tail = np.ascontiguousarray(rows[:, _PDICT_LO:])
        void = tail.view([("v", f"V{tail.shape[1] * 4}")]).ravel()
        uniq, inv = np.unique(void, return_inverse=True)
        idx_of = np.empty(len(uniq), np.int64)
        for u, key_v in enumerate(uniq):
            key = key_v.tobytes()
            gi = self._pdict.get(key)
            if gi is None and len(self._pdict) < _PDICT_CAP:
                gi = len(self._pdict)
                self._pdict[key] = gi
                self._tab_new.append(key)
            idx_of[u] = _PDICT_INLINE if gi is None else gi
        gidx = idx_of[inv].astype("<u4")
        inline = tail[gidx == _PDICT_INLINE]
        return {"dt": "<u4", "sh": [B, W], "pd": 1,
                "head": rows[:, :_PDICT_LO].tobytes(),
                "idx": gidx.tobytes(), "inl": inline.tobytes()}

    def _write_chunk(self, tag: bytes, payload) -> None:
        blob = zlib.compress(msgpack.packb(payload, use_bin_type=True),
                             self._level)
        self._f.write(tag + struct.pack("<I", len(blob)))
        self._f.write(blob)
        self._f.flush()  # chunks are durable during the run, not at close
        self.nbytes += 5 + len(blob)

    def _flush_steps(self) -> None:
        if self._buf:
            self._write_chunk(_TAG_STEPS, {"s": self._buf,
                                           "t": self._tab_new})
            self._buf, self._buf_bytes, self._tab_new = [], 0, []

    def finish(self, *, meta: dict, expect: dict, bank_leaves) -> int:
        self._flush_steps()
        self._write_chunk(_TAG_TAIL, {
            "meta": meta, "expect": expect,
            "bank": (None if bank_leaves is None else
                     [_enc_nd(np.asarray(leaf)) for leaf in bank_leaves]),
        })
        self._f.close()
        return self.nbytes

    def abort(self) -> None:
        if not self._f.closed:
            self._f.close()


def save(trace: WorkloadTrace, path: str) -> int:
    """Write the v2 chunked container; returns bytes written."""
    w = _ChunkWriter(path)
    for s in trace.steps:
        w.add_step(s)
    return w.finish(meta=dict(trace.meta, version=TRACE_VERSION),
                    expect=trace.expect, bank_leaves=trace.bank_leaves)


def _save_v1(trace: WorkloadTrace, path: str) -> int:
    """The pre-chunking monolithic writer, kept for compatibility tests
    (old trace files in the wild must stay loadable)."""
    doc = {
        "meta": dict(trace.meta, version=1),
        "steps": [_enc_step(s) for s in trace.steps],
        "expect": trace.expect,
        "bank": (None if trace.bank_leaves is None else
                 [_enc_nd(np.asarray(leaf)) for leaf in trace.bank_leaves]),
    }
    blob = MAGIC + bytes([1]) + zlib.compress(
        msgpack.packb(doc, use_bin_type=True), 6)
    with open(path, "wb") as f:
        f.write(blob)
    return len(blob)


def _dec_rows_pd(d: dict, table: np.ndarray) -> np.ndarray:
    """Decode a dictionary-encoded burst against the accumulated table."""
    B, W = d["sh"]
    tail_w = W - _PDICT_LO
    rows = np.empty((B, W), "<u4")
    rows[:, :_PDICT_LO] = np.frombuffer(
        d["head"], "<u4").reshape(B, _PDICT_LO)
    idx = np.frombuffer(d["idx"], "<u4")
    tail_view = rows[:, _PDICT_LO:]
    inline_mask = idx == _PDICT_INLINE
    if inline_mask.any():
        tail_view[inline_mask] = np.frombuffer(
            d["inl"], "<u4").reshape(-1, tail_w)
    hit_mask = ~inline_mask
    if hit_mask.any():
        tail_view[hit_mask] = table[idx[hit_mask]]
    return rows.astype(np.uint32, copy=False)


def _load_v2(f, path: str) -> WorkloadTrace:
    steps: list[dict] = []
    tail = None
    table = np.empty((0, pkt.PACKET_WORDS - _PDICT_LO), "<u4")
    while True:
        head = f.read(5)
        if not head:
            break
        if len(head) != 5:
            raise ValueError(f"{path}: truncated chunk header")
        tag, (length,) = head[:1], struct.unpack("<I", head[1:])
        blob = f.read(length)
        if len(blob) != length:
            raise ValueError(f"{path}: truncated chunk body")
        payload = msgpack.unpackb(zlib.decompress(blob), raw=False,
                                  strict_map_key=False)
        if tag == _TAG_STEPS:
            if payload["t"]:
                new = np.frombuffer(b"".join(payload["t"]),
                                    "<u4").reshape(len(payload["t"]), -1)
                table = np.concatenate([table, new]) if table.size else new
            for enc in payload["s"]:
                if enc["k"] == "b" and enc["rows"].get("pd"):
                    steps.append({"kind": "burst",
                                  "rows": _dec_rows_pd(enc["rows"], table)})
                else:
                    steps.append(_dec_step(enc))
        elif tag == _TAG_TAIL:
            tail = payload
        else:
            raise ValueError(f"{path}: unknown chunk tag {tag!r}")
    if tail is None:
        raise ValueError(f"{path}: no tail chunk (recording not finished?)")
    bank = tail.get("bank")
    return WorkloadTrace(
        meta=tail["meta"],
        steps=steps,
        expect=tail.get("expect") or {},
        bank_leaves=(None if bank is None else
                     tuple(_dec_nd(x) for x in bank)),
    )


def load(path: str) -> WorkloadTrace:
    with open(path, "rb") as f:
        head = f.read(len(MAGIC) + 1)
        if head[: len(MAGIC)] != MAGIC or len(head) != len(MAGIC) + 1:
            raise ValueError(f"{path}: not a workload trace (bad magic)")
        version = head[len(MAGIC)]
        if version == 2:
            return _load_v2(f, path)
        if version != 1:
            raise ValueError(
                f"{path}: trace version {version} unsupported "
                f"(this build reads v1-v{TRACE_VERSION})")
        blob = f.read()
    doc = msgpack.unpackb(zlib.decompress(blob), raw=False,
                          strict_map_key=False)
    bank = doc.get("bank")
    return WorkloadTrace(
        meta=doc["meta"],
        steps=[_dec_step(s) for s in doc["steps"]],
        expect=doc.get("expect") or {},
        bank_leaves=(None if bank is None else
                     tuple(_dec_nd(x) for x in bank)),
    )
