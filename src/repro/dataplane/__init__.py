"""Multi-queue data-plane runtime (DESIGN.md §6-§8).

The AF_XDP deployment shape in software: ``rss`` hashes flows to queues
(and, at mesh scale, to (host, queue) pairs via global queue ids),
``ring`` buffers each queue with counted tail-drop, ``runtime`` fans the
fused forwarding program out across queues (loop / vmap / shard_map)
behind an epoch-stamped control plane (`repro.control`), ``mesh`` lifts
the runtime to a multi-host mesh (per-host shards, cross-host RSS,
epoch-barrier control fan-out), ``telemetry`` exports per-queue counters
with a mesh-wide ``merge``, and ``scenarios`` generates phased emergency
traffic — rendered as command scripts — to drive it all.
"""

from repro.dataplane.ring import PacketRing, RingCounters  # noqa: F401
from repro.dataplane.runtime import DataplaneRuntime, queue_mesh  # noqa: F401
from repro.dataplane.mesh import MeshDataplane  # noqa: F401
from repro.dataplane.scenarios import (  # noqa: F401
    Phase, ScenarioTrace, cascading_failover_phases, elephant_skew_phases,
    emergency_phases, make_scenario, phase_commands, play, render, SEQ_WORD,
)
from repro.dataplane import rss, telemetry  # noqa: F401
