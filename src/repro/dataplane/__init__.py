"""Multi-queue data-plane runtime (DESIGN.md §6-§8).

The AF_XDP deployment shape in software: ``rss`` hashes flows to queues
(and, at mesh scale, to (host, queue) pairs via global queue ids),
``ring`` buffers each queue with counted tail-drop, ``runtime`` fans the
fused forwarding program out across queues (loop / vmap / shard_map)
behind an epoch-stamped control plane (`repro.control`), ``mesh`` lifts
the runtime to a multi-host mesh (per-host shards, cross-host RSS,
epoch-barrier control fan-out), ``telemetry`` exports per-queue counters
with a mesh-wide ``merge``, and ``workloads`` generates phased emergency
traffic — rendered as command scripts, recordable and bit-exactly
replayable as versioned traces — to drive it all (``scenarios`` is its
compatibility shim).  ``faults`` injects typed, deterministic failures
(stalls, crashes, shard errors, lost acks, delayed retires) at named
points in both runtimes; `repro.control.health` turns the resulting
missed ticks into lease expiry, and the mesh commits degraded over a
quorum instead of stalling (DESIGN.md §10).
"""

from repro.dataplane.ring import PacketRing, RingCounters  # noqa: F401
from repro.dataplane.runtime import DataplaneRuntime, queue_mesh  # noqa: F401
from repro.dataplane.mesh import MeshDataplane, QuorumLost  # noqa: F401
from repro.dataplane.faults import (  # noqa: F401
    CrashHost, DelayRetire, DropAck, FaultInjector, FaultPlan, InjectedFault,
    ShardError, StallHost, demo_plan, load_plan, random_plan, save_plan,
)
from repro.dataplane.workloads import (  # noqa: F401
    ChaosEvent, Phase, ScenarioTrace, WorkloadTrace,
    cascading_failover_phases, elephant_skew_phases, emergency_phases,
    make_scenario, make_workload, phase_commands, play, render, SEQ_WORD,
)
from repro.dataplane import rss, scenarios, telemetry, workloads  # noqa: F401
