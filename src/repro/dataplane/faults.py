"""Deterministic fault injection for the mesh data plane (DESIGN.md §10).

Emergency networks are exactly where hosts stall, crash, and rejoin
mid-operation — the Emergency-HRL line treats node failure as the normal
case and the INSIGHT survey names fault tolerance as the open gap for
in-network AI.  This module makes those failures *first-class inputs*:
a ``FaultPlan`` is a typed, serializable schedule of faults, and a
``FaultInjector`` fires them at **named injection points** inside
`repro.dataplane.runtime.DataplaneRuntime` and
`repro.dataplane.mesh.MeshDataplane` — deterministically, so a faulted
run records and replays bit-exactly (the plan rides along in trace
metadata).

Fault vocabulary (one frozen dataclass per class):

* ``StallHost(host, at_tick, ticks)``   — the host neither ticks nor
  heartbeats for ``ticks`` mesh ticks (a GC pause, a wedged NIC queue);
* ``CrashHost(host, at_tick)``          — the host goes permanently
  unresponsive; packets already in its rings are *stranded* (counted by
  the mesh conservation audit) until a rejoin drains them;
* ``ShardError(host, at_tick, point)``  — the host raises
  ``InjectedFault`` the next time it stages (``point="stage"``) or
  applies (``point="apply"``) a control epoch — the deterministic form
  of a shard exception mid-transaction;
* ``DropAck(host, at_tick, count)``     — the host applies an epoch but
  its commit acknowledgement is lost ``count`` times;
* ``DelayRetire(host, at_tick, ticks)`` — the host keeps ticking but
  cannot quiesce at an epoch barrier for ``ticks`` ticks (the barrier
  straggler).

Injection points (``POINTS``): ``tick`` (host liveness each mesh tick),
``stage``/``apply`` (the two phases of the epoch broadcast),
``commit-ack`` (quorum collection), ``retire`` (barrier readiness).

``InjectedFault`` subclasses `repro.control.NonFatalControlError`: an
epoch it rejects rolls back atomically and is *logged*, but the run
continues — chaos is an input, not a crash of the harness itself.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.control.plane import NonFatalControlError

#: Named injection points the runtimes consult.
POINTS = ("tick", "stage", "apply", "commit-ack", "retire")

PLAN_VERSION = 1

#: Fault-class names (CI fault matrix and ``demo_plan`` vocabulary).
FAULT_CLASSES = ("stall", "crash", "stage-error", "apply-error",
                 "drop-ack", "delay-retire")


class InjectedFault(NonFatalControlError):
    """A deterministic injected shard failure (stage/apply points)."""


@dataclasses.dataclass(frozen=True)
class StallHost:
    """Host is unresponsive for ``ticks`` ticks starting at ``at_tick``."""
    host: int
    at_tick: int
    ticks: int

    def window(self) -> tuple[int, float]:
        return (self.at_tick, self.at_tick + self.ticks)


@dataclasses.dataclass(frozen=True)
class CrashHost:
    """Host is permanently unresponsive from ``at_tick`` on."""
    host: int
    at_tick: int

    def window(self) -> tuple[int, float]:
        return (self.at_tick, float("inf"))


@dataclasses.dataclass(frozen=True)
class ShardError:
    """Raise ``InjectedFault`` on the host's next ``point`` at >= at_tick."""
    host: int
    at_tick: int
    point: str = "apply"            # "stage" | "apply"

    def __post_init__(self):
        if self.point not in ("stage", "apply"):
            raise ValueError(f"ShardError point must be stage|apply, "
                             f"got {self.point!r}")


@dataclasses.dataclass(frozen=True)
class DropAck:
    """Drop the host's next ``count`` commit acks at >= at_tick."""
    host: int
    at_tick: int
    count: int = 1


@dataclasses.dataclass(frozen=True)
class DelayRetire:
    """Host ticks but cannot quiesce at a barrier for ``ticks`` ticks."""
    host: int
    at_tick: int
    ticks: int

    def window(self) -> tuple[int, float]:
        return (self.at_tick, self.at_tick + self.ticks)


Fault = StallHost | CrashHost | ShardError | DropAck | DelayRetire
FAULT_KINDS = {
    "stall": StallHost,
    "crash": CrashHost,
    "shard-error": ShardError,
    "drop-ack": DropAck,
    "delay-retire": DelayRetire,
}
_KIND_OF = {v: k for k, v in FAULT_KINDS.items()}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A serializable schedule of faults (the injector's only input)."""
    faults: tuple = ()
    name: str = ""
    seed: int | None = None         # provenance of generated plans

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))
        for f in self.faults:
            if type(f) not in _KIND_OF:
                raise TypeError(f"not a fault: {f!r}")

    def to_dict(self) -> dict:
        return {
            "version": PLAN_VERSION,
            "name": self.name,
            "seed": self.seed,
            "faults": [dict(kind=_KIND_OF[type(f)],
                            **dataclasses.asdict(f))
                       for f in self.faults],
        }

    @classmethod
    def from_dict(cls, doc: dict) -> "FaultPlan":
        version = int(doc.get("version", PLAN_VERSION))
        if version != PLAN_VERSION:
            raise ValueError(f"fault plan version {version} unsupported "
                             f"(this build reads v{PLAN_VERSION})")
        faults = []
        for d in doc.get("faults", ()):
            d = dict(d)
            kind = d.pop("kind")
            if kind not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {kind!r} "
                                 f"(known: {sorted(FAULT_KINDS)})")
            faults.append(FAULT_KINDS[kind](**d))
        return cls(faults=tuple(faults), name=doc.get("name", ""),
                   seed=doc.get("seed"))


def save_plan(plan: FaultPlan, path: str) -> None:
    with open(path, "w") as f:
        json.dump(plan.to_dict(), f, indent=2)
        f.write("\n")


def load_plan(path: str) -> FaultPlan:
    with open(path) as f:
        return FaultPlan.from_dict(json.load(f))


class FaultInjector:
    """Deterministic fault firing against a ``FaultPlan``.

    Stateless for window faults (stall / crash / delay-retire: pure
    functions of the tick) and consume-once for point faults
    (shard errors, dropped acks), so the same plan over the same step
    stream always produces the same failure history — ``events`` is that
    history, for reports and tests.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._stalls = [f for f in plan.faults
                        if isinstance(f, (StallHost, CrashHost))]
        self._delays = [f for f in plan.faults
                        if isinstance(f, DelayRetire)]
        self._errors = [f for f in plan.faults if isinstance(f, ShardError)]
        self._acks = {id(f): f.count for f in plan.faults
                      if isinstance(f, DropAck)}
        self._ack_faults = [f for f in plan.faults if isinstance(f, DropAck)]
        self.events: list[dict] = []
        self._seen: set[tuple] = set()

    def _event(self, tick: int, point: str, host: int, detail: str,
               *, once_key: tuple | None = None) -> None:
        if once_key is not None:
            if once_key in self._seen:
                return
            self._seen.add(once_key)
        self.events.append({"tick": int(tick), "point": point,
                            "host": int(host), "detail": detail})

    # -- window faults -------------------------------------------------------

    def responsive(self, host: int, tick: int) -> bool:
        """``tick`` point: False while the host is stalled or crashed."""
        for f in self._stalls:
            lo, hi = f.window()
            if f.host == host and lo <= tick < hi:
                self._event(tick, "tick", host,
                            f"{_KIND_OF[type(f)]} (from tick {lo})",
                            once_key=("win", id(f)))
                return False
        return True

    def crashed(self, host: int, tick: int) -> bool:
        return any(isinstance(f, CrashHost) and f.host == host
                   and tick >= f.at_tick for f in self._stalls)

    def retire_blocked(self, host: int, tick: int) -> bool:
        """``retire`` point: host cannot quiesce at a barrier right now."""
        for f in self._delays:
            lo, hi = f.window()
            if f.host == host and lo <= tick < hi:
                self._event(tick, "retire", host,
                            f"delay-retire (from tick {lo})",
                            once_key=("ret", id(f)))
                return True
        return False

    # -- consume-once faults -------------------------------------------------

    def check(self, point: str, host: int, tick: int) -> None:
        """``stage``/``apply`` points: raise ``InjectedFault`` once per
        armed ``ShardError`` whose window has opened."""
        for f in list(self._errors):
            if f.point == point and f.host == host and tick >= f.at_tick:
                self._errors.remove(f)
                self._event(tick, point, host, "shard error raised")
                raise InjectedFault(
                    f"injected shard error on host {host} at {point} "
                    f"(tick {tick})")

    def drop_ack(self, host: int, tick: int) -> bool:
        """``commit-ack`` point: True when this host's ack is dropped."""
        for f in self._ack_faults:
            if f.host == host and tick >= f.at_tick and self._acks[id(f)] > 0:
                self._acks[id(f)] -= 1
                self._event(tick, "commit-ack", host, "commit ack dropped")
                return True
        return False

    @property
    def armed(self) -> bool:
        return bool(self.plan.faults)


# ---------------------------------------------------------------------------
# plan generators (CI fault matrix, fig12, hypothesis properties)
# ---------------------------------------------------------------------------

def demo_plan(kind: str, *, hosts: int, lease_ticks: int = 8,
              at_tick: int = 6) -> FaultPlan:
    """The canonical one-fault plan per fault class (CI matrix + fig12).

    Always targets the last host so host 0 survives.  On a single host
    the host-loss classes degenerate to a short stall (killing the only
    host would strand the whole data plane — there is nothing to fail
    over *to*), which still exercises lease accounting.
    """
    victim = hosts - 1
    if hosts == 1 and kind in ("crash", "delay-retire", "drop-ack"):
        kind = "stall"
    if kind == "stall":
        # long enough to expire the lease, short enough to rejoin
        f: Fault = StallHost(victim, at_tick, lease_ticks + 4)
    elif kind == "crash":
        f = CrashHost(victim, at_tick)
    elif kind == "stage-error":
        f = ShardError(victim, at_tick, "stage")
    elif kind == "apply-error":
        f = ShardError(victim, at_tick, "apply")
    elif kind == "drop-ack":
        f = DropAck(victim, max(at_tick - 4, 0), count=1)
    elif kind == "delay-retire":
        f = DelayRetire(victim, at_tick, lease_ticks + 4)
    else:
        raise ValueError(f"unknown fault class {kind!r} "
                         f"(known: {list(FAULT_CLASSES)})")
    return FaultPlan(faults=(f,), name=f"demo-{kind}")


def random_plan(seed: int, *, hosts: int, horizon: int = 24,
                max_faults: int = 3, allow_crash: bool = True) -> FaultPlan:
    """A seeded random plan over the recoverable fault classes.

    Deterministic in ``seed``.  Host 0 is never stalled or crashed (a
    survivor always exists to absorb failover), and shard errors are
    excluded (they reject epochs by design; the hypothesis property
    covers them separately).
    """
    rng = np.random.default_rng(seed)
    kinds = ["stall", "delay-retire", "drop-ack"]
    if allow_crash and hosts > 1:
        kinds.append("crash")
    faults: list[Fault] = []
    crashed_hosts: set[int] = set()
    for _ in range(int(rng.integers(0, max_faults + 1))):
        kind = kinds[int(rng.integers(0, len(kinds)))]
        host = int(rng.integers(1, hosts)) if hosts > 1 else 0
        at = int(rng.integers(0, horizon))
        ticks = int(rng.integers(1, 12))
        if kind == "stall":
            faults.append(StallHost(host, at, ticks))
        elif kind == "delay-retire":
            faults.append(DelayRetire(host, at, ticks))
        elif kind == "drop-ack":
            faults.append(DropAck(host, at, count=int(rng.integers(1, 3))))
        elif kind == "crash" and host not in crashed_hosts \
                and len(crashed_hosts) + 1 < hosts:
            faults.append(CrashHost(host, at))
            crashed_hosts.add(host)
    return FaultPlan(faults=tuple(faults), name=f"random-{seed}", seed=seed)
