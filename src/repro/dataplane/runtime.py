"""Multi-queue data-plane runtime: RSS dispatch -> rings -> sharded workers.

This is the repo's analogue of the paper's AF_XDP deployment shape: the
NIC hashes each flow to one of N queues (``rss``), every queue buffers
into a bounded ring (``ring``), and each queue drains through the *same*
resident-bank forwarding program (`repro.core.pipeline.packet_step`) —
one fused launch per queue-block, per-queue FIFO ordering, and online
slot swaps that never produce a wrong verdict.

Fan-out modes (``fanout=``):

* ``loop``      — one jitted ``packet_step`` call per non-empty queue per
                  tick.  The default for the fused strategy: the
                  structural audit can assert exactly ONE Pallas launch
                  per queue-block.
* ``vmap``      — queue batches stacked to (Q, B, 272) and processed by a
                  single vmapped program; best for the gather strategies
                  on one device.
* ``shard_map`` — the vmapped program sharded over a device mesh (reusing
                  `repro.launch.mesh.make_host_mesh`), so queues map onto
                  devices exactly like RSS maps flows onto NIC queues.
                  Host-simulated on 1-device CPU CI; real spread on TPU.
* ``auto``      — ``loop`` for fused/grouped strategies, ``vmap`` else.

Every tick pops at most ``batch`` rows per queue, pads to the static batch
shape (no recompiles), runs the workers, then retires rows against the
ring counters so ``admitted == completed + occupancy`` holds at any
instant.  ``audit=True`` re-scores every tick through the exact ``take``
path and counts verdict mismatches — the multi-queue extension of the
``replay_trace`` zero-wrong-verdict regression, valid across online
``swap_slot`` updates because both paths read the same bank version.
"""

from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import bank as bank_lib, pipeline
from repro.dataplane import rss
from repro.dataplane.ring import PacketRing
from repro.dataplane.scenarios import SEQ_WORD
from repro.dataplane.telemetry import Telemetry
from repro.launch import mesh as mesh_lib

_LOOP_STRATEGIES = ("fused", "grouped", "grouped_staged")


def queue_mesh(num_queues: int):
    """A mesh whose leading axis shards the queue dimension.

    Reuses the production host mesh when its data axis divides the queue
    count; otherwise builds a dedicated 1-axis mesh over the largest
    device count that does.
    """
    m = mesh_lib.make_host_mesh(1)
    if num_queues % m.devices.shape[0] == 0:
        return m, "data"
    d = math.gcd(num_queues, jax.device_count())
    return jax.make_mesh((d,), ("queues",)), "queues"


class DataplaneRuntime:
    def __init__(
        self,
        bank,
        *,
        num_queues: int,
        num_slots: int | None = None,
        strategy: str = "fused",
        fanout: str = "auto",
        batch: int = 128,
        block_b: int = 32,
        ring_capacity: int = 2048,
        backend: str = "auto",
        rss_key: bytes = rss.DEFAULT_KEY,
        audit: bool = False,
        record: bool = False,
    ):
        self.bank = bank
        self.num_queues = int(num_queues)
        self.num_slots = int(num_slots if num_slots is not None
                             else bank_lib.bank_size(bank))
        self.strategy = strategy
        self.batch = int(batch)
        self.block_b = min(int(block_b), self.batch)
        self.backend = backend
        self.rss_key = rss_key
        self.audit = audit
        self.reta = rss.indirection_table(self.num_queues)
        self.rings = [PacketRing(ring_capacity) for _ in range(self.num_queues)]
        self.telemetry = Telemetry(self.num_queues, self.num_slots)
        self._record = record
        self.completed_seq = [[] for _ in range(self.num_queues)]
        self.completed_verdicts = [[] for _ in range(self.num_queues)]
        self.completed_slots = [[] for _ in range(self.num_queues)]
        self.dropped_seq: list[int] = []
        self._t_start: float | None = None
        if fanout == "auto":
            fanout = "loop" if strategy in _LOOP_STRATEGIES else "vmap"
        if fanout not in ("loop", "vmap", "shard_map"):
            raise ValueError(f"unknown fanout {fanout!r}")
        self.fanout = fanout
        self._vstep = None if fanout == "loop" else self._build_fanout(fanout)

    # -- worker construction ------------------------------------------------

    def _step_kwargs(self) -> dict:
        return dict(num_slots=self.num_slots, strategy=self.strategy,
                    backend=self.backend, block_b=self.block_b)

    def _build_fanout(self, fanout: str):
        kw = self._step_kwargs()

        def per_queue(bank, qpackets):  # (Qlocal, B, 272) -> PacketResult
            return jax.vmap(
                lambda p: pipeline.packet_step(bank, p, **kw))(qpackets)

        if fanout == "vmap":
            return jax.jit(per_queue)
        mesh, axis = queue_mesh(self.num_queues)
        return jax.jit(shard_map(
            per_queue, mesh=mesh,
            in_specs=(P(), P(axis)), out_specs=P(axis), check_rep=False,
        ))

    # -- control plane ------------------------------------------------------

    def swap_slot(self, k: int, params) -> None:
        """Online resident-slot replacement: the bank array is updated
        between ticks; in-flight rows of other slots are unaffected."""
        self.bank = bank_lib.update_slot(self.bank, k, params)
        self.telemetry.slot_swaps += 1

    def set_reta(self, reta: np.ndarray) -> None:
        reta = np.asarray(reta, np.int32)
        if reta.min() < 0 or reta.max() >= self.num_queues:
            raise ValueError("RETA entry out of queue range")
        self.reta = reta
        self.telemetry.reta_updates += 1

    def fail_queues(self, failed: tuple[int, ...]) -> None:
        self.set_reta(rss.failover_table(
            self.reta, failed, num_queues=self.num_queues))

    def reset_reta(self) -> None:
        self.set_reta(rss.indirection_table(self.num_queues))

    # -- data plane ---------------------------------------------------------

    def dispatch(self, packets_np: np.ndarray, now: float | None = None) -> dict:
        """RSS-dispatch one arrival burst into the per-queue rings."""
        if self._t_start is None:
            self._t_start = time.perf_counter()
        if now is None:
            now = time.perf_counter()
        packets_np = np.asarray(packets_np)
        q = rss.queue_of(packets_np, self.num_queues,
                         key=self.rss_key, reta=self.reta)
        per_queue = []
        for i, ring in enumerate(self.rings):
            rows = packets_np[q == i]
            admitted = ring.push(rows, now)
            if self._record and admitted < rows.shape[0]:
                self.dropped_seq.extend(
                    int(s) for s in rows[admitted:, SEQ_WORD])
            per_queue.append({"offered": int(rows.shape[0]),
                              "admitted": admitted,
                              "dropped": int(rows.shape[0]) - admitted})
        return {"per_queue": per_queue,
                "dropped": sum(p["dropped"] for p in per_queue)}

    def _pad(self, rows: np.ndarray) -> np.ndarray:
        n = rows.shape[0]
        if n == self.batch:
            return rows
        out = np.zeros((self.batch, rows.shape[1]), np.uint32)
        out[:n] = rows
        if n:  # repeat the last valid row; results beyond n are discarded
            out[n:] = rows[n - 1]
        return out

    def tick(self) -> int:
        """Drain up to ``batch`` rows per queue through the workers."""
        popped = [ring.pop(self.batch) for ring in self.rings]
        counts = [rows.shape[0] for rows, _ in popped]
        total = sum(counts)
        if total == 0:
            return 0
        t0 = time.perf_counter()
        if self.fanout == "loop":
            results = {}
            for q, (rows, _) in enumerate(popped):
                if counts[q] == 0:
                    continue
                results[q] = pipeline.packet_step(
                    self.bank, jnp.asarray(self._pad(rows)),
                    **self._step_kwargs())
            for res in results.values():
                res.scores.block_until_ready()
        else:
            qstack = np.stack([self._pad(rows) for rows, _ in popped])
            res_all = self._vstep(self.bank, jnp.asarray(qstack))
            res_all.scores.block_until_ready()
            results = {
                q: pipeline.PacketResult(*(leaf[q] for leaf in res_all))
                for q in range(self.num_queues) if counts[q]
            }
        now = time.perf_counter()
        tick_s = now - t0
        for q, res in results.items():
            n = counts[q]
            rows, ts = popped[q]
            slots = np.asarray(res.slots)[:n]
            verdicts = np.asarray(res.verdicts)[:n]
            actions = np.asarray(res.actions)[:n]
            self.telemetry.record_tick(
                q, slots, verdicts, actions,
                latency_us=(now - ts) * 1e6,
                tick_s=tick_s * n / total,
            )
            self.rings[q].mark_completed(n)
            if self.audit:
                exact = pipeline.packet_step(
                    self.bank, jnp.asarray(self._pad(rows)),
                    num_slots=self.num_slots, strategy="take",
                    backend=self.backend)
                bad = (np.asarray(exact.verdicts)[:n] != verdicts).sum()
                bad += (np.asarray(exact.slots)[:n] != slots).sum()
                self.telemetry.wrong_verdict += int(bad)
            if self._record:
                self.completed_seq[q].extend(int(s) for s in rows[:, SEQ_WORD])
                self.completed_verdicts[q].extend(bool(v) for v in verdicts)
                self.completed_slots[q].extend(int(s) for s in slots)
        return total

    def drain(self, max_ticks: int = 100_000) -> int:
        done = 0
        for _ in range(max_ticks):
            n = self.tick()
            done += n
            if n == 0 and not any(len(r) for r in self.rings):
                return done
        raise RuntimeError("drain did not converge")

    # -- audit + reporting --------------------------------------------------

    def audit_conservation(self) -> dict:
        """Per-queue + aggregate packet conservation; must always hold."""
        per_queue = [ring.conservation() for ring in self.rings]
        totals = {k: sum(c[k] for c in per_queue)
                  for k in ("offered", "admitted", "dropped", "completed",
                            "occupancy")}
        ok = all(c["producer_ok"] and c["consumer_ok"] for c in per_queue)
        return {"per_queue": per_queue, "totals": totals, "ok": ok,
                "wrong_verdict": self.telemetry.wrong_verdict}

    def snapshot(self) -> dict:
        elapsed = (time.perf_counter() - self._t_start
                   if self._t_start is not None else None)
        out = self.telemetry.snapshot(elapsed_s=elapsed)
        out["conservation"] = self.audit_conservation()
        out["fanout"] = self.fanout
        out["strategy"] = self.strategy
        return out
