"""Multi-queue data-plane runtime: RSS dispatch -> rings -> sharded workers.

This is the repo's analogue of the paper's AF_XDP deployment shape: the
NIC hashes each flow to one of N queues (``rss``), every queue buffers
into a bounded ring (``ring``), and each queue drains through the *same*
resident-bank forwarding program (`repro.core.pipeline.packet_step`) —
one fused launch per queue-block, per-queue FIFO ordering, and online
slot swaps that never produce a wrong verdict.

Control plane (DESIGN.md §7): every runtime mutation — slot swap, RETA
rewrite, queue fail/restore, policy change — flows through
``self.control`` (`repro.control.ControlPlane`) as an epoch-stamped
command batch.  Epochs apply only at tick boundaries (entry of
``dispatch``/``tick``), so in-flight device work keeps the bank/RETA
version it was dispatched with; the legacy ``swap_slot``/``set_reta``/
``fail_queues`` methods are deprecation shims that emit single-command
epochs.  An installed ``RoutingPolicy`` is consulted at every tick
boundary and its rebalances land as ordinary ``ProgramReta`` epochs.

Fan-out modes (``fanout=``):

* ``loop``      — one jitted ``packet_step`` call per non-empty queue per
                  tick.  The default for the fused strategy: the
                  structural audit can assert exactly ONE Pallas launch
                  per queue-block.
* ``vmap``      — queue batches stacked to (Q, B, 272) and processed by a
                  single vmapped program; best for the gather strategies
                  on one device.
* ``shard_map`` — the vmapped program sharded over a device mesh (reusing
                  `repro.launch.mesh.make_host_mesh`), so queues map onto
                  devices exactly like RSS maps flows onto NIC queues.
                  Host-simulated on 1-device CPU CI; real spread on TPU.
* ``auto``      — ``loop`` for fused/grouped strategies, ``vmap`` else.

The tick loop is a 3-stage pipeline (dispatch / device / retire) with a
bounded in-flight window of ``pipeline_depth`` ticks, the multi-queue
form of ``switching.replay_trace(stream=True)``: each ``tick()`` pops at
most ``batch`` rows per queue, pads to the static batch shape (no
recompiles), issues the workers asynchronously, and retires the oldest
tick once the window is full.  ``pipeline_depth=1`` degenerates to the
synchronous loop; any depth produces bit-identical verdicts because
every tick captures the bank/RETA version current at its dispatch.
``audit=True`` re-scores every tick through the exact ``take`` path
*against that captured bank* and counts verdict mismatches — valid
across every control command kind, not just slot swaps.
"""

from __future__ import annotations

import collections
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.control import (ControlPlane, FailQueues, ProgramReta,
                           RestoreQueues, SetPolicy, SwapSlot)
from repro.control import policy as policy_mod
from repro.core import bank as bank_lib, pipeline
from repro.dataplane import rss
from repro.dataplane.ring import PacketRing
from repro.dataplane.workloads.phases import SEQ_WORD
from repro.dataplane.telemetry import Telemetry
from repro.launch import mesh as mesh_lib

_LOOP_STRATEGIES = ("fused", "grouped", "grouped_staged")

_DEPRECATION = ("%s() is a deprecation shim: submit a %s command through "
                "runtime.control.submit(...) instead")


def queue_mesh(num_queues: int):
    """Compatibility alias: device layout now lives in one place —
    `repro.launch.mesh.make_queue_mesh` (the single source of truth)."""
    return mesh_lib.make_queue_mesh(num_queues)


def apply_routing_command(rt, cmd) -> bool:
    """Apply the service-state commands whose semantics are identical on
    the single-host runtime and the mesh facade (which passes global
    queue ids through ``rt.num_queues`` and its own ``_install_reta``):
    ``FailQueues`` (union + affinity-preserving failover), ``RestoreQueues``
    (default table minus still-failed), ``SetPolicy``.  Returns False for
    any other command so callers keep their own dispatch."""
    if isinstance(cmd, FailQueues):
        failed = rt.failed_queues | set(cmd.queues)
        # compute-then-commit: an unservable failover (zero live queues)
        # raises here without mutating any runtime state
        table = rss.failover_table(rt.reta, tuple(sorted(failed)),
                                   num_queues=rt.num_queues)
        rt.failed_queues = failed
        rt._install_reta(table)
    elif isinstance(cmd, RestoreQueues):
        rt.failed_queues -= set(cmd.queues or range(rt.num_queues))
        rt._install_reta(rss.restore_table(
            rt.num_queues, len(rt.reta), rt.failed_queues))
    elif isinstance(cmd, SetPolicy):
        rt.policy = cmd.policy
    else:
        return False
    return True


def consult_policy(rt, *, num_hosts: int = 1) -> None:
    """Tick-boundary policy consultation, shared by the single-host
    runtime and the mesh facade: freeze a view of the runtime's queue
    pressure, and submit any proposal as an ordinary ``ProgramReta``
    epoch (effective at the *next* boundary).  ``rt`` needs the runtime
    protocol surface: policy / rings / reta / bucket_load /
    failed_queues / control."""
    if rt.policy is None:
        return
    view = policy_mod.PolicyView(
        tick=rt._tick_count,
        num_queues=rt.num_queues,
        num_hosts=num_hosts,
        reta=rt.reta.copy(),
        queue_depth=np.array([len(r) for r in rt.rings], np.int64),
        queue_dropped=np.array(
            [r.counters.dropped for r in rt.rings], np.int64),
        bucket_load=rt.bucket_load.copy(),
        failed_queues=frozenset(rt.failed_queues),
    )
    proposal = rt.policy.propose(view)
    if proposal is not None and not np.array_equal(proposal, rt.reta):
        rt.control.submit(ProgramReta(tuple(proposal)))


def drain_rings(rt, max_ticks: int = 100_000) -> int:
    """Tick until every ring is empty, then flush the pipeline — the one
    drain loop both the single-host runtime and the mesh facade use."""
    done = 0
    for _ in range(max_ticks):
        n = rt.tick()
        done += n
        if n == 0 and not any(len(r) for r in rt.rings):
            rt.retire_all()
            return done
    raise RuntimeError("drain did not converge")


class _InFlight:
    """One dispatched-but-unretired tick (the device stage of the pipeline)."""

    __slots__ = ("tick", "popped", "counts", "results", "bank", "t0")

    def __init__(self, tick, popped, counts, results, bank, t0):
        self.tick = tick
        self.popped = popped      # [(rows, ts)] per queue
        self.counts = counts      # rows popped per queue
        self.results = results    # {queue: PacketResult} (async)
        self.bank = bank          # bank version captured at dispatch
        self.t0 = t0


class DataplaneRuntime:
    """Single-host multi-queue data-plane runtime (DESIGN.md §6/§7).

    Public surface: ``dispatch`` (arrival edge), ``tick`` (pipeline
    step), ``retire_all``/``drain`` (flush), ``control`` (the epoch-
    stamped mutation funnel, `repro.control.ControlPlane`),
    ``flush_control``, ``adopt_bank``, ``audit_conservation`` and
    ``snapshot`` (reporting).  All state mutation flows through control
    epochs; the attributes (``bank``, ``reta``, ``policy``, ...) are
    read-only views between tick boundaries.

    With ``double_buffer=True`` (default) the resident bank is held in a
    `repro.core.bank.DoubleBufferedBank`: SwapSlot params stage into the
    shadow copy at submit time while traffic flows, and the epoch commit
    is an O(1) pointer flip (DESIGN.md §14) instead of a bank re-stage.
    """

    def __init__(
        self,
        bank,
        *,
        num_queues: int,
        num_slots: int | None = None,
        strategy: str = "fused",
        fanout: str = "auto",
        batch: int = 128,
        block_b: int = 32,
        ring_capacity: int = 2048,
        backend: str = "auto",
        rss_key: bytes = rss.DEFAULT_KEY,
        audit: bool = False,
        record: bool = False,
        pipeline_depth: int = 1,
        megastep_ticks: int = 1,
        policy=None,
        fault_injector=None,
        log_capacity: int | None = None,
        log_spill: str | None = None,
        double_buffer: bool = True,
    ):
        self.bank = bank
        self.num_queues = int(num_queues)
        self.num_slots = int(num_slots if num_slots is not None
                             else bank_lib.bank_size(bank))
        # Double-buffered bank: the runtime owns two private device
        # copies; ``self.bank`` aliases the active one.  The caller's
        # ``bank`` argument is never donated.
        self._bankbuf = None
        self._epoch_nonce: object = None
        if double_buffer:
            self._bankbuf = bank_lib.DoubleBufferedBank(bank)
            self.bank = self._bankbuf.active
        self.strategy = strategy
        self.batch = int(batch)
        self.block_b = min(int(block_b), self.batch)
        self.backend = backend
        self.rss_key = rss_key
        self.audit = audit
        self.reta = rss.indirection_table(self.num_queues)
        self.rings = [PacketRing(ring_capacity) for _ in range(self.num_queues)]
        self.telemetry = Telemetry(self.num_queues, self.num_slots)
        self._record = record
        self.completed_seq = [[] for _ in range(self.num_queues)]
        self.completed_verdicts = [[] for _ in range(self.num_queues)]
        self.completed_slots = [[] for _ in range(self.num_queues)]
        self.dropped_seq: list[int] = []
        # deploy/observability taps (host callbacks off the hot path; they
        # must treat their arguments as read-only and stay cheap — the tick
        # loop does not shield itself from a slow tap):
        #   on_retire(queue, rows, slots, verdicts, actions, tick)
        #   on_drop(queue, rows)   — dispatch-edge tail drops
        self.on_retire = None
        self.on_drop = None
        self._t_start: float | None = None
        if pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")
        self.pipeline_depth = int(pipeline_depth)
        self._inflight: collections.deque[_InFlight] = collections.deque()
        self._last_retire_s: float | None = None
        self._tick_count = 0
        self._faults = fault_injector
        self.control = ControlPlane(self, log_capacity=log_capacity,
                                    spill_path=log_spill)
        self.policy = policy          # initial config, not a mutation
        self.failed_queues: set[int] = set()
        self.bucket_load = np.zeros(len(self.reta), np.int64)
        if fanout == "auto":
            fanout = "loop" if strategy in _LOOP_STRATEGIES else "vmap"
        if fanout not in ("loop", "vmap", "shard_map"):
            raise ValueError(f"unknown fanout {fanout!r}")
        self.fanout = fanout
        self._vstep = None if fanout == "loop" else self._build_fanout(fanout)
        if megastep_ticks < 1:
            raise ValueError("megastep_ticks must be >= 1")
        self.megastep_ticks = int(megastep_ticks)
        # Deferred (megastep) mode: dispatch/tick stage work and run the
        # authoritative host ring simulation; a window of N ticks executes
        # on device in ONE compiled scan at flush (DESIGN.md §13).  Typed
        # fault injection needs per-tick host control, and the megastep's
        # batched forward replicates the fused strategy on the reference
        # backend only — every other configuration falls back to the
        # sequential loop.  Verdicts and telemetry totals are
        # bit-identical either way.
        self._mega = None
        if (self.megastep_ticks > 1 and fault_injector is None
                and strategy == "fused"):
            from repro.kernels import ops as _ops
            if _ops._resolve(backend) == "ref":
                from repro.dataplane.megastep import MegastepEngine
                self._mega = MegastepEngine(self)

    # -- worker construction ------------------------------------------------

    def _step_kwargs(self) -> dict:
        return dict(num_slots=self.num_slots, strategy=self.strategy,
                    backend=self.backend, block_b=self.block_b)

    def _build_fanout(self, fanout: str):
        kw = self._step_kwargs()

        def per_queue(bank, qpackets):  # (Qlocal, B, 272) -> PacketResult
            return jax.vmap(
                lambda p: pipeline.packet_step(bank, p, **kw))(qpackets)

        if fanout == "vmap":
            return jax.jit(per_queue)
        mesh, axis = queue_mesh(self.num_queues)
        return jax.jit(shard_map(
            per_queue, mesh=mesh,
            in_specs=(P(), P(axis)), out_specs=P(axis), check_rep=False,
        ))

    # -- control plane: command application (ControlPlane-only entry) -------

    def _validate_command(self, cmd) -> None:
        """Raise without mutating when ``cmd`` cannot apply to the current
        state.  ``ControlPlane.apply_pending`` validates a whole epoch
        before applying any of it, so a rejected epoch is atomic: nothing
        mutates.  (Validation is against the pre-epoch state; an epoch
        whose commands only conflict with *each other* still fails at
        apply time and is logged with its error.)"""
        self._fault_check("stage")
        if isinstance(cmd, SwapSlot):
            if not 0 <= int(cmd.slot) < self.num_slots:
                raise ValueError(f"slot {cmd.slot} out of range")
            if (jax.tree_util.tree_structure(cmd.params)
                    != jax.tree_util.tree_structure(self.bank)):
                raise ValueError("params pytree does not match bank slots")
        elif isinstance(cmd, ProgramReta):
            reta = np.asarray(cmd.reta, np.int32)
            if reta.size == 0:
                raise ValueError("empty RETA")
            if reta.min() < 0 or reta.max() >= self.num_queues:
                raise ValueError("RETA entry out of queue range")
        elif isinstance(cmd, FailQueues):
            if any(not 0 <= q < self.num_queues for q in cmd.queues):
                raise ValueError("failed queue id out of range")
            # NOTE: no zero-live-queues check here — it would judge each
            # command against the pre-epoch state and falsely reject
            # sequentially-valid epochs like [RestoreQueues, FailQueues];
            # the apply-time failover_table raises instead and the state
            # snapshot rolls the epoch back atomically.
        elif isinstance(cmd, RestoreQueues):
            if any(not 0 <= q < self.num_queues for q in cmd.queues):
                raise ValueError("restored queue id out of range")
        elif isinstance(cmd, SetPolicy):
            if cmd.policy is not None and not hasattr(cmd.policy, "propose"):
                raise TypeError("policy must implement propose(view)")
        else:
            raise TypeError(f"not a control command: {cmd!r}")

    def _apply_command(self, cmd) -> None:
        """Apply ONE control command.  Only ``ControlPlane.apply_pending``
        may call this — it is the single mutation funnel."""
        self._fault_check("apply")
        if isinstance(cmd, SwapSlot):
            if self._bankbuf is not None:
                # zero-copy path: make sure the params are staged in the
                # shadow (a no-op when the epoch prestaged at submit),
                # then leave publication to the _finish_epoch flip
                tok = id(cmd)
                if not self._bankbuf.committed(tok):
                    self._bankbuf.stage(int(cmd.slot), cmd.params,
                                        token=tok, epoch=self._epoch_nonce,
                                        force=True)
            else:
                self.bank = bank_lib.update_slot(
                    self.bank, cmd.slot, cmd.params)
            self.telemetry.slot_swaps += 1
        elif isinstance(cmd, ProgramReta):
            self._install_reta(np.asarray(cmd.reta, np.int32))
        elif not apply_routing_command(self, cmd):
            raise TypeError(f"not a control command: {cmd!r}")
        if self._mega is not None:
            # deferred mode: the host mirror just mutated; serialize the
            # same mutation into the on-device epoch queue so it applies
            # at the matching scan step of the staged window
            self._mega.stage_delta(cmd)

    def _fault_check(self, point: str) -> None:
        """Consult the armed ``FaultInjector`` (if any) at a stage/apply
        injection point; a single-host runtime is always host 0."""
        if self._faults is not None:
            self._faults.check(point, 0, self._tick_count)

    def _control_state(self) -> dict:
        """Snapshot everything epochs mutate (apply-time rollback).  Safe
        by reference: appliers install fresh objects, never mutate these."""
        self._epoch_nonce = object()  # scopes apply-time staging (§14)
        return dict(bank=self.bank, reta=self.reta,
                    failed=set(self.failed_queues), policy=self.policy,
                    bucket_load=self.bucket_load,
                    slot_swaps=self.telemetry.slot_swaps,
                    reta_updates=self.telemetry.reta_updates,
                    bankswap=(self._bankbuf.mark()
                              if self._bankbuf is not None else None),
                    mega=(self._mega.delta_mark()
                          if self._mega is not None else None))

    def _rollback_control_state(self, s: dict) -> None:
        if self._bankbuf is not None and s.get("bankswap") is not None:
            self._bankbuf.restore(s["bankswap"])
            # the rolled-back epoch's staged params are garbage; its slots
            # go dirty and resync from the (restored) active bank later
            self._bankbuf.discard_staged()
        self.bank = s["bank"]
        self.reta = s["reta"]
        self.failed_queues = s["failed"]
        self.policy = s["policy"]
        self.bucket_load = s["bucket_load"]
        self.telemetry.slot_swaps = s["slot_swaps"]
        self.telemetry.reta_updates = s["reta_updates"]
        if self._mega is not None and s.get("mega") is not None:
            self._mega.delta_rollback(s["mega"])

    def _prestage_epoch(self, rec) -> None:
        """Submit-time hook (``ControlPlane.submit``): stage the epoch's
        SwapSlot params into the shadow bank while traffic keeps flowing,
        so the barrier commit is a pointer flip (DESIGN.md §14).

        Best-effort by design: a busy shadow (another epoch already
        prestaged, or a live prefetch) just defers staging to apply time,
        and obviously-invalid commands are left for ``_validate_command``
        to reject with the normal epoch-atomic semantics."""
        if self._bankbuf is None:
            return
        for cmd in rec.commands:
            if not isinstance(cmd, SwapSlot):
                continue
            if not 0 <= int(cmd.slot) < self.num_slots:
                continue
            try:
                if (jax.tree_util.tree_structure(cmd.params)
                        != jax.tree_util.tree_structure(self.bank)):
                    continue
                self._bankbuf.stage(int(cmd.slot), cmd.params,
                                    token=id(cmd), epoch=rec.epoch)
            except Exception:
                # e.g. leaf-shape mismatch: apply-time validation owns the
                # rejection; drop whatever partially staged
                self._bankbuf.discard_staged()

    def _finish_epoch(self, rec) -> None:
        """Epoch barrier commit: publish every staged SwapSlot by flipping
        which device buffer is active — O(1), no weights move."""
        if self._bankbuf is not None:
            self.bank = self._bankbuf.commit()

    def adopt_bank(self, bank) -> None:
        """Install externally supplied bank contents outside the epoch
        path (trace-replay install, mesh shard resync).  Under double
        buffering the contents are copied into a fresh active buffer so
        staging and flips keep working; otherwise a plain reference
        install."""
        if self._bankbuf is not None:
            self._bankbuf.reseed(bank)
            self.bank = self._bankbuf.active
        else:
            self.bank = bank

    def bank_pin(self):
        """Pin the current active bank buffer against donation (taken by
        holders that outlive the next epoch, e.g. an open megastep
        window).  Returns an opaque handle for ``bank_unpin``; None when
        double buffering is off (nothing is ever donated then)."""
        return (self._bankbuf.pin_active()
                if self._bankbuf is not None else None)

    def bank_unpin(self, handle) -> None:
        """Release a ``bank_pin`` handle."""
        if handle is not None and self._bankbuf is not None:
            self._bankbuf.unpin(handle)

    def _install_reta(self, reta: np.ndarray) -> None:
        reta = np.asarray(reta, np.int32)
        if reta.min() < 0 or reta.max() >= self.num_queues:
            raise ValueError("RETA entry out of queue range")
        if len(reta) != len(self.bucket_load):
            self.bucket_load = np.zeros(len(reta), np.int64)
        self.reta = reta
        self.telemetry.reta_updates += 1

    def _apply_control(self) -> None:
        """Apply queued epochs at a *fully quiescent* boundary: in-flight
        ticks retire first, so the wrong-verdict counter each epoch
        snapshots has absorbed every pre-epoch tick and per-epoch
        continuity attribution is exact even at pipeline_depth > 1.

        In deferred (megastep) mode epochs do NOT force a flush — that
        is the point of the on-device epoch queue: the epoch applies
        eagerly to the host mirrors (exact atomic apply / rollback /
        log) and its serialized deltas land mid-window at the matching
        scan step.  The window only flushes early when the epoch batch
        would overflow the bounded device queue.  Trade-off: the
        ``wrong_verdict_at_apply`` each epoch snapshots is then the
        value as of the last flush — identical in the zero-wrong-verdict
        world the audit enforces, coarser only once something is already
        broken."""
        if self.control.has_pending:
            if self._mega is not None:
                self._mega.prepare_epochs(
                    sum(len(r.commands) for r in self.control.pending))
            else:
                self.retire_all()
            self.control.apply_pending(self._tick_count)

    def _tick_boundary(self) -> None:
        """Quiescent point between ticks: apply queued control epochs,
        then let the routing policy react to current telemetry (its
        proposal lands as an epoch at the *next* boundary)."""
        self._apply_control()
        consult_policy(self)

    def flush_control(self) -> None:
        """Force-apply pending epochs now (we are between ticks by
        construction when host code runs)."""
        self._apply_control()

    # -- deprecated direct-mutation shims ------------------------------------

    def swap_slot(self, k: int, params) -> None:
        """Deprecated: emits a single-command ``SwapSlot`` epoch."""
        warnings.warn(_DEPRECATION % ("swap_slot", "SwapSlot"),
                      DeprecationWarning, stacklevel=2)
        self.control.submit(SwapSlot(int(k), params))
        self.flush_control()

    def set_reta(self, reta: np.ndarray) -> None:
        """Deprecated: emits a single-command ``ProgramReta`` epoch."""
        warnings.warn(_DEPRECATION % ("set_reta", "ProgramReta"),
                      DeprecationWarning, stacklevel=2)
        self.control.submit(ProgramReta(tuple(np.asarray(reta, np.int32))))
        self.flush_control()

    def fail_queues(self, failed: tuple[int, ...]) -> None:
        """Deprecated: emits a single-command ``FailQueues`` epoch."""
        warnings.warn(_DEPRECATION % ("fail_queues", "FailQueues"),
                      DeprecationWarning, stacklevel=2)
        self.control.submit(FailQueues(tuple(failed)))
        self.flush_control()

    def reset_reta(self) -> None:
        """Deprecated: emits a single-command ``RestoreQueues`` epoch."""
        warnings.warn(_DEPRECATION % ("reset_reta", "RestoreQueues"),
                      DeprecationWarning, stacklevel=2)
        self.control.submit(RestoreQueues())
        self.flush_control()

    # -- data plane ---------------------------------------------------------

    def dispatch(self, packets_np: np.ndarray, now: float | None = None,
                 *, queues: np.ndarray | None = None) -> dict:
        """RSS-dispatch one arrival burst into the per-queue rings.

        The arrival edge is a tick boundary: queued control epochs (RETA
        rewrites in particular) become effective before routing.

        ``queues`` is an optional precomputed per-packet queue-id array:
        the mesh facade resolves (host, queue) from ONE mesh-level hash
        and hands each shard its local ids, so the burst is never hashed
        twice.  The caller then owns per-bucket load accounting; when
        omitted the runtime hashes and resolves through its own RETA.
        """
        self._apply_control()
        if self._t_start is None:
            self._t_start = time.perf_counter()
        if now is None:
            now = time.perf_counter()
        packets_np = np.asarray(packets_np)
        if queues is None:
            h = rss.toeplitz_hash(rss.flow_words_of(packets_np), self.rss_key)
            bucket = rss.bucket_index(h, len(self.reta)).astype(np.int64)
            self.bucket_load += np.bincount(bucket, minlength=len(self.reta))
            q = self.reta[bucket]
        else:
            q = np.asarray(queues, np.int64)
            if q.size and not (0 <= q.min() and q.max() < self.num_queues):
                # a global id handed to a shard would otherwise match no
                # ring and vanish without tripping the conservation audit
                raise ValueError(
                    f"precomputed queue ids out of range for "
                    f"{self.num_queues} queues")
        self.telemetry.touch(now)
        per_queue = []
        for i, ring in enumerate(self.rings):
            rows = packets_np[q == i]
            admitted = ring.push(rows, now)
            if self._record and admitted < rows.shape[0]:
                self.dropped_seq.extend(
                    int(s) for s in rows[admitted:, SEQ_WORD])
            if self.on_drop is not None and admitted < rows.shape[0]:
                self.on_drop(i, rows[admitted:])
            self.telemetry.record_drops(i, int(rows.shape[0]) - admitted)
            per_queue.append({"offered": int(rows.shape[0]),
                              "admitted": admitted,
                              "dropped": int(rows.shape[0]) - admitted})
        if self._mega is not None:
            # deferred mode: the host rings above stay authoritative;
            # the device replays the identical admission at flush
            self._mega.stage_burst(packets_np, q)
        return {"per_queue": per_queue,
                "dropped": sum(p["dropped"] for p in per_queue)}

    def _pad(self, rows: np.ndarray) -> np.ndarray:
        n = rows.shape[0]
        if n == self.batch:
            return rows
        out = np.zeros((self.batch, rows.shape[1]), np.uint32)
        out[:n] = rows
        if n:  # repeat the last valid row; results beyond n are discarded
            out[n:] = rows[n - 1]
        return out

    def tick(self) -> int:
        """Pipeline stage 1 (dispatch): pop up to ``batch`` rows per queue
        and issue the workers asynchronously; stage 3 (retire) runs for
        the oldest tick once more than ``pipeline_depth`` are in flight."""
        if (self._faults is not None
                and not self._faults.responsive(0, self._tick_count)):
            # injected stall: the tick elapses but the host serves
            # nothing — pending epochs stay queued, rings keep backlog
            self._tick_count += 1
            return 0
        self._tick_boundary()
        self._tick_count += 1
        self.telemetry.runtime_ticks += 1
        if self._mega is not None:
            # deferred mode: pop the host mirror now (authoritative FIFO
            # order / counters), run the compute on device at flush —
            # ``pipeline_depth`` is superseded by the scan window
            return self._mega.stage_tick()
        popped = [ring.pop(self.batch) for ring in self.rings]
        counts = [rows.shape[0] for rows, _ in popped]
        total = sum(counts)
        if total == 0:
            return 0
        t0 = time.perf_counter()
        if self.fanout == "loop":
            results = {}
            for q, (rows, _) in enumerate(popped):
                if counts[q] == 0:
                    continue
                results[q] = pipeline.packet_step(
                    self.bank, jnp.asarray(self._pad(rows)),
                    **self._step_kwargs())
        else:
            qstack = np.stack([self._pad(rows) for rows, _ in popped])
            res_all = self._vstep(self.bank, jnp.asarray(qstack))
            results = {
                q: pipeline.PacketResult(*(leaf[q] for leaf in res_all))
                for q in range(self.num_queues) if counts[q]
            }
        self._inflight.append(_InFlight(
            self._tick_count, popped, counts, results, self.bank, t0))
        while len(self._inflight) > self.pipeline_depth - 1:
            self._retire(self._inflight.popleft())
        return total

    def _retire(self, rec: _InFlight) -> None:
        """Pipeline stage 3: block on the tick's device work, then fold
        results into telemetry / audit / record and retire ring rows."""
        total = sum(rec.counts)
        for res in rec.results.values():
            res.scores.block_until_ready()
        now = time.perf_counter()
        # busy time must not double-count overlapping in-flight windows:
        # charge this tick only for the span since the previous retire
        # (identical to dispatch->retire when the pipeline is synchronous)
        start = (rec.t0 if self._last_retire_s is None
                 else max(rec.t0, self._last_retire_s))
        tick_s = now - start
        self._last_retire_s = now
        for q, res in rec.results.items():
            n = rec.counts[q]
            rows, ts = rec.popped[q]
            slots = np.asarray(res.slots)[:n]
            verdicts = np.asarray(res.verdicts)[:n]
            actions = np.asarray(res.actions)[:n]
            if self.on_retire is not None:
                self.on_retire(q, rows, slots, verdicts, actions, rec.tick)
            self.telemetry.record_tick(
                q, slots, verdicts, actions,
                latency_us=(now - ts) * 1e6,
                tick_s=tick_s * n / total,
            )
            self.rings[q].mark_completed(n)
            if self.audit:
                # audit against the bank version this tick was dispatched
                # with — a later epoch must not invalidate earlier work
                exact = pipeline.packet_step(
                    rec.bank, jnp.asarray(self._pad(rows)),
                    num_slots=self.num_slots, strategy="take",
                    backend=self.backend)
                bad = (np.asarray(exact.verdicts)[:n] != verdicts).sum()
                bad += (np.asarray(exact.slots)[:n] != slots).sum()
                self.telemetry.wrong_verdict += int(bad)
            if self._record:
                self.completed_seq[q].extend(int(s) for s in rows[:, SEQ_WORD])
                self.completed_verdicts[q].extend(bool(v) for v in verdicts)
                self.completed_slots[q].extend(int(s) for s in slots)
        self.telemetry.touch(now)
        if self.telemetry.has_sink:
            self.telemetry.emit_delta(
                tick=rec.tick, now=now,
                depths=[len(r) for r in self.rings])

    def retire_all(self) -> None:
        """Flush the pipeline: retire every in-flight tick (oldest first).
        In deferred mode this is the megastep flush point — the staged
        window runs on device and drains to telemetry/taps/recorder."""
        if self._mega is not None:
            self._mega.flush()
        while self._inflight:
            self._retire(self._inflight.popleft())
        if self.telemetry.has_sink:
            # flush counters with no retire to ride on (e.g. trailing
            # dispatch-edge drops) so the delta stream sums to snapshot()
            self.telemetry.emit_delta(tick=self._tick_count)

    def in_flight_rows(self) -> list[int]:
        """Rows popped but not yet retired, per queue (pipelined ticks,
        plus the staged-but-unflushed megastep window in deferred mode —
        conservation is checkable mid-window without forcing a flush)."""
        out = [0] * self.num_queues
        for rec in self._inflight:
            for q, n in enumerate(rec.counts):
                out[q] += n
        if self._mega is not None:
            for q, n in enumerate(self._mega.staged_rows()):
                out[q] += n
        return out

    def drain(self, max_ticks: int = 100_000) -> int:
        """Tick until every ring is empty, then flush the pipeline.
        Returns the number of rows served."""
        return drain_rings(self, max_ticks)

    # -- audit + reporting --------------------------------------------------

    def audit_conservation(self) -> dict:
        """Per-queue + aggregate packet conservation; must always hold —
        including mid-pipeline, where popped-but-unretired rows are
        accounted as ``in_flight``."""
        inflight = self.in_flight_rows()
        per_queue = [ring.conservation(in_flight=inflight[q])
                     for q, ring in enumerate(self.rings)]
        totals = {k: sum(c[k] for c in per_queue)
                  for k in ("offered", "admitted", "dropped", "completed",
                            "occupancy", "in_flight")}
        ok = all(c["producer_ok"] and c["consumer_ok"] for c in per_queue)
        return {"per_queue": per_queue, "totals": totals, "ok": ok,
                "wrong_verdict": self.telemetry.wrong_verdict}

    def snapshot(self) -> dict:
        """One-call runtime report: telemetry totals, conservation audit,
        configuration echo, and control-plane stats."""
        elapsed = (time.perf_counter() - self._t_start
                   if self._t_start is not None else None)
        out = self.telemetry.snapshot(elapsed_s=elapsed)
        out["conservation"] = self.audit_conservation()
        out["fanout"] = self.fanout
        out["strategy"] = self.strategy
        out["pipeline_depth"] = self.pipeline_depth
        out["policy"] = getattr(self.policy, "name", None)
        out["control"] = self.control.stats()
        return out
