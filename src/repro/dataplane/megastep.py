"""Device-resident megastep: N ticks inside ONE compiled scan (DESIGN.md §13).

The sequential runtime round-trips through Python for every tick —
dispatch, per-queue kernel launches, retire — so throughput *falls* as
queues scale.  This module keeps the hot-path state resident on device
and replays a whole *window* of ticks in one compiled program:

* ``DeviceState``: the flattened multi-queue ring pytree
  (`repro.dataplane.ring.device_rings`) is the scan carry and persists
  across flushes (donated into every call, so the ring buffer is
  updated in place, never copied).
* The ``lax.scan`` replays the window's ring traffic: each staged tick
  pushes its arrival bursts, pops up to ``batch`` rows FIFO from every
  ring, and *compacts* them queue-major into one ``(width, ...)`` slab.
  The scan moves rows, not verdicts — it emits only the three key words
  each popped row needs downstream (slot id, control word, first
  payload word), so a tick costs a handful of gathers.
* The forwarding math for the WHOLE window then runs as one batched
  launch after the scan: all queues, all ticks, one program.  It
  exploits a payload-structure invariant the host mirror verifies per
  flush: any two rows whose payload *suffix* (words 1..255) is
  identical share the suffix part of the XNOR-popcount, so the kernel
  computes each distinct ``(suffix, effective-slot)`` pair once and
  per-row work collapses to a single-word popcount plus the tiny dense
  head.  The decomposition is exact integer arithmetic — verdicts are
  bit-identical to the per-row path for ANY traffic; repeated flows
  just make it fast.
* Control epochs are applied eagerly to the host mirrors (so atomic
  apply, rollback, and the epoch log keep their exact semantics) and
  *also* serialized as ``DeviceDelta`` entries into a bounded epoch
  queue (`repro.control.plane.serialize_device_delta`).  At flush the
  delta params are stacked behind the window's base bank as an
  *extended bank* on device; every popped row carries the extended
  index of the bank version live at its tick, so mid-window SwapSlot
  transitions resolve per row with no in-scan weight mutation.
* Telemetry counters accumulate on device (scan carry + batched
  scatters); verdict/slot/action slabs come back shaped ``(T, width)``.
  Both drain to the Python side ONCE per flush: bulk counter fold
  (``Telemetry.record_window``), then one pass over the staged window
  for the obs/deploy taps and the trace recorder — per-megastep, not
  per-tick.

The host ``PacketRing`` mirror stays fully authoritative for counters,
timestamps, routing, and policy views: ``dispatch``/``tick`` stage the
work *and* run the deterministic host-side ring simulation, so every
host-visible return value is exact without a device sync.  The device
rings must reproduce the mirror's row flow bit-for-bit; the flush
asserts the two agree on per-queue pop counts.

Bit-exactness contract (the hypothesis property in
``tests/test_megastep.py``): verdicts, slots, actions, telemetry count
totals, and epoch apply ticks are identical to N sequential ``tick()``
calls.  Wall-clock attribution (``busy_s``, latency histograms, epoch
``apply_latency_us``) is measured at flush granularity instead and is
outside the contract.
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.control.plane import (DELTA_RETA, DELTA_SWAP,
                                 serialize_device_delta)
from repro.core import packet as pkt
from repro.dataplane import ring as ring_lib
from repro.dataplane.workloads.phases import SEQ_WORD
from repro.kernels import fused_forward as _fusedk
from repro.kernels import ref as _refk

#: Bounded on-device epoch queue depth per window.  The runtime flushes
#: the window before applying an epoch batch that would not fit, so the
#: queue can never overflow mid-transaction.
EPOCH_CAPACITY = 8

#: Fixed device RETA mirror length (tables are padded / truncated).
DEVICE_RETA_SIZE = 128

#: Shape quantization for the compiled-variant cache: burst capacity,
#: compaction width, scan length and suffix-table size round up to
#: these, so phase-constant traces reuse a handful of compiled programs
#: instead of one per flush.
_BURST_GRAIN = 64
_WIDTH_GRAIN = 32
_TICK_GRAIN = 8
_SUFFIX_GRAIN = 64

#: Word columns the non-audit scan emits per popped row: slot id,
#: control word, first payload word — everything the batched forward
#: needs that is not covered by the deduplicated payload suffix.
_KEY_COLS = (pkt.SLOT_WORD, pkt.CONTROL_WORD_LO, pkt.META_WORDS)

#: Fixed fold for the host-side suffix hash: an f64 dot over a fixed
#: sample of suffix columns (BLAS, ~16x cheaper than hashing all 255).
#: The hash only *accelerates* grouping — group membership is verified
#: by exact full-width comparison and falls back to a full
#: lexicographic unique, so a collision can never change results.
_HASH_COLS = np.linspace(0, pkt.PAYLOAD_WORDS - 2, 16).astype(np.intp)
_HASH_VEC = np.cos((_HASH_COLS + 1) * 0.7310585786300049) * 65537.0
_HASH_ES = 2654435761.000001


def _round_up(n: int, g: int) -> int:
    return ((int(n) + g - 1) // g) * g


@dataclasses.dataclass
class _Staged:
    """One staged (deferred) tick: the host mirror already popped its
    rows; the device replays the same push/pop/compute at flush."""
    tick: int                # runtime tick id (``_tick_count`` after bump)
    rows: np.ndarray         # (nb, words) arrival bursts since prior tick
    qids: np.ndarray         # (nb,) int32 queue id per burst row
    pops: list               # [(rows, ts)] per queue, host-mirror copies
    counts: list             # rows popped per queue


@functools.partial(
    jax.jit,
    static_argnames=("capacity", "width", "num_slots", "audit", "has_eps"),
    donate_argnums=(0,))
def _run_window(rings, bank, eps_params, xs, suffix, suffix_es, gid, *,
                capacity, width, num_slots, audit, has_eps):
    """The compiled megastep: scan the staged window's ring traffic,
    then run the whole window's forwarding math as one batch.

    ``rings`` is donated — the multi-queue ring buffer mutates in place
    across flushes.  ``eps_params`` is the stacked epoch-delta param
    queue (appended behind ``bank`` as the extended bank); ``xs.es``
    carries each row's extended-bank index so mid-window swaps resolve
    per row.  ``suffix``/``suffix_es``/``gid`` are the host-verified
    payload-suffix dedup table and per-row group ids; padded scan steps
    (``bt == 0``) and padded batch rows are masked by ``pvalid``.
    """
    num_queues = rings["head"].shape[0]
    k = num_slots
    if has_eps:
        bankx = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b]), bank, eps_params)
    else:
        bankx = bank

    def body(rings, x):
        if x["rows"].shape[0]:
            rings = ring_lib.device_push(rings, x["rows"], x["qids"],
                                         x["count"], capacity=capacity)
        # non-audit rings are already slimmed to the key columns, so the
        # pop is a full-row gather either way
        rings, popped, qq, pvalid, n = ring_lib.device_pop(
            rings, x["bt"], width, capacity=capacity)
        return rings, dict(rows=popped, qq=qq, pvalid=pvalid, n=n)

    rings, ys = jax.lax.scan(body, rings, xs)
    t_win = ys["qq"].shape[0]
    rows = ys["rows"].reshape(t_win * width, -1)
    qq = ys["qq"].reshape(-1)
    pvalid = ys["pvalid"].reshape(-1)
    es = xs["es"].reshape(-1)
    gid = gid.reshape(-1)
    if audit:
        slotw = rows[:, pkt.SLOT_WORD]
        ctrl = rows[:, pkt.CONTROL_WORD_LO]
        w0 = rows[:, pkt.META_WORDS]
    else:
        slotw, ctrl, w0 = rows[:, 0], rows[:, 1], rows[:, 2]
    slots = jnp.clip(slotw.astype(jnp.int32), 0, k - 1)

    # one batched forward for the whole window, suffix part deduplicated;
    # integer mismatch counts are split exactly: word 0 per row + shared
    # suffix per (suffix, extended-slot) group
    w1x, b1x, w2x, b2x = bankx["w1p"], bankx["b1"], bankx["w2"], bankx["b2"]
    d = w1x.shape[-1] * 32
    suf_mism = _refk.popcount32(
        suffix[:, None, :] ^ w1x[:, :, 1:][suffix_es]).sum(axis=-1)  # (U, H)
    mism0 = _refk.popcount32(w0[:, None] ^ w1x[:, :, 0][es])         # (N, H)
    mism = mism0 + suf_mism[gid]
    pre = (jnp.int32(d) - 2 * mism).astype(jnp.float32) + b1x[es]
    h = jnp.where(pre >= 0, 1.0, -1.0)
    y = jnp.einsum("bh,bch->bc", h, w2x[es]) + b2x[es]
    verd = y[:, 0] > 0.0
    acts = _fusedk.actions_ref(y, ctrl)

    wrong = jnp.int32(0)
    if audit:
        # exact reference: full per-row popcount against the same
        # extended-bank entry — no suffix sharing, no dedup table
        payload = rows[:, pkt.META_WORDS:]
        mism_e = _refk.popcount32(payload[:, None, :] ^ w1x[es]).sum(axis=-1)
        pre_e = (jnp.int32(d) - 2 * mism_e).astype(jnp.float32) + b1x[es]
        h_e = jnp.where(pre_e >= 0, 1.0, -1.0)
        y_e = jnp.einsum("bh,bch->bc", h_e, w2x[es]) + b2x[es]
        wrong = (((y_e[:, 0] > 0.0) != verd) & pvalid).sum(dtype=jnp.int32)

    pv = pvalid.astype(jnp.int32)
    ctr = dict(
        completed=ys["n"].sum(axis=0),
        served=(ys["n"] > 0).astype(jnp.int32).sum(axis=0),
        per_slot=jnp.zeros((num_queues, k), jnp.int32).at[qq, slots].add(pv),
        per_slot_mal=jnp.zeros((num_queues, k), jnp.int32).at[qq, slots].add(
            pv * verd.astype(jnp.int32)),
        actions=jnp.zeros((num_queues, 3), jnp.int32).at[qq, acts].add(pv),
        wrong=wrong,
    )
    ys_out = dict(verdicts=verd.reshape(t_win, width),
                  slots=slots.reshape(t_win, width),
                  actions=acts.reshape(t_win, width))
    return rings, ctr, ys_out


@functools.partial(jax.jit, static_argnames=("capacity",),
                   donate_argnums=(0,))
def _push_trailing(rings, rows, qids, count, *, capacity):
    """Push bursts staged after the window's last tick (flush with no
    following ``tick()`` — e.g. an audit right after a dispatch)."""
    return ring_lib.device_push(rings, rows, qids, count, capacity=capacity)


class MegastepEngine:
    """Deferred-execution engine behind ``DataplaneRuntime``.

    ``dispatch()``/``tick()`` stage work (and run the authoritative host
    ring simulation); ``flush()`` replays the window on device in one
    compiled program and drains results to telemetry, taps, and the
    trace recorder.  Flush triggers: the window reaching
    ``megastep_ticks`` staged ticks, ``retire_all()``, or an epoch
    batch that would overflow the bounded delta queue.
    """

    def __init__(self, runtime):
        rt = runtime
        self.rt = rt
        self.window = rt.megastep_ticks
        self.capacity = rt.rings[0].capacity
        self.words = rt.rings[0]._buf.shape[1]
        # Non-audit windows move only the key columns through the device
        # rings — the batched forward reads everything else from the
        # deduplicated suffix table — so the ring buffer and every staged
        # transfer shrink from 272 words/row to 3.  Audit windows keep
        # full rows: the exact re-score needs the whole payload on device.
        self.dev_words = self.words if rt.audit else len(_KEY_COLS)
        self.dev_rings = ring_lib.device_rings(
            rt.num_queues, self.capacity, packet_words=self.dev_words)
        self._reta_cache = None
        self.dev_reta = None
        self._sync_reta()
        self._steps: list[_Staged] = []
        self._pend_rows: list[np.ndarray] = []
        self._pend_qids: list[np.ndarray] = []
        self._deltas: list = []          # [(seq, DeviceDelta)]
        self._seq = 0
        self._window_bank = None         # bank version at window start
        self._window_pin = None          # donation pin on that buffer
        self._window_t0: float | None = None
        self._last_flush_s: float | None = None

    # -- staging (the runtime's dispatch/tick edge) --------------------------

    def stage_burst(self, rows: np.ndarray, qids: np.ndarray) -> None:
        """Record one routed arrival burst; the host rings already
        admitted it — the device replays the identical admission."""
        if rows.shape[0] == 0:
            return
        self._open_window()
        rows = np.asarray(rows, np.uint32)
        self._pend_rows.append(rows.copy() if self.rt.audit
                               else rows[:, list(_KEY_COLS)])
        self._pend_qids.append(np.asarray(qids, np.int32).copy())

    def stage_tick(self) -> int:
        """Stage one tick: pop the host mirror (authoritative counters /
        timestamps / FIFO order) and defer the device work.  Ticks that
        move no rows and carry no pending burst cost nothing — they are
        never staged, so drain loops do not pad the scan."""
        rt = self.rt
        popped = [ring.pop(rt.batch) for ring in rt.rings]
        counts = [rows.shape[0] for rows, _ in popped]
        total = sum(counts)
        if total == 0 and not self._pend_rows:
            return 0
        self._open_window()
        if self._pend_rows:
            rows = np.concatenate(self._pend_rows)
            qids = np.concatenate(self._pend_qids)
            self._pend_rows, self._pend_qids = [], []
        else:
            rows = np.zeros((0, self.dev_words), np.uint32)
            qids = np.zeros(0, np.int32)
        self._steps.append(_Staged(tick=rt._tick_count, rows=rows,
                                   qids=qids, pops=popped, counts=counts))
        if len(self._steps) >= self.window:
            self.flush()
        return total

    def prepare_epochs(self, n_commands: int) -> None:
        """Make room in the bounded device delta queue *before* an epoch
        batch applies, so a flush never lands mid-transaction."""
        if self._deltas and len(self._deltas) + n_commands > EPOCH_CAPACITY:
            self.flush()

    def stage_delta(self, cmd) -> None:
        """Serialize one just-applied command for the device epoch queue
        (called from ``_apply_command`` inside the epoch transaction)."""
        d = serialize_device_delta(cmd, step=len(self._steps),
                                   runtime=self.rt,
                                   reta_size=DEVICE_RETA_SIZE)
        if d is None:
            return
        if self._window_bank is None:
            # empty window: the next window re-feeds the (already
            # mutated) host bank, so only the RETA mirror needs syncing
            if d.kind == DELTA_RETA:
                self._sync_reta()
            return
        self._seq += 1
        self._deltas.append((self._seq, d))

    def delta_mark(self) -> int:
        """Rollback cookie for ``_control_state`` snapshots."""
        return self._seq

    def delta_rollback(self, mark: int) -> None:
        """Drop deltas staged after ``mark`` — a rolled-back epoch never
        reaches the device."""
        self._deltas = [(s, d) for s, d in self._deltas if s <= mark]

    def staged_rows(self) -> list[int]:
        """Popped-but-unflushed rows per queue (conservation in_flight)."""
        out = [0] * self.rt.num_queues
        for st in self._steps:
            for q, n in enumerate(st.counts):
                out[q] += n
        return out

    def _open_window(self) -> None:
        if self._window_bank is None:
            self._window_bank = self.rt.bank
            # pin the active buffer: a mid-window epoch flip would make it
            # the staging shadow, and staging donates unpinned buffers —
            # the window must keep computing against its opening version
            self._window_pin = self.rt.bank_pin()
            self._window_t0 = time.perf_counter()

    def _sync_reta(self) -> None:
        """Refresh the decorative device RETA mirror iff the host table
        changed (direct ``_install_reta`` callers bypass the deltas)."""
        table = np.asarray(self.rt.reta, np.int32)
        if self._reta_cache is not None and \
                np.array_equal(table, self._reta_cache):
            return
        self._reta_cache = table.copy()
        out = np.full(DEVICE_RETA_SIZE, -1, np.int32)
        n = min(DEVICE_RETA_SIZE, table.shape[0])
        out[:n] = table[:n]
        self.dev_reta = jnp.asarray(out)

    # -- flush ---------------------------------------------------------------

    def flush(self) -> None:
        """Run the staged window on device and drain everything host-side."""
        rt = self.rt
        steps, self._steps = self._steps, []
        deltas = [d for _, d in self._deltas]
        self._deltas = []
        if not steps:
            # queued deltas only exist alongside staged steps; with the
            # window empty the host mirrors already carry every epoch
            self._flush_trailing()
            self._close_window()
            return

        t_pad = min(_round_up(len(steps), _TICK_GRAIN), self.window)
        words = self.words
        k = rt.num_slots
        bmax = max(st.rows.shape[0] for st in steps)
        bmax = _round_up(bmax, _BURST_GRAIN) if bmax else 0
        width = max(_WIDTH_GRAIN,
                    _round_up(max(sum(st.counts) for st in steps),
                              _WIDTH_GRAIN))

        # per-step extended-bank view: cur[s] is the extended index of
        # slot s's live params (base bank, or K + delta index after a
        # mid-window SwapSlot)
        cur = np.arange(k, dtype=np.int32)
        cur_by_step = np.empty((len(steps), k), np.int32)
        di = 0
        for t in range(len(steps)):
            while di < len(deltas) and deltas[di].step <= t:
                if deltas[di].kind == DELTA_SWAP:
                    cur[deltas[di].slot] = k + di
                di += 1
            cur_by_step[t] = cur
        has_eps = any(d.kind == DELTA_SWAP for d in deltas)

        # exact suffix dedup over the window's popped rows, in device
        # compaction order (queue-major within each step)
        meta = pkt.META_WORDS
        chunks, es_chunks = [], []
        for t, st in enumerate(steps):
            cv = cur_by_step[t]
            for q in range(rt.num_queues):
                r = st.pops[q][0]
                if r.shape[0]:
                    chunks.append(r)
                    sl = np.clip(r[:, pkt.SLOT_WORD].astype(np.int64),
                                 0, k - 1)
                    es_chunks.append(cv[sl])
        gid_np = np.zeros((t_pad, width), np.int32)
        es_np = np.zeros((t_pad, width), np.int32)
        if chunks:
            allrows = np.concatenate(chunks)
            es_all = np.concatenate(es_chunks)
            suffix_all = allrows[:, meta + 1:]
            hsh = suffix_all[:, _HASH_COLS].astype(np.float64) @ _HASH_VEC \
                + es_all * _HASH_ES
            _, rep, inv = np.unique(hsh, return_index=True,
                                    return_inverse=True)
            agree = (es_all == es_all[rep][inv]).all() and \
                (suffix_all == suffix_all[rep[inv]]).all()
            if not agree:  # hash collision: exact lexicographic fallback
                key = np.concatenate(
                    [suffix_all, es_all[:, None].astype(np.uint32)], axis=1)
                _, rep, inv = np.unique(key, axis=0, return_index=True,
                                        return_inverse=True)
            suffix_u = suffix_all[rep]
            suffix_es_u = es_all[rep]
            off = 0
            for t, st in enumerate(steps):
                w_off = 0
                for q in range(rt.num_queues):
                    nq = st.counts[q]
                    if nq:
                        gid_np[t, w_off:w_off + nq] = inv[off:off + nq]
                        es_np[t, w_off:w_off + nq] = es_all[off:off + nq]
                        off += nq
                        w_off += nq
        else:
            suffix_u = np.zeros((0, words - meta - 1), np.uint32)
            suffix_es_u = np.zeros(0, np.int32)
        u_pad = _round_up(max(suffix_u.shape[0], 1), _SUFFIX_GRAIN)
        suffix_pad = np.zeros((u_pad, words - meta - 1), np.uint32)
        suffix_pad[:suffix_u.shape[0]] = suffix_u
        ses_pad = np.zeros(u_pad, np.int32)
        ses_pad[:suffix_es_u.shape[0]] = suffix_es_u

        # np.empty: rows at/beyond ``count`` scatter out-of-bounds in
        # device_push (mode="drop"), so the pad contents never land.
        # Non-audit windows stage only the key columns (dev_words == 3).
        rows = np.empty((t_pad, bmax, self.dev_words), np.uint32)
        qids = np.zeros((t_pad, bmax), np.int32)
        count = np.zeros(t_pad, np.int32)
        bt = np.zeros(t_pad, np.int32)
        for t, st in enumerate(steps):
            nb = st.rows.shape[0]
            rows[t, :nb] = st.rows
            qids[t, :nb] = st.qids
            count[t] = nb
            bt[t] = rt.batch
        xs = dict(rows=jnp.asarray(rows), qids=jnp.asarray(qids),
                  count=jnp.asarray(count), bt=jnp.asarray(bt),
                  es=jnp.asarray(es_np))

        eps_params = None
        if has_eps:
            leaves_t, treedef = jax.tree_util.tree_flatten(
                jax.tree_util.tree_map(
                    lambda l: np.zeros((EPOCH_CAPACITY,) + tuple(l.shape[1:]),
                                       np.asarray(l).dtype),
                    self._window_bank))
            for e, dlt in enumerate(deltas):
                if dlt.kind == DELTA_SWAP:
                    for lt, lp in zip(leaves_t,
                                      jax.tree_util.tree_leaves(dlt.params)):
                        lt[e] = np.asarray(lp)
            eps_params = jax.tree_util.tree_unflatten(
                treedef, [jnp.asarray(l) for l in leaves_t])

        self.dev_rings, ctr, ys = _run_window(
            self.dev_rings, self._window_bank, eps_params, xs,
            jnp.asarray(suffix_pad), jnp.asarray(ses_pad),
            jnp.asarray(gid_np),
            capacity=self.capacity, width=width, num_slots=k,
            audit=rt.audit, has_eps=has_eps)
        self._flush_trailing()
        self._drain(steps, ctr, ys)
        self._close_window()

    def _close_window(self) -> None:
        self.rt.bank_unpin(self._window_pin)
        self._window_pin = None
        self._window_bank = None
        self._window_t0 = None
        self._sync_reta()

    def _flush_trailing(self) -> None:
        if not self._pend_rows:
            return
        rows = np.concatenate(self._pend_rows)
        qids = np.concatenate(self._pend_qids)
        self._pend_rows, self._pend_qids = [], []
        nb = rows.shape[0]
        pad = _round_up(nb, _BURST_GRAIN)
        prows = np.zeros((pad, rows.shape[1]), np.uint32)
        prows[:nb] = rows
        pqids = np.zeros(pad, np.int32)
        pqids[:nb] = qids
        self.dev_rings = _push_trailing(
            self.dev_rings, jnp.asarray(prows), jnp.asarray(pqids),
            jnp.int32(nb), capacity=self.capacity)

    def _drain(self, steps, ctr, ys) -> None:
        """Once-per-megastep drain to the Python side: bulk counter
        fold, ring completion, obs/deploy taps, trace recorder."""
        rt = self.rt
        ctr = {k: np.asarray(v) for k, v in ctr.items()}
        completed = ctr["completed"]
        host = np.zeros(rt.num_queues, np.int64)
        for st in steps:
            host += np.asarray(st.counts, np.int64)
        if not np.array_equal(completed, host):
            raise RuntimeError(
                f"device ring divergence: device popped {completed.tolist()}"
                f" rows/queue, host mirror {host.tolist()}")
        now = time.perf_counter()
        start = (self._window_t0 if self._last_flush_s is None
                 else max(self._window_t0, self._last_flush_s))
        span = now - start
        self._last_flush_s = now
        total = int(completed.sum())
        for q in range(rt.num_queues):
            if not completed[q]:
                continue
            lat = np.concatenate(
                [st.pops[q][1] for st in steps if st.counts[q]])
            rt.telemetry.record_window(
                q, ticks=int(ctr["served"][q]),
                completed=int(completed[q]),
                per_slot_total=ctr["per_slot"][q],
                per_slot_malicious=ctr["per_slot_mal"][q],
                actions=ctr["actions"][q],
                latency_us=(now - lat) * 1e6,
                busy_s=span * int(completed[q]) / total)
            rt.rings[q].mark_completed(int(completed[q]))
        if rt.audit:
            rt.telemetry.wrong_verdict += int(ctr["wrong"])
        if rt.on_retire is not None or rt._record:
            verd = np.asarray(ys["verdicts"])
            slots = np.asarray(ys["slots"])
            acts = np.asarray(ys["actions"])
            for t, st in enumerate(steps):
                off = 0
                for q, n in enumerate(st.counts):
                    if not n:
                        continue
                    sl = slice(off, off + n)
                    off += n
                    if rt.on_retire is not None:
                        rt.on_retire(q, st.pops[q][0], slots[t, sl],
                                     verd[t, sl], acts[t, sl], st.tick)
                    if rt._record:
                        rt.completed_seq[q].extend(
                            int(s) for s in st.pops[q][0][:, SEQ_WORD])
                        rt.completed_verdicts[q].extend(
                            bool(v) for v in verd[t, sl])
                        rt.completed_slots[q].extend(
                            int(s) for s in slots[t, sl])
        rt.telemetry.touch(now)
        if rt.telemetry.has_sink:
            rt.telemetry.emit_delta(tick=steps[-1].tick, now=now,
                                    depths=[len(r) for r in rt.rings])
