"""Bounded per-queue packet rings with explicit conservation accounting.

The AF_XDP analogue: each hardware queue drains into a fixed-size UMEM
fill ring; when producers outrun the consumer the NIC tail-drops and the
drop is *counted*, never silent.  The ring is host-side NumPy (packets are
staged here before a tick moves a batch onto the device), FIFO within a
queue, and keeps four monotonic counters whose invariants the runtime
audits after every scenario:

    offered   == admitted + dropped          (at the producer edge)
    admitted  == completed + occupancy       (nothing vanishes in flight)

``push`` admits a burst prefix and tail-drops the suffix; ``pop`` returns
up to ``max_n`` rows in arrival order together with their enqueue
timestamps (for latency accounting); ``mark_completed`` is called by the
runtime once the popped rows have actually been processed, so a crash
between pop and completion shows up as an audit failure instead of a
silently shrinking packet count.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import packet as pkt


@dataclasses.dataclass
class RingCounters:
    offered: int = 0    # rows presented to push()
    admitted: int = 0   # rows accepted into the ring
    dropped: int = 0    # rows tail-dropped (ring full)
    completed: int = 0  # rows processed and retired by the runtime

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class PacketRing:
    """Bounded FIFO ring of fixed-format packet rows."""

    def __init__(self, capacity: int, *, packet_words: int = pkt.PACKET_WORDS):
        if capacity < 1:
            raise ValueError("ring capacity must be >= 1")
        self.capacity = int(capacity)
        self._buf = np.zeros((self.capacity, packet_words), np.uint32)
        self._ts = np.zeros(self.capacity, np.float64)
        self._head = 0  # next row to pop
        self._size = 0
        self.counters = RingCounters()

    def __len__(self) -> int:
        return self._size

    @property
    def free(self) -> int:
        return self.capacity - self._size

    def push(self, packets: np.ndarray, now: float = 0.0) -> int:
        """Admit a burst prefix in arrival order; tail-drop the rest.

        Returns the number of admitted rows (the first ``n`` of the burst).
        """
        packets = np.asarray(packets)
        n_offered = packets.shape[0]
        n = min(n_offered, self.free)
        c = self.counters
        c.offered += n_offered
        c.admitted += n
        c.dropped += n_offered - n
        tail = (self._head + self._size) % self.capacity
        first = min(n, self.capacity - tail)
        self._buf[tail : tail + first] = packets[:first]
        self._ts[tail : tail + first] = now
        if n > first:  # wrap
            self._buf[: n - first] = packets[first:n]
            self._ts[: n - first] = now
        self._size += n
        return n

    def pop(self, max_n: int) -> tuple[np.ndarray, np.ndarray]:
        """Dequeue up to ``max_n`` rows FIFO -> (packets, enqueue_ts) copies."""
        n = min(max_n, self._size)
        head = self._head
        if head + n <= self.capacity:  # contiguous: plain slice copies
            out = self._buf[head : head + n].copy()
            ts = self._ts[head : head + n].copy()
        else:
            idx = (head + np.arange(n)) % self.capacity
            out = self._buf[idx].copy()
            ts = self._ts[idx].copy()
        self._head = (head + n) % self.capacity
        self._size -= n
        return out, ts

    def mark_completed(self, n: int) -> None:
        self.counters.completed += int(n)

    def conservation(self, *, in_flight: int = 0) -> dict:
        """Counter snapshot + the two ring invariants (see module docstring).

        ``in_flight`` is rows the consumer has popped but not yet retired
        (the pipelined runtime's device stage); they extend the consumer
        invariant to ``admitted == completed + occupancy + in_flight`` so
        conservation is checkable at any instant, not just when drained.
        """
        c = self.counters
        return {
            **c.as_dict(),
            "occupancy": self._size,
            "in_flight": int(in_flight),
            "producer_ok": c.offered == c.admitted + c.dropped,
            "consumer_ok": c.admitted == c.completed + self._size + in_flight,
        }

    def ok(self) -> bool:
        s = self.conservation()
        return bool(s["producer_ok"] and s["consumer_ok"])


# ---------------------------------------------------------------------------
# Device-resident rings (the megastep's fast-path mirror, DESIGN.md §13)
# ---------------------------------------------------------------------------
#
# Pure-jnp ring ops over a flat pytree so the whole multi-queue ring state
# can live on device and evolve inside one compiled ``lax.scan``:
#
#     {"buf":  (Q * capacity, words) uint32,   flattened queue-major
#      "head": (Q,) int32,  "size": (Q,) int32}
#
# Semantics are bit-identical to ``PacketRing``: FIFO within a queue,
# burst-prefix admission, tail drop when full.  The host ``PacketRing``
# mirror stays authoritative for counters/timestamps; these ops only have
# to reproduce the *row content and order* the host mirror predicts — the
# runtime asserts the two agree on pop counts at every flush.

def device_rings(num_queues: int, capacity: int,
                 *, packet_words: int = pkt.PACKET_WORDS) -> dict:
    """Fresh empty device ring state pytree for ``num_queues`` rings."""
    import jax.numpy as jnp
    return {
        "buf": jnp.zeros((num_queues * capacity, packet_words), jnp.uint32),
        "head": jnp.zeros(num_queues, jnp.int32),
        "size": jnp.zeros(num_queues, jnp.int32),
    }


def device_push(rings: dict, rows, qids, count, *, capacity: int) -> dict:
    """Push a mixed-queue burst: ``rows[i]`` goes to ring ``qids[i]`` for
    ``i < count``; per-queue arrival order is burst order; each queue
    admits ``min(offered, free)`` and tail-drops the rest (identical to
    ``PacketRing.push`` run per queue on the burst's subsets).

    Traceable (fixed shapes); ``count`` may be a traced scalar.  Rows at
    and beyond ``count`` are ignored via an out-of-range scatter-drop.
    """
    import jax.numpy as jnp
    num_queues = rings["head"].shape[0]
    bmax = rows.shape[0]
    valid = jnp.arange(bmax, dtype=jnp.int32) < count
    onehot = ((qids[:, None] == jnp.arange(num_queues)[None, :])
              & valid[:, None])
    # rank of row i within its queue's subset of this burst
    rank = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1
    ri = jnp.take_along_axis(rank, qids[:, None], axis=1)[:, 0]
    offered = onehot.sum(axis=0, dtype=jnp.int32)
    free = jnp.int32(capacity) - rings["size"]
    admit = valid & (ri < free[qids])
    dest = (rings["head"][qids] + rings["size"][qids] + ri) % capacity
    flat = jnp.where(admit, qids * capacity + dest,
                     num_queues * capacity)            # OOB -> dropped
    buf = rings["buf"].at[flat].set(rows, mode="drop")
    size = rings["size"] + jnp.minimum(offered, jnp.maximum(free, 0))
    return {"buf": buf, "head": rings["head"], "size": size}


def device_pop(rings: dict, batch: int, width: int, *, capacity: int,
               cols: tuple | None = None):
    """Pop up to ``batch`` rows FIFO from every ring and *compact* the
    results queue-major into one ``(width, words)`` batch (no per-queue
    padding): row ``p`` of the output is row ``p - offset[q]`` of queue
    ``q``'s pop, where ``q`` is the queue whose range covers ``p``.

    Returns ``(rings', popped, qq, pvalid, n)`` with ``qq`` the per-row
    queue id, ``pvalid`` the compaction validity mask and ``n`` the (Q,)
    per-queue pop counts.  ``width`` must be static and >= the actual
    total pops (the caller sizes it from the host mirror); ``batch`` may
    be a traced scalar (the megastep gates padded scan steps with 0).
    ``cols`` (static) narrows the gather to those word columns — the
    megastep's fast path only needs the slot / control / first payload
    words per row, so it skips moving the other 269.
    """
    import jax.numpy as jnp
    num_queues = rings["head"].shape[0]
    n = jnp.minimum(rings["size"], jnp.asarray(batch, jnp.int32))  # (Q,)
    csum = jnp.cumsum(n)
    off = csum - n                                          # exclusive
    pos = jnp.arange(width, dtype=jnp.int32)
    qq = jnp.clip(jnp.searchsorted(csum, pos, side="right"),
                  0, num_queues - 1).astype(jnp.int32)
    pvalid = pos < csum[-1]
    rk = pos - off[qq]
    idx = qq * capacity + (rings["head"][qq]
                           + jnp.where(pvalid, rk, 0)) % capacity
    if cols is None:
        popped = rings["buf"][idx]
    else:
        popped = rings["buf"][idx[:, None],
                              jnp.asarray(cols, jnp.int32)[None, :]]
    head = (rings["head"] + n) % capacity
    out = {"buf": rings["buf"], "head": head, "size": rings["size"] - n}
    return out, popped, qq, pvalid, n
