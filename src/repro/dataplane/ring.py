"""Bounded per-queue packet rings with explicit conservation accounting.

The AF_XDP analogue: each hardware queue drains into a fixed-size UMEM
fill ring; when producers outrun the consumer the NIC tail-drops and the
drop is *counted*, never silent.  The ring is host-side NumPy (packets are
staged here before a tick moves a batch onto the device), FIFO within a
queue, and keeps four monotonic counters whose invariants the runtime
audits after every scenario:

    offered   == admitted + dropped          (at the producer edge)
    admitted  == completed + occupancy       (nothing vanishes in flight)

``push`` admits a burst prefix and tail-drops the suffix; ``pop`` returns
up to ``max_n`` rows in arrival order together with their enqueue
timestamps (for latency accounting); ``mark_completed`` is called by the
runtime once the popped rows have actually been processed, so a crash
between pop and completion shows up as an audit failure instead of a
silently shrinking packet count.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import packet as pkt


@dataclasses.dataclass
class RingCounters:
    offered: int = 0    # rows presented to push()
    admitted: int = 0   # rows accepted into the ring
    dropped: int = 0    # rows tail-dropped (ring full)
    completed: int = 0  # rows processed and retired by the runtime

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class PacketRing:
    """Bounded FIFO ring of fixed-format packet rows."""

    def __init__(self, capacity: int, *, packet_words: int = pkt.PACKET_WORDS):
        if capacity < 1:
            raise ValueError("ring capacity must be >= 1")
        self.capacity = int(capacity)
        self._buf = np.zeros((self.capacity, packet_words), np.uint32)
        self._ts = np.zeros(self.capacity, np.float64)
        self._head = 0  # next row to pop
        self._size = 0
        self.counters = RingCounters()

    def __len__(self) -> int:
        return self._size

    @property
    def free(self) -> int:
        return self.capacity - self._size

    def push(self, packets: np.ndarray, now: float = 0.0) -> int:
        """Admit a burst prefix in arrival order; tail-drop the rest.

        Returns the number of admitted rows (the first ``n`` of the burst).
        """
        packets = np.asarray(packets)
        n_offered = packets.shape[0]
        n = min(n_offered, self.free)
        c = self.counters
        c.offered += n_offered
        c.admitted += n
        c.dropped += n_offered - n
        tail = (self._head + self._size) % self.capacity
        first = min(n, self.capacity - tail)
        self._buf[tail : tail + first] = packets[:first]
        self._ts[tail : tail + first] = now
        if n > first:  # wrap
            self._buf[: n - first] = packets[first:n]
            self._ts[: n - first] = now
        self._size += n
        return n

    def pop(self, max_n: int) -> tuple[np.ndarray, np.ndarray]:
        """Dequeue up to ``max_n`` rows FIFO -> (packets, enqueue_ts) copies."""
        n = min(max_n, self._size)
        idx = (self._head + np.arange(n)) % self.capacity
        out = self._buf[idx].copy()
        ts = self._ts[idx].copy()
        self._head = (self._head + n) % self.capacity
        self._size -= n
        return out, ts

    def mark_completed(self, n: int) -> None:
        self.counters.completed += int(n)

    def conservation(self, *, in_flight: int = 0) -> dict:
        """Counter snapshot + the two ring invariants (see module docstring).

        ``in_flight`` is rows the consumer has popped but not yet retired
        (the pipelined runtime's device stage); they extend the consumer
        invariant to ``admitted == completed + occupancy + in_flight`` so
        conservation is checkable at any instant, not just when drained.
        """
        c = self.counters
        return {
            **c.as_dict(),
            "occupancy": self._size,
            "in_flight": int(in_flight),
            "producer_ok": c.offered == c.admitted + c.dropped,
            "consumer_ok": c.admitted == c.completed + self._size + in_flight,
        }

    def ok(self) -> bool:
        s = self.conservation()
        return bool(s["producer_ok"] and s["consumer_ok"])
