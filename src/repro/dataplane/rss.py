"""RSS-style deterministic flow dispatch (the NIC front of the data plane).

The paper's 1.894 Mpps AF_XDP stack relies on the NIC's receive-side
scaling: a Toeplitz hash over the flow tuple selects a hardware queue, so
packets of one flow always land on the same queue (per-flow ordering) while
flows spread across queues (aggregate throughput).  This module reproduces
that dispatch stage in software, bit-compatible with the classic Toeplitz
construction:

* the flow tuple lives in reg0 spare words 4..7 (16 B — src/dst address,
  ports, protocol as the traffic engine lays them out);
* ``toeplitz_hash`` runs the standard MSB-first sliding-window XOR over a
  secret key (default: the Microsoft reference RSS key), vectorized over
  the batch;
* the hash indexes a 128-entry indirection table (RETA) mapping hash LSBs
  to queue ids.  Link failover is a RETA rewrite (``failover_table``), not
  a rehash — exactly how real NIC drivers migrate traffic off a dead queue.

Everything here is host-side NumPy: dispatch happens before packets enter
the device rings, mirroring the hardware split.
"""

from __future__ import annotations

import functools

import numpy as np

# reg0 spare words carrying the flow tuple (see repro.core.packet: words
# 4..15 are padding/spare; the dataplane assigns 4..7 to the flow tuple).
FLOW_WORD_LO = 4
FLOW_WORDS = 4  # 16 bytes = 128 hash input bits
FLOW_BITS = FLOW_WORDS * 32

# Indirection table size (power of two, as in mlx5/ixgbe defaults).
RETA_SIZE = 128

# Microsoft reference RSS key (40 bytes); only the first
# ``FLOW_BITS/8 + 4`` bytes feed the 128-bit window sweep.
DEFAULT_KEY = bytes(
    (0x6D, 0x5A, 0x56, 0xDA, 0x25, 0x5B, 0x0E, 0xC2,
     0x41, 0x67, 0x25, 0x3D, 0x43, 0xA3, 0x8F, 0xB0,
     0xD0, 0xCA, 0x2B, 0xCB, 0xAE, 0x7B, 0x30, 0xB4,
     0x77, 0xCB, 0x2D, 0xA3, 0x80, 0x30, 0xF2, 0x0C,
     0x6A, 0x42, 0xB7, 0x3B, 0xBE, 0xAC, 0x01, 0xFA)
)


@functools.lru_cache(maxsize=8)
def _key_windows(key: bytes, n_bits: int) -> np.ndarray:
    """windows[j] = the 32-bit slice of ``key`` starting at bit j (MSB-first).

    Toeplitz is "XOR together the key windows at every set input bit"; the
    window table turns the per-bit shift loop into one vectorized select.
    """
    total_bits = len(key) * 8
    if total_bits < n_bits + 32:
        raise ValueError(
            f"key too short: {total_bits} bits for {n_bits} input bits")
    acc = int.from_bytes(key, "big")
    out = np.empty(n_bits, np.uint32)
    for j in range(n_bits):
        out[j] = (acc >> (total_bits - 32 - j)) & 0xFFFFFFFF
    return out


def toeplitz_hash(flow_words: np.ndarray, key: bytes = DEFAULT_KEY) -> np.ndarray:
    """Vectorized Toeplitz hash: (B, F) uint32 flow words -> (B,) uint32.

    Bit order matches the canonical definition: words are consumed
    big-endian, MSB first, so the result is reproducible against any
    reference implementation fed the same 16 input bytes.
    """
    fw = np.ascontiguousarray(np.asarray(flow_words, np.uint32))
    if fw.ndim == 1:
        fw = fw[None, :]
    n_bits = fw.shape[-1] * 32
    windows = _key_windows(key, n_bits)
    # explicit width: reshape(-1) is ambiguous for empty batches
    as_bytes = fw.astype(">u4").view(np.uint8).reshape(
        *fw.shape[:-1], fw.shape[-1] * 4)
    bits = np.unpackbits(as_bytes, axis=-1).astype(bool)  # (B, n_bits)
    return np.bitwise_xor.reduce(
        np.where(bits, windows, np.uint32(0)), axis=-1)


def flow_words_of(packets: np.ndarray) -> np.ndarray:
    """Extract the (B, 4) flow tuple words from raw packet rows."""
    return np.asarray(packets)[:, FLOW_WORD_LO : FLOW_WORD_LO + FLOW_WORDS]


def indirection_table(num_queues: int, size: int = RETA_SIZE) -> np.ndarray:
    """Default RETA: round-robin hash buckets over the live queues."""
    if num_queues < 1:
        raise ValueError("need at least one queue")
    if num_queues > size:
        raise ValueError(
            f"{num_queues} queues cannot all be reachable through a "
            f"{size}-entry RETA; raise size")
    return (np.arange(size) % num_queues).astype(np.int32)


def failover_table(
    reta: np.ndarray,
    failed_queues: tuple[int, ...],
    *,
    num_queues: int | None = None,
) -> np.ndarray:
    """Remap RETA entries off failed queues onto survivors (round-robin).

    Surviving entries keep their queue (flow affinity is preserved for
    unaffected flows); only buckets that pointed at a dead queue move.
    Survivors are the live queues of ``range(num_queues)`` when given;
    otherwise only queues currently referenced by the RETA are considered
    (a skewed RETA may then hide live-but-unreferenced queues).
    """
    reta = np.asarray(reta, np.int32).copy()
    failed = set(int(q) for q in failed_queues)
    pool = (set(range(num_queues)) if num_queues is not None
            else set(int(q) for q in reta))
    survivors = sorted(pool - failed)
    if not survivors:
        raise ValueError("failover would leave zero live queues")
    moved = np.nonzero(np.isin(reta, list(failed)))[0]
    for i, bucket in enumerate(moved):
        reta[bucket] = survivors[i % len(survivors)]
    return reta


def restore_table(
    num_queues: int,
    size: int = RETA_SIZE,
    failed: tuple[int, ...] | set | frozenset = (),
) -> np.ndarray:
    """The default round-robin RETA minus still-failed queues — the ONE
    RestoreQueues rebuild both the single-host runtime and the mesh use
    (the mesh passes its global queue count)."""
    base = indirection_table(num_queues, size)
    if failed:
        base = failover_table(base, tuple(sorted(failed)),
                              num_queues=num_queues)
    return base


def bucket_index(h: np.ndarray, reta_len: int) -> np.ndarray:
    """Hash -> RETA bucket: mask for the hardware-style power-of-two
    table; modulo keeps every bucket reachable for arbitrary sizes."""
    size = np.uint32(reta_len)
    return h & (size - 1) if reta_len & (reta_len - 1) == 0 else h % size


def queue_of(
    packets: np.ndarray,
    num_queues: int,
    *,
    key: bytes = DEFAULT_KEY,
    reta: np.ndarray | None = None,
) -> np.ndarray:
    """Full dispatch: flow tuple -> Toeplitz hash -> RETA -> queue id."""
    if reta is None:
        reta = indirection_table(num_queues)
    reta = np.asarray(reta, np.int32)
    h = toeplitz_hash(flow_words_of(packets), key)
    return reta[bucket_index(h, len(reta))]


# ---------------------------------------------------------------------------
# mesh (multi-host) RETA: buckets resolve to (host, queue) pairs
# ---------------------------------------------------------------------------
#
# A mesh RETA entry is a *global queue id* ``gid = host * Q + queue`` in
# host-major order.  Because the global id space is just a larger queue id
# space, every single-host RETA operation (round-robin default, affinity-
# preserving failover, bucket indexing) applies verbatim — the 1-host mesh
# table IS the single-host table, bit for bit, and cross-host failover
# inherits the exact never-remap-a-survivor guarantee of the single-host
# rewrite.


def global_queue_id(host, queue, num_queues: int):
    """(host, queue) -> global queue id, host-major."""
    return np.asarray(host, np.int64) * int(num_queues) + np.asarray(queue)


def split_host_queue(gids, num_queues: int):
    """Global queue ids -> (host, queue); inverse of ``global_queue_id``."""
    g = np.asarray(gids, np.int64)
    return g // int(num_queues), g % int(num_queues)


def mesh_indirection_table(
    num_hosts: int, num_queues: int, size: int = RETA_SIZE
) -> np.ndarray:
    """Default mesh RETA: round-robin buckets over host-major global ids.

    ``num_hosts=1`` degenerates to ``indirection_table(num_queues)``
    bit-for-bit — single-host is the 1-host mesh, not a special case.
    """
    if num_hosts < 1:
        raise ValueError("need at least one host")
    return indirection_table(num_hosts * num_queues, size)


def mesh_failover_table(
    reta: np.ndarray,
    failed_global: tuple[int, ...],
    *,
    num_hosts: int,
    num_queues: int,
) -> np.ndarray:
    """Remap mesh RETA buckets off dead (host, queue) pairs onto survivors.

    ``failed_global`` names dead pairs by global id (a whole dead host is
    its ``num_queues`` consecutive ids).  Buckets whose pair survives keep
    their exact global id — so a flow whose (host, queue) both survive is
    never remapped, exactly the single-host guarantee lifted to the mesh.
    """
    return failover_table(reta, tuple(failed_global),
                          num_queues=num_hosts * num_queues)


def mesh_queue_of(
    packets: np.ndarray,
    num_hosts: int,
    num_queues: int,
    *,
    key: bytes = DEFAULT_KEY,
    reta: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Full mesh dispatch: flow tuple -> hash -> mesh RETA -> (host, queue)."""
    if reta is None:
        reta = mesh_indirection_table(num_hosts, num_queues)
    gids = queue_of(packets, num_hosts * num_queues, key=key, reta=reta)
    return split_host_queue(gids, num_queues)
