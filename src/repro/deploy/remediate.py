"""Auto-remediation + deploy drivers: detector proposals become audited
online control epochs; retrain triggers become sampler -> trainer ->
canary pipelines.

``AutoRemediator`` polls an ``AnomalyDetector`` between ticks and acts on
its typed proposals (``launch.dataplane --auto-remediate``):

* ``ProgramReta`` / ``FailQueues`` — submitted directly as control
  epochs (same stage/apply/rollback path as any operator epoch).
* ``RetrainRequest`` (and its ``SwapSlot`` spec carrier) — fine-tune the
  named slot on the sampler's labeled reservoirs and roll the result out
  through a ``CanaryController``; the canary decides promote/rollback.

Every action appends to the runtime's ``deploy_log``, so the decision
trail rides the same epoch-log document operators already read
(``/epochs``, ``--epoch-log-json``).

``DeployDriver`` is a same-API facade (the ``TraceRecorder`` precedent)
that steps registered pilots (remediator / scheduled rollouts) after
every tick, including through drains, without touching ``workloads.play``.
Pilots should submit epochs through the *driver's* ``control`` so that a
wrapped ``TraceRecorder`` records deployment epochs into the trace.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.control.commands import FailQueues, ProgramReta, SwapSlot
from repro.deploy.canary import CanaryController, deploy_log_of
from repro.obs.anomaly import RetrainRequest


def corrupt_params(params: dict) -> dict:
    """Adversarial weights for forced-rollback demos: negating the output
    layer inverts every verdict while keeping the pytree structure (and
    thus epoch staging) identical."""
    return {**params, "w2": -jnp.asarray(params["w2"]),
            "b2": -jnp.asarray(params["b2"])}


def _proposal_key(prop) -> tuple:
    if isinstance(prop, RetrainRequest):
        return ("retrain", int(prop.slot), prop.reason)
    return (type(prop).__name__, repr(prop.describe()))


class AutoRemediator:
    """Detector proposals -> online epochs / retrain-canary pipelines."""

    def __init__(self, runtime, detector, *, sampler=None, trainer=None,
                 canary_kw: dict | None = None,
                 min_retrain_samples: int = 48, cooldown_ticks: int = 24,
                 max_actions: int = 8):
        self.runtime = runtime
        self.detector = detector
        self.sampler = sampler
        self.trainer = trainer
        self.canary_kw = dict(canary_kw or {})
        self.min_retrain_samples = int(min_retrain_samples)
        self.cooldown_ticks = int(cooldown_ticks)
        self.max_actions = int(max_actions)
        self.log = deploy_log_of(runtime)
        self.canary: CanaryController | None = None
        self.actions = 0
        self._acted: set[tuple] = set()
        self._last_action: int | None = None

    def step(self) -> None:
        rt = self.runtime
        if self.canary is not None and self.canary.step() is not None:
            self.canary = None
        self.detector.poll()
        tick = int(rt._tick_count)
        if self.actions >= self.max_actions:
            return
        if (self._last_action is not None
                and tick - self._last_action < self.cooldown_ticks):
            return
        for prop in self.detector.proposals():
            key = _proposal_key(prop)
            if key in self._acted:
                continue
            if isinstance(prop, (ProgramReta, FailQueues)):
                self._acted.add(key)
                epoch = rt.control.submit(prop)
                self.log.append({
                    "event": "auto_remediate", "tick": tick, "epoch": epoch,
                    "command": prop.describe(),
                    "reason": "detector proposal"})
                self._mark_action(tick)
                return
            if isinstance(prop, RetrainRequest):
                if self._retrain(prop, tick):
                    return
            # SwapSlot specs (params=None) are the RetrainRequest's
            # carrier — the retrain pipeline materializes the weights.

    def _retrain(self, prop: RetrainRequest, tick: int) -> bool:
        if (self.canary is not None or self.trainer is None
                or self.sampler is None):
            return False
        words, labels = self.sampler.training_batch()
        if labels.size < self.min_retrain_samples:
            return False
        self._acted.add(_proposal_key(prop))
        result = self.trainer.fine_tune(words, labels,
                                        extra={"reason": prop.reason})
        self.log.append({
            "event": "retrain", "tick": tick, "slot": int(prop.slot),
            "reason": prop.reason, "checkpoint": result.checkpoint_path,
            "metrics": {k: float(v) for k, v in result.metrics.items()}})
        kw = dict(self.canary_kw)
        kw.setdefault("target_slot", int(prop.slot))
        self.canary = CanaryController(self.runtime, self.sampler, **kw)
        self.canary.start(result.params, reason=f"retrain:{prop.reason}")
        self._mark_action(tick)
        return True

    def _mark_action(self, tick: int) -> None:
        self._last_action = tick
        self.actions += 1

    def flush(self) -> None:
        """End of traffic: force any baking canary to a terminal decision."""
        if self.canary is not None:
            self.canary.flush()
            self.canary = None


class ScheduledRollout:
    """Scripted fine-tune -> canary (demos / fig14 / ``--deploy-demo``):
    after ``warmup_ticks`` and enough labeled samples, fine-tune on the
    sampler's reservoirs and start one canary.  ``corrupt=True`` negates
    the trained output layer first, forcing the bake-window evaluation to
    roll the rollout back."""

    def __init__(self, runtime, sampler, trainer, *, target_slot: int = 0,
                 warmup_ticks: int = 24, min_samples: int = 48,
                 corrupt: bool = False, canary_kw: dict | None = None):
        self.runtime = runtime
        self.sampler = sampler
        self.trainer = trainer
        self.target_slot = int(target_slot)
        self.warmup_ticks = int(warmup_ticks)
        self.min_samples = int(min_samples)
        self.corrupt = bool(corrupt)
        self.canary_kw = dict(canary_kw or {})
        self.log = deploy_log_of(runtime)
        self.canary: CanaryController | None = None
        self.result = None

    def step(self) -> None:
        if self.canary is not None:
            self.canary.step()
            return
        rt = self.runtime
        if self.result is not None or rt._tick_count < self.warmup_ticks:
            return
        words, labels = self.sampler.training_batch()
        if labels.size < self.min_samples:
            return
        self.result = self.trainer.fine_tune(words, labels)
        params = self.result.params
        reason = "scheduled"
        if self.corrupt:
            params = corrupt_params(params)
            reason = "scheduled:corrupted"
        self.log.append({
            "event": "retrain", "tick": int(rt._tick_count),
            "slot": self.target_slot, "reason": reason,
            "checkpoint": self.result.checkpoint_path,
            "metrics": {k: float(v) for k, v in self.result.metrics.items()}})
        self.canary = CanaryController(
            rt, self.sampler, target_slot=self.target_slot, **self.canary_kw)
        self.canary.start(params, reason=reason)

    def flush(self) -> None:
        if self.canary is not None:
            self.canary.flush()

    @property
    def decision(self) -> dict | None:
        if self.canary is not None and self.canary.decisions:
            return self.canary.decisions[-1]
        return None


class DeployDriver:
    """Same-API facade that steps deploy pilots after every tick.

    Wraps a runtime, mesh, or ``TraceRecorder`` (``__getattr__``
    delegation, the recorder precedent); ``drain`` ticks through the
    facade so pilots keep stepping while rings empty, then hands the
    converged (empty) drain to the inner driver so a wrapped recorder
    still logs its drain step and flushes the pipeline.
    """

    def __init__(self, inner, *pilots):
        self._inner = inner
        self._pilots = list(pilots)

    def add(self, pilot) -> "DeployDriver":
        self._pilots.append(pilot)
        return self

    def dispatch(self, packets_np, now=None, **kw):
        return self._inner.dispatch(packets_np, now=now, **kw)

    def tick(self) -> int:
        n = self._inner.tick()
        for p in self._pilots:
            p.step()
        return n

    def drain(self, max_ticks: int = 100_000) -> int:
        done = 0
        for _ in range(max_ticks):
            n = self.tick()
            done += n
            if n == 0 and not self._backlog():
                return done + self._inner.drain(max_ticks)
        raise RuntimeError("drain did not converge")

    def flush_deploy(self) -> None:
        """End of run: force every pilot's pending canary to a decision."""
        for p in self._pilots:
            p.flush()

    def _backlog(self) -> bool:
        inner = self._inner
        shards = getattr(inner, "shards", None)
        if shards is not None:
            if any(len(r) for h, s in enumerate(shards)
                   if not inner.health.is_dead(h) for r in s.rings):
                return True
            return bool(inner._barrier_deferred)
        return any(len(r) for r in inner.rings)

    def __getattr__(self, name):
        return getattr(self._inner, name)
