"""Continuous deployment: sample live traffic, fine-tune BNN slot
models, roll out via canary ``SwapSlot`` epochs, auto-remediate.

The subsystem closes training -> checkpoint -> rollout -> verification
under live traffic (DESIGN.md §12): ``PacketSampler`` harvests labeled
examples off the retire/drop taps, ``OnlineTrainer`` fine-tunes and
checkpoints slot models, ``CanaryController`` stages/bakes/decides every
rollout as typed control epochs covered by ``continuity_audit()``, and
``AutoRemediator`` wires ``AnomalyDetector.proposals()`` into the same
gate (``launch.dataplane --auto-remediate``).
"""

from repro.deploy.canary import (CanaryController, bank_of, deploy_log_of,
                                 live_queues, paired_err, unwrap,
                                 wrong_verdict_total)
from repro.deploy.remediate import (AutoRemediator, DeployDriver,
                                    ScheduledRollout, corrupt_params)
from repro.deploy.sampler import (LabelOracle, PacketSampler, Reservoir,
                                  labeled_pool)
from repro.deploy.trainer import OnlineTrainer, TrainResult, words_to_pm1

__all__ = [
    "AutoRemediator", "CanaryController", "DeployDriver", "LabelOracle",
    "OnlineTrainer", "PacketSampler", "Reservoir", "ScheduledRollout",
    "TrainResult", "bank_of", "corrupt_params", "deploy_log_of",
    "labeled_pool", "live_queues", "paired_err", "unwrap", "words_to_pm1",
]
