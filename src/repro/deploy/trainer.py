"""Online fine-tuning of BNN slot models on live-sampled packets.

``OnlineTrainer`` takes a sampled labeled batch (payload words from a
``PacketSampler``), runs a bounded number of STE-SGD steps through the
existing training loop (``train.bnn._sgd_step``), packs the latents into
resident-slot format with ``executor.pack_real_weights`` (via
``bnn.pack_trained``), evaluates on a held-out slice, and commits every
fine-tune as an atomic checkpoint step (``checkpoint.store.save``) so a
rollout decision is always traceable to restorable weights.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.core import executor
from repro.data import packets as pk
from repro.train import bnn


def words_to_pm1(payload_words: np.ndarray) -> np.ndarray:
    """(N, 256) uint32 payload words -> (N, 8192) +-1 float32 bits."""
    words = np.ascontiguousarray(np.asarray(payload_words, dtype="<u4"))
    return pk.to_pm1_bits(words.view(np.uint8).reshape(words.shape[0], -1))


@dataclasses.dataclass
class TrainResult:
    params: dict                  # packed resident-slot weights
    latent: dict                  # real-valued latents (warm-start source)
    step: int                     # checkpoint step id
    metrics: dict                 # holdout precision/recall/f1/err + losses
    checkpoint_path: str | None
    train_us: float


class OnlineTrainer:
    """Bounded-step STE fine-tuner with atomic checkpoint commits."""

    def __init__(self, *, checkpoint_dir: str | None = None, steps: int = 48,
                 batch: int = 128, lr: float = 0.05, pos_weight: float = 2.0,
                 holdout_frac: float = 0.25, seed: int = 0,
                 keep_last: int | None = 4,
                 cfg: executor.BNNConfig = executor.H32):
        self.checkpoint_dir = checkpoint_dir
        self.steps = int(steps)
        self.batch = int(batch)
        self.lr = float(lr)
        self.pos_weight = float(pos_weight)
        self.holdout_frac = float(holdout_frac)
        self.seed = int(seed)
        self.keep_last = keep_last
        self.cfg = cfg
        self._step = 0

    def fine_tune(self, payload_words: np.ndarray, labels: np.ndarray, *,
                  warm_latent: dict | None = None,
                  extra: dict | None = None) -> TrainResult:
        t0 = time.perf_counter()
        payload_words = np.asarray(payload_words, np.uint32)
        labels = np.asarray(labels).astype(np.float32)
        n = payload_words.shape[0]
        if n < 2:
            raise ValueError(f"need >= 2 labeled samples, got {n}")
        rng = np.random.default_rng(self.seed + self._step)
        order = rng.permutation(n)
        n_hold = max(1, int(n * self.holdout_frac))
        hold, train = order[:n_hold], order[n_hold:]
        if train.size == 0:
            train = order

        x = jnp.asarray(words_to_pm1(payload_words[train]))
        y = jnp.asarray(labels[train])
        latent = (warm_latent if warm_latent is not None
                  else bnn.init_latent(
                      jax.random.PRNGKey(self.seed + self._step), self.cfg))
        losses = []
        bsz = min(self.batch, train.size)
        for _ in range(self.steps):
            idx = jnp.asarray(rng.integers(0, train.size, size=bsz))
            latent, loss = bnn._sgd_step(
                latent, x[idx], y[idx],
                pos_weight=self.pos_weight, lr=self.lr)
            losses.append(float(loss))

        params = bnn.pack_trained(latent, self.cfg)
        hold_labels = labels[hold].astype(np.int64)
        metrics = bnn.evaluate(params, payload_words[hold], hold_labels)
        metrics["err"] = (metrics["fp"] + metrics["fn"]) / max(n_hold, 1)
        metrics.update(samples=int(n), holdout=int(n_hold),
                       steps=self.steps, loss_first=losses[0],
                       loss_last=losses[-1])

        step, path = self._step, None
        if self.checkpoint_dir is not None:
            path = store.save(
                self.checkpoint_dir, step, latent,
                extra={"metrics": {k: float(v) for k, v in metrics.items()},
                       **(extra or {})},
                keep_last=self.keep_last)
        self._step += 1
        return TrainResult(params=params, latent=latent, step=step,
                           metrics=metrics, checkpoint_path=path,
                           train_us=(time.perf_counter() - t0) * 1e6)
