"""Telemetry-attached packet sampling: labeled reservoirs off the retire tap.

``PacketSampler`` hooks the runtime's ``on_retire`` / ``on_drop`` taps
(mesh: one hook per host shard) and harvests a bounded, uniformly-sampled
stream of labeled examples from live traffic — per-slot training
reservoirs (Algorithm R), a recent-window ring for canary bake-window
evaluation, and a drop reservoir for packets lost at the ring edge.  The
taps run on the host thread between device launches, so they do the bare
minimum inline: enqueue references to the already-copied retired batch
and return.  Subsampling to O(``per_tick``) rows, labeling, and
reservoir filing all happen in ``flush()`` — one vectorized pass over
the queued batches, run from the consumption APIs (``training_batch`` /
``window_since`` / ``stats`` / ``detach``) or when the queue hits its
``max_pending`` bound, never per tick (fig14 audits the
attached-vs-detached overhead at <= 5%).

Ground truth comes from a ``LabelOracle`` built over the workload's
labeled payload pool.  The trace renderer twists payload word 0 with a
per-packet nonce (``workloads.phases.render``), so oracle keys cover
payload words[1:] only; packets with payloads outside the pool (synthetic
regimes without a corpus) simply stay unlabeled and are counted, not
sampled.
"""

from __future__ import annotations

import numpy as np

from repro.core.packet import META_WORDS
from repro.data import packets as pk


def labeled_pool(samples_per_group: int = 512, seed: int = 0):
    """(pool_words (N,256) uint32, labels (N,) {0,1}) from the corpus."""
    xb, yb = pk.load_split("train", samples_per_group, seed)
    return pk.to_payload_words(xb), yb


class LabelOracle:
    """payload words -> ground-truth label for live traffic (-1 unknown).

    Rows are keyed by a vectorized 64-bit multiplicative hash over 32
    randomly chosen payload columns (word 0 excluded — it carries the
    renderer's nonce twist), resolved against a sorted key array with
    ``searchsorted``; a Python dict costs ~0.5 us/row just in the get
    loop — the whole fig14 overhead budget by itself.  A collision
    mislabeling a packet needs two payloads agreeing on 32 sampled words
    *and* a random-odd-multiplier checksum: ~N^2/2^64 for an N-row pool,
    negligible."""

    _HASH_SEED = 0x9E3779B97F4A7C15

    def __init__(self, pool_words: np.ndarray, labels: np.ndarray):
        pool = np.asarray(pool_words)
        rng = np.random.default_rng(self._HASH_SEED)
        k = min(32, pool.shape[1] - 1)
        self._cols = np.sort(rng.choice(np.arange(1, pool.shape[1]),
                                        size=k, replace=False))
        # odd multipliers: every sampled word stays information-bearing
        self._mult = rng.integers(0, 1 << 62, k, dtype=np.uint64) * 2 + 1
        keys = self._hash(pool)
        order = np.argsort(keys, kind="stable")
        self._keys = keys[order]
        self._vals = np.asarray(labels, np.int8)[order]

    @classmethod
    def from_corpus(cls, samples_per_group: int = 512, seed: int = 0):
        return cls(*labeled_pool(samples_per_group, seed))

    def __len__(self) -> int:
        return int(self._keys.size)

    def _hash(self, payload_words: np.ndarray) -> np.ndarray:
        sub = np.asarray(payload_words)[:, self._cols].astype(np.uint64)
        return (sub * self._mult).sum(axis=1, dtype=np.uint64)

    def lookup(self, payload_words: np.ndarray) -> np.ndarray:
        keys = self._hash(payload_words)
        if self._keys.size == 0:
            return np.full(keys.shape[0], -1, np.int8)
        pos = np.minimum(np.searchsorted(self._keys, keys),
                         self._keys.size - 1)
        return np.where(self._keys[pos] == keys, self._vals[pos],
                        np.int8(-1)).astype(np.int8)


class Reservoir:
    """Bounded uniform sample (Algorithm R) over an unbounded row stream."""

    def __init__(self, capacity: int, width: int,
                 rng: np.random.Generator | None = None):
        self.capacity = int(capacity)
        self.words = np.zeros((self.capacity, width), np.uint32)
        self.labels = np.full(self.capacity, -1, np.int8)
        self.verdicts = np.full(self.capacity, -1, np.int8)
        self.ticks = np.zeros(self.capacity, np.int64)
        self.count = 0
        self.seen = 0
        self._rng = rng if rng is not None else np.random.default_rng(0)

    def add(self, words, labels, verdicts, tick) -> None:
        """Batched Algorithm R: row i of the batch is stream position
        ``seen + i``; it replaces a uniformly drawn slot iff that draw
        lands under ``capacity`` (within-batch duplicate draws resolve
        newest-wins, which is itself a uniform choice).  ``tick`` may be
        a scalar or a per-row array."""
        n = int(words.shape[0])
        if n == 0:
            return
        start = self.seen
        self.seen += n
        vds = (np.full(n, -1, np.int8) if verdicts is None
               else np.asarray(verdicts))
        tks = np.broadcast_to(np.asarray(tick, np.int64), (n,))
        fill = min(self.capacity - self.count, n)
        if fill:
            dst = np.arange(self.count, self.count + fill)
            self._write(dst, words[:fill], labels[:fill], vds[:fill],
                        tks[:fill])
            self.count += fill
        if fill < n:
            src = np.arange(fill, n)
            j = self._rng.integers(0, start + src + 1)
            keep = j < self.capacity
            if keep.any():
                src = src[keep]
                self._write(j[keep], words[src], labels[src], vds[src],
                            tks[src])

    def _write(self, dst, words, labels, verdicts, ticks) -> None:
        self.words[dst] = words
        self.labels[dst] = labels
        self.verdicts[dst] = verdicts
        self.ticks[dst] = ticks

    def rows(self):
        """(words, labels, verdicts) of everything currently held."""
        n = self.count
        return self.words[:n], self.labels[:n], self.verdicts[:n]


class _Window:
    """Circular recent-sample ring keyed by tick (canary bake evaluation)."""

    def __init__(self, capacity: int, width: int):
        self.capacity = int(capacity)
        self.words = np.zeros((self.capacity, width), np.uint32)
        self.labels = np.full(self.capacity, -1, np.int8)
        self.verdicts = np.full(self.capacity, -1, np.int8)
        self.slots = np.zeros(self.capacity, np.int32)
        self.ticks = np.full(self.capacity, -1, np.int64)
        self._head = 0
        self.count = 0

    def add(self, words, labels, verdicts, slots, tick) -> None:
        n = words.shape[0]
        if n == 0:
            return
        tks = np.broadcast_to(np.asarray(tick, np.int64), (n,))
        if n > self.capacity:  # only the newest rows can survive anyway
            words, labels = words[-self.capacity:], labels[-self.capacity:]
            verdicts, slots = verdicts[-self.capacity:], slots[-self.capacity:]
            tks = tks[-self.capacity:]
            n = self.capacity
        idx = (self._head + np.arange(n)) % self.capacity
        self.words[idx] = words
        self.labels[idx] = labels
        self.verdicts[idx] = verdicts
        self.slots[idx] = slots
        self.ticks[idx] = tks
        self._head = (self._head + n) % self.capacity
        self.count = min(self.count + n, self.capacity)

    def since(self, tick: int):
        """(words, labels, verdicts, slots) sampled at tick >= ``tick``."""
        mask = self.ticks >= tick
        return (self.words[mask], self.labels[mask],
                self.verdicts[mask], self.slots[mask])


class PacketSampler:
    """Bounded labeled-example harvester attached to a running dataplane."""

    def __init__(self, oracle: LabelOracle | None = None, *,
                 num_slots: int, capacity: int = 1024,
                 window_capacity: int = 4096, per_tick: int = 32,
                 seed: int = 0, width: int = 256, max_pending: int = 256):
        self.oracle = oracle
        self.num_slots = int(num_slots)
        self.per_tick = int(per_tick)
        # bounded backlog of un-labeled batches (256 full 128-row batches
        # is ~36 MB held at peak; the arrays were already allocated by
        # the runtime — the queue only delays their release until flush,
        # and a consumer flush normally fires long before the bound does)
        self._pending: list = []        # (rows, slots, verdicts, tick)
        self._pending_drops: list = []  # payload words
        self._max_pending = int(max_pending)
        self._rng = np.random.default_rng(seed)
        self.reservoirs = [Reservoir(capacity, width, self._rng)
                           for _ in range(self.num_slots)]
        self.drop_reservoir = Reservoir(capacity, width, self._rng)
        self.window = _Window(window_capacity, width)
        self.seen = 0
        self.sampled = 0
        self.labeled = 0
        self.unknown = 0
        self.mispredicted = 0
        self.drops_seen = 0
        self.slot_mispredicts = np.zeros(self.num_slots, np.int64)
        self._attached: list = []

    # -- tap wiring ----------------------------------------------------------

    def attach(self, runtime) -> "PacketSampler":
        """Hook every shard's retire/drop taps; returns self."""
        shards = getattr(runtime, "shards", None) or [runtime]
        for host, sh in enumerate(shards):
            if sh.on_retire is not None or sh.on_drop is not None:
                raise RuntimeError(f"host {host} already has a sampler tap")
            sh.on_retire = self._make_retire(host)
            sh.on_drop = self._make_drop(host)
            self._attached.append(sh)
        return self

    def detach(self) -> None:
        for sh in self._attached:
            sh.on_retire = None
            sh.on_drop = None
        self._attached = []
        self.flush()

    def _make_retire(self, host: int):
        def tap(queue, rows, slots, verdicts, actions, tick):
            self._on_retire(rows, slots, verdicts, tick)
        return tap

    def _make_drop(self, host: int):
        def tap(queue, rows):
            self._on_drop(rows)
        return tap

    # -- ingestion (tick-path: enqueue references, nothing else) -------------
    #
    # The retire tap receives arrays the runtime just created and never
    # reuses (`ring.pop` copies out of the ring; slots/verdicts are fresh
    # device fetches), so the tap holds references and returns — no copy,
    # no RNG, no labeling.  The drop tap's rows are a view of the caller's
    # dispatch buffer, so it subsamples + copies before enqueueing.

    def _subsample(self, rows: np.ndarray) -> np.ndarray:
        """Indices of <= ``per_tick`` uniformly chosen rows.

        Without-replacement draw via argpartition over random keys: ~5 us
        for a 128-row batch, vs ~40 us for ``Generator.choice`` (which
        permutes the whole batch)."""
        n = rows.shape[0]
        if n <= self.per_tick:
            return np.arange(n)
        return np.argpartition(self._rng.random(n),
                               self.per_tick)[:self.per_tick]

    def _on_retire(self, rows, slots, verdicts, tick: int) -> None:
        n = rows.shape[0]
        self.seen += int(n)
        if n == 0:
            return
        if self.oracle is None:
            k = min(n, self.per_tick)
            self.sampled += k
            self.unknown += k
            return
        self._pending.append((rows, slots, verdicts, tick))
        if len(self._pending) >= self._max_pending:
            self.flush()

    def _on_drop(self, rows) -> None:
        n = rows.shape[0]
        self.drops_seen += int(n)
        if n == 0 or self.oracle is None:
            return
        idx = self._subsample(rows)
        self._pending_drops.append(rows[idx, META_WORDS:])
        if len(self._pending_drops) >= self._max_pending:
            self.flush()

    # -- deferred labeling (off the tick path, one vectorized pass) ----------

    def flush(self) -> None:
        """Subsample + label + file everything the taps enqueued."""
        if self._pending:
            batches, self._pending = self._pending, []
            rws, svs_l, vds_l, sizes, ticks = [], [], [], [], []
            for rows, slots, verdicts, tick in batches:
                if rows.shape[0] > self.per_tick:
                    idx = self._subsample(rows)
                    rows = rows[idx]
                    slots = np.asarray(slots)[idx]
                    verdicts = np.asarray(verdicts)[idx]
                rws.append(rows)
                svs_l.append(slots)
                vds_l.append(verdicts)
                sizes.append(rows.shape[0])
                ticks.append(tick)
            self.sampled += int(sum(sizes))
            words = np.concatenate(rws)[:, META_WORDS:]
            svs = np.concatenate(svs_l).astype(np.int32)
            vds = np.concatenate(vds_l).astype(np.int8)
            tks = np.repeat(np.asarray(ticks, np.int64), sizes)
            labels = self.oracle.lookup(words)
            known = labels >= 0
            nk = int(known.sum())
            self.labeled += nk
            self.unknown += int(labels.size - nk)
            mis = known & (vds != labels)
            self.mispredicted += int(mis.sum())
            np.add.at(self.slot_mispredicts, svs[mis] % self.num_slots, 1)
            if nk:
                kw, kl, kv = words[known], labels[known], vds[known]
                ks, kt = svs[known], tks[known]
                for s in np.unique(ks):
                    m = ks == s
                    self.reservoirs[int(s) % self.num_slots].add(
                        kw[m], kl[m], kv[m], kt[m])
                self.window.add(kw, kl, kv, ks, kt)
        if self._pending_drops:
            drops, self._pending_drops = self._pending_drops, []
            words = np.concatenate(drops)
            labels = self.oracle.lookup(words)
            known = labels >= 0
            if known.any():
                self.drop_reservoir.add(words[known], labels[known], None, 0)

    # -- consumption ---------------------------------------------------------

    def training_batch(self, slot: int | None = None,
                       include_drops: bool = True):
        """(payload_words, labels) pooled from the training reservoirs.

        ``slot=None`` pools every slot — labels are global (malicious or
        not), so any slot's traffic trains any slot model; dropped
        packets ride along as extra signal when ``include_drops``.
        """
        self.flush()
        parts = (self.reservoirs if slot is None
                 else [self.reservoirs[int(slot) % self.num_slots]])
        if include_drops:
            parts = list(parts) + [self.drop_reservoir]
        words = [r.words[:r.count] for r in parts if r.count]
        labels = [r.labels[:r.count] for r in parts if r.count]
        if not words:
            return (np.zeros((0, 256), np.uint32), np.zeros(0, np.int8))
        return np.concatenate(words), np.concatenate(labels)

    def window_since(self, tick: int):
        self.flush()
        return self.window.since(tick)

    def stats(self) -> dict:
        self.flush()
        return {
            "seen": self.seen, "sampled": self.sampled,
            "labeled": self.labeled, "unknown": self.unknown,
            "mispredicted": self.mispredicted,
            "drops_seen": self.drops_seen,
            "reservoir_rows": [r.count for r in self.reservoirs],
            "drop_rows": self.drop_reservoir.count,
            "window_rows": self.window.count,
        }
