"""Canary rollout: stage new weights on a canary subset, bake, decide.

``CanaryController`` rolls new slot weights out in three audited moves,
every one a typed control-plane epoch (visible in the epoch log, covered
by ``continuity_audit()``):

1. **start** — one epoch swaps the weights into a designated *canary
   slot* and reprograms a small bucket share of the RETA onto a canary
   queue (``ProgramReta``), so the new model serves real traffic without
   touching the incumbent slot.
2. **bake** — for ``bake_ticks`` ticks the controller watches the
   dataplane (wrong-verdict counter, ring-edge drop fraction) while the
   sampler accumulates labeled examples from the live window.
3. **decide** — a paired evaluation of new-vs-baseline weights on the
   bake window picks exactly one terminal outcome: *promote* (one epoch
   installs the weights in the target slot, restores the canary slot and
   the prior RETA) or *roll back* (one epoch restores both).  No samples,
   a quality regression, or any dataplane-health regression all roll
   back — the conservative default.

Every transition appends a decision record to ``runtime.deploy_log``
(surfaced by ``launch.dataplane`` and the ``/epochs`` endpoint via
``obs.spans.epoch_log_doc``).
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.control.commands import ProgramReta, SwapSlot
from repro.core import bank as bank_lib
from repro.core import executor


def unwrap(runtime):
    """Peel same-API facades (TraceRecorder ``_rt``, DeployDriver
    ``_inner``) down to the base runtime/mesh.  ``__dict__`` lookups so a
    facade's ``__getattr__`` delegation can't loop."""
    while True:
        inner = (runtime.__dict__.get("_inner")
                 or runtime.__dict__.get("_rt"))
        if inner is None:
            return runtime
        runtime = inner


def deploy_log_of(runtime) -> list:
    """The runtime's deployment decision log (created on first use).

    Always stored on the *base* runtime so the one epoch-log serializer
    (``obs.spans.epoch_log_doc``) finds it regardless of which facade a
    controller was handed.
    """
    base = unwrap(runtime)
    log = base.__dict__.get("deploy_log")
    if log is None:
        log = []
        base.deploy_log = log
    return log


def bank_of(runtime):
    """Resident bank of a runtime or mesh facade (slots are global)."""
    bank = getattr(runtime, "bank", None)
    return bank if bank is not None else runtime.shards[0].bank


def wrong_verdict_total(runtime) -> int:
    shards = getattr(runtime, "shards", None) or [runtime]
    return sum(int(s.telemetry.wrong_verdict) for s in shards)


def live_queues(runtime) -> list[int]:
    """Global ids of queues not administratively failed."""
    shards = getattr(runtime, "shards", None)
    if shards is None:
        return [q for q in range(runtime.num_queues)
                if q not in runtime.failed_queues]
    qph = runtime.num_queues_per_host
    return [h * qph + q for h, s in enumerate(shards)
            for q in range(qph) if q not in s.failed_queues]


def paired_err(params, payload_words: np.ndarray, labels: np.ndarray) -> float:
    """Misclassification rate of packed ``params`` on labeled payloads."""
    scores = np.asarray(
        executor.forward(params, jnp.asarray(payload_words))[:, 0])
    return float(((scores > 0) != (np.asarray(labels) == 1)).mean())


class CanaryController:
    """One in-flight canary rollout; terminal state is exactly one of
    ``promoted`` / ``rolled_back`` (``flush()`` forces the decision when
    traffic ends mid-bake, so a canary can never dangle)."""

    IDLE, BAKING = "idle", "baking"

    def __init__(self, runtime, sampler=None, *, target_slot: int = 0,
                 canary_slot: int | None = None, canary_share: float = 0.125,
                 bake_ticks: int = 16, tolerance: float = 0.02,
                 min_samples: int = 24, drop_tolerance: float = 0.10):
        num_slots = runtime.num_slots
        if num_slots < 2:
            raise ValueError("canary rollout needs >= 2 resident slots")
        self.target_slot = int(target_slot)
        self.canary_slot = (int(canary_slot) if canary_slot is not None
                            else (self.target_slot + 1) % num_slots)
        if self.canary_slot == self.target_slot:
            raise ValueError("canary slot must differ from target slot")
        if not 0 < canary_share <= 0.5:
            raise ValueError("canary_share must be in (0, 0.5]")
        self.runtime = runtime
        self.sampler = sampler
        self.canary_share = float(canary_share)
        self.bake_ticks = int(bake_ticks)
        self.tolerance = float(tolerance)
        self.min_samples = int(min_samples)
        self.drop_tolerance = float(drop_tolerance)
        self.log = deploy_log_of(runtime)
        self.decisions: list[dict] = []   # terminal records only
        self.state = self.IDLE

    # -- lifecycle -----------------------------------------------------------

    def start(self, params, *, baseline=None, reason: str = "manual") -> int:
        """Stage ``params`` on the canary slot + steered bucket share;
        returns the epoch id of the canary_start transition."""
        if self.state != self.IDLE:
            raise RuntimeError("a canary is already baking")
        rt = self.runtime
        bank = bank_of(rt)
        self._params = params
        self._baseline = (baseline if baseline is not None
                          else bank_lib.select_slot(bank, self.target_slot))
        self._old_canary = bank_lib.select_slot(bank, self.canary_slot)
        self._prior_reta = np.asarray(rt.reta, np.int32).copy()
        live = live_queues(rt) or [0]
        canary_queue = live[-1]
        steered = self._prior_reta.copy()
        n_steer = max(1, int(round(len(steered) * self.canary_share)))
        buckets = np.linspace(0, len(steered) - 1, n_steer).astype(np.int64)
        steered[buckets] = canary_queue

        self._tick0 = int(rt._tick_count)
        self._t0 = time.perf_counter()
        self._wv0 = wrong_verdict_total(rt)
        totals = rt.audit_conservation()["totals"]
        self._drop0, self._offered0 = totals["dropped"], totals["offered"]

        epoch = rt.control.submit(
            SwapSlot(self.canary_slot, params),
            ProgramReta(tuple(int(q) for q in steered)))
        rt.flush_control()
        self.state = self.BAKING
        self._log("canary_start", epoch=epoch, reason=reason, metrics={
            "share": self.canary_share, "bake_ticks": self.bake_ticks,
            "canary_queue": int(canary_queue), "steered_buckets": int(n_steer),
        })
        return epoch

    def step(self) -> dict | None:
        """Advance the bake clock; returns the terminal decision record
        once the window closes, else None.  Call after each tick."""
        if self.state != self.BAKING:
            return None
        if self.runtime._tick_count - self._tick0 < self.bake_ticks:
            return None
        return self._decide()

    def flush(self) -> dict | None:
        """Force the decision now (end of traffic)."""
        if self.state == self.BAKING:
            return self._decide()
        return None

    # -- decision ------------------------------------------------------------

    def _decide(self) -> dict:
        rt = self.runtime
        metrics: dict = {"bake_window_ticks":
                         int(rt._tick_count - self._tick0)}
        wv_delta = wrong_verdict_total(rt) - self._wv0
        totals = rt.audit_conservation()["totals"]
        offered = totals["offered"] - self._offered0
        drop_frac = (totals["dropped"] - self._drop0) / max(offered, 1)
        metrics.update(wrong_verdict_delta=int(wv_delta),
                       drop_frac=round(float(drop_frac), 4))

        if self.sampler is not None:
            words, labels, _verdicts, _slots = \
                self.sampler.window_since(self._tick0)
        else:
            words = np.zeros((0, 256), np.uint32)
            labels = np.zeros(0, np.int8)
        metrics["bake_samples"] = int(labels.size)

        promote, reason = False, ""
        if wv_delta > 0:
            reason = f"wrong verdicts during bake ({wv_delta})"
        elif drop_frac > self.drop_tolerance:
            reason = f"drop fraction {drop_frac:.3f} > {self.drop_tolerance}"
        elif labels.size < self.min_samples:
            reason = (f"insufficient labeled bake samples "
                      f"({labels.size} < {self.min_samples})")
        else:
            err_new = paired_err(self._params, words, labels)
            err_base = paired_err(self._baseline, words, labels)
            metrics.update(err_new=round(err_new, 4),
                           err_base=round(err_base, 4))
            if err_new <= err_base + self.tolerance:
                promote = True
                reason = (f"err {err_new:.3f} <= baseline {err_base:.3f} "
                          f"+ tol {self.tolerance}")
            else:
                reason = (f"err {err_new:.3f} > baseline {err_base:.3f} "
                          f"+ tol {self.tolerance}")

        prior_reta = ProgramReta(tuple(int(q) for q in self._prior_reta))
        if promote:
            epoch = rt.control.submit(
                SwapSlot(self.target_slot, self._params),
                SwapSlot(self.canary_slot, self._old_canary),
                prior_reta)
        else:
            epoch = rt.control.submit(
                SwapSlot(self.canary_slot, self._old_canary),
                prior_reta)
        rt.flush_control()
        self.state = self.IDLE
        metrics["elapsed_us"] = round((time.perf_counter() - self._t0) * 1e6, 1)
        rec = self._log("promoted" if promote else "rolled_back",
                        epoch=epoch, reason=reason, metrics=metrics)
        self.decisions.append(rec)
        return rec

    def _log(self, event: str, *, epoch=None, reason: str = "",
             metrics: dict | None = None) -> dict:
        rec = {"event": event, "tick": int(self.runtime._tick_count),
               "slot": self.target_slot, "canary_slot": self.canary_slot,
               "epoch": epoch, "reason": reason, "metrics": metrics or {}}
        self.log.append(rec)
        return rec
