"""``TelemetryStream`` — the bounded, tailable observability event bus.

One stream carries every event class the runtime emits:

* ``kind="delta"``  — per-queue counter increments (``telemetry.emit_delta``)
* ``kind="epoch"``  — control-plane epoch spans (``ControlPlane.on_record``)
* ``kind="health"`` — host health-lease transitions (``HealthMonitor``)

Events are plain dicts.  The stream is a fixed-capacity ring: producers
never block, old events fall off the head, and every event gets a
monotonic stream id (``sid``).  Subscribers poll with ``tail(cursor)``
— an absolute-sid cursor, so a slow subscriber that falls off the ring
observes a gap (``dropped_events`` grows) instead of corrupt data.
A ``threading.Lock`` guards the deque because the HTTP server tails from
its own threads while the run loop pushes.
"""

from __future__ import annotations

import collections
import threading


class TelemetryStream:
    """Fixed-capacity multi-subscriber event ring."""

    def __init__(self, capacity: int = 8192):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._buf: collections.deque = collections.deque(maxlen=capacity)
        self._lock = threading.Lock()
        self.next_sid = 0        # sid the NEXT pushed event will get
        self.dropped_events = 0  # events evicted by ring overflow

    def push(self, event: dict) -> int:
        """Stamp ``event`` with a stream id and append it; returns the sid."""
        with self._lock:
            sid = self.next_sid
            event["sid"] = sid
            if len(self._buf) == self.capacity:
                self.dropped_events += 1
            self._buf.append(event)
            self.next_sid = sid + 1
            return sid

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)

    def tail(self, cursor: int, limit: int = 1024) -> tuple[list[dict], int]:
        """Events with ``sid >= cursor`` (up to ``limit``) and the cursor
        to pass next time.  A cursor that has fallen off the ring resumes
        at the oldest retained event — the gap is visible as a jump in
        ``sid``."""
        with self._lock:
            if not self._buf:
                return [], max(cursor, self.next_sid)
            oldest = self._buf[0]["sid"]
            start = max(cursor, oldest)
            first = start - oldest
            out = []
            for i in range(first, len(self._buf)):
                if len(out) >= limit:
                    break
                out.append(self._buf[i])
            new_cursor = out[-1]["sid"] + 1 if out else start
            return out, new_cursor

    def latest(self, n: int = 64) -> list[dict]:
        """The most recent ``n`` events (oldest first)."""
        with self._lock:
            if n >= len(self._buf):
                return list(self._buf)
            return list(self._buf)[-n:]

    def snapshot_stats(self) -> dict:
        with self._lock:
            return {"capacity": self.capacity, "buffered": len(self._buf),
                    "next_sid": self.next_sid,
                    "dropped_events": self.dropped_events}


def attach(runtime, stream: TelemetryStream) -> None:
    """Wire a ``DataplaneRuntime`` or ``MeshDataplane`` into ``stream``.

    Per-shard telemetry sinks (delta events are tagged with their host),
    the control plane's epoch-record tap, and — on meshes — the health
    monitor's transition tap all publish into the one stream.  Idempotent
    in effect: re-attaching replaces previous taps.
    """
    from repro.obs import spans

    shards = getattr(runtime, "shards", None)
    if shards is None:
        runtime.telemetry.attach_sink(
            lambda ev: stream.push(dict(ev, host=0)))
    else:
        for h, shard in enumerate(shards):
            shard.telemetry.attach_sink(
                lambda ev, h=h: stream.push(dict(ev, host=h)))
    runtime.control.on_record = \
        lambda rec: stream.push(spans.epoch_event(rec))
    health = getattr(runtime, "health", None)
    if health is not None:
        health.on_transition = \
            lambda tr: stream.push(spans.health_event(tr))


def detach(runtime) -> None:
    """Undo ``attach``: stop all emission into the stream."""
    shards = getattr(runtime, "shards", None)
    for shard in ([runtime] if shards is None else shards):
        shard.telemetry.detach_sink()
    runtime.control.on_record = None
    health = getattr(runtime, "health", None)
    if health is not None:
        health.on_transition = None
