"""Streaming observability for the data plane (DESIGN.md §11).

Three layers, each usable alone:

* ``stream``  — ``TelemetryStream``, the bounded in-process event bus the
  runtime publishes telemetry deltas, epoch spans, and health-lease
  transitions onto; ``attach`` wires any runtime or mesh into one.
* ``server``  — ``ObsServer``, a threaded stdlib HTTP server exposing
  live mesh state as JSON + SSE, plus the self-contained
  ``dashboard.html`` renderer.
* ``anomaly`` — ``AnomalyDetector``, rolling-window detectors over the
  delta stream that classify the active traffic regime and *propose*
  (never auto-apply) typed command epochs.
"""

from repro.obs.anomaly import AnomalyDetector  # noqa: F401
from repro.obs.spans import epoch_event, epoch_log_doc, health_event  # noqa: F401
from repro.obs.stream import TelemetryStream, attach, detach  # noqa: F401
