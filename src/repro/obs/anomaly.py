"""Rolling-window anomaly detection over the telemetry delta stream.

``AnomalyDetector`` tails a ``TelemetryStream`` (``poll()``) and folds
delta / epoch / health events into per-tick features: aggregate load,
ring-edge drops, per-queue completion shares, slot-mix windows, the
epoch timeline, and health-lease transitions.  Five detectors run over
those features —

* **pps spike**              — load >= ``spike_factor`` x trailing median
* **drop-rate surge**        — window drop fraction >= ``drop_frac``
* **slot-mix shift**         — windowed mix L1-distance >= ``mix_shift``
* **queue silence**          — backlogged queue completing nothing
* **barrier-latency inflation** — epoch latency >> median, or any
  degraded/rollback commit

— and a decision tree over the same features classifies the active
traffic regime with one of the 11 corpus names (``generators.
REGIME_NAMES``) or ``"steady"``.  The detector only ever *proposes*
typed command epochs (``proposals()``); nothing is auto-applied — an
operator (or a later learned agent) decides.  ``timeline`` records the
rolling classification after every processed tick, so replay tests and
fig13 can measure detect-latency-in-ticks.
"""

from __future__ import annotations

import dataclasses
import statistics

import numpy as np

from repro.control.commands import FailQueues, ProgramReta, SwapSlot
from repro.dataplane import rss
from repro.obs.stream import TelemetryStream


@dataclasses.dataclass(frozen=True)
class RetrainRequest:
    """Deploy-plane proposal — NOT a control command (never staged on the
    control plane): fine-tune the named slot's model on freshly sampled
    traffic and roll the result out through a canary ``SwapSlot`` epoch
    (``repro.deploy``).  Carries the same ``describe()`` surface as the
    typed commands so dashboards serialize proposals uniformly."""
    slot: int
    reason: str
    tick: int

    def describe(self) -> dict:
        return {"cmd": "retrain", "slot": int(self.slot),
                "reason": self.reason, "tick": int(self.tick)}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One detector firing at one tick."""
    detector: str
    tick: int
    detail: dict

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class AnomalyDetector:
    """Streaming regime classifier + epoch proposer (read-only)."""

    def __init__(self, stream: TelemetryStream, *, num_queues: int,
                 num_slots: int, hosts: int = 1,
                 reta_size: int = rss.RETA_SIZE,
                 window: int = 8, spike_factor: float = 3.0,
                 drop_frac: float = 0.05, mix_shift: float = 0.5,
                 silence_ticks: int = 6, latency_factor: float = 8.0,
                 dominance_share: float = 0.55, dominance_run: int = 10):
        self.stream = stream
        self.num_queues = num_queues      # global (all hosts)
        self.num_slots = num_slots
        self.hosts = hosts
        self.queues_per_host = num_queues // max(hosts, 1)
        self.reta_size = reta_size
        self.window = window
        self.spike_factor = spike_factor
        self.drop_frac = drop_frac
        self.mix_shift = mix_shift
        self.silence_ticks = silence_ticks
        self.latency_factor = latency_factor
        self.dominance_share = dominance_share
        self.dominance_run = dominance_run
        self._cursor = 0
        # per-tick features (tick -> value); ticks with no traffic are absent
        self.load: dict[int, int] = {}
        self.drops: dict[int, int] = {}
        self.qload: dict[int, dict[int, int]] = {}
        self.slot_mix: dict[int, np.ndarray] = {}
        self.depth: dict[int, int] = {}           # gid -> last seen depth
        self._last_completion: dict[int, int] = {}  # gid -> last active tick
        self.epochs: list[dict] = []
        self.health: list[dict] = []
        self.findings: list[Finding] = []
        self.timeline: list[tuple[int, str]] = []  # (tick, rolling regime)
        self._fired: set[tuple] = set()
        self._seen_tick: int | None = None

    # -- ingestion -----------------------------------------------------------

    def poll(self) -> int:
        """Consume pending stream events; returns how many were processed.

        The rolling classification is re-run every time the observed
        tick advances, so ``timeline`` records what the detector would
        have said live at each tick (detect-latency is measured off it).
        """
        events, self._cursor = self.stream.tail(self._cursor, limit=1 << 20)
        for ev in events:
            kind = ev.get("kind")
            if kind == "delta":
                t = ev["tick"]
                if self._seen_tick is not None and t > self._seen_tick:
                    self.timeline.append(
                        (self._seen_tick, self._classify()[0]))
                self._seen_tick = (t if self._seen_tick is None
                                   else max(self._seen_tick, t))
                self._ingest_delta(ev)
            elif kind == "epoch":
                self._ingest_epoch(ev)
            elif kind == "health":
                self.health.append(ev)
        return len(events)

    def _gid(self, ev: dict, queue: int) -> int:
        return ev.get("host", 0) * self.queues_per_host + queue

    def _ingest_delta(self, ev: dict) -> None:
        t = ev["tick"]
        for q in ev["queues"]:
            gid = self._gid(ev, q["queue"])
            done = q["completed"]
            self.load[t] = self.load.get(t, 0) + done
            self.drops[t] = self.drops.get(t, 0) + q["dropped"]
            if done:
                self.qload.setdefault(t, {})
                self.qload[t][gid] = self.qload[t].get(gid, 0) + done
                self._last_completion[gid] = t
            if "depth" in q:
                self.depth[gid] = q["depth"]
            mix = self.slot_mix.setdefault(
                t, np.zeros(self.num_slots, np.int64))
            mix += np.asarray(q["per_slot"], np.int64)
        self._run_detectors(t)

    def _ingest_epoch(self, ev: dict) -> None:
        kinds = [c["cmd"] for c in ev["commands"]]
        fail = sorted(set(q for c in ev["commands"] if c["cmd"] == "fail_queues"
                          for q in c["queues"]))
        self.epochs.append({
            "epoch": ev["epoch"], "tick": ev["applied_tick"],
            "kinds": kinds, "fail": fail,
            "commit_mode": ev["commit_mode"],
            "latency_us": ev["apply_latency_us"],
        })
        self._detect_latency_inflation(self.epochs[-1])

    # -- rolling detectors ---------------------------------------------------

    def _fire(self, detector: str, tick: int, **detail) -> None:
        key = (detector, tick, tuple(sorted(detail.get("queues", ()))))
        if key in self._fired:
            return
        self._fired.add(key)
        self.findings.append(Finding(detector, tick, detail))

    def _trailing(self, series: dict[int, int], tick: int) -> list[int]:
        ticks = sorted(t for t in series if t < tick)[-self.window:]
        return [series[t] for t in ticks]

    def _run_detectors(self, tick: int) -> None:
        load = self.load.get(tick, 0)
        prior = self._trailing(self.load, tick)
        if len(prior) >= 3:
            med = statistics.median(prior)
            if med > 0 and load >= self.spike_factor * med:
                self._fire("pps_spike", tick, load=load, median=med)
        window_ticks = sorted(t for t in self.load if t <= tick)[-self.window:]
        w_load = sum(self.load[t] for t in window_ticks)
        w_drops = sum(self.drops.get(t, 0) for t in window_ticks)
        if w_load + w_drops > 0 and w_drops >= self.drop_frac * (w_load + w_drops):
            self._fire("drop_surge", tick, dropped=w_drops, window_load=w_load)
        self._detect_mix_shift(tick)
        self._detect_silence(tick)

    def _detect_mix_shift(self, tick: int) -> None:
        ticks = sorted(t for t in self.slot_mix if t <= tick)
        if len(ticks) < 2 * self.window:
            return
        zero = np.zeros(self.num_slots, np.float64)
        cur = sum((self.slot_mix[t] for t in ticks[-self.window:]), zero)
        prev = sum((self.slot_mix[t] for t in
                    ticks[-2 * self.window:-self.window]), zero.copy())
        if cur.sum() == 0 or prev.sum() == 0:
            return
        l1 = float(np.abs(cur / cur.sum() - prev / prev.sum()).sum())
        if l1 >= self.mix_shift:
            self._fire("slot_mix_shift", tick, l1=round(l1, 3))

    def _detect_silence(self, tick: int) -> None:
        failed = set(q for e in self.epochs for q in e["fail"])
        silent = [gid for gid, d in self.depth.items()
                  if d > 0 and gid not in failed
                  and tick - self._last_completion.get(gid, tick) >=
                  self.silence_ticks]
        if silent:
            self._fire("queue_silence", tick, queues=tuple(sorted(silent)))

    def _detect_latency_inflation(self, epoch: dict) -> None:
        if epoch["commit_mode"] in ("degraded", "rollback"):
            self._fire("barrier_latency_inflation", epoch["tick"] or 0,
                       commit_mode=epoch["commit_mode"], epoch=epoch["epoch"])
            return
        prior = [e["latency_us"] for e in self.epochs[:-1]
                 if e["latency_us"] is not None]
        lat = epoch["latency_us"]
        if lat is not None and len(prior) >= 3:
            med = statistics.median(prior)
            if med > 0 and lat >= self.latency_factor * med:
                self._fire("barrier_latency_inflation", epoch["tick"] or 0,
                           latency_us=lat, median_us=med)

    # -- regime features -----------------------------------------------------

    def _spike_regions(self) -> list[tuple[int, int, int]]:
        """Maximal (onset, end, peak) regions around trailing-median
        spikes, extended while load stays >= half the region peak."""
        spikes = sorted({f.tick for f in self.findings
                         if f.detector == "pps_spike"})
        ticks = sorted(self.load)
        regions: list[tuple[int, int, int]] = []
        for s in spikes:
            if regions and regions[-1][0] <= s <= regions[-1][1]:
                continue
            region = [t for t in ticks if t >= s]
            peak = self.load[s]
            end = s
            for t in region:
                if self.load[t] >= 0.5 * peak:
                    peak = max(peak, self.load[t])
                    end = t
                else:
                    break
            regions.append((s, end, peak))
        return regions

    def _dominance_run(self) -> tuple[int, int | None]:
        """Longest run of consecutive active ticks where one queue owns
        >= ``dominance_share`` of completions; returns (length, gid)."""
        best, best_gid = 0, None
        run, run_gid, prev_t = 0, None, None
        for t in sorted(self.qload):
            total = sum(self.qload[t].values())
            gid, top = max(self.qload[t].items(), key=lambda kv: kv[1])
            dominated = total >= 32 and top >= self.dominance_share * total
            contiguous = prev_t is None or t - prev_t <= 2
            if dominated and gid == run_gid and contiguous:
                run += 1
            elif dominated:
                run, run_gid = 1, gid
            else:
                run, run_gid = 0, None
            if run > best:
                best, best_gid = run, run_gid
            prev_t = t
        return best, best_gid

    def _host_group(self, queues: list[int]) -> int | None:
        """The host whose full queue set ``queues`` is, if any."""
        if self.hosts < 2 or not queues:
            return None
        h = queues[0] // self.queues_per_host
        group = set(range(h * self.queues_per_host,
                          (h + 1) * self.queues_per_host))
        return h if set(queues) == group else None

    def _epoch_burst_rate(self) -> float:
        """Max applied-epoch count in any ``window`` consecutive ticks,
        normalized by the window."""
        ticks = sorted(e["tick"] for e in self.epochs
                       if e["tick"] is not None)
        if not ticks:
            return 0.0
        best = max(sum(1 for t in ticks if lo <= t < lo + self.window)
                   for lo in ticks)
        return best / self.window

    # -- classification ------------------------------------------------------

    def classify(self) -> dict:
        """Name the active regime from everything ingested so far."""
        regime, evidence = self._classify()
        return {"regime": regime, "evidence": evidence,
                "findings": len(self.findings)}

    def _classify(self) -> tuple[str, dict]:
        deaths = [h for h in self.health if h["to"] == "dead"]
        if deaths:
            t_dead = deaths[0]["tick"]
            rejoined = any(h["to"] in ("recovering", "healthy")
                           and h["tick"] > t_dead for h in self.health)
            if rejoined:
                return "barrier-straggler", {"dead_at": t_dead,
                                             "rejoined": True}
            return "crash-mid-commit", {"dead_at": t_dead, "rejoined": False}

        fail_epochs = [e for e in self.epochs
                       if e["fail"] and e["tick"] is not None]
        rate = self._epoch_burst_rate()
        if not fail_epochs and rate >= 0.75:
            return "slot-thrash", {"epoch_burst_rate": rate}

        spikes = self._spike_regions()
        if fail_epochs:
            sets = [set(e["fail"]) for e in fail_epochs]
            if len(sets) >= 2 and any(
                    a < b for a, b in zip(sets, sets[1:])):
                return "cascading-failover", {
                    "fail_sets": [sorted(s) for s in sets]}
            host = self._host_group(fail_epochs[0]["fail"])
            if host is not None:
                return "chaos-host-failover", {"host": host}
            t_fail = fail_epochs[0]["tick"]
            in_spike = any(lo <= t_fail <= hi + 1 for lo, hi, _ in spikes)
            if in_spike:
                return "chaos-queue-surge", {
                    "fail_tick": t_fail, "spikes": spikes}
            return "emergency", {"fail_tick": t_fail}

        run, gid = self._dominance_run()
        if run >= self.dominance_run:
            return "elephant-skew", {"dominant_queue": gid, "run": run}
        # a flash crowd is a TRANSIENT: the elevated region rises and
        # falls within ~one window (a diurnal ramp or a multi-phase file
        # load also trips the trailing-median test, but stays elevated)
        transient = [s for s in spikes if s[1] - s[0] <= self.window + 2]
        if transient:
            return "flash-crowd", {"spikes": transient}

        shape = self._load_shape()
        if shape is not None:
            return "diurnal", shape
        levels = self._load_levels()
        if len(levels) >= 3:
            return "file-replay", {"levels": levels}
        return "steady", {}

    def _load_shape(self) -> dict | None:
        """Rise-and-fall (diurnal) shape: peak in the middle, both ends
        well below it."""
        ticks = sorted(self.load)
        if len(ticks) < 3 * self.window:
            return None
        loads = [self.load[t] for t in ticks]
        n = len(loads)
        q = max(1, n // 4)
        head, tail = statistics.mean(loads[:q]), statistics.mean(loads[-q:])
        peak = max(loads)
        peak_at = loads.index(peak) / n
        if (head <= 0.6 * peak and tail <= 0.6 * peak
                and 0.2 <= peak_at <= 0.85):
            return {"peak": peak, "head": head, "tail": tail,
                    "peak_at": round(peak_at, 2)}
        return None

    def _load_levels(self) -> list[int]:
        """Distinct sustained load plateaus (log2-bucketed)."""
        counts: dict[int, int] = {}
        for v in self.load.values():
            if v >= 8:
                b = int(np.log2(v))
                counts[b] = counts.get(b, 0) + 1
        return sorted(b for b, c in counts.items() if c >= 2)

    # -- outputs -------------------------------------------------------------

    def detect_tick(self) -> int | None:
        """First tick of the stable suffix of the rolling classification
        (== the final regime); None when nothing was observed."""
        if self._seen_tick is None:
            return None
        final = self._classify()[0]
        tick = self._seen_tick
        for t, regime in reversed(self.timeline):
            if regime != final:
                break
            tick = t
        return tick

    def proposals(self) -> list:
        """Typed command epochs the detector would submit — NEVER applied
        here; the caller stages them (``_validate_command``) or shows an
        operator."""
        out = []
        regime = self.classify()["regime"]
        run, gid = self._dominance_run()
        if regime == "elephant-skew" and gid is not None:
            out.append(ProgramReta(tuple(
                self._rebalanced_reta(gid).tolist())))
        silent = sorted({q for f in self.findings
                         if f.detector == "queue_silence"
                         for q in f.detail["queues"]})
        if silent:
            out.append(FailQueues(tuple(silent)))
        # model-quality regimes: a shifted slot mix or a sustained drop
        # surge (routing skew already handled above) means the resident
        # model no longer matches the traffic — propose a retrain of the
        # dominant slot plus the SwapSlot that would carry it.  The
        # SwapSlot is a *spec* (params=None, the trace-format convention):
        # the deploy plane materializes freshly trained weights before it
        # can stage (`phases.materialize_command` in tests).
        slot = self._dominant_slot()
        if slot is not None:
            shifts = [f for f in self.findings
                      if f.detector == "slot_mix_shift"]
            surges = [f for f in self.findings if f.detector == "drop_surge"]
            if shifts:
                out.append(SwapSlot(slot, None))
                out.append(RetrainRequest(slot, "slot_mix_shift",
                                          shifts[-1].tick))
            elif surges and regime != "elephant-skew":
                out.append(SwapSlot(slot, None))
                out.append(RetrainRequest(slot, "drop_surge",
                                          surges[-1].tick))
        return out

    def _dominant_slot(self) -> int | None:
        """The slot carrying the most completions over the last window."""
        ticks = sorted(self.slot_mix)[-self.window:]
        if not ticks:
            return None
        mix = sum((self.slot_mix[t] for t in ticks),
                  np.zeros(self.num_slots, np.int64))
        return int(mix.argmax()) if mix.sum() else None

    def _rebalanced_reta(self, hot: int) -> np.ndarray:
        """Round-robin RETA with half the hot queue's buckets re-dealt to
        the other queues — the skew-relief rebalance."""
        reta = rss.indirection_table(self.num_queues, self.reta_size)
        others = [q for q in range(self.num_queues) if q != hot]
        if not others:
            return reta
        hot_buckets = np.flatnonzero(reta == hot)
        for i, b in enumerate(hot_buckets[::2]):
            reta[b] = others[i % len(others)]
        return reta
