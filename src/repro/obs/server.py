"""Live dashboard API over a running data plane (stdlib-only).

``ObsServer`` wraps a ``ThreadingHTTPServer`` around one runtime (or
mesh) + its ``TelemetryStream``:

* ``GET /``        — the self-contained ``dashboard.html`` renderer
* ``GET /metrics`` — live per-queue pps / drops / ring depth / slot mix
  plus runtime shape, event counters, control stats, health states
* ``GET /epochs``  — the machine-readable epoch log (``spans.epoch_log_doc``
  — the same serializer ``--epoch-log-json`` writes)
* ``GET /anomaly`` — detector classification, findings, proposed epochs
* ``GET /stream``  — Server-Sent Events tail of the telemetry stream
  (``?cursor=N`` resumes; events are the raw stream dicts)
* ``GET /healthz`` — liveness probe for smoke tests

The server threads only ever *read* run-loop state: per-queue counters
come from folding the delta stream (``_Aggregator``), never from walking
live telemetry, and the run loop never blocks on a subscriber.
"""

from __future__ import annotations

import json
import os
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

import numpy as np

from repro.obs import spans
from repro.obs.stream import TelemetryStream

_DASHBOARD = os.path.join(os.path.dirname(__file__), "dashboard.html")
#: wall-clock span the /metrics pps gauges average over
RATE_WINDOW_S = 2.0


def _json_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    return str(o)


class _Aggregator:
    """Folds the delta stream into cumulative per-queue state + a short
    rate window; consumed lazily from server threads under a lock."""

    def __init__(self, stream: TelemetryStream, *, num_queues: int,
                 queues_per_host: int, num_slots: int):
        self._stream = stream
        self._cursor = 0
        self._lock = threading.Lock()
        self.num_queues = num_queues
        self.queues_per_host = queues_per_host
        self.completed = np.zeros(num_queues, np.int64)
        self.dropped = np.zeros(num_queues, np.int64)
        self.per_slot = np.zeros((num_queues, num_slots), np.int64)
        self.actions = np.zeros((num_queues, 3), np.int64)
        self.depth = np.zeros(num_queues, np.int64)
        self.events: dict[str, int] = {}
        self.last_tick = 0
        self.epochs_seen = 0
        self.health_last: dict[int, str] = {}
        self._rate: list[tuple[float, np.ndarray]] = []  # (t_s, d_completed)

    def refresh(self) -> None:
        with self._lock:
            events, self._cursor = self._stream.tail(self._cursor,
                                                     limit=1 << 20)
            for ev in events:
                kind = ev.get("kind")
                if kind == "delta":
                    self._fold_delta(ev)
                elif kind == "epoch":
                    self.epochs_seen += 1
                elif kind == "health":
                    self.health_last[ev["host"]] = ev["to"]

    def _fold_delta(self, ev: dict) -> None:
        base = ev.get("host", 0) * self.queues_per_host
        burst = np.zeros(self.num_queues, np.int64)
        for q in ev["queues"]:
            gid = base + q["queue"]
            self.completed[gid] += q["completed"]
            self.dropped[gid] += q["dropped"]
            self.per_slot[gid] += np.asarray(q["per_slot"], np.int64)
            self.actions[gid] += np.asarray(q["actions"], np.int64)
            if "depth" in q:
                self.depth[gid] = q["depth"]
            burst[gid] = q["completed"]
        self.last_tick = max(self.last_tick, ev["tick"])
        for name, d in ev.get("events", {}).items():
            self.events[name] = self.events.get(name, 0) + d
        t = ev.get("t_s") or time.perf_counter()
        self._rate.append((t, burst))
        cutoff = t - RATE_WINDOW_S
        while len(self._rate) > 1 and self._rate[0][0] < cutoff:
            self._rate.pop(0)

    def metrics(self) -> dict:
        self.refresh()
        with self._lock:
            if len(self._rate) >= 2:
                span = max(self._rate[-1][0] - self._rate[0][0], 1e-9)
                pps = sum(b for _, b in self._rate[1:]) / span
            else:
                pps = np.zeros(self.num_queues)
            queues = []
            for gid in range(self.num_queues):
                queues.append({
                    "gid": gid,
                    "host": gid // self.queues_per_host,
                    "queue": gid % self.queues_per_host,
                    "completed": int(self.completed[gid]),
                    "dropped": int(self.dropped[gid]),
                    "depth": int(self.depth[gid]),
                    "pps": float(pps[gid]),
                    "per_slot": self.per_slot[gid].tolist(),
                    "actions": {"forward": int(self.actions[gid][0]),
                                "drop": int(self.actions[gid][1]),
                                "flag": int(self.actions[gid][2])},
                })
            slot_tot = self.per_slot.sum(axis=0)
            return {
                "tick": self.last_tick,
                "queues": queues,
                "totals": {"completed": int(self.completed.sum()),
                           "dropped": int(self.dropped.sum()),
                           "pps": float(pps.sum())},
                "slot_mix": slot_tot.tolist(),
                "events": dict(self.events),
                "epochs_seen": self.epochs_seen,
                "health": dict(self.health_last),
            }


class ObsServer:
    """Threaded HTTP observer for one runtime; start() returns at once."""

    def __init__(self, runtime, stream: TelemetryStream, *,
                 host: str = "127.0.0.1", port: int = 0, detector=None):
        self.runtime = runtime
        self.stream = stream
        self.detector = detector
        qph = getattr(runtime, "queues_per_host",
                      getattr(runtime, "num_queues_per_host",
                              runtime.num_queues))
        self.shape = {
            "hosts": getattr(runtime, "hosts", 1),
            "queues_per_host": qph,
            "num_queues": runtime.num_queues,
            "num_slots": getattr(runtime, "num_slots", None),
            "strategy": getattr(runtime, "strategy", None),
            "pipeline_depth": getattr(runtime, "pipeline_depth", None),
        }
        self.agg = _Aggregator(
            stream, num_queues=runtime.num_queues, queues_per_host=qph,
            num_slots=self.shape["num_slots"] or 1)
        self._det_lock = threading.Lock()
        self._stopping = threading.Event()
        self._httpd = ThreadingHTTPServer((host, port), self._handler())
        self._httpd.daemon_threads = True
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread: threading.Thread | None = None

    # -- endpoint payloads ---------------------------------------------------

    def metrics_doc(self) -> dict:
        doc = {"t_s": time.time(), "shape": self.shape,
               **self.agg.metrics(),
               "stream": self.stream.snapshot_stats()}
        try:
            doc["control"] = self.runtime.control.stats()
        except Exception:
            pass
        health = getattr(self.runtime, "health", None)
        if health is not None:
            try:
                doc["health_states"] = health.snapshot()["hosts"]
            except Exception:
                pass
        return doc

    def epochs_doc(self) -> dict:
        return spans.epoch_log_doc(self.runtime)

    def anomaly_doc(self) -> dict:
        if self.detector is None:
            return {"enabled": False}
        with self._det_lock:
            self.detector.poll()
            doc = self.detector.classify()
            doc.update({
                "enabled": True,
                "detect_tick": self.detector.detect_tick(),
                "findings": [f.as_dict()
                             for f in self.detector.findings[-64:]],
                "proposals": [c.describe()
                              for c in self.detector.proposals()],
            })
        return doc

    # -- plumbing ------------------------------------------------------------

    def _handler(self):
        server = self

        class Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"

            def log_message(self, *a):  # quiet by default
                pass

            def _send_json(self, doc, code=200):
                body = json.dumps(doc, default=_json_default).encode()
                self.send_response(code)
                self.send_header("Content-Type", "application/json")
                self.send_header("Access-Control-Allow-Origin", "*")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):
                url = urlparse(self.path)
                try:
                    if url.path in ("/", "/dashboard", "/dashboard.html"):
                        self._send_file(_DASHBOARD, "text/html")
                    elif url.path == "/metrics":
                        self._send_json(server.metrics_doc())
                    elif url.path == "/epochs":
                        self._send_json(server.epochs_doc())
                    elif url.path == "/anomaly":
                        self._send_json(server.anomaly_doc())
                    elif url.path == "/healthz":
                        self._send_json({"ok": True, "port": server.port})
                    elif url.path == "/stream":
                        self._sse(url)
                    else:
                        self._send_json({"error": "unknown endpoint",
                                         "endpoints": ["/", "/metrics",
                                                       "/epochs", "/anomaly",
                                                       "/stream", "/healthz"]},
                                        code=404)
                except (BrokenPipeError, ConnectionResetError):
                    pass

            def _send_file(self, path, ctype):
                with open(path, "rb") as f:
                    body = f.read()
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def _sse(self, url):
                qs = parse_qs(url.query)
                cursor = int(qs.get("cursor", [max(
                    server.stream.next_sid - 64, 0)])[0])
                self.send_response(200)
                self.send_header("Content-Type", "text/event-stream")
                self.send_header("Cache-Control", "no-cache")
                self.send_header("Access-Control-Allow-Origin", "*")
                self.end_headers()
                last_ping = time.monotonic()
                while not server._stopping.is_set():
                    events, cursor = server.stream.tail(cursor, limit=256)
                    for ev in events:
                        data = json.dumps(ev, default=_json_default)
                        self.wfile.write(
                            f"id: {ev['sid']}\ndata: {data}\n\n".encode())
                    if events:
                        self.wfile.flush()
                    else:
                        now = time.monotonic()
                        if now - last_ping > 2.0:
                            self.wfile.write(b": ping\n\n")
                            self.wfile.flush()
                            last_ping = now
                        time.sleep(0.05)
                self.close_connection = True

        return Handler

    def start(self) -> "ObsServer":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, kwargs={"poll_interval": 0.1},
            name="obs-server", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stopping.set()
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
