"""Span/trace-event model for control-plane epochs and health leases.

An epoch's life is submit -> stage -> (barrier) -> commit | rollback.
``EpochRecord`` already timestamps the endpoints: ``submitted_s`` at
submit, ``apply_latency_us`` submit->effective, ``apply_us`` for the
stage+apply window alone.  ``epoch_event`` folds those into a span dict
(queued time = latency - apply) suitable for a timeline renderer, and
``health_event`` does the same for ``HealthMonitor`` transitions.

``epoch_log_doc`` is the ONE serializer for the machine-readable epoch
log — the ``/epochs`` API endpoint and ``--epoch-log-json`` both call
it, so the wire formats cannot drift apart.
"""

from __future__ import annotations

from repro.control.plane import API_VERSION, EpochRecord


def epoch_event(rec: EpochRecord) -> dict:
    """One epoch record as a stream event with an embedded span."""
    doc = rec.as_dict()
    queued_us = None
    if rec.apply_latency_us is not None and rec.apply_us is not None:
        queued_us = max(0.0, rec.apply_latency_us - rec.apply_us)
    doc.update({
        "kind": "epoch",
        "span": {
            "submitted_s": rec.submitted_s,
            # time spent queued waiting for a quiescent tick boundary
            # (and, on meshes, for the cross-host barrier)
            "queued_us": queued_us,
            "apply_us": rec.apply_us,
            "total_us": rec.apply_latency_us,
            "outcome": rec.commit_mode,
        },
    })
    return doc


def health_event(tr) -> dict:
    """One ``HealthMonitor`` transition as a stream event."""
    return {"kind": "health", **tr.as_dict()}


def epoch_log_doc(runtime) -> dict:
    """The full machine-readable epoch log for ``runtime`` (single-host
    or mesh): per-epoch spans, commit-mode counts, continuity audit,
    health transitions, and injected fault events when present."""
    control = runtime.control
    doc = {
        "api_version": API_VERSION,
        "epochs": [epoch_event(rec) for rec in control.log],
        "stats": control.stats(),
        "continuity": control.continuity_audit(),
    }
    health = getattr(runtime, "health", None)
    if health is not None:
        doc["health"] = health.snapshot()  # states + transitions
    faults = getattr(runtime, "_faults", None)
    if faults is not None and getattr(faults, "events", None):
        doc["fault_events"] = [dict(e) for e in faults.events]
    deploy = getattr(runtime, "deploy_log", None)
    if deploy:
        # deployment decision trail (repro.deploy): canary start/promote/
        # rollback, retrain triggers, auto-remediation actions — each tied
        # to its typed epoch id in "epochs" above
        doc["deployments"] = [dict(d) for d in deploy]
    return doc
