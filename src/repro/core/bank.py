"""Resident model bank (paper §II-C) as a generic JAX pytree container.

``M = {f_0 .. f_{K-1}}`` is realized by stacking K structurally identical
parameter pytrees on a new leading axis.  All slots live at fixed HBM
locations inside ONE compiled program for the whole runtime — switching is
slot *indexing* (data), never recompilation or weight delivery (code).

Selection strategies (see DESIGN.md §3):
  * ``take``    — per-row gather ``leaf[slots]``.  Exact packet granularity;
                  materializes per-row weights (memory-bound).
  * ``onehot``  — contraction with ``one_hot(slots, K)``; selection becomes
                  an MXU einsum.  K x FLOPs, zero gathers — wins for small K.
  * ``grouped`` — sort rows by slot so each kernel block serves one slot,
                  then ONE scalar-prefetch fused Pallas kernel gathers each
                  block's rows by DMA and fetches only the selected slot's
                  weights from HBM (O(1) per block, the closest TPU analogue
                  of the paper's pointer-chase).  Zero-copy: the batch stays
                  in arrival order in HBM.
  * ``grouped_staged`` — the pre-fused layout: materialize a padded
                  slot-sorted copy of the batch (``scatter_padded``), run the
                  kernel, un-permute (``gather_padded``).  Kept as the
                  fused-vs-staged benchmark baseline.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import expand_block_slots

Params = Any  # pytree


def stack_bank(param_sets: list[Params]) -> Params:
    """Stack K structurally identical pytrees into (K, ...) leaves."""
    if not param_sets:
        raise ValueError("empty bank")
    treedefs = {jax.tree_util.tree_structure(p) for p in param_sets}
    if len(treedefs) != 1:
        raise ValueError("bank slots must share one pytree structure")
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *param_sets)


def bank_size(bank: Params) -> int:
    leaves = jax.tree_util.tree_leaves(bank)
    return int(leaves[0].shape[0])


def select_slot(bank: Params, k) -> Params:
    """f_k: materialize one resident slot (traceable; k may be a tracer)."""
    return jax.tree_util.tree_map(lambda leaf: leaf[k], bank)


def update_slot(bank: Params, k: int, new_params: Params) -> Params:
    """Control-plane style in-place slot replacement (the *heavyweight* path —
    used only by the Table V baseline, never by resident switching)."""
    return jax.tree_util.tree_map(
        lambda leaf, new: leaf.at[k].set(new), bank, new_params
    )


def bank_bytes(bank: Params) -> int:
    return sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(bank))


# ---------------------------------------------------------------------------
# grouped execution support
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Grouping:
    """Result of sorting a batch by slot for block-wise execution."""
    order: jnp.ndarray        # (B,) permutation applied to rows
    inverse: jnp.ndarray      # (B,) inverse permutation
    block_slots: jnp.ndarray  # (B // block_b,) slot id per block
    valid: jnp.ndarray        # (B,) bool — False for rows whose block mixes slots


def group_by_slot(slots: jnp.ndarray, block_b: int) -> Grouping:
    """Stable-sort rows by slot and derive per-block slot ids.

    With B a multiple of ``block_b``, blocks that land entirely inside one
    slot's segment are exact; rows in straddling blocks are flagged invalid
    so callers can re-run them through the exact ``take`` path (in practice
    the scheduler pads each slot's segment to a block multiple so ``valid``
    is all-True; the flag makes the invariant checkable).
    """
    bsz = slots.shape[0]
    if bsz % block_b:
        raise ValueError(f"B={bsz} must be a multiple of block_b={block_b}")
    order = jnp.argsort(slots, stable=True)
    sorted_slots = slots[order]
    blocks = sorted_slots.reshape(-1, block_b)
    block_slots = blocks[:, 0].astype(jnp.int32)
    valid_blocks = jnp.all(blocks == blocks[:, :1], axis=1)
    valid_sorted = expand_block_slots(valid_blocks, block_b, bsz)
    inverse = jnp.argsort(order)
    return Grouping(
        order=order,
        inverse=inverse,
        block_slots=block_slots,
        valid=valid_sorted[inverse],
    )


@dataclasses.dataclass
class PaddedGrouping:
    """Exact, static-shape grouping: every block is single-slot.

    Each slot's segment is padded up to a multiple of ``block_b`` inside a
    buffer of static size ``b_pad = roundup(B + K*block_b)``; padding rows
    execute under their block's slot (wasted-but-bounded compute:
    < K * block_b rows).  This is the in-jit production path for the grouped
    strategy — exact per-row semantics with O(1)-per-block slot resolution.

    ``row_ids`` / ``result_rows`` are the zero-copy form consumed by the
    fused kernel's DMA gather prologue: the batch itself is never scattered
    into the padded layout — only these two tiny int32 index vectors exist.
    ``order``/``dest`` remain for the legacy staged path (``scatter_padded``
    / ``gather_padded``), kept as the fused-vs-staged benchmark baseline.
    """
    order: jnp.ndarray        # (B,) stable sort permutation
    dest: jnp.ndarray         # (B,) destination of sorted row i in the padded buffer
    block_slots: jnp.ndarray  # (b_pad // block_b,) slot id per block
    b_pad: int                # static padded row count
    row_ids: jnp.ndarray      # (b_pad,) source row per padded position (pad -> 0)
    result_rows: jnp.ndarray  # (B,) padded position holding row i's result


def _exclusive_cumsum(x: jnp.ndarray) -> jnp.ndarray:
    """[x0, x1, ...] -> [0, x0, x0+x1, ...] (segment start offsets)."""
    return jnp.concatenate([jnp.zeros(1, x.dtype), jnp.cumsum(x)[:-1]])


def group_by_slot_padded(
    slots: jnp.ndarray, num_slots: int, block_b: int
) -> PaddedGrouping:
    b = slots.shape[0]
    order = jnp.argsort(slots, stable=True)
    sorted_slots = slots[order]
    counts = jnp.bincount(slots, length=num_slots)
    padded = ((counts + block_b - 1) // block_b) * block_b
    rank = jnp.arange(b) - _exclusive_cumsum(counts)[sorted_slots]
    dest = (_exclusive_cumsum(padded)[sorted_slots] + rank).astype(jnp.int32)
    b_pad = ((b + num_slots * block_b + block_b - 1) // block_b) * block_b
    seg_end = jnp.cumsum(padded)
    block_starts = jnp.arange(b_pad // block_b) * block_b
    block_seg = jnp.searchsorted(seg_end, block_starts, side="right")
    block_slots = jnp.clip(block_seg, 0, num_slots - 1).astype(jnp.int32)
    row_ids = jnp.zeros(b_pad, jnp.int32).at[dest].set(order.astype(jnp.int32))
    result_rows = jnp.zeros(b, jnp.int32).at[order].set(dest)
    return PaddedGrouping(order=order, dest=dest, block_slots=block_slots,
                          b_pad=b_pad, row_ids=row_ids,
                          result_rows=result_rows)


def scatter_padded(x: jnp.ndarray, g: PaddedGrouping) -> jnp.ndarray:
    """Place rows into the padded, slot-grouped layout (padding rows zero)."""
    out = jnp.zeros((g.b_pad,) + x.shape[1:], x.dtype)
    return out.at[g.dest].set(x[g.order])


def gather_padded(y_pad: jnp.ndarray, g: PaddedGrouping) -> jnp.ndarray:
    """Undo ``scatter_padded`` on the kernel output."""
    b = g.order.shape[0]
    out = jnp.zeros((b,) + y_pad.shape[1:], y_pad.dtype)
    return out.at[g.order].set(y_pad[g.dest])


def pad_group_by_slot(
    slots: np.ndarray, block_b: int, pad_slot: int = 0
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side scheduler grouping: pad each slot segment to a block multiple.

    Returns (order, block_slots, row_valid) where ``order`` indexes into the
    original batch with repeats allowed for padding rows (marked invalid).
    Guarantees every block is single-slot — the production path for the
    grouped strategy.
    """
    slots = np.asarray(slots)
    order_parts: list[np.ndarray] = []
    block_slots: list[int] = []
    valid_parts: list[np.ndarray] = []
    for k in np.unique(slots):
        idx = np.nonzero(slots == k)[0]
        pad = (-len(idx)) % block_b
        padded = np.concatenate([idx, np.repeat(idx[-1:], pad)])
        order_parts.append(padded)
        valid_parts.append(
            np.concatenate([np.ones(len(idx), bool), np.zeros(pad, bool)])
        )
        block_slots.extend([int(k)] * (len(padded) // block_b))
    return (
        np.concatenate(order_parts),
        np.asarray(block_slots, np.int32),
        np.concatenate(valid_parts),
    )


# ---------------------------------------------------------------------------
# double-buffered bank: zero-copy SwapSlot commit (DESIGN.md §14)
# ---------------------------------------------------------------------------

def copy_bank(bank: Params) -> Params:
    """Deep device copy of a bank pytree (fresh buffers, same contents)."""
    return jax.tree_util.tree_map(lambda leaf: jnp.asarray(leaf).copy(), bank)


@functools.partial(jax.jit, donate_argnums=(0,))
def _stage_slot(shadow: Params, params: Params, slot) -> Params:
    """Write one slot's params into the shadow, donating the shadow's
    buffers so XLA updates in place — no second copy of the bank survives.
    ``slot`` is a traced scalar: one compilation serves every slot id."""
    return jax.tree_util.tree_map(
        lambda leaf, new: leaf.at[slot].set(new), shadow, params)


@functools.partial(jax.jit, donate_argnums=(0,))
def _sync_slot(shadow: Params, active: Params, slot) -> Params:
    """Catch the shadow up on one slot the active bank has since published
    (dirty-slot resync).  Donates the shadow only; the active bank — still
    serving traffic — is read, never consumed."""
    return jax.tree_util.tree_map(
        lambda leaf, cur: leaf.at[slot].set(cur[slot]), shadow, active)


class _Buf:
    """One of the two device-resident bank copies, with a pin count.

    A pinned buffer is referenced outside the double buffer (an open
    megastep window, an epoch snapshot held for rollback) and must never
    be donated; ``DoubleBufferedBank.stage`` un-aliases it with a fresh
    copy instead (copy-on-write — a lingering pin costs one extra copy,
    never correctness)."""

    __slots__ = ("tree", "pins")

    def __init__(self, tree: Params):
        self.tree = tree
        self.pins = 0


class DoubleBufferedBank:
    """Two device-resident copies of the bank: *active* (serving traffic)
    and *shadow* (staging target).  ``SwapSlot`` staging donates into the
    shadow while ticks keep reading the active copy; the epoch's barrier
    commit is then ``commit()`` — a Python reference flip, O(1) regardless
    of bank size.  Protocol, staging states, and rollback rules are
    documented in DESIGN.md §14.

    Invariants:
      * the active buffer is never donated — every holder of the runtime's
        ``bank`` attribute stays valid until the next flip *and* the next
        staging onto that (by then shadow) buffer; holders that span that
        window pin the buffer (``pin_active``/``unpin``).
      * at most ONE epoch's swaps are prestaged at a time
        (``_staged_epoch``); a second epoch's prestage is refused and
        falls back to staging at apply time (``force=True``), which still
        commits by flip.
      * per-buffer dirty-slot sets record how far each buffer lags the
        other; ``stage`` resyncs the shadow's dirty slots from the active
        buffer before writing new params, so a flip always publishes a
        complete bank.
    """

    def __init__(self, bank: Params):
        self.num_slots = bank_size(bank)
        # private copies: donation must never invalidate the caller's arrays
        self._bufs = [_Buf(copy_bank(bank)), _Buf(copy_bank(bank))]
        self._active = 0
        self._dirty: list[set[int]] = [set(), set()]
        self._staged: dict[Any, tuple[int, Params]] = {}
        self._staged_epoch: Any = None
        self._committed: dict[Any, int] = {}
        self.stages = self.syncs = self.flips = 0
        self.discards = self.unalias_copies = 0

    # -- views ------------------------------------------------------------

    @property
    def active(self) -> Params:
        return self._bufs[self._active].tree

    @property
    def shadow(self) -> Params:
        return self._bufs[1 - self._active].tree

    @property
    def has_staged(self) -> bool:
        return bool(self._staged)

    def is_staged(self, token) -> bool:
        return token in self._staged

    def committed(self, token) -> bool:
        return token in self._committed

    # -- pinning ----------------------------------------------------------

    def pin_active(self) -> _Buf:
        """Pin the current active buffer (returns the pin handle)."""
        buf = self._bufs[self._active]
        buf.pins += 1
        return buf

    def unpin(self, buf: _Buf) -> None:
        buf.pins = max(0, buf.pins - 1)

    # -- staging ----------------------------------------------------------

    def stage(self, slot: int, params: Params, *, token, epoch,
              force: bool = False) -> bool:
        """Stage ``params`` into the shadow's ``slot``; True if staged.

        ``token`` identifies the request (a command's ``id()``, or a
        prefetch key) so commit/rollback bookkeeping survives re-entry;
        ``epoch`` scopes the one-staged-epoch policy.  A same-slot,
        same-params re-stage (a prefetch being promoted to a real epoch)
        adopts the existing staged entry without touching the device.
        ``force=True`` (apply-time staging) evicts a stale staged epoch
        instead of refusing.
        """
        if token in self._staged:
            return True
        for t, (s, p) in list(self._staged.items()):
            if s == slot and p is params:  # prefetch promotion: rebind
                del self._staged[t]
                self._staged[token] = (slot, params)
                self._staged_epoch = epoch
                return True
        if self._staged and self._staged_epoch != epoch:
            if not force:
                return False
            self.discard_staged()
        sh = 1 - self._active
        buf = self._bufs[sh]
        if buf.pins:
            # copy-on-write: the pinned buffer stays with its pinner
            buf = self._bufs[sh] = _Buf(copy_bank(buf.tree))
            self.unalias_copies += 1
        act = self._bufs[self._active].tree
        for k in sorted(self._dirty[sh]):
            if k == slot:
                continue  # about to be overwritten anyway
            buf.tree = _sync_slot(buf.tree, act, jnp.int32(k))
            self.syncs += 1
        self._dirty[sh].clear()
        buf.tree = _stage_slot(
            buf.tree, jax.tree_util.tree_map(jnp.asarray, params),
            jnp.int32(slot))
        self._staged[token] = (slot, params)
        self._staged_epoch = epoch
        self.stages += 1
        return True

    def discard_staged(self) -> None:
        """Drop staged-but-uncommitted entries (their slots go dirty)."""
        if not self._staged:
            return
        sh = 1 - self._active
        self._dirty[sh].update(s for s, _ in self._staged.values())
        self._staged.clear()
        self._staged_epoch = None
        self.discards += 1

    # -- commit / rollback -------------------------------------------------

    def commit(self) -> Params:
        """Publish every staged slot by flipping which buffer is active.

        O(1) — a Python reference swap; no weights move.  The demoted
        buffer becomes the next shadow, dirty at exactly the slots just
        published.  Returns the new active bank pytree."""
        if not self._staged:
            return self.active
        old = self._active
        self._active = 1 - old
        for s, _ in self._staged.values():
            self._dirty[old].add(s)
        self._committed.update(
            {t: s for t, (s, _) in self._staged.items()})
        self._staged.clear()
        self._staged_epoch = None
        self.flips += 1
        return self.active

    def mark(self):
        """Snapshot flip/staging bookkeeping for epoch rollback.

        Taken at the epoch barrier's ``_control_state``; the previous
        epoch's committed tokens are dead by then and are purged so
        ``id()`` reuse can never alias a new command onto them."""
        self._committed.clear()
        return (self._active, dict(self._staged), self._staged_epoch,
                dict(self._committed),
                (set(self._dirty[0]), set(self._dirty[1])))

    def restore(self, m) -> None:
        """Roll back to a ``mark()``: un-flip if the epoch flipped, and
        mark every slot staged/committed since the mark dirty (the shadow
        holds rolled-back params there)."""
        active, staged, staged_epoch, committed, dirty = m
        rolled = {s for t, (s, _) in self._staged.items() if t not in staged}
        rolled |= {s for t, s in self._committed.items() if t not in committed}
        self._active = active
        self._staged = dict(staged)
        self._staged_epoch = staged_epoch
        self._committed = dict(committed)
        self._dirty = [set(dirty[0]), set(dirty[1])]
        self._dirty[1 - active].update(rolled)

    def reseed(self, bank: Params) -> None:
        """Adopt externally supplied contents (trace-replay install, mesh
        shard resync) as the new active bank.  The shadow is left in place
        — possibly pinned — and marked fully dirty so the next stage
        resyncs it."""
        self.discard_staged()
        self._bufs[self._active] = _Buf(copy_bank(bank))
        self._dirty[self._active].clear()
        self._dirty[1 - self._active] = set(range(self.num_slots))
        self._committed.clear()


# ---------------------------------------------------------------------------
# generic banked apply
# ---------------------------------------------------------------------------

def apply_banked(
    bank: Params,
    apply_fn: Callable[[Params, jnp.ndarray], jnp.ndarray],
    x: jnp.ndarray,
    slots: jnp.ndarray,
    *,
    strategy: str = "take",
) -> jnp.ndarray:
    """Run ``apply_fn(f_{slots[i]}, x[i])`` for every row under a strategy.

    ``take`` vmaps a per-row gather; ``onehot`` computes all K results per
    row and contracts (exact, K x FLOPs — only for cheap apply_fns / small K).
    The grouped strategy lives with the kernels (`repro.kernels.ops`), since
    it changes the execution layout, not just the math.
    """
    if strategy == "take":
        return jax.vmap(lambda s, xi: apply_fn(select_slot(bank, s), xi))(slots, x)
    if strategy == "onehot":
        k = bank_size(bank)
        all_out = jax.vmap(
            lambda xi: jax.vmap(lambda s: apply_fn(select_slot(bank, s), xi))(
                jnp.arange(k)
            )
        )(x)  # (B, K, ...)
        onehot = jax.nn.one_hot(slots, k, dtype=all_out.dtype)
        return jnp.einsum("bk,bk...->b...", onehot, all_out)
    raise ValueError(f"unknown strategy {strategy!r}")
