"""Resident model bank (paper §II-C) as a generic JAX pytree container.

``M = {f_0 .. f_{K-1}}`` is realized by stacking K structurally identical
parameter pytrees on a new leading axis.  All slots live at fixed HBM
locations inside ONE compiled program for the whole runtime — switching is
slot *indexing* (data), never recompilation or weight delivery (code).

Selection strategies (see DESIGN.md §3):
  * ``take``    — per-row gather ``leaf[slots]``.  Exact packet granularity;
                  materializes per-row weights (memory-bound).
  * ``onehot``  — contraction with ``one_hot(slots, K)``; selection becomes
                  an MXU einsum.  K x FLOPs, zero gathers — wins for small K.
  * ``grouped`` — sort rows by slot so each kernel block serves one slot,
                  then ONE scalar-prefetch fused Pallas kernel gathers each
                  block's rows by DMA and fetches only the selected slot's
                  weights from HBM (O(1) per block, the closest TPU analogue
                  of the paper's pointer-chase).  Zero-copy: the batch stays
                  in arrival order in HBM.
  * ``grouped_staged`` — the pre-fused layout: materialize a padded
                  slot-sorted copy of the batch (``scatter_padded``), run the
                  kernel, un-permute (``gather_padded``).  Kept as the
                  fused-vs-staged benchmark baseline.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import expand_block_slots

Params = Any  # pytree


def stack_bank(param_sets: list[Params]) -> Params:
    """Stack K structurally identical pytrees into (K, ...) leaves."""
    if not param_sets:
        raise ValueError("empty bank")
    treedefs = {jax.tree_util.tree_structure(p) for p in param_sets}
    if len(treedefs) != 1:
        raise ValueError("bank slots must share one pytree structure")
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *param_sets)


def bank_size(bank: Params) -> int:
    leaves = jax.tree_util.tree_leaves(bank)
    return int(leaves[0].shape[0])


def select_slot(bank: Params, k) -> Params:
    """f_k: materialize one resident slot (traceable; k may be a tracer)."""
    return jax.tree_util.tree_map(lambda leaf: leaf[k], bank)


def update_slot(bank: Params, k: int, new_params: Params) -> Params:
    """Control-plane style in-place slot replacement (the *heavyweight* path —
    used only by the Table V baseline, never by resident switching)."""
    return jax.tree_util.tree_map(
        lambda leaf, new: leaf.at[k].set(new), bank, new_params
    )


def bank_bytes(bank: Params) -> int:
    return sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(bank))


# ---------------------------------------------------------------------------
# grouped execution support
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Grouping:
    """Result of sorting a batch by slot for block-wise execution."""
    order: jnp.ndarray        # (B,) permutation applied to rows
    inverse: jnp.ndarray      # (B,) inverse permutation
    block_slots: jnp.ndarray  # (B // block_b,) slot id per block
    valid: jnp.ndarray        # (B,) bool — False for rows whose block mixes slots


def group_by_slot(slots: jnp.ndarray, block_b: int) -> Grouping:
    """Stable-sort rows by slot and derive per-block slot ids.

    With B a multiple of ``block_b``, blocks that land entirely inside one
    slot's segment are exact; rows in straddling blocks are flagged invalid
    so callers can re-run them through the exact ``take`` path (in practice
    the scheduler pads each slot's segment to a block multiple so ``valid``
    is all-True; the flag makes the invariant checkable).
    """
    bsz = slots.shape[0]
    if bsz % block_b:
        raise ValueError(f"B={bsz} must be a multiple of block_b={block_b}")
    order = jnp.argsort(slots, stable=True)
    sorted_slots = slots[order]
    blocks = sorted_slots.reshape(-1, block_b)
    block_slots = blocks[:, 0].astype(jnp.int32)
    valid_blocks = jnp.all(blocks == blocks[:, :1], axis=1)
    valid_sorted = expand_block_slots(valid_blocks, block_b, bsz)
    inverse = jnp.argsort(order)
    return Grouping(
        order=order,
        inverse=inverse,
        block_slots=block_slots,
        valid=valid_sorted[inverse],
    )


@dataclasses.dataclass
class PaddedGrouping:
    """Exact, static-shape grouping: every block is single-slot.

    Each slot's segment is padded up to a multiple of ``block_b`` inside a
    buffer of static size ``b_pad = roundup(B + K*block_b)``; padding rows
    execute under their block's slot (wasted-but-bounded compute:
    < K * block_b rows).  This is the in-jit production path for the grouped
    strategy — exact per-row semantics with O(1)-per-block slot resolution.

    ``row_ids`` / ``result_rows`` are the zero-copy form consumed by the
    fused kernel's DMA gather prologue: the batch itself is never scattered
    into the padded layout — only these two tiny int32 index vectors exist.
    ``order``/``dest`` remain for the legacy staged path (``scatter_padded``
    / ``gather_padded``), kept as the fused-vs-staged benchmark baseline.
    """
    order: jnp.ndarray        # (B,) stable sort permutation
    dest: jnp.ndarray         # (B,) destination of sorted row i in the padded buffer
    block_slots: jnp.ndarray  # (b_pad // block_b,) slot id per block
    b_pad: int                # static padded row count
    row_ids: jnp.ndarray      # (b_pad,) source row per padded position (pad -> 0)
    result_rows: jnp.ndarray  # (B,) padded position holding row i's result


def _exclusive_cumsum(x: jnp.ndarray) -> jnp.ndarray:
    """[x0, x1, ...] -> [0, x0, x0+x1, ...] (segment start offsets)."""
    return jnp.concatenate([jnp.zeros(1, x.dtype), jnp.cumsum(x)[:-1]])


def group_by_slot_padded(
    slots: jnp.ndarray, num_slots: int, block_b: int
) -> PaddedGrouping:
    b = slots.shape[0]
    order = jnp.argsort(slots, stable=True)
    sorted_slots = slots[order]
    counts = jnp.bincount(slots, length=num_slots)
    padded = ((counts + block_b - 1) // block_b) * block_b
    rank = jnp.arange(b) - _exclusive_cumsum(counts)[sorted_slots]
    dest = (_exclusive_cumsum(padded)[sorted_slots] + rank).astype(jnp.int32)
    b_pad = ((b + num_slots * block_b + block_b - 1) // block_b) * block_b
    seg_end = jnp.cumsum(padded)
    block_starts = jnp.arange(b_pad // block_b) * block_b
    block_seg = jnp.searchsorted(seg_end, block_starts, side="right")
    block_slots = jnp.clip(block_seg, 0, num_slots - 1).astype(jnp.int32)
    row_ids = jnp.zeros(b_pad, jnp.int32).at[dest].set(order.astype(jnp.int32))
    result_rows = jnp.zeros(b, jnp.int32).at[order].set(dest)
    return PaddedGrouping(order=order, dest=dest, block_slots=block_slots,
                          b_pad=b_pad, row_ids=row_ids,
                          result_rows=result_rows)


def scatter_padded(x: jnp.ndarray, g: PaddedGrouping) -> jnp.ndarray:
    """Place rows into the padded, slot-grouped layout (padding rows zero)."""
    out = jnp.zeros((g.b_pad,) + x.shape[1:], x.dtype)
    return out.at[g.dest].set(x[g.order])


def gather_padded(y_pad: jnp.ndarray, g: PaddedGrouping) -> jnp.ndarray:
    """Undo ``scatter_padded`` on the kernel output."""
    b = g.order.shape[0]
    out = jnp.zeros((b,) + y_pad.shape[1:], y_pad.dtype)
    return out.at[g.order].set(y_pad[g.dest])


def pad_group_by_slot(
    slots: np.ndarray, block_b: int, pad_slot: int = 0
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side scheduler grouping: pad each slot segment to a block multiple.

    Returns (order, block_slots, row_valid) where ``order`` indexes into the
    original batch with repeats allowed for padding rows (marked invalid).
    Guarantees every block is single-slot — the production path for the
    grouped strategy.
    """
    slots = np.asarray(slots)
    order_parts: list[np.ndarray] = []
    block_slots: list[int] = []
    valid_parts: list[np.ndarray] = []
    for k in np.unique(slots):
        idx = np.nonzero(slots == k)[0]
        pad = (-len(idx)) % block_b
        padded = np.concatenate([idx, np.repeat(idx[-1:], pad)])
        order_parts.append(padded)
        valid_parts.append(
            np.concatenate([np.ones(len(idx), bool), np.zeros(pad, bool)])
        )
        block_slots.extend([int(k)] * (len(padded) // block_b))
    return (
        np.concatenate(order_parts),
        np.asarray(block_slots, np.int32),
        np.concatenate(valid_parts),
    )


# ---------------------------------------------------------------------------
# generic banked apply
# ---------------------------------------------------------------------------

def apply_banked(
    bank: Params,
    apply_fn: Callable[[Params, jnp.ndarray], jnp.ndarray],
    x: jnp.ndarray,
    slots: jnp.ndarray,
    *,
    strategy: str = "take",
) -> jnp.ndarray:
    """Run ``apply_fn(f_{slots[i]}, x[i])`` for every row under a strategy.

    ``take`` vmaps a per-row gather; ``onehot`` computes all K results per
    row and contracts (exact, K x FLOPs — only for cheap apply_fns / small K).
    The grouped strategy lives with the kernels (`repro.kernels.ops`), since
    it changes the execution layout, not just the math.
    """
    if strategy == "take":
        return jax.vmap(lambda s, xi: apply_fn(select_slot(bank, s), xi))(slots, x)
    if strategy == "onehot":
        k = bank_size(bank)
        all_out = jax.vmap(
            lambda xi: jax.vmap(lambda s: apply_fn(select_slot(bank, s), xi))(
                jnp.arange(k)
            )
        )(x)  # (B, K, ...)
        onehot = jax.nn.one_hot(slots, k, dtype=all_out.dtype)
        return jnp.einsum("bk,bk...->b...", onehot, all_out)
    raise ValueError(f"unknown strategy {strategy!r}")
