"""BoundSwitch fixed packet representation (paper §II-B).

A packet is ``p = (m_p, x_p)``: seventeen 64-byte register blocks (1088 B).

* ``reg0`` (64 B = 16 uint32 words) carries control metadata:
    word 0      : Model Slot ID (4 B)            -> selects ``k_p``
    word 1      : Format / version (4 B)         -> parser compatibility guard
    words 2..3  : Control / reserved (8 B)       -> action hints for Pi
    words 4..15 : Padding / spare metadata (48 B)
* ``reg1..reg16`` (1024 B = 256 uint32 words) carry the payload presented to
  the BNN executor.

On TPU the x86 "64 B block <-> 512-bit ZMM" alignment maps to lane-aligned
uint32 words: the payload is 256 words = 2 x 128 lanes, i.e. two full vector
registers of the (8, 128) VREG tiling.  All host-side helpers are NumPy; all
device-side helpers are jnp and shape-polymorphic over a leading batch dim.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

REG_BYTES = 64
N_REGS = 17
PACKET_BYTES = REG_BYTES * N_REGS          # 1088
PAYLOAD_BYTES = REG_BYTES * (N_REGS - 1)   # 1024
PAYLOAD_BITS = PAYLOAD_BYTES * 8           # 8192

WORD_BYTES = 4
PACKET_WORDS = PACKET_BYTES // WORD_BYTES    # 272
META_WORDS = REG_BYTES // WORD_BYTES         # 16
PAYLOAD_WORDS = PAYLOAD_BYTES // WORD_BYTES  # 256

SLOT_WORD = 0
VERSION_WORD = 1
CONTROL_WORD_LO = 2
CONTROL_WORD_HI = 3

FORMAT_VERSION = 1

# Pi action codes.
ACTION_FORWARD = 0
ACTION_DROP = 1
ACTION_FLAG = 2  # forward but mark (monitor-only control bit set)

# Control bit 0 of word2: monitor-only (never drop, only flag).
CTRL_MONITOR_ONLY = np.uint32(1)


def make_packets(
    slots: np.ndarray,
    payload_words: np.ndarray,
    *,
    version: int = FORMAT_VERSION,
    control: int = 0,
) -> np.ndarray:
    """Assemble a batch of fixed-format packets.

    slots: (B,) integer slot ids; payload_words: (B, 256) uint32.
    Returns (B, 272) uint32.
    """
    slots = np.asarray(slots, dtype=np.uint32)
    payload_words = np.asarray(payload_words, dtype=np.uint32)
    if payload_words.ndim != 2 or payload_words.shape[1] != PAYLOAD_WORDS:
        raise ValueError(f"payload must be (B, {PAYLOAD_WORDS}) words, got {payload_words.shape}")
    b = payload_words.shape[0]
    if slots.shape != (b,):
        raise ValueError(f"slots must be ({b},), got {slots.shape}")
    pkt = np.zeros((b, PACKET_WORDS), dtype=np.uint32)
    pkt[:, SLOT_WORD] = slots
    pkt[:, VERSION_WORD] = np.uint32(version)
    pkt[:, CONTROL_WORD_LO] = np.uint32(control)
    pkt[:, META_WORDS:] = payload_words
    return pkt


def payload_bytes_to_words(payload: np.ndarray) -> np.ndarray:
    """(B, 1024) uint8 -> (B, 256) uint32, little-endian within each word."""
    payload = np.asarray(payload, dtype=np.uint8)
    if payload.shape[-1] != PAYLOAD_BYTES:
        raise ValueError(f"payload must have {PAYLOAD_BYTES} bytes")
    return payload.view("<u4").reshape(*payload.shape[:-1], PAYLOAD_WORDS)


# ---------------------------------------------------------------------------
# Device-side parsing (sigma and friends).  All are trivially O(1) slices —
# the structural analogue of the paper's "one slot lookup" per packet.
# ---------------------------------------------------------------------------

def slot_of(packets: jnp.ndarray, num_slots: int) -> jnp.ndarray:
    """sigma(m_p): extract the model slot index from reg0 word 0.

    Out-of-range ids are clamped into the resident bank (defensive parse);
    the version guard is handled separately by ``version_ok``.
    """
    raw = packets[..., SLOT_WORD].astype(jnp.int32)
    return jnp.clip(raw, 0, num_slots - 1)


def raw_slot_of(packets: jnp.ndarray) -> jnp.ndarray:
    return packets[..., SLOT_WORD].astype(jnp.int32)


def version_ok(packets: jnp.ndarray) -> jnp.ndarray:
    return packets[..., VERSION_WORD] == jnp.uint32(FORMAT_VERSION)


def control_of(packets: jnp.ndarray) -> jnp.ndarray:
    return packets[..., CONTROL_WORD_LO]


def payload_of(packets: jnp.ndarray) -> jnp.ndarray:
    """x_p: the 256 payload words (reg1..reg16)."""
    return packets[..., META_WORDS:]


def decide_action(packets: jnp.ndarray, scores: jnp.ndarray) -> jnp.ndarray:
    """Pi(m_p, y_p): forwarding action from metadata + inference result.

    Malicious verdict (score > 0) drops, unless the monitor-only control bit
    is set, in which case the packet is forwarded but flagged.  Benign
    packets always forward.
    """
    malicious = scores > 0.0
    monitor = (control_of(packets) & CTRL_MONITOR_ONLY) != 0
    return jnp.where(
        malicious,
        jnp.where(monitor, ACTION_FLAG, ACTION_DROP),
        ACTION_FORWARD,
    ).astype(jnp.int32)
