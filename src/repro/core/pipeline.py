"""The shared forwarding path (paper Algorithm 1).

One jitted function implements the whole per-packet pipeline:

    1. parse slot metadata from reg0
    2. k_p  <- sigma(m_p)          (O(1) slot extraction)
    3. resolve resident slot f_{k_p} in the bank
    4. y_p  <- f_{k_p}(x_p)        (shared BNN executor)
    5. a_p  <- Pi(m_p, y_p)        (forwarding action)

The parser, executor and forwarding logic are byte-identical across packets
and across slots — the compiled XLA program never changes; only the slot
index (data) differs.  The "fixed single-model path" used as the paper's
baseline operating mode is the same pipeline with sigma replaced by a
constant.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bank as bank_lib, executor, packet as pkt
from repro.kernels import fused_forward as _fused_kernel
from repro.kernels import ops

# The kernel package mirrors the reg0 layout so it stays core-free; make the
# mirror impossible to drift silently.
assert _fused_kernel.CTRL_WORD == pkt.CONTROL_WORD_LO
assert _fused_kernel.CTRL_MONITOR_ONLY == int(pkt.CTRL_MONITOR_ONLY)
assert (_fused_kernel.ACTION_FORWARD, _fused_kernel.ACTION_DROP,
        _fused_kernel.ACTION_FLAG) == (pkt.ACTION_FORWARD, pkt.ACTION_DROP,
                                       pkt.ACTION_FLAG)


class PacketResult(NamedTuple):
    slots: jnp.ndarray     # (B,) resolved k_p
    scores: jnp.ndarray    # (B,) y_p (first output column)
    verdicts: jnp.ndarray  # (B,) bool — malicious?
    actions: jnp.ndarray   # (B,) int32 Pi output


@functools.partial(
    jax.jit,
    static_argnames=("num_slots", "strategy", "backend", "fixed_slot",
                     "block_b"),
)
def packet_step(
    bank,
    packets: jnp.ndarray,  # (B, 272) uint32
    *,
    num_slots: int,
    strategy: str = "take",
    backend: str = "auto",
    fixed_slot: int | None = None,
    block_b: int = 256,
) -> PacketResult:
    """Process one batch of packets along the shared forwarding path.

    ``strategy="fused"`` runs steps 1-5 as ONE Pallas launch over the raw
    packet rows: the kernel gathers each block's packets by DMA, slices the
    payload, runs the banked BNN in VMEM, and emits verdict + Pi action —
    no payload view, no padded batch copy, no HBM intermediates.  The other
    strategies share the staged executor (`executor.forward_banked`).
    """
    if fixed_slot is None:
        slots = pkt.slot_of(packets, num_slots)           # sigma(m_p)
    else:  # baseline operating mode: fixed single-model path
        slots = jnp.full(packets.shape[:1], fixed_slot, jnp.int32)
    if strategy == "fused":
        if ops._resolve(backend) in ("ref", "mxu"):
            # No Pallas launch to feed: the oracle gathers per-row weights
            # anyway, so slot-grouping only adds an argsort and up to
            # ``num_slots`` padding blocks of dead compute.  Run the bank
            # directly on the arrival-order batch (bit-identical scores).
            from repro.kernels import ref as _ref
            scores_d = _ref.banked_xnor_forward_ref(
                bank["w1p"], bank["b1"], bank["w2"], bank["b2"],
                pkt.payload_of(packets), slots)
            actions_d = _fused_kernel.actions_ref(
                scores_d, packets[:, pkt.CONTROL_WORD_LO])
            return PacketResult(slots, scores_d[:, 0], scores_d[:, 0] > 0.0,
                                actions_d)
        bb = min(block_b, packets.shape[0])
        g = bank_lib.group_by_slot_padded(slots, num_slots, bb)
        scores_pad, actions_pad = ops.packet_forward_fused(
            bank, packets, g.block_slots, g.row_ids,
            meta_words=pkt.META_WORDS, block_b=bb, backend=backend,
        )
        scores = jnp.take(scores_pad[:, 0], g.result_rows)
        actions = jnp.take(actions_pad, g.result_rows)
        return PacketResult(slots, scores, scores > 0.0, actions)
    payload = pkt.payload_of(packets)                     # x_p
    scores = executor.forward_banked(
        bank, payload, slots, strategy=strategy, backend=backend,
        block_b=block_b,
    )[:, 0]                                               # y_p
    actions = pkt.decide_action(packets, scores)          # Pi(m_p, y_p)
    return PacketResult(slots, scores, scores > 0.0, actions)


@functools.partial(jax.jit, static_argnames=("backend",))
def slot_select_only(packets: jnp.ndarray, num_slots: int, *, backend="auto"):
    """Isolated sigma for the Fig. 4 / Fig. 5 microbenchmarks."""
    return pkt.slot_of(packets, num_slots)


@functools.partial(jax.jit, static_argnames=("backend",))
def inference_only(params, payload_words, *, backend: str = "auto"):
    """Isolated single-slot inference for the Fig. 4 breakdown."""
    return executor.forward(params, payload_words, backend=backend)
