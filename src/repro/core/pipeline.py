"""The shared forwarding path (paper Algorithm 1).

One jitted function implements the whole per-packet pipeline:

    1. parse slot metadata from reg0
    2. k_p  <- sigma(m_p)          (O(1) slot extraction)
    3. resolve resident slot f_{k_p} in the bank
    4. y_p  <- f_{k_p}(x_p)        (shared BNN executor)
    5. a_p  <- Pi(m_p, y_p)        (forwarding action)

The parser, executor and forwarding logic are byte-identical across packets
and across slots — the compiled XLA program never changes; only the slot
index (data) differs.  The "fixed single-model path" used as the paper's
baseline operating mode is the same pipeline with sigma replaced by a
constant.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import executor, packet as pkt


class PacketResult(NamedTuple):
    slots: jnp.ndarray     # (B,) resolved k_p
    scores: jnp.ndarray    # (B,) y_p (first output column)
    verdicts: jnp.ndarray  # (B,) bool — malicious?
    actions: jnp.ndarray   # (B,) int32 Pi output


@functools.partial(
    jax.jit, static_argnames=("num_slots", "strategy", "backend", "fixed_slot")
)
def packet_step(
    bank,
    packets: jnp.ndarray,  # (B, 272) uint32
    *,
    num_slots: int,
    strategy: str = "take",
    backend: str = "auto",
    fixed_slot: int | None = None,
) -> PacketResult:
    """Process one batch of packets along the shared forwarding path."""
    if fixed_slot is None:
        slots = pkt.slot_of(packets, num_slots)           # sigma(m_p)
    else:  # baseline operating mode: fixed single-model path
        slots = jnp.full(packets.shape[:1], fixed_slot, jnp.int32)
    payload = pkt.payload_of(packets)                     # x_p
    scores = executor.forward_banked(
        bank, payload, slots, strategy=strategy, backend=backend
    )[:, 0]                                               # y_p
    actions = pkt.decide_action(packets, scores)          # Pi(m_p, y_p)
    return PacketResult(slots, scores, scores > 0.0, actions)


@functools.partial(jax.jit, static_argnames=("backend",))
def slot_select_only(packets: jnp.ndarray, num_slots: int, *, backend="auto"):
    """Isolated sigma for the Fig. 4 / Fig. 5 microbenchmarks."""
    return pkt.slot_of(packets, num_slots)


@functools.partial(jax.jit, static_argnames=("backend",))
def inference_only(params, payload_words, *, backend: str = "auto"):
    """Isolated single-slot inference for the Fig. 4 breakdown."""
    return executor.forward(params, payload_words, backend=backend)
