"""Online switching harnesses (paper §III-D, §III-E).

* ``replay_trace``     — per-packet replay with optional pacing; records
  timestamps / slots / verdicts to evaluate boundary continuity (Table IV).
  ``stream=True`` turns it into a streaming engine: batches dispatch
  asynchronously through a bounded in-flight window so device work overlaps
  host trace emission.
* ``control_plane_replay`` — the heavyweight baseline: only slot 0 is
  resident; the slot-1 weight set is "delivered" through a simulated control
  channel after the boundary is detected, and every post-boundary packet
  processed before the update becomes effective is scored against the model
  it *should* have used (Table V wrong-packet window).

The resident path and the control-plane path share the identical executor;
only the residency discipline differs — exactly the paper's comparison.
"""

from __future__ import annotations

import collections
import dataclasses
import io
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bank as bank_lib, executor, packet as pkt, pipeline


# ---------------------------------------------------------------------------
# trace construction
# ---------------------------------------------------------------------------

def boundary_trace(
    n_packets: int,
    payload_words: np.ndarray,
    *,
    slot_a: int = 0,
    slot_b: int = 1,
) -> np.ndarray:
    """First half selects slot_a, second half slot_b — the paper's
    deterministic boundary stream (64-packet and 8192-packet runs)."""
    slots = np.where(np.arange(n_packets) < n_packets // 2, slot_a, slot_b)
    if payload_words.shape[0] != n_packets:
        reps = -(-n_packets // payload_words.shape[0])
        payload_words = np.tile(payload_words, (reps, 1))[:n_packets]
    return pkt.make_packets(slots, payload_words)


def access_trace(kind: str, n_packets: int, num_slots: int, seed: int = 0) -> np.ndarray:
    """Slot-access traces for the Fig. 5 scaling microbenchmark."""
    rng = np.random.default_rng(seed)
    if kind == "fixed":
        return np.zeros(n_packets, np.int64)
    if kind == "round_robin":
        return np.arange(n_packets) % num_slots
    if kind == "random":
        return rng.integers(0, num_slots, n_packets)
    if kind == "hotspot":  # 90% slot 0, rest uniform over the others
        hot = rng.random(n_packets) < 0.9
        cold = rng.integers(1, max(num_slots, 2), n_packets)
        return np.where(hot, 0, cold)
    raise ValueError(f"unknown access trace {kind!r}")


# ---------------------------------------------------------------------------
# continuity replay (Table IV)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ReplayResult:
    timestamps_us: np.ndarray   # (N,) completion time per packet
    slots: np.ndarray           # (N,) resolved slot
    verdicts: np.ndarray        # (N,) bool
    actions: np.ndarray         # (N,)
    wrong_slot: int
    wrong_verdict: int
    boundary_index: int

    def gap_stats_us(self) -> dict:
        gaps = np.diff(self.timestamps_us)
        b = self.boundary_index
        return {
            "median_gap_us": float(np.median(gaps)),
            "boundary_gap_us": float(gaps[b - 1]) if 0 < b <= len(gaps) else float("nan"),
            "max_gap_us": float(gaps.max()),
        }

    def rate_kpps(self, window: int = 512) -> dict:
        """Forwarding rate in a window before and after the boundary."""
        b = self.boundary_index
        t = self.timestamps_us

        def rate(lo, hi):
            if hi - lo < 2:
                return float("nan")
            return (hi - lo - 1) / (t[hi - 1] - t[lo]) * 1e3  # kpps

        return {
            "before_kpps": rate(max(0, b - window), b),
            "after_kpps": rate(b, min(len(t), b + window)),
        }


def _expected(bank, packets_np: np.ndarray, num_slots: int) -> tuple[np.ndarray, np.ndarray]:
    """Ground truth (slot, verdict) for every packet under correct resolution."""
    res = pipeline.packet_step(
        bank, jnp.asarray(packets_np), num_slots=num_slots, strategy="take"
    )
    return np.asarray(res.slots), np.asarray(res.verdicts)


def replay_trace(
    bank,
    packets_np: np.ndarray,
    *,
    num_slots: int,
    pacing_us: float = 0.0,
    batch: int = 1,
    strategy: str = "take",
    stream: bool = False,
    stream_window: int = 8,
) -> ReplayResult:
    """Replay a packet trace through the resident-switching pipeline.

    ``pacing_us`` spaces emissions (the paper paces its 8192-run at 10 us so
    per-packet continuity is not hidden by batching artifacts).

    ``stream=True`` enables the multi-batch streaming engine: batches are
    dispatched asynchronously and retired through a bounded in-flight window
    of ``stream_window`` batches instead of ``block_until_ready`` per batch,
    so device execution overlaps host-side trace emission.  Timestamps then
    record when each batch's result was *observed* (retired), which is the
    honest completion time under overlap.
    """
    n = packets_np.shape[0]
    exp_slots, exp_verd = _expected(bank, packets_np, num_slots)
    # warm up the compiled path (the paper attributes its 61 lost packets to
    # the replay warm-up prefix; we compile ahead so the boundary is clean)
    _ = pipeline.packet_step(
        bank, jnp.asarray(packets_np[:batch]), num_slots=num_slots, strategy=strategy
    ).scores.block_until_ready()

    ts = np.empty(n)
    slots = np.empty(n, np.int64)
    verdicts = np.empty(n, bool)
    actions = np.empty(n, np.int64)
    t0 = time.perf_counter()
    next_emit = t0
    inflight: collections.deque = collections.deque()

    def retire(i: int, res) -> None:
        res.scores.block_until_ready()
        now = (time.perf_counter() - t0) * 1e6
        j = min(i + batch, n)
        ts[i:j] = now
        slots[i:j] = np.asarray(res.slots)[: j - i]
        verdicts[i:j] = np.asarray(res.verdicts)[: j - i]
        actions[i:j] = np.asarray(res.actions)[: j - i]

    for i in range(0, n, batch):
        if pacing_us:
            while time.perf_counter() < next_emit:
                pass
            next_emit += pacing_us * 1e-6 * batch
        res = pipeline.packet_step(
            bank, jnp.asarray(packets_np[i : i + batch]),
            num_slots=num_slots, strategy=strategy,
        )
        if stream:
            # async dispatch: retire the oldest batch only once the window
            # is full, letting up to ``stream_window`` batches overlap
            inflight.append((i, res))
            while len(inflight) > stream_window:
                retire(*inflight.popleft())
        else:
            retire(i, res)
    while inflight:
        retire(*inflight.popleft())

    boundary = int(np.argmax(exp_slots != exp_slots[0])) if n else 0
    return ReplayResult(
        timestamps_us=ts,
        slots=slots,
        verdicts=verdicts,
        actions=actions,
        wrong_slot=int((slots != exp_slots).sum()),
        wrong_verdict=int((verdicts != exp_verd).sum()),
        boundary_index=boundary,
    )


# ---------------------------------------------------------------------------
# control-plane replacement baseline (Table V)
# ---------------------------------------------------------------------------

def _serialize(params) -> bytes:
    """Weight file as shipped over the control socket."""
    buf = io.BytesIO()
    flat, _ = jax.tree_util.tree_flatten(params)
    np.savez(buf, *[np.asarray(x) for x in flat])
    return buf.getvalue()


def _deserialize(blob: bytes, like) -> dict:
    flat, treedef = jax.tree_util.tree_flatten(like)
    with np.load(io.BytesIO(blob)) as z:
        arrs = [jnp.asarray(z[f"arr_{i}"]) for i in range(len(flat))]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def measure_update_latency_us(new_params) -> float:
    """One control-plane update: serialize -> deliver -> deserialize ->
    device_put -> ready.  Median of several trials."""
    blob = _serialize(new_params)
    trials = []
    for _ in range(5):
        t0 = time.perf_counter()
        p = _deserialize(blob, new_params)
        jax.block_until_ready(jax.device_put(p))
        trials.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(trials))


@dataclasses.dataclass
class ControlPlaneResult:
    switch_latency_us: float          # update send start -> effective
    boundary_to_effective_us: float   # detection-triggered window
    wrong_model_packets: int
    wrong_verdict_packets: int
    n_packets: int


def control_plane_replay(
    slot0_params,
    slot1_params,
    packets_np: np.ndarray,
    *,
    pacing_us: float = 10.0,
) -> ControlPlaneResult:
    """Replay the boundary trace with ONLY slot 0 resident.

    The control plane starts delivering slot-1 weights when the first
    boundary packet is *observed* (as in the paper: triggering starts only
    after boundary detection).  Until the update is effective, post-boundary
    packets are processed by the stale model; each one whose verdict differs
    from the correct model's verdict is a wrong-verdict event.
    """
    n = packets_np.shape[0]
    want_slots = np.asarray(packets_np[:, pkt.SLOT_WORD], np.int64)
    boundary = int(np.argmax(want_slots != want_slots[0]))

    payload = jnp.asarray(packets_np[:, pkt.META_WORDS :])
    # verdicts under each model, precomputed (numerics only; timing below)
    v0 = np.asarray(executor.forward(slot0_params, payload)[:, 0] > 0)
    v1 = np.asarray(executor.forward(slot1_params, payload)[:, 0] > 0)

    update_us = measure_update_latency_us(slot1_params)

    active = dict(slot0_params)
    # timed replay: process packets at the pacing rate; once the boundary
    # packet is seen, the update is "in flight" for update_us microseconds.
    _ = executor.forward(active, payload[:1]).block_until_ready()
    t0 = time.perf_counter()
    detect_t = None
    effective_t = None
    wrong_model = 0
    wrong_verdict = 0
    next_emit = t0
    for i in range(n):
        while time.perf_counter() < next_emit:
            pass
        next_emit += pacing_us * 1e-6
        now = time.perf_counter()
        if detect_t is None and want_slots[i] != want_slots[0]:
            detect_t = now  # boundary observed -> control plane starts sending
        if detect_t is not None and effective_t is None:
            if (now - detect_t) * 1e6 >= update_us:
                active = dict(slot1_params)  # swap becomes effective
                effective_t = now
        stale = i >= boundary and effective_t is None
        _ = executor.forward(active, payload[i : i + 1]).block_until_ready()
        if stale:
            wrong_model += 1
            if v0[i] != v1[i]:
                wrong_verdict += 1
    if effective_t is None:
        effective_t = time.perf_counter()
    if detect_t is None:
        detect_t = effective_t
    return ControlPlaneResult(
        switch_latency_us=update_us,
        boundary_to_effective_us=(effective_t - detect_t) * 1e6,
        wrong_model_packets=wrong_model,
        wrong_verdict_packets=wrong_verdict,
        n_packets=n,
    )


def resident_switch_cost_us(bank, packets_np: np.ndarray, num_slots: int,
                            iters: int = 200) -> float:
    """Operation-level resident switching cost: the incremental cost of
    resolving a *different* slot vs re-resolving the same slot (Table V row 1
    uses the same definition as Fig. 4's slot-selection cost)."""
    x = jnp.asarray(packets_np)
    f = lambda: pipeline.slot_select_only(x, num_slots).block_until_ready()
    f()
    t0 = time.perf_counter()
    for _ in range(iters):
        f()
    per_call_us = (time.perf_counter() - t0) / iters * 1e6
    return per_call_us / packets_np.shape[0]
