"""Shared inline BNN executor (paper §II-B, Eq. 1) and its parameter bank.

The executor is *invariant across packets*: one function, one input format
(256 packed uint32 payload words = 1024 B), one output interface (C scores).
Only the referenced weight slot varies, resolved from packet metadata.

``h32`` is the paper's structure: d = 8192 input bits, hidden = 32, C = 1.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bank as bank_lib
from repro.core import packet as pkt
from repro.kernels import ops, ref as kref


@dataclasses.dataclass(frozen=True)
class BNNConfig:
    d_bits: int = pkt.PAYLOAD_BITS  # 8192
    hidden: int = 32                # "h32"
    n_out: int = 1

    @property
    def words(self) -> int:
        return self.d_bits // 32

    def param_bytes(self) -> int:
        """Resident footprint of one slot (packed W1 + b1 + W2 + b2)."""
        return (
            self.hidden * self.words * 4
            + self.hidden * 4
            + self.n_out * self.hidden * 4
            + self.n_out * 4
        )


H32 = BNNConfig()


def init_params(key, cfg: BNNConfig = H32):
    return kref.random_bnn_params(key, cfg.d_bits, cfg.hidden, cfg.n_out)


def init_bank(key, num_slots: int, cfg: BNNConfig = H32):
    """Preload K weight sets into a resident bank (paper Eq. 2-3)."""
    keys = jax.random.split(key, num_slots)
    return bank_lib.stack_bank([init_params(k, cfg) for k in keys])


def pack_real_weights(w1_real: np.ndarray, b1, w2, b2):
    """Binarize + pack a trained real-valued layer-1 (BinaryConnect-style)."""
    w1_pm = jnp.where(jnp.asarray(w1_real) >= 0, 1.0, -1.0)
    return {
        "w1p": kref.pack_bits(w1_pm),
        "b1": jnp.asarray(b1, jnp.float32),
        "w2": jnp.asarray(w2, jnp.float32),
        "b2": jnp.asarray(b2, jnp.float32),
    }


def forward(params, payload_words, *, backend: str = "auto"):
    """Single-slot executor: (B, 256) u32 -> (B, C) f32."""
    return ops.bnn_forward(params, payload_words, backend=backend)


def forward_banked(bank, payload_words, slots, *, strategy: str = "take",
                   backend: str = "auto", block_b: int = 256):
    """Slot-selected executor over the resident bank.

    ``grouped`` runs the zero-copy fused megakernel (one launch, DMA gather
    prologue, no padded batch materialized in HBM); ``grouped_staged`` keeps
    the pre-fused scatter -> kernel -> gather layout as a benchmark baseline.
    """
    if strategy in ("take", "onehot"):
        be = "mxu" if strategy == "onehot" else backend
        return ops.bnn_forward_banked(bank, payload_words, slots, backend=be)
    num_slots = bank_lib.bank_size(bank)
    bb = min(block_b, payload_words.shape[0])
    g = bank_lib.group_by_slot_padded(slots, num_slots, bb)
    if strategy in ("grouped", "fused"):
        y_pad = ops.bnn_forward_fused(
            bank, payload_words, g.block_slots, g.row_ids,
            block_b=bb, backend=backend,
        )
        return jnp.take(y_pad, g.result_rows, axis=0)
    if strategy == "grouped_staged":
        x_pad = bank_lib.scatter_padded(payload_words, g)
        y_pad = ops.bnn_forward_grouped(
            bank, x_pad, g.block_slots, block_b=bb, backend=backend
        )
        return bank_lib.gather_padded(y_pad, g)
    raise ValueError(f"unknown strategy {strategy!r}")
