"""olmoe-1b-7b — MoE, 64 experts top-8 [arXiv:2409.02060; hf].

Token->expert routing reuses the banked grouped-dispatch machinery: MoE is
the paper's sigma at token granularity (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    experts_per_token=8,
    bank_mode="adapter",
    bank_slots=4,
)
