"""arctic-480b — MoE 128 experts top-2 with a dense residual MLP per layer
[hf:Snowflake/snowflake-arctic-base; hf].

At ~480B total params this cell exists to prove state sharding: bf16 adam
moments + no fp32 master + experts sharded over the model axis and expert
matrices additionally sharded over data (ZeRO-style).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,              # 56 heads: flattened-qkv sharding path
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    experts_per_token=2,
    moe_dense_residual=True,
    master_weights=False,    # pure-bf16 params: 480B fp32 masters can't fit
    moments_dtype="bfloat16",
    bank_mode="head",
    bank_slots=4,
)
