"""The paper's own model: h32 BNN packet classifier behind the resident bank."""

from repro.core.executor import BNNConfig

CONFIG = BNNConfig(d_bits=8192, hidden=32, n_out=1)
