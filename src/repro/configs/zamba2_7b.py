"""zamba2-7b — hybrid: Mamba2 backbone + *shared* attention block applied
every 6 layers [arXiv:2411.15242; unverified].

The shared attention block is itself a resident shared executor (one weight
set referenced from many sites) — see DESIGN.md §5.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_chunk=256,
    attn_every=6,
    shared_attn=True,
    bank_mode="adapter",
    bank_slots=4,
)
