"""Architecture registry: ``--arch <id>`` resolution for all assigned archs."""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig, shape_applicable  # noqa: F401

ARCH_IDS = [
    "h2o-danube-3-4b",
    "smollm-360m",
    "deepseek-7b",
    "glm4-9b",
    "zamba2-7b",
    "olmoe-1b-7b",
    "arctic-480b",
    "llava-next-34b",
    "seamless-m4t-medium",
    "mamba2-130m",
    "boundswitch-h32",          # the paper's own model
]


def get_config(arch_id: str) -> ModelConfig:
    mod_name = arch_id.replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS if a != "boundswitch-h32"}
