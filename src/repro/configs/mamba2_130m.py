"""mamba2-130m — attention-free SSD (state-space duality)
[arXiv:2405.21060; unverified].

Attention-oriented sharding is inapplicable (DESIGN.md §Arch-applicability);
the bank applies in *full* mode — K complete residents, paper-faithful.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,               # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,        # padded to 50432 for TP divisibility
    ssm_state=128,
    ssm_head_dim=64,
    ssm_chunk=256,
    tie_embeddings=True,
    bank_mode="full",
    bank_slots=2,
)
