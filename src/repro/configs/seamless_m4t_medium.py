"""seamless-m4t-medium — multimodal encoder-decoder backbone
[arXiv:2308.11596; hf].

Audio frontend is a STUB: precomputed frame embeddings feed the encoder.
12L interpreted as 12 encoder + 12 decoder layers (m4t text-decoder depth).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=24,             # total: enc + dec
    n_enc_layers=12,
    n_dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,       # padded to 256256 for TP divisibility
    cross_len=4096,
    frontend="frame",
    frontend_len=0,          # encoder input IS the frame stream
    bank_mode="head",
    bank_slots=4,
)
