"""smollm-360m — llama-arch small dense GQA [hf:HuggingFaceTB/SmolLM; hf].

Small enough for the paper's *full* model residency: the whole param pytree
is banked K times, the closest LM analogue of BoundSwitch's weight bank.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,              # 15 heads: not divisible by TP=16 on purpose —
    n_kv_heads=5,            # sharding falls to the flattened qkv dim
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
    bank_mode="full",
    bank_slots=2,
)
