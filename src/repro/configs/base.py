"""Model / run configuration system.

One frozen dataclass covers every assigned architecture family; per-arch
files under ``repro/configs`` instantiate it with the exact published
hyper-parameters, and ``reduced()`` derives the CPU smoke-test variant.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None           # default d_model // n_heads
    sliding_window: Optional[int] = None     # SWA (h2o-danube3)

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_dense_residual: bool = False         # arctic: dense FFN in parallel
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # --- hybrid (zamba2): shared attention block every N ssm layers ---
    attn_every: int = 0
    shared_attn: bool = False

    # --- enc-dec (seamless) ---
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    cross_len: int = 4096                    # encoder-memory length at decode

    # --- modality frontend stubs (vlm / audio) ---
    frontend: Optional[str] = None           # "patch" | "frame"
    frontend_len: int = 0                    # embeddings prepended per sample

    # --- numerics / misc ---
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    dtype: str = "bfloat16"
    vocab_pad_multiple: int = 256
    tie_embeddings: bool = False

    # --- model bank (the paper's technique, lifted to this arch) ---
    bank_mode: str = "none"                  # none | full | adapter | head
    bank_slots: int = 2
    adapter_rank: int = 16

    # --- training ---
    remat: str = "full"                      # none | full
    master_weights: bool = True              # fp32 master copy of params
    moments_dtype: str = "float32"           # adam m/v dtype (bf16 for huge)

    # --- perf-iteration knobs (EXPERIMENTS.md §Perf; defaults = baseline) ---
    flash_remat: bool = False        # recompute flash inner scans in bwd
    seq_shard_attention: bool = False  # shard q-block seq dim over TP axis
                                       # (kills head-replication waste when
                                       # n_heads is not divisible by TP)
    cache_dtype: str = "model"       # "model" (= cfg.dtype) | "int8":
                                     # quantized KV cache with native int8
                                     # QK/PV dots (halves decode cache reads)
    seq_shard_activations: bool = False  # Megatron-SP: pin the residual
                                         # stream's token dim to the TP axis
                                         # between layers

    def __post_init__(self):
        if self.head_dim is None and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode state: SSM, hybrid, or bounded (SWA) cache."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def kv_cache_len(self, seq_len: int) -> int:
        """Per-layer attention cache length at decode for a given context."""
        if self.sliding_window is not None:
            return min(seq_len, self.sliding_window)
        return seq_len

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        return _param_count(self, active_only=True)

    def reduced(self, **over) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            vocab_pad_multiple=32,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32,
            ssm_chunk=16,
            attn_every=2 if self.attn_every else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_dec_layers=min(self.n_dec_layers, 2),
            cross_len=32,
            sliding_window=32 if self.sliding_window else None,
            frontend_len=8 if self.frontend else 0,
            adapter_rank=4,
            remat="none",
            name=self.name + "-reduced",
        )
        small.update(over)
        return dataclasses.replace(self, **small)


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    d, f, v = cfg.d_model, cfg.d_ff, cfg.padded_vocab
    hd = cfg.head_dim or 0
    q_dim = cfg.n_heads * hd
    kv_dim = cfg.n_kv_heads * hd

    def attn_params():
        return d * q_dim + 2 * d * kv_dim + q_dim * d

    def mlp_params(ff):
        return 3 * d * ff  # SwiGLU: gate, up, down

    def ssm_params():
        di = cfg.d_inner
        heads = cfg.ssm_heads
        g = 1  # single B/C group
        in_proj = d * (2 * di + 2 * g * cfg.ssm_state + heads)
        conv = cfg.ssm_conv_width * (di + 2 * g * cfg.ssm_state)
        out = di * d + di  # out_proj + D skip(+gate norm folded)
        return in_proj + conv + out + heads  # + A per head

    n = 2 * v * d if not cfg.tie_embeddings else v * d
    if cfg.family == "dense":
        per = attn_params() + mlp_params(f) + 2 * d
        n += cfg.n_layers * per
    elif cfg.family == "moe":
        e = cfg.experts_per_token if active_only else cfg.n_experts
        per = attn_params() + e * mlp_params(f) + d * cfg.n_experts + 2 * d
        if cfg.moe_dense_residual:
            per += mlp_params(f)
        n += cfg.n_layers * per
    elif cfg.family == "ssm":
        n += cfg.n_layers * (ssm_params() + d)
    elif cfg.family == "hybrid":
        n_attn_apps = cfg.n_layers // max(cfg.attn_every, 1)
        shared = attn_params() + mlp_params(f) + 2 * d
        n += cfg.n_layers * (ssm_params() + d)
        n += shared if cfg.shared_attn else n_attn_apps * shared
    elif cfg.family == "encdec":
        enc = attn_params() + mlp_params(f) + 2 * d
        dec = 2 * attn_params() + mlp_params(f) + 3 * d
        n += cfg.n_enc_layers * enc + cfg.n_dec_layers * dec
    else:
        raise ValueError(cfg.family)
    return n


# ---------------------------------------------------------------------------
# input shapes (assigned to every arch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a (arch x shape) cell runs; reason recorded when skipped."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: 500k dense KV decode has no sub-quadratic path (DESIGN.md §5)"
    return True, ""
