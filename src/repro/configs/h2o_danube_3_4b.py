"""h2o-danube-3-4b — dense llama+mistral mix with GQA + sliding-window
attention [arXiv:2401.16818; unverified]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,     # mistral-style SWA -> bounded decode cache
    rope_theta=500000.0,
    bank_mode="adapter",
    bank_slots=4,
)
