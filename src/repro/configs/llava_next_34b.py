"""llava-next-34b — VLM backbone (anyres tiling)
[hf:llava-hf/llava-v1.6; unverified].

Per assignment the modality frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings (B, frontend_len, d_model) prepended to the
token stream; only the transformer backbone is modeled.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    frontend="patch",
    frontend_len=576,        # one 24x24 ViT tile of patch embeddings
    bank_mode="head",
    bank_slots=4,
)
