"""Mamba2 / SSD (state-space duality) block — chunked scan formulation.

The sequence is split into chunks of ``cfg.ssm_chunk``; within a chunk the
quadratic dual form runs (attention-like einsums on (l, l) decay matrices),
between chunks a `lax.scan` carries the (B, H, P, N) state.  The quadratic
intermediates live only inside one scan step, so activation memory stays
O(chunk^2) instead of O(seq^2).

``ssd_sequential`` is the token-recurrence oracle used by the tests; the
decode path reuses the same recurrence for O(1)-state generation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.nn.modules import _dense_init, cdtype, rmsnorm, rmsnorm_init


def ssm_dims(cfg: ModelConfig):
    di = cfg.d_inner
    h = cfg.ssm_heads
    n = cfg.ssm_state
    conv_dim = di + 2 * n  # conv runs over [x, B, C]
    return di, h, n, conv_dim


def mamba_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, h, n, conv_dim = ssm_dims(cfg)
    kin, kconv, kdt, kout = jax.random.split(key, 4)
    dt = cdtype(cfg)
    proj_out = 2 * di + 2 * n + h  # [z, x, B, C, dt]
    return {
        "in_proj": _dense_init(kin, (d, proj_out), dt),
        "conv_w": _dense_init(kconv, (cfg.ssm_conv_width, conv_dim), dt, scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),  # A = -exp(A_log) = -1 at init
        "D": jnp.ones((h,), jnp.float32),
        "norm": rmsnorm_init(di, dt),
        "out_proj": _dense_init(kout, (di, d), dt, scale=di ** -0.5),
    }


# ---------------------------------------------------------------------------
# core SSD math
# ---------------------------------------------------------------------------

def _segsum(cum):
    """cum: (..., L) inclusive cumsum -> (..., L, L) lower-tri pair sums
    ``exp`` argument: cum_i - cum_j for i >= j, -inf above the diagonal."""
    l = cum.shape[-1]
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, chunk: int, init_state=None):
    """SSD over a full sequence.

    x: (B, S, H, P) values; dt: (B, S, H) positive step sizes;
    a: (H,) negative decay rates; b, c: (B, S, N) (single B/C group).
    Returns y: (B, S, H, P) and final state (B, H, P, N).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    if s % chunk:
        raise ValueError(f"seq {s} not divisible by chunk {chunk}")
    nc = s // chunk

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b.reshape(bsz, nc, chunk, n)
    cc = c.reshape(bsz, nc, chunk, n)

    state0 = (
        jnp.zeros((bsz, h, p, n), jnp.float32)
        if init_state is None else init_state.astype(jnp.float32)
    )

    def chunk_step(state, inp):
        xk, dtk, bk, ck = inp  # (B, L, H, P), (B, L, H), (B, L, N), (B, L, N)
        dta = dtk.astype(jnp.float32) * a  # (B, L, H)
        cum = jnp.cumsum(dta, axis=1)      # inclusive
        # intra-chunk (dual quadratic form)
        lmat = jnp.exp(_segsum(cum.transpose(0, 2, 1)))        # (B, H, L, L)
        scores = jnp.einsum("bin,bjn->bij", ck.astype(jnp.float32),
                            bk.astype(jnp.float32))            # (B, L, L)
        m = scores[:, None] * lmat                              # (B, H, i, j)
        xdt = xk.astype(jnp.float32) * dtk[..., None]           # (B, L, H, P)
        y_intra = jnp.einsum("bhij,bjhp->bihp", m, xdt)
        # inter-chunk (incoming state)
        decay_in = jnp.exp(cum)                                 # (B, L, H)
        y_inter = jnp.einsum("bin,bhpn->bihp", ck.astype(jnp.float32), state)
        y_inter = y_inter * decay_in[..., None]
        # state update
        decay_out = jnp.exp(cum[:, -1:, :] - cum)               # (B, L, H)
        new_state = jnp.einsum(
            "blh,bln,blhp->bhpn", decay_out * dtk, bk.astype(jnp.float32), xk.astype(jnp.float32)
        )
        state = jnp.exp(cum[:, -1])[..., None, None] * state + new_state
        return state, (y_intra + y_inter).astype(x.dtype)

    inputs = (
        xc.transpose(1, 0, 2, 3, 4),
        dtc.transpose(1, 0, 2, 3),
        bc.transpose(1, 0, 2, 3),
        cc.transpose(1, 0, 2, 3),
    )
    final_state, yc = lax.scan(chunk_step, state0, inputs)
    y = yc.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, p)
    return y, final_state


def ssd_sequential(x, dt, a, b, c, init_state=None):
    """Token-recurrence oracle: state_t = exp(dt_t a) state + dt_t b_t x_t."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    state0 = (
        jnp.zeros((bsz, h, p, n), jnp.float32)
        if init_state is None else init_state.astype(jnp.float32)
    )

    def step(state, inp):
        xt, dtt, bt, ct = inp
        state, yt = ssd_decode_step(state, xt, dtt, a, bt, ct)
        return state, yt

    inputs = (
        x.transpose(1, 0, 2, 3),
        dt.transpose(1, 0, 2),
        b.transpose(1, 0, 2),
        c.transpose(1, 0, 2),
    )
    state, ys = lax.scan(step, state0, inputs)
    return ys.transpose(1, 0, 2, 3), state


def ssd_decode_step(state, xt, dtt, a, bt, ct):
    """One-token recurrence.  state: (B,H,P,N); xt: (B,H,P); dtt: (B,H);
    bt, ct: (B,N).  Returns (new_state, y_t (B,H,P))."""
    decay = jnp.exp(dtt.astype(jnp.float32) * a)                # (B, H)
    upd = jnp.einsum(
        "bh,bn,bhp->bhpn", dtt.astype(jnp.float32),
        bt.astype(jnp.float32), xt.astype(jnp.float32),
    )
    state = decay[..., None, None] * state + upd
    yt = jnp.einsum("bn,bhpn->bhp", ct.astype(jnp.float32), state)
    return state, yt.astype(xt.dtype)


# ---------------------------------------------------------------------------
# full mamba2 block
# ---------------------------------------------------------------------------

def _split_proj(proj, cfg: ModelConfig):
    di, h, n, _ = ssm_dims(cfg)
    z, xin, b, c, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
    )
    return z, xin, b, c, dt


def _causal_conv(xbc, conv_w, conv_b, width: int):
    """Depthwise causal conv over (B, S, C)."""
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * conv_w[i][None, None, :]
        for i in range(width)
    )
    return out + conv_b[None, None, :]


def mamba_apply(params, x, cfg: ModelConfig, *, ssm_state=None, conv_state=None,
                pad_mask=None, last_valid=None):
    """Mamba2 block.  Full-sequence when states are None; otherwise one-token
    decode carrying (ssm_state (B,H,P,N), conv_state (B, width-1, conv_dim)).

    ``pad_mask`` (B, S) zeroes dt at right-pad positions so the carried SSM
    state is exact for bucketed prefill; ``last_valid`` (B,) makes the carried
    conv window end at each row's true prompt end.

    Returns (out (B,S,d), new_ssm_state, new_conv_state).
    """
    bsz, s, _ = x.shape
    di, h, n, conv_dim = ssm_dims(cfg)
    w = cfg.ssm_conv_width
    proj = x @ params["in_proj"]
    z, xin, b, c, dt_raw = _split_proj(proj, cfg)

    xbc = jnp.concatenate([xin, b, c], axis=-1)  # (B, S, conv_dim)
    if conv_state is None:
        conv_out = _causal_conv(xbc, params["conv_w"], params["conv_b"], w)
        if last_valid is not None:
            padded = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
            new_conv_state = jax.vmap(
                lambda row, end: lax.dynamic_slice_in_dim(row, end, w - 1, 0)
            )(padded, last_valid)  # window ending at each row's prompt end
        else:
            new_conv_state = xbc[:, -(w - 1):, :] if s >= w - 1 else jnp.pad(
                xbc, ((0, 0), (w - 1 - s, 0), (0, 0)))
    else:
        window = jnp.concatenate([conv_state, xbc], axis=1)  # (B, w, C)
        conv_out = (
            jnp.einsum("bwc,wc->bc", window, params["conv_w"]) + params["conv_b"]
        )[:, None, :]
        new_conv_state = window[:, 1:, :]
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xs, bs, cs = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    if pad_mask is not None and ssm_state is None:
        dt = dt * pad_mask[..., None].astype(dt.dtype)  # pads: no state update
    a = -jnp.exp(params["A_log"])
    xh = xs.reshape(bsz, s, h, cfg.ssm_head_dim)

    if ssm_state is None:
        chunk = min(cfg.ssm_chunk, s)
        while s % chunk:
            chunk //= 2
        y, new_state = ssd_chunked(xh, dt, a, bs, cs, max(chunk, 1))
    else:
        new_state, yt = ssd_decode_step(
            ssm_state, xh[:, 0], dt[:, 0], a, bs[:, 0], cs[:, 0]
        )
        y = yt[:, None]
    y = y + params["D"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(bsz, s, di)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                cfg.norm_eps)
    return y @ params["out_proj"], new_state, new_conv_state


def init_mamba_state(cfg: ModelConfig, batch: int):
    di, h, n, conv_dim = ssm_dims(cfg)
    return (
        jnp.zeros((batch, h, cfg.ssm_head_dim, n), jnp.float32),
        jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), cdtype(cfg)),
    )
