"""Functional NN building blocks (no flax — params are plain pytrees).

Conventions
-----------
* every module is an ``init(key, cfg, ...) -> params`` / ``apply(params, x, ...)``
  pair; params are nested dicts with stable key names that the sharding rules
  in ``repro.distributed.sharding`` match by path,
* weights live in ``cfg.dtype`` (bf16), all reductions / softmax / norms
  accumulate in fp32,
* attention is a pure-JAX flash formulation (q-block scan with online
  softmax over kv-block scan) so 32k-sequence compiles stay memory-bounded;
  the quadratic-score reference lives in tests.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    sin = jnp.sin(angles)[..., :, None, :]
    cos = jnp.cos(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, optional sliding window) — flash formulation
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    dt = cdtype(cfg)
    return {
        "wq": _dense_init(kq, (d, cfg.n_heads * hd), dt),
        "wk": _dense_init(kk, (d, cfg.n_kv_heads * hd), dt),
        "wv": _dense_init(kv, (d, cfg.n_kv_heads * hd), dt),
        "wo": _dense_init(ko, (cfg.n_heads * hd, d), dt, scale=(cfg.n_heads * hd) ** -0.5),
    }


def _flash_body(q, k, v, *, causal: bool, window: Optional[int],
                q_offset, k_offset, q_block: int, k_block: int,
                remat: bool = False, seq_shard_axis: Optional[str] = None):
    """Online-softmax attention.

    q: (B, G, gq, Sq, D); k, v: (B, G, Skv, D).  Offsets give absolute
    positions (decode / cache reads use q_offset = cache_len).
    Returns (B, G, gq, Sq, D) in q.dtype.

    ``remat``: recompute the inner kv scan in the backward pass instead of
    saving per-iteration softmax residuals (flash-style backward).
    ``seq_shard_axis``: shard the q-token dim of each block over this mesh
    axis — recovers TP parallelism for archs whose head count does not
    divide the TP degree (the heads would otherwise replicate).
    """
    bsz, g, gq, sq, d = q.shape
    skv = k.shape[2]
    scale = d ** -0.5
    nqb = sq // q_block
    nkb = skv // k_block
    neg = jnp.finfo(jnp.float32).min

    if seq_shard_axis is not None:
        # one reshard per layer: the whole q tensor (and its output) shard
        # their token dim over the TP axis; the q-block scan is collapsed so
        # the backward pass re-runs ONE sharded pass, not nqb reshards.
        q = lax.with_sharding_constraint(
            q, jax.sharding.PartitionSpec(None, None, None, seq_shard_axis, None))
        q_block = sq
        nqb = 1

    def q_step(_, iq):
        qs = lax.dynamic_slice_in_dim(q, iq * q_block, q_block, 3)
        qpos = q_offset + iq * q_block + jnp.arange(q_block)

        def kv_step(carry, jk):
            m, l, acc = carry
            ks = lax.dynamic_slice_in_dim(k, jk * k_block, k_block, 2)
            vs = lax.dynamic_slice_in_dim(v, jk * k_block, k_block, 2)
            kpos = k_offset + jk * k_block + jnp.arange(k_block)
            s = jnp.einsum(
                "bghqd,bgkd->bghqk", qs, ks,
                preferred_element_type=jnp.float32,
            ) * scale
            mask = jnp.ones((q_block, k_block), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(mask[None, None, None], s, neg)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bghqk,bgkd->bghqd", p.astype(vs.dtype), vs,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((bsz, g, gq, q_block), neg, jnp.float32),
            jnp.zeros((bsz, g, gq, q_block), jnp.float32),
            jnp.zeros((bsz, g, gq, q_block, d), jnp.float32),
        )
        (m, l, acc), _ = lax.scan(kv_step, init, jnp.arange(nkb))
        out = acc / jnp.maximum(l, 1e-37)[..., None]
        return None, out.astype(q.dtype)

    step = jax.checkpoint(q_step) if remat else q_step
    _, blocks = lax.scan(step, None, jnp.arange(nqb))  # (nqb, B, G, gq, qb, D)
    out = jnp.moveaxis(blocks, 0, 3).reshape(bsz, g, gq, sq, d)
    return out


def _quantize_rows(x, axis=-1):
    """Symmetric int8 quantization with per-row scale over ``axis``."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=axis) / 127.0
    q = jnp.clip(
        jnp.round(xf / jnp.maximum(scale, 1e-8)[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale


def _decode_attention_int8(q, k, v, kv_cache, slot, valid, upd, hd):
    """Single-token attention over an int8-quantized KV cache.

    Cache: k/v int8 (B, G, L, D) + k_scale/v_scale f32 (B, G, L) (per token
    per kv-head).  Both contractions run as native int8 dots (int32
    accumulation) — the cache is never materialized in a wider dtype, so
    HBM traffic halves.  The per-position v scale cannot be factored out of
    the PV sum, so it is folded into the probabilities before requantizing.
    """
    kq_new, ks_new = _quantize_rows(k)           # (B,G,1,D)i8, (B,G,1)f32
    vq_new, vs_new = _quantize_rows(v)
    upd_s = jax.vmap(
        lambda c, s_, i: jax.lax.dynamic_update_slice_in_dim(c, s_, i, axis=1)
    )
    ck = upd(kv_cache["k"], kq_new, slot)
    cv = upd(kv_cache["v"], vq_new, slot)
    cks = upd_s(kv_cache["k_scale"], ks_new, slot)
    cvs = upd_s(kv_cache["v_scale"], vs_new, slot)

    qq, qs = _quantize_rows(q)                   # (B,G,gq,1,D)i8, (B,G,gq,1)
    scores_i = jnp.einsum(
        "bghqd,bgkd->bghqk", qq, ck, preferred_element_type=jnp.int32
    )
    scores = scores_i.astype(jnp.float32) * qs[..., None] \
        * cks[:, :, None, None, :] * (hd ** -0.5)
    scores = jnp.where(valid[:, None, None, None], scores,
                       jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(scores, axis=-1)
    w = p * cvs[:, :, None, None, :]             # fold per-token v scale in
    wq, ws = _quantize_rows(w)
    out_i = jnp.einsum(
        "bghqk,bgkd->bghqd", wq, cv, preferred_element_type=jnp.int32
    )
    out = out_i.astype(jnp.float32) * ws[..., None]
    return out, {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}


def _pick_block(s: int, target: int) -> int:
    b = min(target, s)
    while s % b:
        b //= 2
    return max(b, 1)


def attention_apply(
    params,
    x,
    cfg: ModelConfig,
    *,
    positions,
    kv_cache: Optional[dict] = None,
    cache_len=None,
    causal: bool = True,
    q_block: int = 512,
    k_block: int = 1024,
):
    """Self-attention over x: (B, S, d).

    Training / prefill: ``kv_cache is None`` -> flash over the sequence;
    returns (out, new_kv) where new_kv holds the full k/v (prefill cache).
    Decode: ``kv_cache = {"k","v"}`` (B, G, L, D) with ``cache_len`` tokens
    valid -> writes the new token at ``cache_len`` and attends over the cache.
    """
    bsz, s, d = x.shape
    hq, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    gq = hq // g
    q = (x @ params["wq"]).reshape(bsz, s, hq, hd)
    k = (x @ params["wk"]).reshape(bsz, s, g, hd)
    v = (x @ params["wv"]).reshape(bsz, s, g, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    # (B, G, gq, S, D) / (B, G, S, D)
    q = q.reshape(bsz, s, g, gq, hd).transpose(0, 2, 3, 1, 4)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    if kv_cache is None:
        qb = _pick_block(s, q_block)
        kb = _pick_block(s, k_block)
        out = _flash_body(
            q, k, v, causal=causal, window=cfg.sliding_window,
            q_offset=0, k_offset=0, q_block=qb, k_block=kb,
            remat=cfg.flash_remat,
            seq_shard_axis="model" if cfg.seq_shard_attention else None,
        )
        new_cache = {"k": k, "v": v}
    else:
        # decode: s == 1; write-then-attend against the cache.  ``cache_len``
        # may be a scalar (synchronous dry-run stepping) or a (B,) vector
        # (serving engine: every row at its own offset).
        lcache = kv_cache["k"].shape[2]
        cl = jnp.broadcast_to(jnp.atleast_1d(cache_len), (bsz,)).astype(jnp.int32)
        if cfg.sliding_window is not None:
            slot = cl % lcache
        else:
            slot = cl
        upd = jax.vmap(
            lambda c, kk, i: lax.dynamic_update_slice_in_dim(c, kk, i, axis=1)
        )
        kpos = jnp.arange(lcache)
        if cfg.sliding_window is None:
            valid = kpos[None, :] <= cl[:, None]
        else:  # ring buffer: everything resident is in-window
            valid = kpos[None, :] < jnp.minimum(cl + 1, lcache)[:, None]

        if "k_scale" in kv_cache:
            out, new_cache = _decode_attention_int8(
                q, k, v, kv_cache, slot, valid, upd, hd)
        else:
            ck = upd(kv_cache["k"], k, slot)
            cv = upd(kv_cache["v"], v, slot)
            scores = jnp.einsum(
                "bghqd,bgkd->bghqk", q, ck, preferred_element_type=jnp.float32
            ) * (hd ** -0.5)
            scores = jnp.where(valid[:, None, None, None], scores,
                               jnp.finfo(jnp.float32).min)
            p = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum(
                "bghqk,bgkd->bghqd", p.astype(cv.dtype), cv,
                preferred_element_type=jnp.float32,
            )
            new_cache = {"k": ck, "v": cv}
        out = out.astype(x.dtype)

    out = out.transpose(0, 3, 1, 2, 4).reshape(bsz, s, hq * hd)
    return out @ params["wo"], new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    kg, ku, kd = jax.random.split(key, 3)
    dt = cdtype(cfg)
    return {
        "wg": _dense_init(kg, (d, f), dt),
        "wu": _dense_init(ku, (d, f), dt),
        "wd": _dense_init(kd, (f, d), dt, scale=f ** -0.5),
    }


def mlp_apply(params, x):
    h = jax.nn.silu((x @ params["wg"]).astype(jnp.float32)).astype(x.dtype)
    return (h * (x @ params["wu"])) @ params["wd"]


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig) -> dict:
    v, d = cfg.padded_vocab, cfg.d_model
    p = {"embedding": _dense_init(key, (v, d), cdtype(cfg), scale=1.0)}
    # zero the padded rows so they never contribute
    if cfg.padded_vocab != cfg.vocab_size:
        mask = (jnp.arange(v) < cfg.vocab_size)[:, None]
        p["embedding"] = p["embedding"] * mask.astype(p["embedding"].dtype)
    return p


def embed_apply(params, tokens):
    return params["embedding"][tokens]


def logits_apply(embed_params, head_params, x, cfg: ModelConfig):
    """Project to (padded) vocab; padded rows masked to -inf."""
    if cfg.tie_embeddings:
        w = embed_params["embedding"]
        logits = jnp.einsum(
            "bsd,vd->bsv", x, w, preferred_element_type=jnp.float32
        )
    else:
        logits = jnp.einsum(
            "bsd,dv->bsv", x, head_params["w"], preferred_element_type=jnp.float32
        )
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.finfo(jnp.float32).min, logits)
    return logits


def head_init(key, cfg: ModelConfig) -> dict:
    if cfg.tie_embeddings:
        return {}
    return {"w": _dense_init(key, (cfg.d_model, cfg.padded_vocab), cdtype(cfg))}
