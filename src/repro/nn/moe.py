"""Mixture-of-Experts layer with sort-based capacity dispatch.

Structurally this is BoundSwitch's grouped slot selection at *token*
granularity (DESIGN.md §5): the router computes the slot (expert) ids, tokens
are grouped so each expert processes a contiguous capacity block, and the
expert weights — a resident bank stacked (E, ...) — are indexed, never moved.
The dispatch math mirrors ``repro.core.bank.group_by_slot_padded`` with a
fixed per-slot capacity instead of block-multiple padding (overflow drops,
as standard for capacity-factor MoE).

Sharding: expert tensors carry a leading E axis sharded over the ``model``
mesh axis; dispatch/combine scatter-gathers become all-to-alls under GSPMD.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.modules import _dense_init, cdtype


def moe_init(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    dt = cdtype(cfg)
    return {
        "router": _dense_init(kr, (d, e), jnp.float32),
        "wg": _dense_init(kg, (e, d, f), dt),
        "wu": _dense_init(ku, (e, d, f), dt),
        "wd": _dense_init(kd, (e, f, d), dt, scale=f ** -0.5),
    }


@dataclasses.dataclass
class Dispatch:
    dest: jnp.ndarray     # (T*k,) destination row in the (E*C) buffer
    token: jnp.ndarray    # (T*k,) source token index
    weight: jnp.ndarray   # (T*k,) combine weight (0 for dropped)
    capacity: int


def dispatch_by_expert(expert_ids, gate_weights, n_experts: int, capacity: int) -> Dispatch:
    """Group (token, expert) assignments into per-expert capacity blocks.

    expert_ids / gate_weights: (T, k).  Overflow beyond ``capacity`` per
    expert is dropped (weight zeroed), underflow rows stay zero — every
    expert sees exactly ``capacity`` rows, so expert matmuls are dense and
    identically shaped (the shared-executor property).

    Assignments with ``expert_id == n_experts`` (masked pad tokens) sort
    after every real assignment and never consume capacity.
    """
    t, k = expert_ids.shape
    flat_e = expert_ids.reshape(-1)
    flat_w = gate_weights.reshape(-1)
    flat_t = jnp.arange(t * k, dtype=jnp.int32) // k
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=n_experts + 1)
    seg_start = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * k) - seg_start[sorted_e]
    keep = (rank < capacity) & (sorted_e < n_experts)
    dest = jnp.where(keep, sorted_e * capacity + rank, n_experts * capacity)  # OOB drops
    return Dispatch(
        dest=dest.astype(jnp.int32),
        token=flat_t[order],
        weight=jnp.where(keep, flat_w[order], 0.0),
        capacity=capacity,
    )


def moe_apply(params, x, cfg: ModelConfig, *, capacity: int | None = None,
              token_mask=None):
    """x: (B, S, d) -> (B, S, d); also returns the router aux loss.

    ``token_mask`` (B, S): masked (pad) tokens are excluded from dispatch —
    they never consume expert capacity and contribute zero output.
    """
    bsz, s, d = x.shape
    t = bsz * s
    e, k = cfg.n_experts, cfg.experts_per_token
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ params["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, expert_ids = jax.lax.top_k(probs, k)                  # (T, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
    if token_mask is not None:
        tm = token_mask.reshape(t) > 0
        expert_ids = jnp.where(tm[:, None], expert_ids, e)  # pads -> drop id
        gate_w = jnp.where(tm[:, None], gate_w, 0.0)

    if capacity is None:
        capacity = int(cfg.moe_capacity_factor * t * k / e)
        capacity = max(8, -(-capacity // 8) * 8)                  # mult of 8
    disp = dispatch_by_expert(expert_ids, gate_w, e, capacity)

    # scatter tokens into per-expert capacity blocks (rows beyond E*C drop)
    buf = jnp.zeros((e * capacity, d), x.dtype)
    buf = buf.at[disp.dest].set(xt[disp.token], mode="drop")
    he = buf.reshape(e, capacity, d)

    g = jnp.einsum("ecd,edf->ecf", he, params["wg"], preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", he, params["wu"], preferred_element_type=jnp.float32)
    hidden = (jax.nn.silu(g) * u).astype(x.dtype)
    out_e = jnp.einsum("ecf,efd->ecd", hidden, params["wd"],
                       preferred_element_type=jnp.float32).astype(x.dtype)

    gathered = out_e.reshape(e * capacity, d)[jnp.clip(disp.dest, 0, e * capacity - 1)]
    contrib = gathered * disp.weight[:, None].astype(x.dtype)
    yt = jnp.zeros((t, d), x.dtype).at[disp.token].add(contrib)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=0)                                       # (E,)
    ce = jnp.zeros(e).at[expert_ids.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)
    return yt.reshape(bsz, s, d), aux
