"""Fault-tolerant training loop.

Production behaviors implemented (and unit-tested):

* periodic checkpointing with atomic commit + keep-last-N GC,
* preemption handling: SIGTERM or a flag file triggers an immediate
  checkpoint and clean exit (exit code distinguishes preemption),
* exact resume: optimizer state, step counter and the data-pipeline cursor
  are part of the checkpoint; restart reproduces the identical stream,
* elastic restart: restore re-places arrays onto the *current* mesh
  (any device count),
* straggler monitor: per-step wall times feed an EWMA; hosts slower than
  ``straggler_factor`` x the fleet median are flagged for data-shard
  reassignment (the reassignment plan is computed and logged; with one
  process it is exercised by tests via synthetic timings).
"""

from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import store
from repro.configs.base import ModelConfig
from repro.data.tokens import SyntheticTokens, TokenPipelineConfig
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts_lib


# ---------------------------------------------------------------------------
# straggler monitoring
# ---------------------------------------------------------------------------

class StragglerMonitor:
    def __init__(self, n_hosts: int, factor: float = 1.5, alpha: float = 0.3):
        self.ewma = np.zeros(n_hosts)
        self.factor = factor
        self.alpha = alpha
        self.initialized = False

    def observe(self, per_host_seconds: np.ndarray) -> list[int]:
        """Update with one step's per-host times; returns flagged host ids."""
        if not self.initialized:
            self.ewma = per_host_seconds.astype(float).copy()
            self.initialized = True
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * per_host_seconds
        med = np.median(self.ewma)
        return [int(i) for i in np.nonzero(self.ewma > self.factor * med)[0]]

    def reassignment_plan(self, flagged: list[int], n_shards: int) -> dict[int, int]:
        """Move one data shard from each flagged host to the fastest host."""
        if not flagged:
            return {}
        fastest = int(np.argmin(self.ewma))
        return {h: fastest for h in flagged if h != fastest}


# ---------------------------------------------------------------------------
# trainer
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    keep_last: int = 3
    checkpoint_dir: str = "/tmp/repro_ckpt"
    preempt_flag_file: Optional[str] = None
    log_every: int = 10
    num_microbatches: int = 1
    compress_gradients: bool = False


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        opt_cfg: opt_lib.OptimizerConfig,
        tcfg: TrainerConfig,
        data: SyntheticTokens,
        *,
        seed: int = 0,
        make_batch: Optional[Callable] = None,
    ):
        self.cfg, self.opt_cfg, self.tcfg, self.data = cfg, opt_cfg, tcfg, data
        self._preempted = False
        self.make_batch = make_batch or (lambda b: {
            k: jax.numpy.asarray(v) for k, v in b.items()
        })
        self.step_fn = jax.jit(ts_lib.make_train_step(
            cfg, opt_cfg,
            num_microbatches=tcfg.num_microbatches,
            compress_gradients=tcfg.compress_gradients,
        ), donate_argnums=(0,))
        key = jax.random.PRNGKey(seed)
        self.state = ts_lib.init_train_state(key, cfg, opt_cfg)
        self.metrics_log: list[dict] = []
        self.monitor = StragglerMonitor(n_hosts=max(jax.process_count(), 1))

    # ------------------------------------------------------------------
    def _install_signal_handler(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not on the main thread (tests)

    def _should_preempt(self) -> bool:
        if self._preempted:
            return True
        f = self.tcfg.preempt_flag_file
        return bool(f and os.path.exists(f))

    # ------------------------------------------------------------------
    def save(self):
        step = int(self.state["step"])
        store.save(
            self.tcfg.checkpoint_dir, step, self.state,
            extra={"data_cursor": self.data.cursor, "model": self.cfg.name},
            keep_last=self.tcfg.keep_last,
        )

    def try_restore(self) -> bool:
        latest = store.latest_step(self.tcfg.checkpoint_dir)
        if latest is None:
            return False
        self.state, extra = store.restore(
            self.tcfg.checkpoint_dir, latest, self.state
        )
        self.data.restore(extra["data_cursor"])
        return True

    # ------------------------------------------------------------------
    def run(self) -> dict:
        """Returns {"status": "done"|"preempted", "steps_run": n}."""
        self._install_signal_handler()
        steps_run = 0
        while int(self.state["step"]) < self.tcfg.total_steps:
            if self._should_preempt():
                self.save()
                return {"status": "preempted", "steps_run": steps_run}
            t0 = time.perf_counter()
            batch = self.make_batch(next(self.data))
            self.state, metrics = self.step_fn(self.state, batch)
            step = int(self.state["step"])
            dt = time.perf_counter() - t0
            self.monitor.observe(np.array([dt]))
            steps_run += 1
            if step % self.tcfg.log_every == 0 or step == 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["sec_per_step"] = dt
                self.metrics_log.append(m)
            if step % self.tcfg.checkpoint_every == 0:
                self.save()
        self.save()
        return {"status": "done", "steps_run": steps_run}
