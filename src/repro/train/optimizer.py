"""AdamW (+ warmup-cosine schedule, global-norm clip) built from scratch.

Mixed-precision policy:
  * params live in the model dtype (bf16 by default),
  * ``master_weights=True`` keeps an fp32 master copy in the optimizer state
    (updates apply to the master, params are re-cast each step),
  * ``moments_dtype`` lets enormous models (arctic-480b) hold m/v in bf16 —
    halves optimizer HBM at negligible quality cost.

Optimizer state shards exactly like the params (same PartitionSpec tree), so
FSDP-sharded params give ZeRO-sharded optimizer state for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moments_dtype: str = "float32"
    master_weights: bool = True


def lr_schedule(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.learning_rate * step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cosine = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac)
    )
    return jnp.where(step < cfg.warmup_steps, warm, cfg.learning_rate * cosine)


def _distinct_cast(x, dtype):
    """astype that never aliases its input buffer (same-dtype astype returns
    the identical array, which breaks donation when both params and master
    are passed to a donating jit — `f(donate(a), donate(a))`)."""
    y = x.astype(dtype)
    if y is x:
        y = x + jnp.zeros((), x.dtype)
    return y


def adamw_init(params, cfg: OptimizerConfig) -> dict:
    mdt = jnp.dtype(cfg.moments_dtype)
    state = {
        "m": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, mdt), params),
        "v": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, mdt), params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.master_weights:
        state["master"] = jax.tree_util.tree_map(
            lambda p: _distinct_cast(p, jnp.float32), params
        )
    return state


def global_norm(tree) -> jnp.ndarray:
    sq = sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)
    )
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(grads, state, params, cfg: OptimizerConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    grads, grad_norm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    ref = state.get("master", params)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        mhat = m_new / bc1
        vhat = v_new / bc2
        pf = p.astype(jnp.float32)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pf
        return m_new.astype(m.dtype), v_new.astype(v.dtype), pf - lr * delta

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(ref)
    new_m, new_v, new_ref = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        mn, vn, pn = upd(g, m, v, p)
        new_m.append(mn)
        new_v.append(vn)
        new_ref.append(pn)
    new_state = {
        "m": jax.tree_util.tree_unflatten(treedef, new_m),
        "v": jax.tree_util.tree_unflatten(treedef, new_v),
        "step": step,
    }
    new_ref_tree = jax.tree_util.tree_unflatten(treedef, new_ref)
    if cfg.master_weights:
        new_state["master"] = new_ref_tree
    param_dtypes = jax.tree_util.tree_map(lambda p: p.dtype, params)
    new_params = jax.tree_util.tree_map(
        lambda p, dt: _distinct_cast(p, dt) if cfg.master_weights
        else p.astype(dt),
        new_ref_tree, param_dtypes,
    )
    return new_params, new_state, {"lr": lr, "grad_norm": grad_norm}
