"""BNN training with straight-through estimation (BinaryConnect-style).

Trains the paper's h32 classifier on the synthetic IoT-23-like workload.
Latent weights are real-valued; the forward pass binarizes layer 1 with a
straight-through ``sign``; ``pos_weight`` reproduces the recall-oriented
(4.0) vs precision-oriented (0.5) slot pair of Fig. 6.  The trained latents
are packed into the resident-bank format via ``executor.pack_real_weights``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import executor
from repro.data import packets as pk
from repro.train.losses import weighted_bce_with_logits


def ste_sign(x):
    """sign with identity gradient inside [-1, 1] (STE)."""
    s = jnp.where(x >= 0, 1.0, -1.0)
    zero_grad = jax.lax.stop_gradient(s - jnp.clip(x, -1.0, 1.0))
    return zero_grad + jnp.clip(x, -1.0, 1.0)


def init_latent(key, cfg: executor.BNNConfig = executor.H32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, h, c = cfg.d_bits, cfg.hidden, cfg.n_out
    return {
        "w1": jax.random.normal(k1, (h, d)) * 0.01,
        "b1": jnp.zeros((h,)),
        "w2": jax.random.normal(k2, (c, h)) * (1.0 / np.sqrt(h)),
        "b2": jnp.zeros((c,)),
    }


def latent_forward(latent, x_pm1):
    """x_pm1: (B, d) in {+-1}.  Binary weights + binary activations w/ STE."""
    w1b = ste_sign(latent["w1"])
    pre = x_pm1 @ w1b.T + latent["b1"]
    h = ste_sign(pre / np.sqrt(x_pm1.shape[-1]))  # normalized pre-activation
    return h @ latent["w2"].T + latent["b2"]


@functools.partial(jax.jit, static_argnames=("pos_weight", "lr"))
def _sgd_step(latent, x, y, *, pos_weight: float, lr: float):
    def loss_fn(p):
        scores = latent_forward(p, x)[:, 0]
        return weighted_bce_with_logits(scores, y, pos_weight)

    loss, grads = jax.value_and_grad(loss_fn)(latent)
    latent = jax.tree_util.tree_map(lambda p, g: p - lr * g, latent, grads)
    return latent, loss


def train_bnn(
    key,
    x_train: np.ndarray,     # (N, 8192) +-1 float
    y_train: np.ndarray,     # (N,) {0,1}
    *,
    pos_weight: float,
    epochs: int = 5,
    batch: int = 256,
    lr: float = 0.05,
    cfg: executor.BNNConfig = executor.H32,
):
    latent = init_latent(key, cfg)
    n = x_train.shape[0]
    rng = np.random.default_rng(0)
    losses = []
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            idx = order[i : i + batch]
            latent, loss = _sgd_step(
                latent, jnp.asarray(x_train[idx]), jnp.asarray(y_train[idx]),
                pos_weight=pos_weight, lr=lr,
            )
            losses.append(float(loss))
    return latent, losses


def pack_trained(latent, cfg: executor.BNNConfig = executor.H32) -> dict:
    """Latent -> packed resident-slot params (bit-exact executor semantics).

    The packed executor computes ``sign(W1b x + b1)``; training used the
    sqrt(d)-normalized pre-activation, so b1 is rescaled accordingly.
    """
    scale = np.sqrt(cfg.d_bits)
    return executor.pack_real_weights(
        np.asarray(latent["w1"]),
        np.asarray(latent["b1"]) * scale,
        np.asarray(latent["w2"]),
        np.asarray(latent["b2"]),
    )


def evaluate(params, payload_words: np.ndarray, labels: np.ndarray) -> dict:
    """Precision / recall / F1 of a packed slot on payload words."""
    scores = np.asarray(
        executor.forward(params, jnp.asarray(payload_words))[:, 0]
    )
    pred = scores > 0
    tp = int((pred & (labels == 1)).sum())
    fp = int((pred & (labels == 0)).sum())
    fn = int((~pred & (labels == 1)).sum())
    precision = tp / max(tp + fp, 1)
    recall = tp / max(tp + fn, 1)
    f1 = 2 * precision * recall / max(precision + recall, 1e-9)
    return {"precision": precision, "recall": recall, "f1": f1,
            "tp": tp, "fp": fp, "fn": fn}


def train_slot_pair(seed: int = 0, epochs: int = 4, samples_per_group: int = 1024):
    """Train the paper's two slots (recall- and precision-oriented)."""
    xb, yb = pk.load_split("train", samples_per_group, seed)
    x = pk.to_pm1_bits(xb)
    key = jax.random.PRNGKey(seed)
    k0, k1 = jax.random.split(key)
    lat0, _ = train_bnn(k0, x, yb, pos_weight=4.0, epochs=epochs)
    lat1, _ = train_bnn(k1, x, yb, pos_weight=0.5, epochs=epochs)
    return pack_trained(lat0), pack_trained(lat1)
