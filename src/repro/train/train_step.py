"""Jitted train / prefill / decode step builders.

These are the functions the launcher jits with explicit in/out shardings;
the dry-run lowers exactly the same code.  Features:

* microbatched gradient accumulation (``lax.scan`` over microbatches —
  per-microbatch gradients reduce as they are produced, which XLA can
  overlap with the next microbatch's compute),
* optional int8 gradient compression stage (cross-pod link modeling),
* fp32 loss, AdamW from ``repro.train.optimizer``.

State pytree: {"params", "opt", "step"}.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed import compress as compress_lib
from repro.models import api
from repro.train import optimizer as opt_lib
from repro.train.losses import cross_entropy

AUX_LOSS_WEIGHT = 0.01


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch):
        logits, aux = api.apply(params, batch, cfg)
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if cfg.frontend == "patch":
            # logits cover [patches; text] — score text positions only
            logits = logits[:, -labels.shape[1]:]
        loss = cross_entropy(logits, labels, mask)
        return loss + AUX_LOSS_WEIGHT * aux, {"loss": loss, "aux": aux}

    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: opt_lib.OptimizerConfig,
    *,
    num_microbatches: int = 1,
    compress_gradients: bool = False,
) -> Callable:
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if num_microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split_mb(x):
                b = x.shape[0]
                return x.reshape(num_microbatches, b // num_microbatches,
                                 *x.shape[1:])

            mbatch = jax.tree_util.tree_map(split_mb, batch)

            def mb_step(acc, mb):
                (l, m), g = grad_fn(params, mb)
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
                return acc, l

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, losses = lax.scan(mb_step, zeros, mbatch)
            grads = jax.tree_util.tree_map(
                lambda g: g / num_microbatches, grads
            )
            loss = losses.mean()
            metrics = {"loss": loss, "aux": jnp.zeros((), jnp.float32)}

        if compress_gradients:
            grads = compress_lib.compress_grads(grads)

        new_params, new_opt, opt_metrics = opt_lib.adamw_update(
            grads, state["opt"], params, opt_cfg
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        metrics = dict(metrics, **opt_metrics)
        return new_state, metrics

    return train_step


def init_train_state(key, cfg: ModelConfig, opt_cfg: opt_lib.OptimizerConfig):
    params = api.init(key, cfg)
    return {
        "params": params,
        "opt": opt_lib.adamw_init(params, opt_cfg),
        "step": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# serving-side steps (lowered by decode/prefill dry-run cells)
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, aux, cache = api.apply(params, batch, cfg, return_cache=True)
        next_token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_token, cache

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, tokens, cache, cache_len, slot_ids=None):
        logits, new_cache = api.decode_step(
            params, tokens, cache, cache_len, cfg, slot_ids
        )
        next_token = jnp.argmax(logits[:, -1:], axis=-1)[..., 0].astype(jnp.int32)
        return next_token, new_cache

    return serve_step
