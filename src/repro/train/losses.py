"""Loss functions (fp32 accumulation)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels, mask=None):
    """Token-level CE.  logits: (B, S, V) fp32 (padded-vocab rows already
    -inf-masked); labels: (B, S) int32; mask: (B, S) {0,1}."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def weighted_bce_with_logits(scores, labels, pos_weight: float = 1.0):
    """Binary CE over raw scores (the BNN verdict head).  ``pos_weight``
    reproduces the paper's recall-oriented (4.0) vs precision-oriented (0.5)
    slot training."""
    scores = scores.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    log_p = jax.nn.log_sigmoid(scores)
    log_np = jax.nn.log_sigmoid(-scores)
    loss = -(pos_weight * labels * log_p + (1.0 - labels) * log_np)
    return loss.mean()
