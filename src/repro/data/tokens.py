"""Synthetic token pipeline with exact-resume cursor semantics.

Deterministic: batch ``i`` is a pure function of (seed, i), so restoring a
checkpoint at step N reproduces the identical remaining stream on any host
count (batches are sharded by host below the global index).

The generator plants learnable n-gram structure (a random bigram transition
table) so example training runs show decreasing loss rather than noise.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenPipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structured: bool = True     # bigram-structured (learnable) vs uniform
    n_hosts: int = 1
    host_id: int = 0


class SyntheticTokens:
    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        self._step = 0
        if cfg.structured:
            rng = np.random.default_rng(cfg.seed)
            v = cfg.vocab_size
            # sparse-ish bigram table: each token has ~8 likely successors
            succ = rng.integers(0, v, size=(v, 8))
            self._succ = succ

    # ------------------------------------------------------------------
    @property
    def cursor(self) -> int:
        return self._step

    def restore(self, cursor: int):
        self._step = int(cursor)

    # ------------------------------------------------------------------
    def _gen(self, step: int) -> dict:
        cfg = self.cfg
        host_batch = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id])
        )
        b, s, v = host_batch, cfg.seq_len, cfg.vocab_size
        if cfg.structured:
            toks = np.empty((b, s + 1), np.int32)
            toks[:, 0] = rng.integers(0, v, b)
            choice = rng.integers(0, 8, (b, s))
            noise = rng.random((b, s)) < 0.1
            rand = rng.integers(0, v, (b, s))
            for t in range(s):
                nxt = self._succ[toks[:, t], choice[:, t]]
                toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        else:
            toks = rng.integers(0, v, (b, s + 1), dtype=np.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
            "loss_mask": np.ones((b, s), np.float32),
        }

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        batch = self._gen(self._step)
        self._step += 1
        return batch
