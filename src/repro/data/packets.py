"""Synthetic IoT-23-like packet workload.

IoT-23 itself is not shipped in this container; we synthesize a labeled
malicious-traffic workload with the same *shape* the paper uses: 1024-byte
payloads mapped to the fixed 1088-byte representation, binary labels, and a
train/validation split keyed by "capture group" ids mirroring the paper's
20-1 / 21-1 / ... group protocol.

Generative model: benign payloads are low-entropy structured bytes
(protocol-header-like prefix + repeated filler); malicious payloads carry
one of several planted high-entropy signature patterns at a random offset,
plus scan-like periodic bytes.  The task is learnable but not trivially
separable (payload noise flips bits), so recall/precision-oriented training
(pos_weight) produces genuinely different operating points — required for
reproducing Fig. 6.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import packet as pkt

TRAIN_GROUPS = ("20-1", "21-1", "33-1", "36-1", "43-1", "48-1")
VAL_GROUPS = ("35-1", "42-1")

_SIGNATURES = [
    bytes([0xDE, 0xAD, 0xBE, 0xEF, 0x13, 0x37]),
    bytes([0x90] * 8),                       # NOP-sled-like
    bytes([0x41, 0x41, 0x41, 0x41, 0x2F, 0x62, 0x69, 0x6E]),  # 'AAAA/bin'
]


@dataclasses.dataclass
class PacketDatasetConfig:
    n_samples: int = 4096
    malicious_frac: float = 0.3
    noise_flip_prob: float = 0.06
    stealth_frac: float = 0.35     # malicious flows w/o periodic scan marker
    benign_burst_frac: float = 0.15  # benign flows with bursty high entropy
    seed: int = 0
    group: str = "20-1"


def _group_seed(cfg: PacketDatasetConfig) -> np.random.Generator:
    gid = sum(ord(c) * (i + 1) for i, c in enumerate(cfg.group))
    return np.random.default_rng(np.random.SeedSequence([cfg.seed, gid]))


def generate(cfg: PacketDatasetConfig) -> tuple[np.ndarray, np.ndarray]:
    """Returns (payload_bytes (N, 1024) uint8, labels (N,) {0,1})."""
    rng = _group_seed(cfg)
    n = cfg.n_samples
    labels = (rng.random(n) < cfg.malicious_frac).astype(np.int64)
    payloads = np.empty((n, pkt.PAYLOAD_BYTES), np.uint8)

    # benign: header-like prefix + low-entropy filler
    header = rng.integers(0, 256, 32, dtype=np.uint8)
    for i in range(n):
        if labels[i]:
            body = rng.integers(0, 256, pkt.PAYLOAD_BYTES, dtype=np.uint8)
            sig = _SIGNATURES[int(rng.integers(len(_SIGNATURES)))]
            off = int(rng.integers(0, pkt.PAYLOAD_BYTES - len(sig)))
            body[off : off + len(sig)] = np.frombuffer(sig, np.uint8)
            if rng.random() > cfg.stealth_frac:
                body[::16] = 0xFF  # scan-like periodic marker (non-stealth)
            payloads[i] = body
        else:
            filler = np.tile(
                rng.integers(0, 64, 16, dtype=np.uint8),
                pkt.PAYLOAD_BYTES // 16,
            )
            payloads[i] = filler
            payloads[i, :32] = header + rng.integers(0, 4, 32, dtype=np.uint8)
            if rng.random() < cfg.benign_burst_frac:
                # bursty benign traffic: a high-entropy media segment that
                # superficially resembles malicious payloads
                seg = int(rng.integers(128, 512))
                off = int(rng.integers(0, pkt.PAYLOAD_BYTES - seg))
                payloads[i, off:off + seg] = rng.integers(
                    0, 256, seg, dtype=np.uint8)
    # channel noise: flip random bits on everything
    flips = rng.random((n, pkt.PAYLOAD_BYTES)) < cfg.noise_flip_prob
    bitpos = rng.integers(0, 8, (n, pkt.PAYLOAD_BYTES), dtype=np.uint8)
    payloads ^= (flips.astype(np.uint8) << bitpos).astype(np.uint8)
    return payloads, labels


def load_split(split: str = "train", samples_per_group: int = 2048,
               seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Concatenate the paper's capture groups for a split."""
    groups = TRAIN_GROUPS if split == "train" else VAL_GROUPS
    xs, ys = [], []
    for g in groups:
        x, y = generate(PacketDatasetConfig(
            n_samples=samples_per_group, seed=seed, group=g))
        xs.append(x)
        ys.append(y)
    return np.concatenate(xs), np.concatenate(ys)


def to_payload_words(payload_bytes: np.ndarray) -> np.ndarray:
    return pkt.payload_bytes_to_words(payload_bytes)


def to_pm1_bits(payload_bytes: np.ndarray) -> np.ndarray:
    """(N, 1024) bytes -> (N, 8192) float32 in {+1, -1} (bit 1 -> -1)."""
    bits = np.unpackbits(payload_bytes, axis=-1, bitorder="little")
    return (1.0 - 2.0 * bits).astype(np.float32)
