"""Versioned control plane for the data-plane runtime (DESIGN.md §7).

``commands`` defines the five typed mutations, ``plane`` batches them
into atomic, epoch-stamped transactions applied only at tick boundaries
and keeps the auditable command log, ``policy`` closes the loop from
telemetry back to ``ProgramReta`` epochs, and ``slotcache`` scales model
residency past the device slot count with LRU eviction and a
telemetry-driven prefetcher (DESIGN.md §14).
"""

from repro.control.commands import (  # noqa: F401
    API_VERSION, Command, FailQueues, ProgramReta, RestoreQueues, SetPolicy,
    SwapSlot,
)
from repro.control.health import (  # noqa: F401
    HealthMonitor, HostState, Transition,
)
from repro.control.plane import (  # noqa: F401
    COMMIT_MODES, ControlPlane, EpochRecord, NonFatalControlError,
    load_epoch_spill,
)
from repro.control.policy import (  # noqa: F401
    POLICIES, DropRateRebalance, LeastDepth, PolicyView, RoutingPolicy,
    StaticReta, make_policy,
)
from repro.control.slotcache import (  # noqa: F401
    CacheError, SlotCache, SlotMixPrefetcher,
)
