"""Typed control-plane commands (the versioned mutation vocabulary).

Every way a running data plane can be mutated is one of these five
commands; anything else is a bug.  Commands are plain frozen dataclasses
so an epoch is a value: it can be logged, diffed, replayed, and shipped
across a control socket.  ``describe()`` renders the serialized delta
that goes into the command log — weight payloads are summarized by their
serialized byte count (the control-channel transfer cost), never inlined.

Command semantics (applied by the runtime at a tick boundary):

* ``SwapSlot``      — replace one resident bank slot with delivered
  weights.  In-flight work keeps the bank version it was dispatched
  with (JAX arrays are immutable), so the swap can never corrupt a
  packet already on the device.
* ``ProgramReta``   — install a full indirection table.  The explicit
  form of every routing decision, including policy rebalances.
* ``FailQueues``    — mark queues dead and remap their RETA buckets onto
  survivors (round-robin, affinity-preserving for live flows).
* ``RestoreQueues`` — return queues to service; with no queues named,
  restore everything and reinstall the default round-robin RETA.
* ``SetPolicy``     — install (or clear) the closed-loop routing policy
  consulted at tick boundaries.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

#: Control-plane wire/API version.  Bump on any change to the command
#: vocabulary or epoch application semantics.  v2: queue-addressed
#: commands (``ProgramReta`` / ``FailQueues`` / ``RestoreQueues``) accept
#: *global* queue ids on mesh runtimes (``host * Q + queue``, host-major
#: — see ``rss.global_queue_id``), epochs commit under a cross-host
#: apply-tick barrier, and the log records per-host apply ticks.
#: v3: fault-tolerant barriers — every epoch records a ``commit_mode``
#: (atomic | degraded | rollback), a quorum of live hosts may commit
#: while lease-expired hosts are failed over via synthesized
#: ``FailQueues`` epochs, and non-fatal (injected/quorum) failures roll
#: back without aborting the run.
API_VERSION = 3


@dataclasses.dataclass(frozen=True)
class SwapSlot:
    """Replace resident slot ``slot`` with already-delivered ``params``."""
    slot: int
    params: Any  # parameter pytree, structurally identical to a bank slot

    def describe(self) -> dict:
        import jax

        nbytes = sum(np.asarray(leaf).nbytes
                     for leaf in jax.tree_util.tree_leaves(self.params))
        return {"cmd": "swap_slot", "slot": int(self.slot),
                "delta_bytes": int(nbytes)}


@dataclasses.dataclass(frozen=True)
class ProgramReta:
    """Install a full indirection table (tuple so the command is a value)."""
    reta: tuple[int, ...]

    def __post_init__(self):
        object.__setattr__(self, "reta",
                           tuple(int(q) for q in np.asarray(self.reta).ravel()))

    def describe(self) -> dict:
        return {"cmd": "program_reta", "size": len(self.reta),
                "queues": sorted(set(self.reta))}


@dataclasses.dataclass(frozen=True)
class FailQueues:
    """Take queues out of service; their buckets remap onto survivors."""
    queues: tuple[int, ...]

    def __post_init__(self):
        object.__setattr__(self, "queues",
                           tuple(sorted(int(q) for q in self.queues)))

    def describe(self) -> dict:
        return {"cmd": "fail_queues", "queues": list(self.queues)}


@dataclasses.dataclass(frozen=True)
class RestoreQueues:
    """Return queues to service (all of them when ``queues`` is empty)."""
    queues: tuple[int, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "queues",
                           tuple(sorted(int(q) for q in self.queues)))

    def describe(self) -> dict:
        return {"cmd": "restore_queues",
                "queues": list(self.queues) or "all"}


@dataclasses.dataclass(frozen=True)
class SetPolicy:
    """Install a closed-loop routing policy (None clears it)."""
    policy: Any  # RoutingPolicy | None

    def describe(self) -> dict:
        name = getattr(self.policy, "name", None)
        return {"cmd": "set_policy", "policy": name}


Command = SwapSlot | ProgramReta | FailQueues | RestoreQueues | SetPolicy
COMMAND_KINDS = (SwapSlot, ProgramReta, FailQueues, RestoreQueues, SetPolicy)
