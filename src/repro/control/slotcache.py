"""LRU slot-cache over the device-resident bank (DESIGN.md §14).

The paper keeps at most 16 models resident; the emergency-network story
("millions of users, heterogeneous demands") needs dozens.  ``SlotCache``
is the control-plane layer that closes the gap: it holds a host-side
registry of packed model params, maps the hot subset onto the runtime's
``num_slots`` device-resident slots with LRU eviction, and turns a miss
into an ordinary ``SwapSlot`` epoch — which, on a double-buffered
runtime, prestages into the shadow bank at submit time so the barrier
commit is a pointer flip.

``SlotMixPrefetcher`` closes the loop from observability: it watches the
per-slot service mix in the `repro.obs` delta stream plus the cache's
own request history, estimates each model's demand period (diurnal and
flash-crowd regimes revisit models), and pre-stages the model predicted
to return next — so the eventual miss commits flip-only, with zero
staging on the apply path.

The cache never touches the data plane directly: every residency change
flows through ``runtime.control.submit`` and applies at a tick boundary,
so the zero-wrong-verdict audit covers cache churn unchanged.
"""

from __future__ import annotations

import collections
from typing import Any

import jax
import jax.numpy as jnp

from repro.control.commands import SwapSlot


class CacheError(RuntimeError):
    """A cache operation that cannot be satisfied — e.g. a miss when
    every resident slot is pinned, or an explicit eviction of a pinned
    (active) slot."""


class SlotCache:
    """LRU cache of registered models over the device-resident slots.

    * ``register(model_id, params)`` adds a model to the host registry.
    * ``ensure(model_id)`` returns the model's resident slot, swapping it
      in first if needed (LRU victim, ``SwapSlot`` epoch; the swap
      becomes effective at the next tick boundary — call it between
      bursts, like any control mutation).
    * ``pin``/``unpin`` protect a resident model from eviction;
      ``evict`` of a pinned model raises ``CacheError``.
    * ``prefetch(model_id)`` reserves a victim slot and (on a
      double-buffered runtime) stages the params into the shadow bank
      early, so a later ``ensure`` miss commits flip-only.

    Victim selection is pure host bookkeeping — deliberately independent
    of whether the runtime double-buffers — so the slot placement (and
    therefore every verdict) is bit-identical between the flip and
    re-staging commit paths.
    """

    def __init__(self, runtime, *, resident: list[str] | None = None):
        self.rt = runtime
        self.num_slots = int(runtime.num_slots)
        self._models: dict[str, Any] = {}
        self._slot_model: list[str | None] = [None] * self.num_slots
        self._resident: dict[str, int] = {}
        self._lru: collections.OrderedDict[str, None] = \
            collections.OrderedDict()
        self._pinned: set[str] = set()
        # model -> (reserved slot, staging token); reservations are made
        # even when staging is impossible so victim choice stays
        # deterministic across runtime configurations
        self._prefetched: dict[str, tuple[int, object]] = {}
        self._clock = 0
        self._requests: list[tuple[int, str]] = []
        self.hits = self.misses = self.evictions = 0
        self.prefetch_issued = self.prefetch_hits = 0
        if resident:
            if len(resident) > self.num_slots:
                raise ValueError("more initial residents than slots")
            for i, m in enumerate(resident):
                self._slot_model[i] = m
                self._resident[m] = i
                self._lru[m] = None

    # -- registry -----------------------------------------------------------

    def register(self, model_id: str, params) -> None:
        """Add (or replace) a model in the host registry.  Params are
        converted to device arrays once so the same pytree object flows
        through prefetch staging and the eventual ``SwapSlot`` — the
        double buffer promotes a staged prefetch by object identity."""
        self._models[model_id] = jax.tree_util.tree_map(jnp.asarray, params)

    @property
    def registered(self) -> list[str]:
        return list(self._models)

    @property
    def clock(self) -> int:
        """Monotonic request counter (the prefetcher's time base)."""
        return self._clock

    def is_resident(self, model_id: str) -> bool:
        return model_id in self._resident

    def model_at(self, slot: int) -> str | None:
        """The model occupying ``slot`` (None for an unnamed slot)."""
        return self._slot_model[slot]

    # -- residency ----------------------------------------------------------

    def _victim(self, *, avoid_reserved: bool) -> int:
        reserved = {s for s, _ in self._prefetched.values()}
        for i, m in enumerate(self._slot_model):  # free slots first
            if m is None and (not avoid_reserved or i not in reserved):
                return i
        for m in self._lru:  # then least-recently used
            if m in self._pinned:
                continue
            slot = self._resident[m]
            if avoid_reserved and slot in reserved:
                continue
            return slot
        raise CacheError(
            f"no evictable slot: {len(self._pinned)}/{self.num_slots} "
            "resident slots pinned")

    def ensure(self, model_id: str) -> int:
        """Return the slot serving ``model_id``, swapping it in on miss.

        A miss submits a ``SwapSlot`` epoch (prestaged into the shadow
        bank on double-buffered runtimes) and immediately updates the
        residency map — the epoch applies at the next tick boundary,
        before any packet dispatched after this call is served."""
        if model_id not in self._models and model_id not in self._resident:
            raise KeyError(f"unregistered model {model_id!r}")
        self._clock += 1
        self._requests.append((self._clock, model_id))
        slot = self._resident.get(model_id)
        if slot is not None:
            self.hits += 1
            self._lru.move_to_end(model_id)
            return slot
        self.misses += 1
        pf = self._prefetched.pop(model_id, None)
        if pf is not None:
            slot, token = pf
            bankbuf = getattr(self.rt, "_bankbuf", None)
            if bankbuf is not None and bankbuf.is_staged(token):
                # shadow already holds the params: the submit below
                # adopts the staged entry and the apply is flip-only
                self.prefetch_hits += 1
        else:
            try:
                slot = self._victim(avoid_reserved=True)
            except CacheError:
                slot = self._victim(avoid_reserved=False)
        self.rt.control.submit(SwapSlot(slot, self._models[model_id]))
        evicted = self._slot_model[slot]
        if evicted is not None:
            del self._resident[evicted]
            self._lru.pop(evicted, None)
            self._prefetched.pop(evicted, None)
            self.evictions += 1
        # drop any reservation that pointed at this slot for another model
        for m, (s, _) in list(self._prefetched.items()):
            if s == slot:
                del self._prefetched[m]
        self._slot_model[slot] = model_id
        self._resident[model_id] = slot
        self._lru[model_id] = None
        return slot

    def prefetch(self, model_id: str) -> bool:
        """Reserve a victim slot for ``model_id`` and stage its params
        into the shadow bank early.  Returns True if the params were
        actually staged (double-buffered runtime with a free shadow);
        the reservation itself is recorded either way.  Best-effort: a
        later unrelated epoch may reclaim the shadow — ``ensure`` checks
        staging liveness before counting a prefetch hit."""
        if model_id not in self._models:
            raise KeyError(f"unregistered model {model_id!r}")
        if model_id in self._resident or model_id in self._prefetched:
            return False
        try:
            slot = self._victim(avoid_reserved=True)
        except CacheError:
            return False
        token = ("prefetch", model_id, self._clock)
        self._prefetched[model_id] = (slot, token)
        self.prefetch_issued += 1
        bankbuf = getattr(self.rt, "_bankbuf", None)
        if bankbuf is None or bankbuf.has_staged:
            # at most one staged-ahead party at a time: a busy shadow
            # (pending epoch or earlier prefetch) must not be clobbered
            return False
        return bankbuf.stage(slot, self._models[model_id],
                             token=token, epoch="prefetch")

    # -- pinning / explicit eviction ----------------------------------------

    def pin(self, model_id: str) -> None:
        """Protect a resident model's slot from eviction."""
        if model_id not in self._resident:
            raise CacheError(f"model {model_id!r} is not resident")
        self._pinned.add(model_id)

    def unpin(self, model_id: str) -> None:
        self._pinned.discard(model_id)

    def evict(self, model_id: str) -> int:
        """Explicitly free a resident model's slot (the device weights
        remain until the slot is reused).  Pinned — active — models are
        rejected with ``CacheError``."""
        if model_id in self._pinned:
            raise CacheError(
                f"model {model_id!r} is pinned to its slot (active); "
                "unpin before evicting")
        slot = self._resident.pop(model_id, None)
        if slot is None:
            raise CacheError(f"model {model_id!r} is not resident")
        self._lru.pop(model_id, None)
        self._slot_model[slot] = None
        self.evictions += 1
        return slot

    # -- prefetcher feed / reporting ----------------------------------------

    def take_requests(self) -> list[tuple[int, str]]:
        """Drain the (clock, model) request history accumulated since the
        last call — the prefetcher's demand signal."""
        out, self._requests = self._requests, []
        return out

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "registered": len(self._models),
            "resident": len(self._resident),
            "num_slots": self.num_slots,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": (self.hits / total) if total else None,
            "evictions": self.evictions,
            "prefetch_issued": self.prefetch_issued,
            "prefetch_hits": self.prefetch_hits,
        }


class SlotMixPrefetcher:
    """Telemetry-driven prefetcher: predicts the next slot mix and
    pre-stages the model most likely to return.

    Two signals feed an inter-arrival model per registered model:

    * the cache's request history (``take_requests``) — every ``ensure``
      marks demand at the cache clock;
    * the per-slot service mix in the `repro.obs` delta stream — while a
      model is resident and actually serving packets, its ``last_seen``
      is refreshed, so the period estimate measures from last *traffic*,
      not last swap-in (a flash crowd keeps its model "recent" for as
      long as it lasts; a diurnal model ages out between its peaks).

    ``poll()`` prefetches the non-resident model whose predicted return
    (last_seen + EWMA period) falls within ``horizon`` cache-clock units
    of now.  Predictions are deterministic in the observed history.
    """

    def __init__(self, cache: SlotCache, stream=None, *,
                 horizon: int = 8, alpha: float = 0.5):
        self.cache = cache
        self.stream = stream
        self.horizon = int(horizon)
        self.alpha = float(alpha)
        self._cursor = 0
        self._last_seen: dict[str, int] = {}
        self._period: dict[str, float] = {}
        self.issued: list[str] = []

    def observe(self) -> None:
        """Fold new evidence (cache requests + telemetry deltas) into the
        per-model inter-arrival estimates."""
        a = self.alpha
        for t, m in self.cache.take_requests():
            last = self._last_seen.get(m)
            if last is not None and t > last:
                gap = float(t - last)
                p = self._period.get(m)
                self._period[m] = gap if p is None else (1 - a) * p + a * gap
            self._last_seen[m] = t
        if self.stream is None:
            return
        events, self._cursor = self.stream.tail(self._cursor)
        now = self.cache.clock
        for ev in events:
            if ev.get("kind") != "delta":
                continue
            for qd in ev.get("queues", ()):
                for slot, n in enumerate(qd.get("per_slot", ())):
                    if not n:
                        continue
                    m = self.cache.model_at(slot)
                    if m is not None:
                        self._last_seen[m] = max(
                            self._last_seen.get(m, 0), now)

    def poll(self, limit: int = 1) -> list[str]:
        """Observe, then prefetch up to ``limit`` models predicted to be
        demanded within ``horizon``.  Returns the models pre-staged."""
        self.observe()
        now = self.cache.clock
        due = []
        for m, period in self._period.items():
            if self.cache.is_resident(m) or m not in self.cache._models:
                continue
            nxt = self._last_seen.get(m, 0) + period
            if nxt <= now + self.horizon:
                due.append((nxt, m))
        due.sort()
        out = []
        for _, m in due[:int(limit)]:
            if self.cache.prefetch(m):
                out.append(m)
        self.issued.extend(out)
        return out
