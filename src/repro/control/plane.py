"""Transactional, epoch-stamped control plane for the data-plane runtime.

The paper's core split — switching is a *data-plane* act, residency is a
*control-plane* act — only holds up if the control side has real
semantics.  This module gives it three:

* **Epochs are atomic.**  Commands submitted together apply together,
  in submission order, between two ticks; no packet ever observes half
  an epoch.
* **Application happens at tick boundaries only.**  ``submit`` never
  touches the runtime; the runtime calls ``apply_pending`` when it is
  quiescent between ticks (entry of ``dispatch``/``tick``).  In-flight
  device work keeps the bank/RETA version it was dispatched with.
* **Everything is logged.**  Each applied epoch records its id, the
  tick it became effective, the serialized command deltas, and two
  wall-clock latencies: submit-to-effective (the paper's control-plane
  update window, subsuming ``switching.measure_update_latency_us``) and
  the apply cost itself.  ``continuity_audit`` joins the log with the
  runtime's wrong-verdict counter so every epoch can prove it corrupted
  zero packets.

The ``ControlPlane`` object is the ONLY sanctioned mutation path; the
legacy ``DataplaneRuntime.swap_slot/set_reta/fail_queues`` methods are
deprecation shims that emit single-command epochs through it.

The same object fronts a multi-host mesh unchanged: a ``MeshDataplane``
implements the runtime protocol this plane drives — ``_validate_command``
is the *stage* phase (every host validates its projection, none mutates;
one host's rejection rejects the whole epoch), ``_apply_command`` is the
*commit* phase (every host applies between the same two mesh ticks), and
``_control_state``/``_rollback_control_state`` snapshot mesh-wide so a
failed commit rolls back every host, not just the one that raised.
Mesh runtimes stamp ``EpochRecord.host_ticks`` with the per-host apply
tick — all equal, the epoch-barrier proof in the log itself.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

from repro.control.commands import (API_VERSION, COMMAND_KINDS, Command,
                                    SwapSlot)


@dataclasses.dataclass
class EpochRecord:
    """One applied (or pending) epoch in the command log."""
    epoch: int
    commands: tuple[Command, ...]
    summaries: tuple[dict, ...]        # describe() frozen at submit time
    submitted_s: float                 # perf_counter at submit
    applied_tick: int | None = None    # runtime tick the epoch preceded
    apply_latency_us: float | None = None  # submit -> effective
    apply_us: float | None = None          # apply duration alone
    wrong_verdict_at_apply: int | None = None
    error: str | None = None           # set when the epoch was rejected
    # mesh runtimes stamp the per-host tick each epoch became effective
    # at (all equal by the barrier); None on single-host runtimes
    host_ticks: tuple[int, ...] | None = None

    @property
    def applied(self) -> bool:
        return self.applied_tick is not None

    def as_dict(self) -> dict:
        return {
            "epoch": self.epoch,
            "api_version": API_VERSION,
            "commands": list(self.summaries),
            "applied_tick": self.applied_tick,
            "apply_latency_us": self.apply_latency_us,
            "apply_us": self.apply_us,
            "error": self.error,
            "host_ticks": (list(self.host_ticks)
                           if self.host_ticks is not None else None),
        }


class ControlPlane:
    """Epoch queue + command log in front of one ``DataplaneRuntime``."""

    API_VERSION = API_VERSION

    def __init__(self, runtime):
        self._runtime = runtime
        self._next_epoch = 1
        self._pending: list[EpochRecord] = []
        self._log: list[EpochRecord] = []

    # -- submission ---------------------------------------------------------

    def submit(self, *commands: Command) -> int:
        """Queue one atomic epoch; returns its id.  Nothing is applied
        until the runtime reaches a tick boundary."""
        if not commands:
            raise ValueError("an epoch needs at least one command")
        for c in commands:
            if not isinstance(c, COMMAND_KINDS):
                raise TypeError(f"not a control command: {c!r}")
        rec = EpochRecord(
            epoch=self._next_epoch,
            commands=tuple(commands),
            summaries=tuple(c.describe() for c in commands),
            submitted_s=time.perf_counter(),
        )
        self._next_epoch += 1
        self._pending.append(rec)
        return rec.epoch

    @property
    def pending(self) -> list[EpochRecord]:
        return list(self._pending)

    @property
    def has_pending(self) -> bool:
        return bool(self._pending)

    # -- application (runtime-side, tick boundary only) ---------------------

    def apply_pending(self, tick: int) -> list[EpochRecord]:
        """Apply every queued epoch atomically, in submission order.

        Called by the runtime when it is quiescent between ticks; user
        code should not call this directly (submit and let the next
        tick boundary pick it up, or use ``runtime.flush_control()``).
        """
        applied = []
        while self._pending:
            rec = self._pending.pop(0)
            t0 = time.perf_counter()
            state = self._runtime._control_state()
            try:
                # validate the WHOLE epoch up front (catches bad commands
                # before any work); the state snapshot backstops apply-time
                # failures validation cannot see (e.g. commands that only
                # conflict with each other) — either way a rejected epoch
                # mutates nothing (atomicity) and is logged with its error
                for cmd in rec.commands:
                    self._runtime._validate_command(cmd)
                for cmd in rec.commands:
                    self._runtime._apply_command(cmd)
            except Exception as e:
                self._runtime._rollback_control_state(state)
                rec.error = f"{type(e).__name__}: {e}"
                rec.wrong_verdict_at_apply = \
                    self._runtime.telemetry.wrong_verdict
                self._log.append(rec)
                self._strip_payloads(rec)
                raise
            t1 = time.perf_counter()
            rec.applied_tick = tick
            rec.apply_us = (t1 - t0) * 1e6
            rec.apply_latency_us = (t1 - rec.submitted_s) * 1e6
            rec.wrong_verdict_at_apply = \
                self._runtime.telemetry.wrong_verdict
            self._log.append(rec)
            self._strip_payloads(rec)
            applied.append(rec)
        return applied

    @staticmethod
    def _strip_payloads(rec: EpochRecord) -> None:
        """Drop delivered weight pytrees from logged SwapSlot commands:
        the log keeps the serialized summary (``delta_bytes``), never the
        payload, so a long-lived runtime does not pin every model it has
        ever swapped in."""
        if any(isinstance(c, SwapSlot) and c.params is not None
               for c in rec.commands):
            rec.commands = tuple(
                dataclasses.replace(c, params=None) if isinstance(c, SwapSlot)
                else c for c in rec.commands)

    # -- observability ------------------------------------------------------

    @property
    def log(self) -> list[EpochRecord]:
        return list(self._log)

    def command_log(self) -> list[dict]:
        """The auditable, serializable command log."""
        return [rec.as_dict() for rec in self._log]

    def continuity_audit(self) -> dict:
        """Per-epoch continuity: wrong-verdict packets attributed to the
        window each epoch opened (its apply to the next epoch's apply,
        or to now for the last one).  With the runtime in audit mode, an
        all-zero column proves no command kind ever corrupted a verdict.
        """
        wrong_now = self._runtime.telemetry.wrong_verdict
        epochs = []
        for i, rec in enumerate(self._log):
            nxt = (self._log[i + 1].wrong_verdict_at_apply
                   if i + 1 < len(self._log) else wrong_now)
            epochs.append({
                "epoch": rec.epoch,
                "applied_tick": rec.applied_tick,
                "commands": [s["cmd"] for s in rec.summaries],
                "wrong_verdict_in_window": nxt - rec.wrong_verdict_at_apply,
            })
        return {
            "api_version": API_VERSION,
            "epochs": epochs,
            "wrong_verdict_total": wrong_now,
            "ok": wrong_now == 0
            and all(e["wrong_verdict_in_window"] == 0 for e in epochs),
        }

    def stats(self) -> dict:
        """Aggregate epoch latencies for telemetry snapshots."""
        applied = [r for r in self._log if r.applied]
        lat = [r.apply_latency_us for r in applied]
        return {
            "api_version": API_VERSION,
            "epochs_applied": len(applied),
            "epochs_pending": len(self._pending),
            "apply_latency_us_max": max(lat) if lat else None,
        }
