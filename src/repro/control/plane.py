"""Transactional, epoch-stamped control plane for the data-plane runtime.

The paper's core split — switching is a *data-plane* act, residency is a
*control-plane* act — only holds up if the control side has real
semantics.  This module gives it three:

* **Epochs are atomic.**  Commands submitted together apply together,
  in submission order, between two ticks; no packet ever observes half
  an epoch.
* **Application happens at tick boundaries only.**  ``submit`` never
  touches the runtime; the runtime calls ``apply_pending`` when it is
  quiescent between ticks (entry of ``dispatch``/``tick``).  In-flight
  device work keeps the bank/RETA version it was dispatched with.
* **Everything is logged.**  Each applied epoch records its id, the
  tick it became effective, the serialized command deltas, and two
  wall-clock latencies: submit-to-effective (the paper's control-plane
  update window, subsuming ``switching.measure_update_latency_us``) and
  the apply cost itself.  ``continuity_audit`` joins the log with the
  runtime's wrong-verdict counter so every epoch can prove it corrupted
  zero packets.

The ``ControlPlane`` object is the ONLY sanctioned mutation path; the
legacy ``DataplaneRuntime.swap_slot/set_reta/fail_queues`` methods are
deprecation shims that emit single-command epochs through it.

The same object fronts a multi-host mesh unchanged: a ``MeshDataplane``
implements the runtime protocol this plane drives — ``_validate_command``
is the *stage* phase (every host validates its projection, none mutates;
one host's rejection rejects the whole epoch), ``_apply_command`` is the
*commit* phase (every host applies between the same two mesh ticks), and
``_control_state``/``_rollback_control_state`` snapshot mesh-wide so a
failed commit rolls back every host, not just the one that raised.
Mesh runtimes stamp ``EpochRecord.host_ticks`` with the per-host apply
tick — all equal, the epoch-barrier proof in the log itself.

API v3 adds fault tolerance (DESIGN.md §10).  Every epoch now ends in
exactly one of three recorded outcomes (``EpochRecord.commit_mode``):
``"atomic"`` (every host staged, applied, and acked), ``"degraded"``
(a quorum of live hosts committed while dead/unacked hosts were failed
over), or ``"rollback"`` (staging, apply, or quorum failed and the
snapshot restored every host).  Failures that are *chaos inputs* —
injected shard errors, lost quorum — subclass ``NonFatalControlError``:
their epoch rolls back and is logged, but ``apply_pending`` keeps
draining the queue instead of unwinding the run.  A mesh runtime may
expose ``_finish_epoch(rec)``; it is called inside the transaction
after the last command applies, and is where quorum is counted and the
commit mode stamped — raising there rolls the epoch back like any
apply-time failure.

The in-memory log is bounded: ``log_capacity`` evicts the oldest
records into a compressed spill (zlib + msgpack chunks, the workload
trace codec), each stamped with its closed wrong-verdict window first,
so slot-thrash regimes (one epoch per tick) run in O(capacity) memory
while ``continuity_audit`` still proves every spilled window was clean.
"""

from __future__ import annotations

import dataclasses
import struct
import time
import zlib
from typing import Any

import msgpack

from repro.control.commands import (API_VERSION, COMMAND_KINDS, Command,
                                    SwapSlot)

#: Spill-file framing: magic + u8 version, then length-prefixed chunks.
SPILL_MAGIC = b"BSWELOG1"

#: The only outcomes an epoch may end in.
COMMIT_MODES = ("atomic", "degraded", "rollback")


class NonFatalControlError(Exception):
    """An epoch failure that is an expected chaos outcome, not a bug:
    the epoch rolls back atomically and is logged with its error, but
    ``apply_pending`` continues with the next epoch instead of raising.
    Injected shard faults and lost commit quorums subclass this."""


@dataclasses.dataclass
class EpochRecord:
    """One applied (or pending) epoch in the command log."""
    epoch: int
    commands: tuple[Command, ...]
    summaries: tuple[dict, ...]        # describe() frozen at submit time
    submitted_s: float                 # perf_counter at submit
    applied_tick: int | None = None    # runtime tick the epoch preceded
    apply_latency_us: float | None = None  # submit -> effective
    apply_us: float | None = None          # apply duration alone
    wrong_verdict_at_apply: int | None = None
    error: str | None = None           # set when the epoch was rejected
    # one of COMMIT_MODES once the epoch has been decided; None while
    # pending ("atomic" = all hosts, "degraded" = quorum of live hosts,
    # "rollback" = rejected and snapshot restored everywhere)
    commit_mode: str | None = None
    # mesh runtimes stamp the per-host tick each epoch became effective
    # at (equal across barrier participants); None on single-host
    # runtimes and on rolled-back epochs
    host_ticks: tuple[int, ...] | None = None

    @property
    def applied(self) -> bool:
        return self.applied_tick is not None

    def as_dict(self) -> dict:
        return {
            "epoch": self.epoch,
            "api_version": API_VERSION,
            "commands": list(self.summaries),
            "applied_tick": self.applied_tick,
            "apply_latency_us": self.apply_latency_us,
            "apply_us": self.apply_us,
            "error": self.error,
            "commit_mode": self.commit_mode,
            "host_ticks": (list(self.host_ticks)
                           if self.host_ticks is not None else None),
        }


# -- device-delta serialization (megastep scan, DESIGN.md §13) --------------

#: DeviceDelta.kind codes, matched by the megastep's in-scan applier.
DELTA_SWAP = 1
DELTA_RETA = 2


@dataclasses.dataclass(frozen=True)
class DeviceDelta:
    """One control command pre-serialized for the on-device epoch queue.

    The megastep runs N ticks inside one compiled ``lax.scan``; an epoch
    that lands mid-window cannot call back into Python, so at apply time
    each bank/RETA mutation is also *serialized* into the fixed-shape
    form the scan body consumes: ``step`` is the scan step index the
    delta precedes (deltas at step s are in effect for every row popped
    at steps >= s, exactly the sequential tick-boundary semantics), and
    within one step later queue entries overwrite earlier ones
    (last-wins == submission order).  Rollback of a failed epoch simply
    truncates the staged delta list back to its pre-epoch length — the
    device never observes a rolled-back epoch.
    """
    step: int                    # scan step the delta applies before
    kind: int                    # DELTA_SWAP | DELTA_RETA
    slot: int = -1               # bank slot (DELTA_SWAP)
    reta: Any = None             # (reta_size,) int32 (DELTA_RETA)
    params: Any = None           # bank-slot pytree (DELTA_SWAP)


def serialize_device_delta(cmd, *, step: int, runtime,
                           reta_size: int) -> DeviceDelta | None:
    """Serialize one *already applied* command into its device delta.

    Called by the runtime's ``_apply_command`` in deferred (megastep)
    mode, after the host mirror mutated: ``SwapSlot`` captures the new
    slot params; every RETA-affecting command (``ProgramReta`` /
    ``FailQueues`` / ``RestoreQueues``) captures the *resulting* host
    table — the device carries a fixed ``reta_size`` mirror, so a
    shorter/longer table is padded (with -1) or truncated.  Commands
    with no device-visible state (``SetPolicy``) return None.
    """
    from repro.control.commands import (FailQueues, ProgramReta,
                                        RestoreQueues)
    import numpy as np
    if isinstance(cmd, SwapSlot):
        return DeviceDelta(step=step, kind=DELTA_SWAP, slot=int(cmd.slot),
                           params=cmd.params)
    if isinstance(cmd, (ProgramReta, FailQueues, RestoreQueues)):
        table = np.asarray(runtime.reta, np.int32)
        out = np.full(reta_size, -1, np.int32)
        n = min(reta_size, table.shape[0])
        out[:n] = table[:n]
        return DeviceDelta(step=step, kind=DELTA_RETA, reta=out)
    return None


class ControlPlane:
    """Epoch queue + command log in front of one ``DataplaneRuntime``."""

    API_VERSION = API_VERSION

    def __init__(self, runtime, *, log_capacity: int | None = None,
                 spill_path: str | None = None):
        if log_capacity is not None and log_capacity < 1:
            raise ValueError("log_capacity must be >= 1 (or None)")
        self._runtime = runtime
        self._next_epoch = 1
        self._pending: list[EpochRecord] = []
        self._log: list[EpochRecord] = []
        self._log_capacity = log_capacity
        self._spill_path = spill_path
        self._spill_chunks: list[bytes] = []   # when no spill_path given
        self._spill_header_written = False
        self.spilled_epochs = 0
        self._spilled_wrong = 0
        self._mode_counts = {m: 0 for m in COMMIT_MODES}
        # observability tap: called with each EpochRecord as it lands in
        # the log (committed AND rolled-back epochs) — obs.attach wires
        # this into a TelemetryStream as span events
        self.on_record = None

    # -- submission ---------------------------------------------------------

    def submit(self, *commands: Command) -> int:
        """Queue one atomic epoch; returns its id.  Nothing is applied
        until the runtime reaches a tick boundary.

        Runtimes with a double-buffered bank expose ``_prestage_epoch``;
        it runs here, after the epoch is queued, so SwapSlot payloads
        start staging into the shadow bank immediately — overlapped with
        the traffic still flowing — and the eventual barrier commit is a
        pointer flip (DESIGN.md §14).  Prestaging is best-effort and
        mutates no runtime-visible state."""
        if not commands:
            raise ValueError("an epoch needs at least one command")
        for c in commands:
            if not isinstance(c, COMMAND_KINDS):
                raise TypeError(f"not a control command: {c!r}")
        rec = EpochRecord(
            epoch=self._next_epoch,
            commands=tuple(commands),
            summaries=tuple(c.describe() for c in commands),
            submitted_s=time.perf_counter(),
        )
        self._next_epoch += 1
        self._pending.append(rec)
        prestage = getattr(self._runtime, "_prestage_epoch", None)
        if prestage is not None:
            prestage(rec)
        return rec.epoch

    @property
    def pending(self) -> list[EpochRecord]:
        """Epochs queued but not yet applied (a defensive copy)."""
        return list(self._pending)

    @property
    def has_pending(self) -> bool:
        """Whether any epoch is queued for the next tick boundary."""
        return bool(self._pending)

    # -- application (runtime-side, tick boundary only) ---------------------

    def apply_pending(self, tick: int) -> list[EpochRecord]:
        """Apply every queued epoch atomically, in submission order.

        Called by the runtime when it is quiescent between ticks; user
        code should not call this directly (submit and let the next
        tick boundary pick it up, or use ``runtime.flush_control()``).
        """
        applied = []
        finish = getattr(self._runtime, "_finish_epoch", None)
        while self._pending:
            rec = self._pending.pop(0)
            t0 = time.perf_counter()
            state = self._runtime._control_state()
            try:
                # validate the WHOLE epoch up front (catches bad commands
                # before any work); the state snapshot backstops apply-time
                # failures validation cannot see (e.g. commands that only
                # conflict with each other) — either way a rejected epoch
                # mutates nothing (atomicity) and is logged with its error
                for cmd in rec.commands:
                    self._runtime._validate_command(cmd)
                for cmd in rec.commands:
                    self._runtime._apply_command(cmd)
                # mesh runtimes count commit acks / stamp host_ticks and
                # commit_mode here; a lost quorum raises and rolls back
                if finish is not None:
                    finish(rec)
            except Exception as e:
                self._runtime._rollback_control_state(state)
                rec.error = f"{type(e).__name__}: {e}"
                rec.commit_mode = "rollback"
                rec.host_ticks = None
                rec.wrong_verdict_at_apply = \
                    self._runtime.telemetry.wrong_verdict
                self._append_log(rec)
                if isinstance(e, NonFatalControlError):
                    continue
                raise
            t1 = time.perf_counter()
            rec.applied_tick = tick
            rec.apply_us = (t1 - t0) * 1e6
            rec.apply_latency_us = (t1 - rec.submitted_s) * 1e6
            rec.wrong_verdict_at_apply = \
                self._runtime.telemetry.wrong_verdict
            if rec.commit_mode is None:
                rec.commit_mode = "atomic"
            self._append_log(rec)
            applied.append(rec)
        return applied

    # -- bounded log + spill -------------------------------------------------

    def _append_log(self, rec: EpochRecord) -> None:
        self._strip_payloads(rec)
        if rec.commit_mode in self._mode_counts:
            self._mode_counts[rec.commit_mode] += 1
        if self.on_record is not None:
            self.on_record(rec)
        self._log.append(rec)
        cap = self._log_capacity
        if cap is not None and len(self._log) > cap:
            evicted, self._log = self._log[:-cap], self._log[-cap:]
            self._spill(evicted)

    def _spill(self, evicted: list[EpochRecord]) -> None:
        """Close each evicted record's wrong-verdict window (its
        successor is still known here) and push the batch out as one
        compressed chunk in the trace codec."""
        succ = self._log[0] if self._log else None
        docs = []
        for i, rec in enumerate(evicted):
            nxt = evicted[i + 1] if i + 1 < len(evicted) else succ
            doc = rec.as_dict()
            window = None
            if (nxt is not None and rec.wrong_verdict_at_apply is not None
                    and nxt.wrong_verdict_at_apply is not None):
                window = (nxt.wrong_verdict_at_apply
                          - rec.wrong_verdict_at_apply)
                self._spilled_wrong += window
            doc["wrong_verdict_in_window"] = window
            docs.append(doc)
        self.spilled_epochs += len(docs)
        blob = zlib.compress(
            msgpack.packb(docs, use_bin_type=True), 6)
        if self._spill_path is not None:
            mode = "ab" if self._spill_header_written else "wb"
            with open(self._spill_path, mode) as f:
                if not self._spill_header_written:
                    f.write(SPILL_MAGIC)
                f.write(struct.pack("<I", len(blob)))
                f.write(blob)
            self._spill_header_written = True
        else:
            self._spill_chunks.append(blob)

    def spilled_records(self) -> list[dict]:
        """Decode in-memory spill chunks (oldest first)."""
        out: list[dict] = []
        for blob in self._spill_chunks:
            out.extend(msgpack.unpackb(zlib.decompress(blob), raw=False))
        return out

    @staticmethod
    def _strip_payloads(rec: EpochRecord) -> None:
        """Drop delivered weight pytrees from logged SwapSlot commands:
        the log keeps the serialized summary (``delta_bytes``), never the
        payload, so a long-lived runtime does not pin every model it has
        ever swapped in."""
        if any(isinstance(c, SwapSlot) and c.params is not None
               for c in rec.commands):
            rec.commands = tuple(
                dataclasses.replace(c, params=None) if isinstance(c, SwapSlot)
                else c for c in rec.commands)

    # -- observability ------------------------------------------------------

    @property
    def log(self) -> list[EpochRecord]:
        """The in-memory epoch log, oldest first (a defensive copy)."""
        return list(self._log)

    def command_log(self) -> list[dict]:
        """The auditable, serializable command log."""
        return [rec.as_dict() for rec in self._log]

    def continuity_audit(self) -> dict:
        """Per-epoch continuity: wrong-verdict packets attributed to the
        window each epoch opened (its apply to the next epoch's apply,
        or to now for the last one).  With the runtime in audit mode, an
        all-zero column proves no command kind ever corrupted a verdict.
        """
        wrong_now = self._runtime.telemetry.wrong_verdict
        epochs = []
        for i, rec in enumerate(self._log):
            nxt = (self._log[i + 1].wrong_verdict_at_apply
                   if i + 1 < len(self._log) else wrong_now)
            epochs.append({
                "epoch": rec.epoch,
                "applied_tick": rec.applied_tick,
                "commands": [s["cmd"] for s in rec.summaries],
                "commit_mode": rec.commit_mode,
                "wrong_verdict_in_window": nxt - rec.wrong_verdict_at_apply,
            })
        ok = (wrong_now == 0
              and all(e["wrong_verdict_in_window"] == 0 for e in epochs)
              and self._spilled_wrong == 0)
        out = {
            "api_version": API_VERSION,
            "epochs": epochs,
            "commit_modes": dict(self._mode_counts),
            "spilled_epochs": self.spilled_epochs,
            "spilled_wrong_verdict": self._spilled_wrong,
            "wrong_verdict_total": wrong_now,
            "ok": ok,
        }
        # degraded commits must also conserve packets — including those
        # stranded on dead hosts — so fold the runtime's conservation
        # audit in when it offers one (mesh and audited runtimes do)
        cons_fn = getattr(self._runtime, "audit_conservation", None)
        if cons_fn is not None:
            cons = cons_fn()
            out["conservation_ok"] = bool(cons["ok"])
            if "stranded" in cons:
                out["stranded"] = cons["stranded"]
            out["ok"] = ok and bool(cons["ok"])
        return out

    def stats(self) -> dict:
        """Aggregate epoch latencies for telemetry snapshots."""
        applied = [r for r in self._log if r.applied]
        lat = [r.apply_latency_us for r in applied]
        return {
            "api_version": API_VERSION,
            "epochs_applied": len(applied),
            "epochs_pending": len(self._pending),
            "epochs_spilled": self.spilled_epochs,
            "commit_modes": dict(self._mode_counts),
            "apply_latency_us_max": max(lat) if lat else None,
        }


def load_epoch_spill(path: str) -> list[dict]:
    """Read a spill file written by a capacity-bounded ``ControlPlane``
    back into epoch dicts (oldest first)."""
    with open(path, "rb") as f:
        magic = f.read(len(SPILL_MAGIC))
        if magic != SPILL_MAGIC:
            raise ValueError(f"not an epoch spill file: {path}")
        out: list[dict] = []
        while True:
            head = f.read(4)
            if not head:
                return out
            (n,) = struct.unpack("<I", head)
            out.extend(msgpack.unpackb(zlib.decompress(f.read(n)),
                                       raw=False))
