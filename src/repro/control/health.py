"""Per-host lease/heartbeat health monitor for the mesh barrier (§10).

The mesh cannot ask a dead host whether it is dead; all it observes at
tick granularity is whether each host served its tick (a *heartbeat*)
or failed to (a *miss*).  ``HealthMonitor`` turns that stream into a
per-host lease state machine:

    HEALTHY --misses >= suspect_after--> SUSPECT
    HEALTHY/SUSPECT --misses >= lease_ticks--> DEAD
    DEAD --successful re-probe--> RECOVERING
    RECOVERING --clean heartbeat--> HEALTHY
    SUSPECT --clean_to_clear consecutive heartbeats--> HEALTHY

Misses are consecutive and deduplicated per (host, tick): a host that is
both unresponsive *and* blocking a barrier in the same tick burns one
tick of lease, not two.  DEAD hosts are re-probed with exponential
backoff (``probe_interval`` doubling up to ``probe_max``), so a crashed
host costs O(log t) probes, not one per tick.

The monitor is pure bookkeeping — it never touches the data plane.  The
mesh reads the transitions returned by ``observe()`` to synthesize
failover epochs (on ``-> dead``) and resync/restore (on
``-> recovering``), and consults ``state()`` to pick barrier
participants.  Everything is deterministic in the heartbeat/miss
stream, so faulted runs replay bit-exactly.
"""

from __future__ import annotations

import dataclasses
import enum


class HostState(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"
    RECOVERING = "recovering"


@dataclasses.dataclass(frozen=True)
class Transition:
    tick: int
    host: int
    frm: str
    to: str
    reason: str

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _Lease:
    state: HostState = HostState.HEALTHY
    misses: int = 0                 # consecutive missed ticks
    clean: int = 0                  # consecutive clean heartbeats
    last_seen: int = -1
    last_miss_tick: int = -1
    died_at: int | None = None
    probe_at: int | None = None     # next re-probe tick (while DEAD)
    probe_gap: int = 0


class HealthMonitor:
    def __init__(self, num_hosts: int, *, lease_ticks: int = 8,
                 suspect_after: int = 2, clean_to_clear: int = 2,
                 probe_interval: int = 2, probe_factor: int = 2,
                 probe_max: int = 64):
        if num_hosts < 1:
            raise ValueError("num_hosts must be >= 1")
        if lease_ticks < 1 or suspect_after < 1:
            raise ValueError("lease_ticks and suspect_after must be >= 1")
        if suspect_after > lease_ticks:
            raise ValueError(f"suspect_after ({suspect_after}) must not "
                             f"exceed lease_ticks ({lease_ticks})")
        self.num_hosts = num_hosts
        self.lease_ticks = lease_ticks
        self.suspect_after = suspect_after
        self.clean_to_clear = clean_to_clear
        self.probe_interval = probe_interval
        self.probe_factor = probe_factor
        self.probe_max = probe_max
        self._leases = [_Lease() for _ in range(num_hosts)]
        self.transitions: list[Transition] = []
        self.total_misses = 0
        self.total_probes = 0
        # observability tap: called with each Transition as it happens —
        # obs.attach wires this into a TelemetryStream as health events
        self.on_transition = None

    def _move(self, tick: int, host: int, to: HostState,
              reason: str) -> Transition:
        lease = self._leases[host]
        tr = Transition(tick=tick, host=host, frm=lease.state.value,
                        to=to.value, reason=reason)
        lease.state = to
        self.transitions.append(tr)
        if self.on_transition is not None:
            self.on_transition(tr)
        return tr

    # -- the tick-granularity observation stream -----------------------------

    def heartbeat(self, host: int, tick: int) -> None:
        """The host served this tick.  A miss already recorded for the
        same tick wins (partially-responsive counts against the lease)."""
        lease = self._leases[host]
        if lease.last_miss_tick == tick:
            return
        lease.last_seen = tick
        lease.misses = 0
        lease.clean += 1
        if lease.state is HostState.RECOVERING:
            self._move(tick, host, HostState.HEALTHY, "rejoined")
        elif (lease.state is HostState.SUSPECT
              and lease.clean >= self.clean_to_clear):
            self._move(tick, host, HostState.HEALTHY, "lease renewed")

    def miss(self, host: int, tick: int) -> None:
        """The host failed to serve this tick (unresponsive, or blocking
        a pending epoch barrier).  Deduplicated per (host, tick)."""
        lease = self._leases[host]
        if lease.last_miss_tick == tick or lease.state is HostState.DEAD:
            return
        lease.last_miss_tick = tick
        lease.misses += 1
        lease.clean = 0
        self.total_misses += 1

    def mark_suspect(self, host: int, tick: int, reason: str) -> None:
        """Out-of-band suspicion (e.g. a dropped commit ack)."""
        lease = self._leases[host]
        if lease.state is HostState.HEALTHY:
            lease.clean = 0
            self._move(tick, host, HostState.SUSPECT, reason)

    def observe(self, tick: int, probe=None) -> list[Transition]:
        """Advance the state machine; returns this call's transitions.

        ``probe(host) -> bool`` is consulted for DEAD hosts whose
        backoff timer has expired; a successful probe moves the host to
        RECOVERING (the caller must resync it before it serves again).
        """
        out: list[Transition] = []
        for host, lease in enumerate(self._leases):
            if lease.state in (HostState.HEALTHY, HostState.SUSPECT):
                if lease.misses >= self.lease_ticks:
                    lease.died_at = tick
                    lease.probe_gap = self.probe_interval
                    lease.probe_at = tick + lease.probe_gap
                    out.append(self._move(
                        tick, host, HostState.DEAD,
                        f"lease expired ({lease.misses} missed ticks)"))
                elif (lease.misses >= self.suspect_after
                      and lease.state is HostState.HEALTHY):
                    out.append(self._move(
                        tick, host, HostState.SUSPECT,
                        f"{lease.misses} missed ticks"))
            elif (lease.state is HostState.DEAD and probe is not None
                  and lease.probe_at is not None and tick >= lease.probe_at):
                self.total_probes += 1
                if probe(host):
                    lease.misses = 0
                    lease.clean = 0
                    out.append(self._move(tick, host, HostState.RECOVERING,
                                          "probe succeeded"))
                else:
                    lease.probe_gap = min(
                        lease.probe_gap * self.probe_factor, self.probe_max)
                    lease.probe_at = tick + lease.probe_gap
        return out

    # -- queries -------------------------------------------------------------

    def state(self, host: int) -> HostState:
        return self._leases[host].state

    def is_dead(self, host: int) -> bool:
        return self._leases[host].state is HostState.DEAD

    def dead_hosts(self) -> tuple[int, ...]:
        return tuple(h for h, le in enumerate(self._leases)
                     if le.state is HostState.DEAD)

    def live_hosts(self) -> tuple[int, ...]:
        return tuple(h for h, le in enumerate(self._leases)
                     if le.state is not HostState.DEAD)

    @property
    def ever_missed(self) -> bool:
        return self.total_misses > 0

    def snapshot(self) -> dict:
        return {
            "lease_ticks": self.lease_ticks,
            "suspect_after": self.suspect_after,
            "total_misses": self.total_misses,
            "total_probes": self.total_probes,
            "hosts": [{"host": h, "state": le.state.value,
                       "misses": le.misses, "last_seen": le.last_seen,
                       "died_at": le.died_at}
                      for h, le in enumerate(self._leases)],
            "transitions": [t.as_dict() for t in self.transitions],
        }
