"""Pluggable routing policies: closing the loop from telemetry to RETA.

The paper's emergency-HRL line of work (and the ROADMAP's "adaptive
per-queue routing" item) needs exactly one mechanism: observe per-queue
pressure, rewrite the indirection table, repeat.  A ``RoutingPolicy`` is
consulted by the runtime at tick boundaries with a frozen ``PolicyView``
of the telemetry it may react to; when it returns a new RETA the runtime
submits it as a ``ProgramReta`` epoch — policies never mutate anything
directly, so every rebalance is logged, versioned, and auditable like
any operator-issued command.

Policies are deterministic functions of their view (plus their own
internal deltas), so a replayed scenario reproduces the exact same
sequence of rebalance epochs.

* ``StaticReta``        — the do-nothing baseline: whatever table is
  installed stays installed.
* ``LeastDepth``        — greedy bucket migration from the deepest queue
  to the shallowest, weighted by observed per-bucket offered load.
* ``DropRateRebalance`` — reacts only to actual tail-drops: sheds the
  heaviest buckets off any queue that dropped packets since the last
  consultation onto the least-pressured survivor.
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import numpy as np


@dataclasses.dataclass(frozen=True)
class PolicyView:
    """Frozen snapshot a policy may react to (no live runtime access).

    On a mesh runtime the view spans every host: ``num_queues`` is the
    global queue count, queue-indexed arrays are in host-major global
    order, and RETA entries are global queue ids — so depth/drop policies
    written against this view rebalance across hosts without change.
    """
    tick: int
    num_queues: int
    reta: np.ndarray          # (RETA_SIZE,) current bucket -> queue map
    queue_depth: np.ndarray   # (Q,) ring occupancy at the tick boundary
    queue_dropped: np.ndarray  # (Q,) cumulative tail-drops per queue
    bucket_load: np.ndarray   # (RETA_SIZE,) cumulative offered per bucket
    failed_queues: frozenset[int] = frozenset()
    num_hosts: int = 1        # mesh host count (1 = single-host runtime)

    def live_queues(self) -> list[int]:
        return [q for q in range(self.num_queues) if q not in self.failed_queues]


@runtime_checkable
class RoutingPolicy(Protocol):
    """Protocol: ``propose`` returns a new RETA or None (keep current)."""
    name: str

    def propose(self, view: PolicyView) -> np.ndarray | None: ...


class StaticReta:
    """Baseline: never rebalances (the pre-policy behavior)."""
    name = "static"

    def propose(self, view: PolicyView) -> np.ndarray | None:
        return None


def _greedy_rebalance(reta: np.ndarray, weight: np.ndarray,
                      live: list[int], *, max_moves: int) -> np.ndarray | None:
    """Move heavy buckets from the most- to the least-loaded live queue.

    ``weight`` is the per-bucket pressure estimate; per-queue pressure is
    the sum over its buckets.  Each move takes the heaviest bucket off
    the max queue if doing so strictly reduces the max/min imbalance.
    Deterministic: ties break on the lowest queue / bucket index.
    """
    if len(live) < 2:
        return None
    reta = np.asarray(reta, np.int32).copy()
    qload = np.zeros(max(live) + 1, np.float64)
    live_mask = np.isin(reta, live)
    np.add.at(qload, reta[live_mask], weight[live_mask])
    live_arr = np.asarray(live)
    moved = False
    for _ in range(max_moves):
        loads = qload[live_arr]
        src = int(live_arr[int(np.argmax(loads))])
        dst = int(live_arr[int(np.argmin(loads))])
        if src == dst:
            break
        candidates = np.nonzero(reta == src)[0]
        if candidates.size == 0:
            break
        bucket = int(candidates[int(np.argmax(weight[candidates]))])
        w = float(weight[bucket])
        # only move if the bucket actually shrinks the imbalance: the
        # source must stay at least as loaded as the destination becomes
        if w <= 0 or qload[src] - w < qload[dst]:
            break
        reta[bucket] = dst
        qload[src] -= w
        qload[dst] += w
        moved = True
    return reta if moved else None


class LeastDepth:
    """Rebalance toward equal queue depth, weighted by recent bucket load.

    Pressure per bucket = offered packets since the last proposal; a
    queue's pressure additionally counts its current ring backlog,
    attributed to its buckets proportionally, so a queue that is already
    deep sheds load even when arrivals are momentarily quiet.
    """
    name = "least-depth"

    def __init__(self, *, interval: int = 1, max_moves: int = 32):
        self.interval = max(1, int(interval))
        self.max_moves = int(max_moves)
        self._last_load: np.ndarray | None = None

    def propose(self, view: PolicyView) -> np.ndarray | None:
        if view.tick % self.interval:
            return None
        if (self._last_load is not None
                and self._last_load.shape != view.bucket_load.shape):
            self._last_load = None  # RETA was resized: restart the deltas
        delta = (view.bucket_load if self._last_load is None
                 else view.bucket_load - self._last_load)
        self._last_load = view.bucket_load.copy()
        weight = delta.astype(np.float64)
        # spread each queue's backlog over its buckets in proportion to
        # their recent load (uniformly when the queue saw no arrivals)
        reta = np.asarray(view.reta, np.int32)
        for q in range(view.num_queues):
            mask = reta == q
            if not mask.any():
                continue
            qw = weight[mask]
            share = (qw / qw.sum() if qw.sum() > 0
                     else np.full(qw.shape, 1.0 / qw.size))
            weight[mask] += float(view.queue_depth[q]) * share
        if weight.sum() <= 0:
            return None
        return _greedy_rebalance(reta, weight, view.live_queues(),
                                 max_moves=self.max_moves)


class DropRateRebalance:
    """Shed load off queues that are actually dropping packets.

    Quieter than ``LeastDepth``: it proposes nothing while every queue
    keeps up, and rebalances by observed per-bucket load only when the
    drop counters move — the policy a conservative operator runs.
    """
    name = "drop-rate"

    def __init__(self, *, min_drops: int = 1, max_moves: int = 32):
        self.min_drops = int(min_drops)
        self.max_moves = int(max_moves)
        self._last_dropped: np.ndarray | None = None
        self._last_load: np.ndarray | None = None

    def propose(self, view: PolicyView) -> np.ndarray | None:
        dropped = view.queue_dropped.astype(np.int64)
        d_drop = (dropped if self._last_dropped is None
                  else dropped - self._last_dropped)
        self._last_dropped = dropped.copy()
        load = view.bucket_load.astype(np.float64)
        if (self._last_load is not None
                and self._last_load.shape != load.shape):
            self._last_load = None  # RETA was resized: restart the deltas
        d_load = load if self._last_load is None else load - self._last_load
        self._last_load = load.copy()
        if int(d_drop.max(initial=0)) < self.min_drops:
            return None
        weight = d_load + 1e-9  # strictly positive so moves are possible
        return _greedy_rebalance(np.asarray(view.reta, np.int32), weight,
                                 view.live_queues(), max_moves=self.max_moves)


#: CLI registry: ``--policy`` name -> constructor.
POLICIES = {
    "static": StaticReta,
    "least-depth": LeastDepth,
    "drop-rate": DropRateRebalance,
}


def make_policy(name: str) -> RoutingPolicy:
    try:
        return POLICIES[name]()
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r} (known: {sorted(POLICIES)})") from None
