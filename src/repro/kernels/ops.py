"""Jitted public wrappers over the Pallas kernels with oracle fallbacks.

Backend selection:
  * ``pallas``    — compiled Pallas kernel (TPU target; ``interpret=True``
                    under tests on CPU).
  * ``ref``       — pure-jnp oracle (fast on CPU; bit-identical semantics).
  * ``mxu``       — beyond-paper path: unpack bits to +-1 bf16 and contract
                    on the MXU instead of VPU popcount.
  * ``auto``      — ``pallas`` on TPU, ``ref`` elsewhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref as _ref
from . import bnn_xnor as _bnn_xnor
from . import banked_matmul as _banked
from . import fused_forward as _fused


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "ref"
    return backend


# ---------------------------------------------------------------------------
# binary (XNOR-popcount) matmul
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("backend",))
def xnor_matmul(x_packed, w_packed, *, backend: str = "auto"):
    """(B, W)u32 x (H, W)u32 -> (B, H)i32 binary dot products."""
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.xnor_matmul_ref(x_packed, w_packed)
    if backend == "mxu":
        return _ref.xnor_matmul_mxu_ref(x_packed, w_packed)
    return _bnn_xnor.xnor_matmul(
        x_packed, w_packed, interpret=not _on_tpu()
    )


@functools.partial(jax.jit, static_argnames=("backend",))
def bnn_forward(params, x_packed, *, backend: str = "auto"):
    """Single-slot BNN forward (paper Eq. 1): -> (B, C) f32 scores."""
    pre = xnor_matmul(x_packed, params["w1p"], backend=backend).astype(jnp.float32)
    pre = pre + params["b1"][None, :]
    h = jnp.where(pre >= 0, 1.0, -1.0)
    return h @ params["w2"].T + params["b2"][None, :]


# ---------------------------------------------------------------------------
# banked (slot-selected) execution
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("backend",))
def bnn_forward_banked(bank, x_packed, slots, *, backend: str = "auto"):
    """Per-packet slot-selected BNN forward (gather/onehot semantics).

    bank leaves are stacked (K, ...).  Exact per-packet granularity — the
    grouped Pallas path lives in ``bnn_forward_grouped``.
    """
    backend = _resolve(backend)
    if backend == "mxu":
        # onehot-style MXU contraction: selection becomes a K-contraction.
        d = x_packed.shape[-1] * _ref.PACK
        xv = _ref.unpack_bits(x_packed, d).astype(jnp.bfloat16)   # (B, d)
        wv = _ref.unpack_bits(bank["w1p"], d).astype(jnp.bfloat16)  # (K, H, d)
        onehot = jax.nn.one_hot(slots, bank["w1p"].shape[0], dtype=jnp.bfloat16)
        pre = jnp.einsum(
            "bd,khd,bk->bh", xv, wv, onehot,
            preferred_element_type=jnp.float32,
        )
        pre = pre + bank["b1"][slots]
        h = jnp.where(pre >= 0, 1.0, -1.0)
        y = jnp.einsum("bh,bch->bc", h, bank["w2"][slots]) + bank["b2"][slots]
        return y
    return _ref.banked_xnor_forward_ref(
        bank["w1p"], bank["b1"], bank["w2"], bank["b2"], x_packed, slots
    )


@functools.partial(jax.jit, static_argnames=("block_b", "backend"))
def bnn_forward_grouped(
    bank, x_packed, block_slots, *, block_b: int = 256, backend: str = "auto"
):
    """Grouped slot-selected BNN forward via the scalar-prefetch kernel.

    Rows must be pre-grouped so each ``block_b`` block shares a slot
    (``repro.core.bank.group_by_slot``).  block_slots: (B // block_b,) i32.
    """
    bb = min(block_b, x_packed.shape[0])
    # contiguous fused mode: one launch, layer 1 + sign + layer 2 in VMEM
    return bnn_forward_fused(
        bank, x_packed, block_slots, None, block_b=bb, backend=backend
    )


@functools.partial(jax.jit, static_argnames=("block_b", "backend"))
def bnn_forward_fused(
    bank, x_packed, block_slots, row_ids=None, *, block_b: int = 256,
    backend: str = "auto",
):
    """Zero-copy fused BNN forward: one kernel launch, gather prologue.

    ``row_ids`` maps output row r to input row ``row_ids[r]`` so the batch
    never has to be re-laid-out in HBM (``repro.core.bank.group_by_slot_padded``
    provides it).  ``row_ids=None`` means rows are already grouped
    contiguously.  The ref/mxu backends reproduce the same semantics with a
    jnp gather — the oracle for parity tests.
    """
    backend = _resolve(backend)
    n_rows = block_slots.shape[0] * block_b if row_ids is None \
        else row_ids.shape[0]
    if backend in ("ref", "mxu"):
        rows = x_packed if row_ids is None \
            else jnp.take(x_packed, row_ids, axis=0)
        slots = _ref.expand_block_slots(block_slots, block_b, n_rows)
        return _ref.banked_xnor_forward_ref(
            bank["w1p"], bank["b1"], bank["w2"], bank["b2"], rows, slots
        )
    return _fused.fused_forward(
        x_packed, bank["w1p"], bank["b1"], bank["w2"], bank["b2"],
        block_slots, row_ids, block_b=block_b, interpret=not _on_tpu(),
    )


@functools.partial(jax.jit, static_argnames=("meta_words", "block_b", "backend"))
def packet_forward_fused(
    bank, packets, block_slots, row_ids, *, meta_words: int,
    block_b: int = 256, backend: str = "auto",
):
    """Whole forwarding path in one launch: parse + select + BNN + Pi.

    ``packets`` are raw (B, meta_words + W) uint32 rows in arrival order;
    the kernel gathers each block's rows by DMA, slices the payload, and
    emits (scores, actions).  Returns ``(n_rows, C) f32, (n_rows,) i32``.

    A 3-D ``packets`` of shape (Q, B, words) is the queue-major stacked
    form: it is flattened so ``row_ids`` index the (Q * B) host batch and
    ALL queues share one launch (``fused_forward_qmajor``).
    """
    backend = _resolve(backend)
    qmajor = packets.ndim == 3
    if backend in ("ref", "mxu"):
        if qmajor:
            packets = packets.reshape(-1, packets.shape[-1])
        rows = jnp.take(packets, row_ids, axis=0)
        payload = rows[:, meta_words:]
        slots = _ref.expand_block_slots(block_slots, block_b, row_ids.shape[0])
        scores = _ref.banked_xnor_forward_ref(
            bank["w1p"], bank["b1"], bank["w2"], bank["b2"], payload, slots
        )
        return scores, _fused.actions_ref(scores, rows[:, _fused.CTRL_WORD])
    fwd = _fused.fused_forward_qmajor if qmajor else _fused.fused_forward
    scores, actions = fwd(
        packets, bank["w1p"], bank["b1"], bank["w2"], bank["b2"],
        block_slots, row_ids, block_b=block_b, meta_words=meta_words,
        with_actions=True, interpret=not _on_tpu(),
    )
    return scores, actions[:, 0]


@functools.partial(jax.jit, static_argnames=("block_b", "backend"))
def banked_matmul(x, w, b, block_slots, *, block_b: int = 128, backend: str = "auto"):
    """Grouped slot-selected float matmul (adapter/head banks)."""
    backend = _resolve(backend)
    bsz = x.shape[0]
    bb = min(block_b, bsz)
    if backend == "ref":
        slots = _ref.expand_block_slots(block_slots, bb, bsz)
        return _ref.banked_matmul_ref(x, w, b, slots)
    return _banked.banked_matmul(
        x, w, b, block_slots, block_b=bb, interpret=not _on_tpu()
    )
