"""Jitted public wrappers over the Pallas kernels with oracle fallbacks.

Backend selection:
  * ``pallas``    — compiled Pallas kernel (TPU target; ``interpret=True``
                    under tests on CPU).
  * ``ref``       — pure-jnp oracle (fast on CPU; bit-identical semantics).
  * ``mxu``       — beyond-paper path: unpack bits to +-1 bf16 and contract
                    on the MXU instead of VPU popcount.
  * ``auto``      — ``pallas`` on TPU, ``ref`` elsewhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref as _ref
from . import bnn_xnor as _bnn_xnor
from . import banked_matmul as _banked


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "ref"
    return backend


# ---------------------------------------------------------------------------
# binary (XNOR-popcount) matmul
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("backend",))
def xnor_matmul(x_packed, w_packed, *, backend: str = "auto"):
    """(B, W)u32 x (H, W)u32 -> (B, H)i32 binary dot products."""
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.xnor_matmul_ref(x_packed, w_packed)
    if backend == "mxu":
        return _ref.xnor_matmul_mxu_ref(x_packed, w_packed)
    return _bnn_xnor.xnor_matmul(
        x_packed, w_packed, interpret=not _on_tpu()
    )


@functools.partial(jax.jit, static_argnames=("backend",))
def bnn_forward(params, x_packed, *, backend: str = "auto"):
    """Single-slot BNN forward (paper Eq. 1): -> (B, C) f32 scores."""
    pre = xnor_matmul(x_packed, params["w1p"], backend=backend).astype(jnp.float32)
    pre = pre + params["b1"][None, :]
    h = jnp.where(pre >= 0, 1.0, -1.0)
    return h @ params["w2"].T + params["b2"][None, :]


# ---------------------------------------------------------------------------
# banked (slot-selected) execution
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("backend",))
def bnn_forward_banked(bank, x_packed, slots, *, backend: str = "auto"):
    """Per-packet slot-selected BNN forward (gather/onehot semantics).

    bank leaves are stacked (K, ...).  Exact per-packet granularity — the
    grouped Pallas path lives in ``bnn_forward_grouped``.
    """
    backend = _resolve(backend)
    if backend == "mxu":
        # onehot-style MXU contraction: selection becomes a K-contraction.
        d = x_packed.shape[-1] * _ref.PACK
        xv = _ref.unpack_bits(x_packed, d).astype(jnp.bfloat16)   # (B, d)
        wv = _ref.unpack_bits(bank["w1p"], d).astype(jnp.bfloat16)  # (K, H, d)
        onehot = jax.nn.one_hot(slots, bank["w1p"].shape[0], dtype=jnp.bfloat16)
        pre = jnp.einsum(
            "bd,khd,bk->bh", xv, wv, onehot,
            preferred_element_type=jnp.float32,
        )
        pre = pre + bank["b1"][slots]
        h = jnp.where(pre >= 0, 1.0, -1.0)
        y = jnp.einsum("bh,bch->bc", h, bank["w2"][slots]) + bank["b2"][slots]
        return y
    return _ref.banked_xnor_forward_ref(
        bank["w1p"], bank["b1"], bank["w2"], bank["b2"], x_packed, slots
    )


@functools.partial(jax.jit, static_argnames=("block_b", "backend"))
def bnn_forward_grouped(
    bank, x_packed, block_slots, *, block_b: int = 256, backend: str = "auto"
):
    """Grouped slot-selected BNN forward via the scalar-prefetch kernel.

    Rows must be pre-grouped so each ``block_b`` block shares a slot
    (``repro.core.bank.group_by_slot``).  block_slots: (B // block_b,) i32.
    """
    backend = _resolve(backend)
    interpret = not _on_tpu()
    bsz = x_packed.shape[0]
    bb = min(block_b, bsz)
    if backend == "ref":
        slots = jnp.repeat(block_slots, bb, total_repeat_length=bsz)
        return _ref.banked_xnor_forward_ref(
            bank["w1p"], bank["b1"], bank["w2"], bank["b2"], x_packed, slots
        )
    pre = _banked.banked_xnor_layer1(
        x_packed, bank["w1p"], bank["b1"], block_slots,
        block_b=bb, interpret=interpret,
    )
    h = jnp.where(pre >= 0, 1.0, -1.0)
    y = jnp.einsum("bh,bch->bc", h, bank["w2"][jnp.repeat(
        block_slots, bb, total_repeat_length=bsz)])
    y = y + bank["b2"][jnp.repeat(block_slots, bb, total_repeat_length=bsz)]
    return y


@functools.partial(jax.jit, static_argnames=("block_b", "backend"))
def banked_matmul(x, w, b, block_slots, *, block_b: int = 128, backend: str = "auto"):
    """Grouped slot-selected float matmul (adapter/head banks)."""
    backend = _resolve(backend)
    bsz = x.shape[0]
    bb = min(block_b, bsz)
    if backend == "ref":
        slots = jnp.repeat(block_slots, bb, total_repeat_length=bsz)
        return _ref.banked_matmul_ref(x, w, b, slots)
    return _banked.banked_matmul(
        x, w, b, block_slots, block_b=bb, interpret=not _on_tpu()
    )
