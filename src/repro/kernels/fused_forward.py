"""Fused packet-forwarding megakernel (parse -> select -> XNOR -> verdict).

The paper's per-packet numbers come from keeping the whole forwarding path
inline in one pass over the payload.  The staged TPU port split that path
across four XLA programs (layer-1 Pallas kernel, sign, layer-2 einsum, three
``jnp.repeat`` gathers) with HBM round trips between them.  This kernel runs
the complete executor in VMEM inside ONE ``pl.pallas_call``:

  * the per-block slot id is scalar-prefetched into SMEM (the O(1)
    pointer-chase analogue: one SMEM read steers the weight DMA at the
    selected bank entry; the K-1 non-selected slots never leave HBM),
  * layer 1 (XNOR-popcount), the sign activation, layer 2, and optionally
    the Pi action are computed on the block without touching HBM,
  * only the final ``(block_b, C)`` score tile (and the ``(block_b, 1)``
    action tile) is written back.

Two input modes:

  * **contiguous** (``row_ids is None``) — rows are already grouped so each
    ``block_b`` block shares one slot; the payload is streamed through the
    normal blocked-BlockSpec pipeline.
  * **gather** (``row_ids`` given) — the batch stays in HBM in its original
    arrival order (``memory_space=ANY``); a prefetched per-row index table
    drives a DMA gather prologue that copies exactly the rows of each block
    into VMEM scratch.  Grouped execution is therefore zero-copy: no
    ``scatter_padded``/``gather_padded`` materialization of a padded batch
    in HBM.  (Production note: the prologue issues one row DMA at a time;
    a double-buffered start/wait-behind scheme can hide the latency further,
    but even serialized the copies are HBM-sequential 1 KiB reads.)

``meta_words > 0`` means ``x`` rows are full packets (reg0 metadata followed
by payload words); the parse is then inline too — the kernel slices the
payload and reads the control word for the action, so nothing upstream has
to materialize a payload view.

The reg0 constants are mirrored from ``repro.core.packet`` (the kernels
package stays importable without the core layer); ``repro.core.pipeline``
asserts they agree.

Double-buffered banks (DESIGN.md §14): selection is steered entirely by
the prefetched ``block_slots`` table, so the zero-copy commit story from
``repro.kernels.banked_matmul`` applies unchanged — lay the active and
shadow banks out as one (2K, ...) allocation (``stack_double_bank``) and
pass ``flip_slots(block_slots, active, k)``; a SwapSlot commit then
changes only the ``active`` scalar, and the DMA fetches from the other
half with zero weight movement (see ``double_buffered_forward``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

PACK = 32

# reg0 layout + Pi codes, mirrored from repro.core.packet.
CTRL_WORD = 2
CTRL_MONITOR_ONLY = 1
ACTION_FORWARD = 0
ACTION_DROP = 1
ACTION_FLAG = 2


def actions_ref(scores: jnp.ndarray, ctrl_words: jnp.ndarray) -> jnp.ndarray:
    """Pi oracle on (B, C) scores + (B,) uint32 control words -> (B,) i32."""
    malicious = scores[:, 0] > 0.0
    monitor = (ctrl_words & jnp.uint32(CTRL_MONITOR_ONLY)) != 0
    return jnp.where(
        malicious,
        jnp.where(monitor, ACTION_FLAG, ACTION_DROP),
        ACTION_FORWARD,
    ).astype(jnp.int32)


def _bnn_block(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, *, meta_words, chunk,
               d_bits):
    """Full executor on one block: x_ref rows (meta + payload words) ->
    (block_b, C) f32 scores, entirely in VMEM."""
    w_words = d_bits // PACK
    n_chunks = w_words // chunk
    n_hidden = w1_ref.shape[1]
    bb = x_ref.shape[0]

    def body(c, acc):
        xs = x_ref[:, pl.ds(meta_words + c * chunk, chunk)]
        ws = w1_ref[0, :, pl.ds(c * chunk, chunk)]  # selected slot only
        xor = jnp.bitwise_xor(xs[:, None, :], ws[None, :, :])
        return acc + jax.lax.population_count(xor).astype(jnp.int32).sum(axis=-1)

    mism = jax.lax.fori_loop(0, n_chunks, body, jnp.zeros((bb, n_hidden), jnp.int32))
    pre = (jnp.int32(d_bits) - 2 * mism).astype(jnp.float32) + b1_ref[0][None, :]
    h = jnp.where(pre >= 0, 1.0, -1.0)
    y = jnp.dot(h, w2_ref[0].T, preferred_element_type=jnp.float32)
    return y + b2_ref[0][None, :]


def _emit(x_ref, y, out_refs, with_actions):
    out_refs[0][...] = y
    if with_actions:
        ctrl = x_ref[:, CTRL_WORD]
        out_refs[1][...] = actions_ref(y, ctrl)[:, None]


def _fused_contig_kernel(slots_ref, x_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                         *out_refs, meta_words, chunk, d_bits, with_actions):
    del slots_ref  # consumed by the index_maps, not the body
    y = _bnn_block(x_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                   meta_words=meta_words, chunk=chunk, d_bits=d_bits)
    _emit(x_ref, y, out_refs, with_actions)


def _fused_gather_kernel(slots_ref, rows_ref, x_hbm, w1_ref, b1_ref, w2_ref,
                         b2_ref, *out_refs_and_scratch, meta_words, chunk,
                         d_bits, with_actions):
    del slots_ref
    *out_refs, x_vmem, sem = out_refs_and_scratch
    i = pl.program_id(0)
    bb = out_refs[0].shape[0]

    def copy_row(r, carry):
        src = rows_ref[i * bb + r]
        cp = pltpu.make_async_copy(
            x_hbm.at[pl.ds(src, 1)], x_vmem.at[pl.ds(r, 1)], sem
        )
        cp.start()
        cp.wait()
        return carry

    jax.lax.fori_loop(0, bb, copy_row, 0)
    y = _bnn_block(x_vmem, w1_ref, b1_ref, w2_ref, b2_ref,
                   meta_words=meta_words, chunk=chunk, d_bits=d_bits)
    _emit(x_vmem, y, out_refs, with_actions)


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "chunk", "interpret", "meta_words",
                     "with_actions"),
)
def fused_forward(
    x: jnp.ndarray,            # (B, meta_words + W) uint32 rows
    bank_w1: jnp.ndarray,      # (K, H, W) uint32
    bank_b1: jnp.ndarray,      # (K, H) f32
    bank_w2: jnp.ndarray,      # (K, C, H) f32
    bank_b2: jnp.ndarray,      # (K, C) f32
    block_slots: jnp.ndarray,  # (n_blocks,) i32 — one slot per output block
    row_ids: jnp.ndarray | None = None,  # (n_blocks * block_b,) i32 gather map
    *,
    block_b: int = 256,
    chunk: int = 64,
    interpret: bool = False,
    meta_words: int = 0,
    with_actions: bool = False,
):
    """One-launch fused forwarding path.

    Returns ``(n_blocks * block_b, C)`` f32 scores, plus a
    ``(n_blocks * block_b, 1)`` i32 action tile when ``with_actions``.
    Output row r belongs to input row ``row_ids[r]`` (gather mode) or row r
    (contiguous mode).
    """
    total_words = x.shape[-1]
    w_words = total_words - meta_words
    k, h, ww = bank_w1.shape
    c = bank_w2.shape[1]
    if ww != w_words:
        raise ValueError(f"payload words {w_words} != bank words {ww}")
    if bank_b1.shape != (k, h) or bank_w2.shape != (k, c, h) \
            or bank_b2.shape != (k, c):
        raise ValueError("bank shape mismatch")
    if with_actions and meta_words <= CTRL_WORD:
        raise ValueError("with_actions requires metadata words in x")
    n_blocks = block_slots.shape[0]
    n_rows = n_blocks * block_b
    chunk = min(chunk, w_words)
    if w_words % chunk:
        raise ValueError(f"chunk={chunk} must divide payload words {w_words}")

    d_bits = w_words * PACK
    kern_kwargs = dict(meta_words=meta_words, chunk=chunk, d_bits=d_bits,
                       with_actions=with_actions)
    out_shape = [jax.ShapeDtypeStruct((n_rows, c), jnp.float32)]
    out_specs = [pl.BlockSpec((block_b, c), lambda i, *_: (i, 0))]
    if with_actions:
        out_shape.append(jax.ShapeDtypeStruct((n_rows, 1), jnp.int32))
        out_specs.append(pl.BlockSpec((block_b, 1), lambda i, *_: (i, 0)))

    bank_specs = [
        pl.BlockSpec((1, h, w_words), lambda i, s, *_: (s[i], 0, 0)),
        pl.BlockSpec((1, h), lambda i, s, *_: (s[i], 0)),
        pl.BlockSpec((1, c, h), lambda i, s, *_: (s[i], 0, 0)),
        pl.BlockSpec((1, c), lambda i, s, *_: (s[i], 0)),
    ]

    if row_ids is None:
        if x.shape[0] != n_rows:
            raise ValueError(
                f"contiguous mode needs B={n_rows} rows, got {x.shape[0]}")
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_blocks,),
            in_specs=[pl.BlockSpec((block_b, total_words),
                                   lambda i, s: (i, 0))] + bank_specs,
            out_specs=out_specs,
        )
        kernel = functools.partial(_fused_contig_kernel, **kern_kwargs)
        operands = (block_slots, x, bank_w1, bank_b1, bank_w2, bank_b2)
    else:
        if row_ids.shape != (n_rows,):
            raise ValueError(f"row_ids must be ({n_rows},), got {row_ids.shape}")
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(n_blocks,),
            in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] + bank_specs,
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((block_b, total_words), jnp.uint32),
                pltpu.SemaphoreType.DMA,
            ],
        )
        kernel = functools.partial(_fused_gather_kernel, **kern_kwargs)
        operands = (block_slots, row_ids.astype(jnp.int32), x,
                    bank_w1, bank_b1, bank_w2, bank_b2)

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    return tuple(out) if with_actions else out[0]


def double_buffered_forward(
    x: jnp.ndarray,
    front: dict,               # bank pytree A: w1p/b1/w2/b2 (K, ...) leaves
    back: dict,                # bank pytree B, same structure
    active,                    # scalar 0/1 (may be traced) — which is live
    block_slots: jnp.ndarray,  # (n_blocks,) i32 slot ids in [0, K)
    row_ids: jnp.ndarray | None = None,
    **kwargs,
):
    """``fused_forward`` over a double-buffered bank (DESIGN.md §14).

    The two bank copies are concatenated on the slot axis and the
    per-block slot table is offset into the ``active`` half — so a
    SwapSlot commit is the change of ONE scalar, never a weight move,
    even at kernel level.  ``active`` may be a traced value carried in
    scan state (the megastep's ``DeviceDelta`` path), keeping the flip
    inside one compiled program.  Accepts every ``fused_forward``
    keyword."""
    from repro.kernels.banked_matmul import flip_slots, stack_double_bank
    both = stack_double_bank(front, back)
    k = front["b1"].shape[0]
    return fused_forward(
        x, both["w1p"], both["b1"], both["w2"], both["b2"],
        flip_slots(block_slots, active, k), row_ids, **kwargs)


def fused_forward_qmajor(
    x_qmajor: jnp.ndarray,     # (Q, B, meta_words + W) uint32 rows
    bank_w1: jnp.ndarray,
    bank_b1: jnp.ndarray,
    bank_w2: jnp.ndarray,
    bank_b2: jnp.ndarray,
    block_slots: jnp.ndarray,  # (n_blocks,) i32 over the flattened batch
    row_ids: jnp.ndarray,      # (n_blocks * block_b,) i32 into Q*B rows
    **kwargs,
):
    """All queues of a host in ONE launch (the megastep's device compute).

    ``x_qmajor`` stacks every queue's tick batch queue-major; flattening
    to ``(Q * B, words)`` turns the per-queue grids into one grid whose
    ``row_ids`` gather crosses queue boundaries freely, so a host-tick
    costs one ``pallas_call`` regardless of queue count — instead of one
    launch per queue-block.  Queue identity stays recoverable as
    ``row // B``.  Accepts every ``fused_forward`` keyword.
    """
    q, b, words = x_qmajor.shape
    return fused_forward(
        x_qmajor.reshape(q * b, words), bank_w1, bank_b1, bank_w2, bank_b2,
        block_slots, row_ids, **kwargs)
