"""Pure-jnp oracles for every Pallas kernel in this package.

Conventions
-----------
* Bit packing: a {+1,-1} vector is stored as uint32 words, little-endian
  within the word; bit ``b`` encodes value ``1 - 2b`` (bit 0 -> +1,
  bit 1 -> -1).
* ``d`` (input bits) must be a multiple of 32.
* The binary dot product of two +-1 vectors of length d packed as words
  x, w is ``d - 2 * popcount(x XOR w)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

PACK = 32


# ---------------------------------------------------------------------------
# packing helpers (host + device safe)
# ---------------------------------------------------------------------------

def pack_bits(x_pm1: jnp.ndarray) -> jnp.ndarray:
    """Pack a (+1/-1) array of shape (..., d) into (..., d//32) uint32."""
    d = x_pm1.shape[-1]
    if d % PACK:
        raise ValueError(f"d={d} must be a multiple of {PACK}")
    bits = (x_pm1 < 0).astype(jnp.uint32)          # bit 1 <=> -1
    bits = bits.reshape(*x_pm1.shape[:-1], d // PACK, PACK)
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    return (bits << shifts).sum(axis=-1, dtype=jnp.uint32)


def unpack_bits(packed: jnp.ndarray, d: int) -> jnp.ndarray:
    """Inverse of pack_bits -> (+1/-1) int8 of shape (..., d)."""
    if d != packed.shape[-1] * PACK:
        raise ValueError("d mismatch")
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(*packed.shape[:-1], d)
    return (1 - 2 * bits.astype(jnp.int8)).astype(jnp.int8)


# ---------------------------------------------------------------------------
# kernel oracles
# ---------------------------------------------------------------------------

def expand_block_slots(block_slots: jnp.ndarray, block_b: int,
                       total: int) -> jnp.ndarray:
    """Broadcast per-block slot ids to per-row ids: (n_blocks,) -> (total,).

    The single home for the ``jnp.repeat(block_slots, block_b, ...)`` pattern
    the grouped oracles need (the fused Pallas path reads the block id from
    SMEM instead and never materializes this).
    """
    return jnp.repeat(block_slots, block_b, total_repeat_length=total)


def popcount32(v: jnp.ndarray) -> jnp.ndarray:
    """SWAR popcount over uint32 words -> int32 bit counts.

    Bit-identical to ``jax.lax.population_count`` but lowers to plain
    shift/mask/multiply ops, which XLA:CPU vectorizes noticeably better
    than its POPCNT expansion — the whole forwarding path is
    popcount-bound, so this is measurable end to end.  TPU keeps using
    ``population_count`` (VPU-native).
    """
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def xnor_matmul_ref(x_packed: jnp.ndarray, w_packed: jnp.ndarray) -> jnp.ndarray:
    """Binary matmul oracle.

    x_packed: (B, W) uint32, w_packed: (H, W) uint32 -> (B, H) int32 dot
    products of the underlying +-1 vectors of length d = W*32.
    """
    d = x_packed.shape[-1] * PACK
    xor = jnp.bitwise_xor(x_packed[:, None, :], w_packed[None, :, :])
    mism = popcount32(xor).sum(axis=-1)
    return jnp.int32(d) - 2 * mism


def bnn_forward_ref(
    w1_packed: jnp.ndarray,  # (H, W) uint32
    b1: jnp.ndarray,         # (H,) float32
    w2: jnp.ndarray,         # (C, H) float32
    b2: jnp.ndarray,         # (C,) float32
    x_packed: jnp.ndarray,   # (B, W) uint32
) -> jnp.ndarray:
    """h = sign(W1 x + b1); y = W2 h + b2   (paper Eq. 1).  -> (B, C) f32."""
    pre = xnor_matmul_ref(x_packed, w1_packed).astype(jnp.float32) + b1[None, :]
    h = jnp.where(pre >= 0, 1.0, -1.0)
    return h @ w2.T + b2[None, :]


def banked_matmul_ref(
    x: jnp.ndarray,      # (B, D)
    w: jnp.ndarray,      # (K, D, H)
    b: jnp.ndarray,      # (K, H) or None
    slots: jnp.ndarray,  # (B,) int32
) -> jnp.ndarray:
    """Slot-selected matmul oracle: y[i] = x[i] @ w[slots[i]] + b[slots[i]]."""
    wg = w[slots]                       # (B, D, H)
    y = jnp.einsum("bd,bdh->bh", x, wg)
    if b is not None:
        y = y + b[slots]
    return y.astype(x.dtype)


def banked_xnor_forward_ref(
    bank_w1: jnp.ndarray,  # (K, H, W) uint32
    bank_b1: jnp.ndarray,  # (K, H) f32
    bank_w2: jnp.ndarray,  # (K, C, H) f32
    bank_b2: jnp.ndarray,  # (K, C) f32
    x_packed: jnp.ndarray, # (B, W) uint32
    slots: jnp.ndarray,    # (B,) int32
) -> jnp.ndarray:
    """Per-packet slot-selected BNN forward (gather strategy oracle)."""
    d = x_packed.shape[-1] * PACK
    w1g = bank_w1[slots]                              # (B, H, W)
    xor = jnp.bitwise_xor(x_packed[:, None, :], w1g)  # (B, H, W)
    mism = popcount32(xor).sum(axis=-1)
    pre = (jnp.int32(d) - 2 * mism).astype(jnp.float32) + bank_b1[slots]
    h = jnp.where(pre >= 0, 1.0, -1.0)                # (B, H)
    y = jnp.einsum("bh,bch->bc", h, bank_w2[slots]) + bank_b2[slots]
    return y


# ---------------------------------------------------------------------------
# MXU-path oracle (beyond-paper TPU adaptation): unpack to +-1 bf16 and use
# the systolic array instead of VPU popcount.
# ---------------------------------------------------------------------------

def xnor_matmul_mxu_ref(x_packed: jnp.ndarray, w_packed: jnp.ndarray) -> jnp.ndarray:
    d = x_packed.shape[-1] * PACK
    xv = unpack_bits(x_packed, d).astype(jnp.bfloat16)
    wv = unpack_bits(w_packed, d).astype(jnp.bfloat16)
    return jnp.dot(xv, wv.T, preferred_element_type=jnp.float32).astype(jnp.int32)


def random_bnn_params(key, d_bits: int, hidden: int, n_out: int = 1):
    """Random single-slot BNN parameter set (packed)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    w1 = jnp.where(jax.random.bernoulli(k1, 0.5, (hidden, d_bits)), 1.0, -1.0)
    w1p = pack_bits(w1)
    b1 = jax.random.normal(k2, (hidden,), jnp.float32) * 8.0
    w2 = jax.random.normal(k3, (n_out, hidden), jnp.float32) / np.sqrt(hidden)
    b2 = jax.random.normal(k4, (n_out,), jnp.float32) * 0.1
    return {"w1p": w1p, "b1": b1, "w2": w2, "b2": b2}
