"""Pallas TPU kernel: scalar-prefetch banked matmul — the paper's O(1) slot
selection, TPU-native.

BoundSwitch resolves the active model by reading a 4-byte slot id from reg0
and chasing one pointer into the resident bank.  The TPU analogue is scalar
prefetch: per-block slot ids are staged into SMEM *before* the grid runs, and
the weight BlockSpec's ``index_map`` reads them to steer the DMA engine at
the slot'th bank entry.  Selection therefore costs one SMEM read per block —
no gather materialization, no recompilation, and the non-selected K-1 slots
are never moved out of HBM.

Contract: packets/requests are pre-grouped so each block of ``block_b``
consecutive rows shares one slot (see ``repro.core.bank.group_by_slot``).
The ungrouped oracles in ``ref.py`` keep exact per-row granularity for
validation.

Also hosts the banked BNN layer-1 variant (uint32 XNOR words instead of a
float matmul) so the *entire* paper executor can run slot-selected inside
one kernel family.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

PACK = 32


# ---------------------------------------------------------------------------
# double-buffered bank view (zero-copy commit, DESIGN.md §14)
#
# The kernels themselves are already pointer-flip friendly: the slot id
# table in SMEM is the only thing that decides which HBM bank entry the
# DMA engine fetches.  To double-buffer at kernel level, lay both bank
# copies out as ONE (2K, ...) allocation (``stack_double_bank``) and
# offset the slot table by ``active * K`` (``flip_slots``) — committing a
# swap changes one scalar, the DMA steers into the other half, and no
# weight ever moves.  ``fused_forward`` consumes the same ``block_slots``
# argument, so the identical two helpers serve the fused executor.
# ---------------------------------------------------------------------------

def stack_double_bank(front, back) -> jnp.ndarray:
    """Concatenate two structurally identical (K, ...) bank leaves (or
    pytrees) into the (2K, ...) double-buffer layout the kernels index
    with ``flip_slots``-offset slot ids."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a, b], axis=0), front, back)


def flip_slots(block_slots: jnp.ndarray, active, k: int) -> jnp.ndarray:
    """Steer a per-block slot table at the ``active`` half (0 or 1) of a
    ``stack_double_bank`` layout.  ``active`` may be a traced scalar —
    the flip is data, not code: one compiled kernel serves both halves,
    and a commit is a change of this one scalar."""
    return (block_slots + jnp.int32(active) * jnp.int32(k)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# float banked matmul: y[i] = x[i] @ W[slot_of_block(i)] (+ b)
# ---------------------------------------------------------------------------

def _banked_kernel(slots_ref, x_ref, w_ref, b_ref, o_ref):
    del slots_ref  # consumed by the index_map, not the body
    y = jnp.dot(
        x_ref[...], w_ref[0], preferred_element_type=jnp.float32
    )
    o_ref[...] = (y + b_ref[0][None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def banked_matmul(
    x: jnp.ndarray,            # (B, D)
    w: jnp.ndarray,            # (K, D, H)
    b: jnp.ndarray,            # (K, H)
    block_slots: jnp.ndarray,  # (B // block_b,) int32 — one slot per block
    *,
    block_b: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    bsz, d = x.shape
    k, dw, h = w.shape
    if dw != d or b.shape != (k, h):
        raise ValueError(f"bank shape mismatch: x {x.shape}, w {w.shape}, b {b.shape}")
    block_b = min(block_b, bsz)
    if bsz % block_b:
        raise ValueError(f"B={bsz} must divide block_b={block_b}")
    n_blocks = bsz // block_b
    if block_slots.shape != (n_blocks,):
        raise ValueError(f"block_slots must be ({n_blocks},), got {block_slots.shape}")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, s: (i, 0)),
            pl.BlockSpec((1, d, h), lambda i, s: (s[i], 0, 0)),
            pl.BlockSpec((1, h), lambda i, s: (s[i], 0)),
        ],
        out_specs=pl.BlockSpec((block_b, h), lambda i, s: (i, 0)),
    )
    return pl.pallas_call(
        _banked_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, h), x.dtype),
        interpret=interpret,
    )(block_slots, x, w, b)


# ---------------------------------------------------------------------------
# banked BNN layer 1: slot-selected XNOR-popcount
# ---------------------------------------------------------------------------

def _banked_xnor_kernel(slots_ref, x_ref, w_ref, b1_ref, o_ref, *, d_bits, chunk):
    del slots_ref
    w_words = x_ref.shape[-1]
    n_chunks = w_words // chunk
    n_hidden = w_ref.shape[1]

    def body(c, acc):
        xs = x_ref[:, pl.ds(c * chunk, chunk)]
        ws = w_ref[0, :, pl.ds(c * chunk, chunk)]  # selected slot's weights
        xor = jnp.bitwise_xor(xs[:, None, :], ws[None, :, :])
        return acc + jax.lax.population_count(xor).astype(jnp.int32).sum(axis=-1)

    mism = jax.lax.fori_loop(
        0, n_chunks, body, jnp.zeros((x_ref.shape[0], n_hidden), jnp.int32)
    )
    pre = (jnp.int32(d_bits) - 2 * mism).astype(jnp.float32) + b1_ref[0, :][None, :]
    o_ref[...] = pre


@functools.partial(jax.jit, static_argnames=("block_b", "chunk", "interpret"))
def banked_xnor_layer1(
    x_packed: jnp.ndarray,     # (B, W) uint32
    bank_w1: jnp.ndarray,      # (K, H, W) uint32
    bank_b1: jnp.ndarray,      # (K, H) f32
    block_slots: jnp.ndarray,  # (B // block_b,) int32
    *,
    block_b: int = 256,
    chunk: int = 64,
    interpret: bool = False,
) -> jnp.ndarray:
    """Slot-selected layer-1 pre-activations (float32, bias added)."""
    bsz, w_words = x_packed.shape
    k, h, ww = bank_w1.shape
    if ww != w_words or bank_b1.shape != (k, h):
        raise ValueError("bank shape mismatch")
    block_b = min(block_b, bsz)
    chunk = min(chunk, w_words)
    if bsz % block_b or w_words % chunk:
        raise ValueError("blocking must divide shapes")
    n_blocks = bsz // block_b
    if block_slots.shape != (n_blocks,):
        raise ValueError(f"block_slots must be ({n_blocks},)")

    kernel = functools.partial(_banked_xnor_kernel, d_bits=w_words * PACK, chunk=chunk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_b, w_words), lambda i, s: (i, 0)),
            pl.BlockSpec((1, h, w_words), lambda i, s: (s[i], 0, 0)),
            pl.BlockSpec((1, h), lambda i, s: (s[i], 0)),
        ],
        out_specs=pl.BlockSpec((block_b, h), lambda i, s: (i, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, h), jnp.float32),
        interpret=interpret,
    )(block_slots, x_packed, bank_w1, bank_b1)
