"""Pallas TPU kernel: bit-packed XNOR-popcount binary matmul (paper Eq. 1, layer 1).

This is the TPU-native adaptation of BoundSwitch's AVX-512 executor.  The
x86 design loads sixteen 64-byte payload blocks into ZMM registers and runs
XNOR + VPOPCNT accumulation.  On TPU:

* the payload lives as uint32 words; a (block_b, W) tile of packets and a
  (block_h, W) tile of weight rows are staged into VMEM via BlockSpecs,
* the VPU computes ``popcount(x XOR w)`` on (8, 128)-lane int32 vectors,
* accumulation runs over W in chunks so the broadcast intermediate
  (block_b, block_h, chunk) stays comfortably inside VMEM.

Grid: (B / block_b, H / block_h).  Each grid cell writes a (block_b, block_h)
int32 tile of binary dot products ``d - 2 * mismatches``.

VMEM budget at the default production blocking (block_b=256, block_h=32,
chunk=64, W=256 for the paper's 1024-byte payload):
  x tile 256*256*4 = 256 KiB, w tile 32*256*4 = 32 KiB,
  xor intermediate 256*32*64*4 = 2 MiB, out tile 32 KiB  -> ~2.4 MiB << VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PACK = 32


def _xnor_kernel(x_ref, w_ref, o_ref, *, d_bits: int, chunk: int):
    """x_ref: (bB, W) uint32; w_ref: (bH, W) uint32; o_ref: (bB, bH) int32."""
    w_words = x_ref.shape[-1]
    n_chunks = w_words // chunk

    def body(c, acc):
        xs = x_ref[:, pl.ds(c * chunk, chunk)]          # (bB, chunk)
        ws = w_ref[:, pl.ds(c * chunk, chunk)]          # (bH, chunk)
        xor = jnp.bitwise_xor(xs[:, None, :], ws[None, :, :])
        pc = jax.lax.population_count(xor).astype(jnp.int32)
        return acc + pc.sum(axis=-1)

    mism = jax.lax.fori_loop(
        0, n_chunks, body,
        jnp.zeros((x_ref.shape[0], w_ref.shape[0]), jnp.int32),
    )
    o_ref[...] = jnp.int32(d_bits) - 2 * mism


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_h", "chunk", "interpret")
)
def xnor_matmul(
    x_packed: jnp.ndarray,   # (B, W) uint32
    w_packed: jnp.ndarray,   # (H, W) uint32
    *,
    block_b: int = 256,
    block_h: int = 32,
    chunk: int = 64,
    interpret: bool = False,
) -> jnp.ndarray:
    """Binary matmul: (B, W) x (H, W) -> (B, H) int32 +-1 dot products."""
    b, w_words = x_packed.shape
    h = w_packed.shape[0]
    if w_packed.shape[1] != w_words:
        raise ValueError("word-count mismatch between x and w")
    block_b = min(block_b, b)
    block_h = min(block_h, h)
    chunk = min(chunk, w_words)
    if b % block_b or h % block_h or w_words % chunk:
        raise ValueError(
            f"shapes (B={b}, H={h}, W={w_words}) must divide blocks "
            f"({block_b}, {block_h}, chunk={chunk})"
        )
    d_bits = w_words * PACK
    kernel = functools.partial(_xnor_kernel, d_bits=d_bits, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(b // block_b, h // block_h),
        in_specs=[
            pl.BlockSpec((block_b, w_words), lambda i, j: (i, 0)),
            pl.BlockSpec((block_h, w_words), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_h), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, h), jnp.int32),
        interpret=interpret,
    )(x_packed, w_packed)
