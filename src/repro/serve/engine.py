"""Batched serving engine with per-request model-slot routing.

This is the paper's forwarding path lifted to LLM serving: one compiled
decode step (the shared executor), a resident bank of model behaviors
(adapters / heads / full weight sets), and per-request metadata (the reg0
analogue) selecting the slot — switching happens at request granularity
with O(1) cost and zero engine reconfiguration.

Continuous-batching-lite tick loop:

  1. ADMIT   — waiting requests fill free rows; batch formation is
               deadline-bounded (straggler mitigation: a tick never waits
               more than ``max_admit_wait_s`` for stragglers, late arrivals
               roll to the next tick; requests past their deadline are
               rejected and counted),
  2. PREFILL — newly admitted prompts run through bucketed prefill
               (pow-2 padding, one compiled program per bucket) and their
               caches are spliced into the resident batch cache,
  3. DECODE  — one synchronous decode step for all active rows (inactive
               rows ride along masked),
  4. RETIRE  — rows hitting max_new_tokens (or EOS) free their slot.

``bank_mode='full'`` routes each tick's decode through per-slot segments
(uniform-slot sub-batches, the grouped strategy at engine level); adapter /
head banks pass per-row slot_ids straight into the compiled step.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    slot_id: int = 0
    max_new_tokens: int = 16
    deadline_s: Optional[float] = None   # absolute deadline (time.monotonic)
    arrival_s: float = 0.0


@dataclasses.dataclass
class Finished:
    rid: int
    output: list[int]
    prompt_len: int
    latency_s: float
    rejected: bool = False


def _bucket(n: int, buckets: tuple[int, ...]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class ServeEngine:
    def __init__(
        self,
        params,
        cfg: ModelConfig,
        *,
        max_batch: int = 8,
        max_seq: int = 512,
        prefill_buckets: tuple[int, ...] = (32, 128, 512),
        max_admit_wait_s: float = 0.0,
        eos_token: Optional[int] = None,
    ):
        self.params, self.cfg = params, cfg
        self.max_batch, self.max_seq = max_batch, max_seq
        self.buckets = prefill_buckets
        self.max_admit_wait_s = max_admit_wait_s
        self.eos_token = eos_token

        self.cache = api.init_cache(cfg, max_batch, max_seq)
        self.tokens = np.zeros((max_batch,), np.int32)     # last token per row
        self.lengths = np.zeros((max_batch,), np.int32)    # context length
        self.slot_ids = np.zeros((max_batch,), np.int32)
        self.active = np.zeros((max_batch,), bool)
        self.row_req: list[Optional[Request]] = [None] * max_batch
        self.row_out: list[list[int]] = [[] for _ in range(max_batch)]
        self.row_start: list[float] = [0.0] * max_batch

        self.waiting: list[Request] = []
        self.finished: list[Finished] = []
        self.rejected_count = 0
        self.ticks = 0

        self._decode = jax.jit(self._decode_impl)
        self._prefills: dict[int, object] = {}

    # ------------------------------------------------------------------
    def _decode_impl(self, params, tokens, cache, lengths, slot_ids):
        logits, new_cache = api.decode_step(
            params, tokens[:, None], cache, lengths, self.cfg,
            slot_ids if self.cfg.bank_mode in ("adapter", "head") else None,
        )
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, new_cache

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefills:
            cfg = self.cfg

            def prefill(params, tokens, slot_ids, prompt_len):
                batch = {"tokens": tokens}
                batch["pad_mask"] = (
                    jnp.arange(tokens.shape[1])[None, :] < prompt_len[:, None]
                ).astype(jnp.float32)
                if cfg.bank_mode in ("adapter", "head"):
                    batch["slot_ids"] = slot_ids
                logits, _, cache = api.apply(params, batch, cfg, return_cache=True)
                last = jnp.take_along_axis(
                    logits, (prompt_len - 1)[:, None, None], axis=1
                )[:, 0]
                nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
                return nxt, cache

            self._prefills[bucket] = jax.jit(prefill)
        return self._prefills[bucket]

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.arrival_s = time.monotonic()
        self.waiting.append(req)

    def _splice_cache(self, row: int, row_cache, prompt_len: int):
        """Write a prefill cache (leaves (..., 1, ...)) into batch row."""

        def splice(path, full, part):
            name = "/".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in path
            )
            if name.endswith("/k") or name.endswith("/v") or name in ("k", "v"):
                # full: (L, B, G, Lmax, hd); part: (L, 1, G, S, hd)
                s = min(part.shape[3], full.shape[3])
                return full.at[:, row, :, :s].set(part[:, 0, :, :s])
            # ssm/conv state leaves: (..., B, ...) at the same position as
            # init_cache builds them — batch dim right after stack dims.
            bdim = _batch_dim(name, full.ndim)
            idx = [slice(None)] * full.ndim
            idx[bdim] = row
            pidx = [slice(None)] * part.ndim
            pidx[bdim] = 0
            return full.at[tuple(idx)].set(part[tuple(pidx)])

        self.cache = jax.tree_util.tree_map_with_path(
            splice, self.cache, row_cache
        )

    def _admit(self):
        tick_start = time.monotonic()
        while self.waiting and (~self.active).any():
            req = self.waiting[0]
            now = time.monotonic()
            if req.deadline_s is not None and now > req.deadline_s:
                self.waiting.pop(0)
                self.rejected_count += 1
                self.finished.append(Finished(
                    rid=req.rid, output=[], prompt_len=len(req.prompt),
                    latency_s=now - req.arrival_s, rejected=True,
                ))
                continue
            if now - tick_start > self.max_admit_wait_s and self.ticks > 0 \
                    and self.active.any():
                break  # deadline-bounded batch formation
            self.waiting.pop(0)
            row = int(np.nonzero(~self.active)[0][0])
            self._prefill_into_row(req, row)

    def _prefill_into_row(self, req: Request, row: int):
        bucket = _bucket(len(req.prompt), self.buckets)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, : len(req.prompt)] = req.prompt[:bucket]
        nxt, row_cache = self._prefill_fn(bucket)(
            self.params, jnp.asarray(toks),
            jnp.asarray([req.slot_id], jnp.int32),
            jnp.asarray([len(req.prompt)], jnp.int32),
        )
        # NOTE: bucket padding attends over pad tokens to the right of the
        # prompt; we splice only the first len(prompt) cache entries.
        self._splice_cache(row, row_cache, len(req.prompt))
        self.active[row] = True
        self.lengths[row] = len(req.prompt)
        self.tokens[row] = int(nxt[0])
        self.slot_ids[row] = req.slot_id
        self.row_req[row] = req
        self.row_out[row] = [int(nxt[0])]
        self.row_start[row] = time.monotonic()

    def _retire(self):
        for row in range(self.max_batch):
            if not self.active[row]:
                continue
            req = self.row_req[row]
            out = self.row_out[row]
            done = len(out) >= req.max_new_tokens or (
                self.eos_token is not None and out and out[-1] == self.eos_token
            )
            if done:
                self.finished.append(Finished(
                    rid=req.rid, output=list(out), prompt_len=len(req.prompt),
                    latency_s=time.monotonic() - req.arrival_s,
                ))
                self.active[row] = False
                self.row_req[row] = None

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine tick; returns number of active rows decoded."""
        self._admit()
        if not self.active.any():
            self.ticks += 1
            return 0
        nxt, self.cache = self._decode(
            self.params, jnp.asarray(self.tokens), self.cache,
            jnp.asarray(self.lengths), jnp.asarray(self.slot_ids),
        )
        nxt = np.asarray(nxt)
        for row in range(self.max_batch):
            if self.active[row]:
                self.lengths[row] += 1
                self.tokens[row] = nxt[row]
                self.row_out[row].append(int(nxt[row]))
        self._retire()
        self.ticks += 1
        return int(self.active.sum())

    def run_until_done(self, max_ticks: int = 10_000) -> list[Finished]:
        while (self.waiting or self.active.any()) and self.ticks < max_ticks:
            self.step()
        return self.finished


def _batch_dim(name: str, ndim: int) -> int:
    if name.endswith("ssm"):
        return ndim - 4
    if name.endswith("conv"):
        return ndim - 3
    return 1
