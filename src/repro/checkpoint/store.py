"""Sharded tensor checkpoint store: msgpack manifest + compressed leaf files.

Leaves are zstd-compressed when ``zstandard`` is importable, else stdlib
zlib; the codec is recorded in the manifest and either codec is accepted on
restore (restore reads leaf filenames from the manifest, so the extension is
informational only — legacy checkpoints whose zlib leaves were written with
a ``.zst`` suffix still restore).

Layout::

    <dir>/step_<N>/
        MANIFEST.msgpack     # {paths, shapes, dtypes, codec, extra}
        <leaf-hash>.bin.zst  # one compressed raw-bytes file per leaf
                             # (.bin.zlib under the zlib fallback)

Commit protocol: everything is written into ``step_<N>.tmp`` and atomically
renamed — a crash mid-save never corrupts the latest checkpoint.  Restore is
**elastic**: arrays are loaded host-side and re-placed with whatever
sharding the *restoring* run asks for, so a checkpoint taken on a 512-chip
mesh restores onto 8 chips (or 1) unchanged — tested in
``tests/test_checkpoint.py`` across device counts.

On a real multi-host pod each process writes only the leaf shards it owns
(process-local addressable shards) and reads back its slice via
``jax.make_array_from_callback``; in this single-process container the
degenerate form (full leaves) exercises the same manifest/commit logic.
"""

from __future__ import annotations

import hashlib
import os
import shutil
import zlib

import jax
import jax.numpy as jnp
import ml_dtypes
import msgpack
import numpy as np

try:
    import zstandard as zstd
except ImportError:  # gated: fall back to stdlib zlib (codec recorded below)
    zstd = None


def _compressor():
    """(codec_name, compress_fn) — zstd when available, else stdlib zlib."""
    if zstd is not None:
        return "zstd", zstd.ZstdCompressor(level=3).compress
    return "zlib", lambda raw: zlib.compress(raw, 3)


def _decompress(codec: str, blob: bytes) -> bytes:
    if codec == "zstd":
        if zstd is None:
            raise ModuleNotFoundError(
                "checkpoint was written with zstd; install `zstandard` to "
                "restore it")
        return zstd.ZstdDecompressor().decompress(blob)
    if codec == "zlib":
        return zlib.decompress(blob)
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _dtype_name(dt: np.dtype) -> str:
    return dt.name  # 'bfloat16', 'float32', ... (ml_dtypes registers names)


def _dtype_from_name(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


_LEAF_EXT = {"zstd": "zst", "zlib": "zlib"}


def _leaf_file(path_s: str, codec: str) -> str:
    return hashlib.sha1(path_s.encode()).hexdigest()[:16] + ".bin." + _LEAF_EXT[codec]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(directory: str, step: int, tree, extra: dict | None = None,
         keep_last: int | None = None) -> str:
    """Write ``tree`` as checkpoint ``step_<step>``; returns final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    codec, compress = _compressor()
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest: dict = {"step": step, "codec": codec, "leaves": [],
                      "extra": extra or {}}
    for path, leaf in leaves:
        ps = _path_str(path)
        arr = np.asarray(leaf)
        fname = _leaf_file(ps, codec)
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(compress(arr.tobytes()))
        manifest["leaves"].append({
            "path": ps,
            "file": fname,
            "shape": list(arr.shape),
            "dtype": _dtype_name(arr.dtype),
        })
    with open(os.path.join(tmp, "MANIFEST.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit

    if keep_last is not None:
        steps = sorted(list_steps(directory))
        for s in steps[:-keep_last]:
            shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                          ignore_errors=True)
    return final


def list_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name.split("_")[1]))
            except (IndexError, ValueError):
                continue
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int | None, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``.

    ``shardings``: optional matching pytree of ``jax.sharding.Sharding`` —
    arrays are placed accordingly (elastic: any mesh/device count).
    Returns (tree, extra_metadata).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    ckpt = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(ckpt, "MANIFEST.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    by_path = {e["path"]: e for e in manifest["leaves"]}
    codec = manifest.get("codec", "zstd")  # pre-codec checkpoints were zstd

    paths_leaves = jax.tree_util.tree_flatten_with_path(like_tree)[0]
    treedef = jax.tree_util.tree_structure(like_tree)
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None
        else [None] * len(paths_leaves)
    )
    out = []
    for (path, like), sh in zip(paths_leaves, shard_leaves):
        ps = _path_str(path)
        if ps not in by_path:
            raise KeyError(f"checkpoint missing leaf {ps}")
        e = by_path[ps]
        with open(os.path.join(ckpt, e["file"]), "rb") as f:
            raw = _decompress(codec, f.read())
        arr = np.frombuffer(raw, dtype=_dtype_from_name(e["dtype"])).reshape(e["shape"])
        if tuple(arr.shape) != tuple(np.shape(like)):
            raise ValueError(
                f"shape mismatch for {ps}: ckpt {arr.shape} vs model {np.shape(like)}"
            )
        out.append(jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
