"""Training launcher: ``python -m repro.launch.train --arch smollm-360m ...``

Runs on whatever devices this process has (elastic); production meshes are
exercised by the dry-run.  Reduced configs train end-to-end on CPU.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, get_config
from repro.data.tokens import SyntheticTokens, TokenPipelineConfig
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import OptimizerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m",
                    choices=[a for a in ARCH_IDS if a != "boundswitch-h32"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-gradients", action="store_true")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--preempt-flag-file", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(remat="none")
    opt_cfg = OptimizerConfig(
        learning_rate=args.lr, warmup_steps=min(20, args.steps // 5),
        total_steps=args.steps,
        moments_dtype=cfg.moments_dtype, master_weights=cfg.master_weights,
    )
    data = SyntheticTokens(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch))
    trainer = Trainer(
        cfg, opt_cfg,
        TrainerConfig(
            total_steps=args.steps, checkpoint_every=args.checkpoint_every,
            checkpoint_dir=args.checkpoint_dir,
            preempt_flag_file=args.preempt_flag_file,
            num_microbatches=args.microbatches,
            compress_gradients=args.compress_gradients,
        ),
        data,
    )
    if args.resume and trainer.try_restore():
        print(f"resumed at step {int(trainer.state['step'])}")
    out = trainer.run()
    print(out)
    for m in trainer.metrics_log:
        print({k: round(v, 4) for k, v in m.items()})


if __name__ == "__main__":
    main()
