"""Docs lint: keep the prose wired to the code it describes.

Checks, over README.md / DESIGN.md / docs/*.md and the `repro` source
tree:

  * **dead file paths** — every ``src/repro/...`` (or ``benchmarks/...``,
    ``tests/...``, ``examples/...``) path mentioned in the docs must
    exist in the repo;
  * **dead module refs** — every dotted ``repro.x.y`` reference must
    resolve to a real module or package under ``src/``;
  * **broken intra-repo links** — relative markdown link targets must
    exist, and ``#anchor`` fragments must match a heading slug in the
    target file;
  * **DESIGN section anchors** — every ``§N`` referenced from markdown
    *or from a source docstring/comment* must be a real DESIGN.md
    section;
  * **CLI reference parity** — the flag set documented in docs/cli.md
    must equal the live ``launch.dataplane.build_parser()`` flag set
    (both directions: no rotted flags, no undocumented flags);
  * **public API docstrings** — every public method of the
    ``DataplaneRuntime`` / ``ControlPlane`` / ``MeshDataplane`` surface
    must carry a docstring.

Run as ``PYTHONPATH=src python -m repro.launch.doclint`` (the CI docs
step); exits nonzero listing every violation.
"""

from __future__ import annotations

import os
import re
import sys

#: Markdown files linted (relative to the repo root); docs/*.md join in.
DOC_FILES = ("README.md", "DESIGN.md", "ROADMAP.md")

#: Classes whose public surface must be documented.
API_SURFACE = (
    ("repro.dataplane.runtime", "DataplaneRuntime"),
    ("repro.control.plane", "ControlPlane"),
    ("repro.dataplane.mesh", "MeshDataplane"),
)

_PATH_RE = re.compile(
    r"\b((?:src/repro|benchmarks|tests|examples|docs)/[\w./-]*\w)")
_MODULE_RE = re.compile(r"\brepro(?:\.[a-z_][a-z_0-9]*)+\b")
_LINK_RE = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
_SECTION_RE = re.compile(r"§(\d+)")
_HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$", re.M)


def _repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))


def _doc_paths(root: str) -> list[str]:
    out = [p for p in DOC_FILES if os.path.exists(os.path.join(root, p))]
    docs_dir = os.path.join(root, "docs")
    if os.path.isdir(docs_dir):
        out += sorted("docs/" + f for f in os.listdir(docs_dir)
                      if f.endswith(".md"))
    return out


def _slugify(heading: str) -> str:
    """GitHub-style heading anchor: lower, spaces to dashes, drop
    everything but word chars and dashes."""
    s = heading.strip().lower().replace(" ", "-")
    return re.sub(r"[^\w\-]", "", s)


def _design_sections(root: str) -> set[int]:
    try:
        text = open(os.path.join(root, "DESIGN.md")).read()
    except OSError:
        return set()
    return {int(m.group(1))
            for m in re.finditer(r"^## §(\d+)\b", text, re.M)}


def check_paths(root: str, doc: str, text: str, problems: list[str]) -> None:
    for m in _PATH_RE.finditer(text):
        path = m.group(1).rstrip(".")
        if not os.path.exists(os.path.join(root, path)):
            problems.append(f"{doc}: dead path {path!r}")


def check_modules(root: str, doc: str, text: str,
                  problems: list[str]) -> None:
    for m in _MODULE_RE.finditer(text):
        parts = m.group(0).split(".")
        # accept the longest prefix that is a package or module — the
        # tail may name a function/class attribute (pipeline.packet_step)
        ok = False
        for i in range(len(parts), 0, -1):
            base = os.path.join(root, "src", *parts[:i])
            if os.path.exists(base + ".py"):
                ok = True
                break
            if os.path.isdir(base):
                ok = i == len(parts)  # bare package ref is fine; a
                break                 # missing submodule below it is not
        if not ok:
            problems.append(f"{doc}: dead module ref {m.group(0)!r}")


def check_links(root: str, doc: str, text: str, problems: list[str]) -> None:
    base = os.path.dirname(os.path.join(root, doc))
    for m in _LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path, _, frag = target.partition("#")
        full = os.path.normpath(os.path.join(base, path)) if path else \
            os.path.join(root, doc)
        if path and not os.path.exists(full):
            problems.append(f"{doc}: broken link target {target!r}")
            continue
        if frag and full.endswith(".md"):
            try:
                slugs = {_slugify(h) for _, h in
                         _HEADING_RE.findall(open(full).read())}
            except OSError:
                slugs = set()
            if frag not in slugs:
                problems.append(f"{doc}: broken anchor {target!r}")


def check_sections(root: str, sections: set[int], doc: str, text: str,
                   problems: list[str]) -> None:
    for m in _SECTION_RE.finditer(text):
        n = int(m.group(1))
        if n not in sections:
            problems.append(f"{doc}: reference to missing DESIGN.md §{n}")


def check_source_sections(root: str, sections: set[int],
                          problems: list[str]) -> None:
    src = os.path.join(root, "src", "repro")
    for dirpath, _, files in os.walk(src):
        for f in files:
            if not f.endswith(".py"):
                continue
            full = os.path.join(dirpath, f)
            rel = os.path.relpath(full, root)
            text = open(full).read()
            for m in re.finditer(r"DESIGN\.md\s+§(\d+)", text):
                if int(m.group(1)) not in sections:
                    problems.append(
                        f"{rel}: docstring references missing "
                        f"DESIGN.md §{m.group(1)}")


def check_cli_parity(root: str, problems: list[str]) -> None:
    cli_md = os.path.join(root, "docs", "cli.md")
    if not os.path.exists(cli_md):
        problems.append("docs/cli.md: missing (CLI reference required)")
        return
    from repro.launch.dataplane import build_parser
    live = {opt for a in build_parser()._actions
            for opt in a.option_strings if opt.startswith("--")}
    live.discard("--help")
    documented = set(re.findall(r"`(--[\w-]+)[^`]*`",
                                open(cli_md).read()))
    for flag in sorted(live - documented):
        problems.append(f"docs/cli.md: flag {flag} undocumented")
    for flag in sorted(documented - live):
        problems.append(f"docs/cli.md: documents unknown flag {flag}")


def check_api_docstrings(problems: list[str]) -> None:
    import importlib
    for mod_name, cls_name in API_SURFACE:
        cls = getattr(importlib.import_module(mod_name), cls_name)
        if not (cls.__doc__ or "").strip():
            problems.append(f"{mod_name}.{cls_name}: missing class "
                            "docstring")
        for name, attr in vars(cls).items():
            if name.startswith("_"):
                continue
            fn = getattr(attr, "fget", attr)  # unwrap properties
            if not callable(fn):
                continue
            if not (getattr(fn, "__doc__", None) or "").strip():
                problems.append(
                    f"{mod_name}.{cls_name}.{name}: public API method "
                    "missing docstring")


def run(root: str | None = None) -> list[str]:
    """All doc-lint checks; returns the list of problems (empty = clean)."""
    root = root or _repo_root()
    problems: list[str] = []
    sections = _design_sections(root)
    if not sections:
        problems.append("DESIGN.md: no '## §N' sections found")
    for doc in _doc_paths(root):
        text = open(os.path.join(root, doc)).read()
        check_paths(root, doc, text, problems)
        check_modules(root, doc, text, problems)
        check_links(root, doc, text, problems)
        check_sections(root, sections, doc, text, problems)
    check_source_sections(root, sections, problems)
    check_cli_parity(root, problems)
    check_api_docstrings(problems)
    return problems


def main(argv=None) -> int:
    problems = run()
    for p in problems:
        print(f"doclint: {p}")
    if problems:
        print(f"doclint: {len(problems)} problem(s)")
        return 1
    print("doclint: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
