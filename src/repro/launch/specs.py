"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

No allocation ever happens here: batches, caches and train state are built
with ``jax.eval_shape`` / ShapeDtypeStructs (weak-type-correct, shardable).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import api
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts_lib


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        # split the position budget between encoder frames and decoder tokens
        s_half = s // 2
        return {
            "frames": _sds((b, s_half, cfg.d_model), cfg.dtype),
            "tokens": _sds((b, s_half), jnp.int32),
            "labels": _sds((b, s_half), jnp.int32),
            "loss_mask": _sds((b, s_half), jnp.float32),
        }
    batch = {}
    s_text = s
    if cfg.frontend == "patch":
        s_text = s - cfg.frontend_len
        batch["patch_embeds"] = _sds((b, cfg.frontend_len, cfg.d_model), cfg.dtype)
    batch["tokens"] = _sds((b, s_text), jnp.int32)
    batch["labels"] = _sds((b, s_text), jnp.int32)
    batch["loss_mask"] = _sds((b, s_text), jnp.float32)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        s_half = s // 2
        return {
            "frames": _sds((b, s_half, cfg.d_model), cfg.dtype),
            "tokens": _sds((b, s_half), jnp.int32),
        }
    batch = {}
    s_text = s
    if cfg.frontend == "patch":
        s_text = s - cfg.frontend_len
        batch["patch_embeds"] = _sds((b, cfg.frontend_len, cfg.d_model), cfg.dtype)
    batch["tokens"] = _sds((b, s_text), jnp.int32)
    if cfg.bank_mode in ("adapter", "head"):
        batch["slot_ids"] = _sds((b,), jnp.int32)
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(tokens, cache, cache_len, slot_ids) ShapeDtypeStructs."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: api.init_cache(cfg, b, s))
    tokens = _sds((b, 1), jnp.int32)
    cache_len = _sds((), jnp.int32)
    slot_ids = (
        _sds((b,), jnp.int32) if cfg.bank_mode in ("adapter", "head") else None
    )
    return tokens, cache, cache_len, slot_ids


def train_state_specs(cfg: ModelConfig, opt_cfg: opt_lib.OptimizerConfig):
    return jax.eval_shape(
        lambda k: ts_lib.init_train_state(k, cfg, opt_cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )


def param_shape_specs(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: api.init(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                opt_cfg: opt_lib.OptimizerConfig | None = None) -> dict:
    """Everything the dry-run needs for one cell, keyed by step kind."""
    opt_cfg = opt_cfg or opt_lib.OptimizerConfig(
        moments_dtype=cfg.moments_dtype,
        master_weights=cfg.master_weights,
    )
    if shape.kind == "train":
        return {
            "kind": "train",
            "state": train_state_specs(cfg, opt_cfg),
            "batch": train_batch_specs(cfg, shape),
            "opt_cfg": opt_cfg,
        }
    if shape.kind == "prefill":
        return {
            "kind": "prefill",
            "params": param_shape_specs(cfg),
            "batch": prefill_batch_specs(cfg, shape),
        }
    if shape.kind == "decode":
        tokens, cache, cache_len, slot_ids = decode_input_specs(cfg, shape)
        return {
            "kind": "decode",
            "params": param_shape_specs(cfg),
            "tokens": tokens,
            "cache": cache,
            "cache_len": cache_len,
            "slot_ids": slot_ids,
        }
    raise ValueError(shape.kind)
