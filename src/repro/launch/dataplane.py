"""Multi-queue data-plane driver: RSS -> rings -> sharded fused workers.

Runs the emergency-scenario traffic engine (steady -> flash crowd -> link
failover -> slot churn) through the multi-queue runtime and reports
per-phase throughput, per-queue telemetry, and the packet-conservation
audit.  Host-simulated queues on CPU; device-spread via ``--fanout
shard_map`` on real meshes.

    PYTHONPATH=src python -m repro.launch.dataplane --queues 4
"""

from __future__ import annotations

import argparse
import json
import sys

import jax

from repro.core import executor
from repro.dataplane import (DataplaneRuntime, emergency_phases, play, render,
                             scenarios)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--queues", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4,
                    help="resident bank size (models preloaded)")
    ap.add_argument("--strategy", default="fused",
                    choices=["fused", "grouped", "grouped_staged", "take",
                             "onehot"])
    ap.add_argument("--fanout", default="auto",
                    choices=["auto", "loop", "vmap", "shard_map"])
    ap.add_argument("--batch", type=int, default=128,
                    help="max rows drained per queue per tick")
    ap.add_argument("--ring-capacity", type=int, default=1024)
    ap.add_argument("--scale", type=int, default=1,
                    help="burst-size multiplier for every phase")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--audit", action="store_true",
                    help="re-score every tick through the exact take path "
                         "and count wrong verdicts")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the full report as JSON")
    args = ap.parse_args(argv)

    print(f"== resident bank: {args.slots} slots (random init) ==")
    bank = executor.init_bank(jax.random.PRNGKey(args.seed), args.slots)
    phases = emergency_phases(args.slots, scale=args.scale)
    trace = render(phases, num_slots=args.slots, seed=args.seed)
    print(f"scenario: {len(phases)} phases, {trace.total_packets} packets, "
          f"seed={args.seed} (replayable)")

    rt = DataplaneRuntime(
        bank, num_queues=args.queues, strategy=args.strategy,
        fanout=args.fanout, batch=args.batch,
        ring_capacity=args.ring_capacity, audit=args.audit)
    print(f"runtime: {args.queues} queues x batch {args.batch}, "
          f"strategy={args.strategy}, fanout={rt.fanout}, "
          f"ring={args.ring_capacity}")

    reports = play(rt, trace, swap_delivery=scenarios.default_swap_delivery)
    print(f"{'phase':<16}{'offered':>9}{'done':>9}{'dropped':>9}"
          f"{'wrong':>7}{'kpps':>10}")
    for r in reports:
        print(f"{r['phase']:<16}{r['offered']:>9}{r['completed']:>9}"
              f"{r['dropped']:>9}{r['wrong_verdict']:>7}{r['kpps']:>10.1f}")

    snap = rt.snapshot()
    for q in snap["queues"]:
        print(f"queue {q['queue']}: completed={q['completed']} "
              f"pps_busy={q['pps_busy']:.0f} "
              f"lat p50/p99/max={q['latency_p50_us']:.0f}/"
              f"{q['latency_p99_us']:.0f}/{q['latency_max_us']:.0f}us "
              f"per_slot={q['per_slot_total']}")
    aud = snap["conservation"]
    print(f"conservation: offered={aud['totals']['offered']} = "
          f"completed={aud['totals']['completed']} + "
          f"dropped={aud['totals']['dropped']} "
          f"(+{aud['totals']['occupancy']} in flight) "
          f"ok={aud['ok']} wrong_verdict={aud['wrong_verdict']}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"phases": reports, "snapshot": snap}, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json}")
    if not aud["ok"] or aud["wrong_verdict"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
