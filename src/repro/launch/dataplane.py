"""Multi-queue data-plane driver: RSS -> rings -> sharded fused workers.

Runs a workload regime from the trace-driven engine (``--scenario``
names any regime in `repro.dataplane.workloads.REGIME_NAMES`: the
emergency storyline, elephant skew, cascading failover, diurnal load,
flash-crowd surge, adversarial slot thrash, chaos regimes, recorded-file
replay) through the multi-queue runtime and reports per-phase
throughput, per-queue telemetry, the packet-conservation audit, and the
control-plane epoch log.  ``--hosts`` lifts the run to the multi-host
mesh data plane; ``--policy`` installs a closed-loop routing policy;
``--pipeline-depth`` overlaps dispatch/device/retire.

``--trace record PATH`` records the run — packet batches, typed command
timeline (chaos events included), per-phase invariants, and the initial
bank — as a versioned compressed trace, *streamed* to disk in chunks as
the run progresses; ``--trace replay PATH`` replays a recorded trace
bit-exactly (verdict-stream digest checked) through a runtime rebuilt
from the trace's own metadata.

``--observe PORT`` starts the live observability server
(`repro.obs.server`) alongside the run: the dashboard at
``http://127.0.0.1:PORT/``, ``/metrics``, ``/epochs``, ``/anomaly``,
and the ``/stream`` SSE tail; ``--observe-linger SECS`` keeps it up
after the run finishes so dashboards and smoke tests can read the
final state.  ``--epoch-log-json PATH`` writes the machine-readable
epoch log (the same serializer the ``/epochs`` endpoint uses).

``--auto-remediate`` closes the observability loop (`repro.deploy`): a
packet sampler harvests labeled examples from live traffic, the anomaly
detector's typed proposals execute online — ``ProgramReta`` /
``FailQueues`` as direct epochs, retrain triggers as fine-tune ->
checkpoint -> canary ``SwapSlot`` rollouts that promote or auto-roll-back
on the bake-window evidence.  ``--deploy-demo promote|rollback`` scripts
one end-to-end rollout (``rollback`` corrupts the trained weights to
force the auto-rollback path) and fails the run unless that terminal
decision is reached.  Every deployment decision lands in the epoch-log
printout, ``/epochs``, and ``--epoch-log-json``.

``--fault-plan FILE`` arms a typed fault plan (`repro.dataplane.faults`
JSON: stalls, crashes, shard errors, dropped acks, delayed retires);
the fault regimes (``barrier-straggler``, ``crash-mid-commit``) arm
their built-in plan automatically.  ``--lease-ticks`` bounds how long a
straggler can defer the mesh barrier before the commit goes degraded
over a quorum; the epoch-log printout tags every degraded or
rolled-back epoch with its commit mode and error.  ``--log-capacity``
bounds epoch-log memory, spilling evicted records to ``--log-spill``.

    PYTHONPATH=src python -m repro.launch.dataplane \\
        --hosts 2 --scenario crash-mid-commit --lease-ticks 4 --audit

    PYTHONPATH=src python -m repro.launch.dataplane --queues 4
    PYTHONPATH=src python -m repro.launch.dataplane \\
        --policy least-depth --scenario elephant-skew
    PYTHONPATH=src python -m repro.launch.dataplane \\
        --hosts 2 --scenario chaos-host-failover --audit
    PYTHONPATH=src python -m repro.launch.dataplane \\
        --scenario diurnal --trace record /tmp/diurnal.bswt
    PYTHONPATH=src python -m repro.launch.dataplane \\
        --trace replay /tmp/diurnal.bswt --audit
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time

import jax

from repro.control import make_policy
from repro.core import executor
from repro.dataplane import (DataplaneRuntime, MeshDataplane, faults,
                             workloads)


def _print_run_report(rt, reports, hosts: int, queues_per_host: int) -> dict:
    """Shared tail of both the play and replay paths: per-phase table,
    telemetry, conservation, epoch log.  Returns the snapshot."""
    print(f"{'phase':<16}{'offered':>9}{'done':>9}{'dropped':>9}"
          f"{'wrong':>7}{'kpps':>10}")
    for r in reports:
        kpps = r.get("kpps")
        print(f"{r['phase']:<16}{r['offered']:>9}{r['completed']:>9}"
              f"{r['dropped']:>9}{r['wrong_verdict']:>7}"
              + (f"{kpps:>10.1f}" if kpps is not None else f"{'-':>10}"))

    snap = rt.snapshot()
    for q in snap["queues"]:
        label = (f"host {q['queue'] // queues_per_host} "
                 f"queue {q['queue'] % queues_per_host}"
                 if hosts > 1 else f"queue {q['queue']}")
        print(f"{label}: completed={q['completed']} "
              f"pps_busy={q['pps_busy']:.0f} "
              f"lat p50/p99/max={q['latency_p50_us']:.0f}/"
              f"{q['latency_p99_us']:.0f}/{q['latency_max_us']:.0f}us "
              f"per_slot={q['per_slot_total']}")
    aud = snap["conservation"]
    print(f"conservation: offered={aud['totals']['offered']} = "
          f"completed={aud['totals']['completed']} + "
          f"dropped={aud['totals']['dropped']} "
          f"(+{aud['totals']['occupancy']} queued, "
          f"+{aud['totals']['in_flight']} in flight) "
          f"ok={aud['ok']} wrong_verdict={aud['wrong_verdict']}")
    if hosts > 1:
        for i, h in enumerate(aud["per_host"]):
            t = h["totals"]
            print(f"  host {i}: offered={t['offered']} "
                  f"completed={t['completed']} dropped={t['dropped']} "
                  f"ok={h['ok']}")

    deploy_log = getattr(rt, "deploy_log", None) or []
    for d in deploy_log:
        ep = d.get("epoch")
        slot = d.get("slot")
        print(f"deploy: tick {d['tick']:>4} {d['event']:<14}"
              + (f" slot={slot}" if slot is not None else "")
              + (f" epoch={ep}" if ep is not None else "")
              + (f" ({d['reason']})" if d.get("reason") else ""))
    snap["deployments"] = deploy_log

    log = rt.control.command_log()
    cont = rt.control.continuity_audit()
    modes = cont.get("commit_modes", {})
    mode_str = " ".join(f"{k}={v}" for k, v in modes.items() if v)
    print(f"control: api_v{rt.control.API_VERSION}, "
          f"{len(log)} epoch(s) in log, continuity ok={cont['ok']}"
          + (f" [{mode_str}]" if mode_str else ""))
    if cont.get("spilled_epochs"):
        print(f"  ({cont['spilled_epochs']} older epoch(s) spilled, "
              f"wrong_verdict_in_spill={cont['spilled_wrong_verdict']})")
    for rec in log:
        cmds = ", ".join(c["cmd"] for c in rec["commands"])
        barrier = (f" hosts@{rec['host_ticks']}"
                   if rec.get("host_ticks") else "")
        mode = rec.get("commit_mode")
        tag = f" <{mode}>" if mode and mode != "atomic" else ""
        at = rec["applied_tick"] if rec["applied_tick"] is not None else "-"
        head = f"  epoch {rec['epoch']:>3} @tick {at!s:<6} [{cmds}]"
        if rec.get("apply_us") is None:
            print(f"{head} ROLLED BACK{tag}: {rec.get('error')}")
        else:
            print(f"{head} apply={rec['apply_us']:.0f}us "
                  f"latency={rec['apply_latency_us']:.0f}us{barrier}{tag}")
    health = snap.get("health")
    if health and health.get("transitions"):
        states = " ".join(f"host{h['host']}={h['state']}"
                          for h in health["hosts"])
        print(f"health: lease={health['lease_ticks']} ticks, {states}")
        for t in health["transitions"]:
            print(f"  tick {t['tick']:>4}: host {t['host']} "
                  f"{t['frm']} -> {t['to']} ({t['reason']})")
    for ev in snap.get("fault_events") or ():
        print(f"fault: tick {ev['tick']} host {ev['host']} "
              f"@{ev['point']}: {ev['detail']}")
    stranded = snap["conservation"].get("stranded")
    if stranded and stranded["packets"]:
        print(f"stranded: {stranded['packets']} packet(s) on dead "
              f"host(s) {stranded['hosts']} (counted, not lost)")
    snap["control_log"] = log
    snap["continuity"] = cont
    return snap


def _make_detector(rt, args, *, num_slots: int):
    """Attach the delta stream + anomaly detector when ``--observe`` or
    ``--auto-remediate`` needs them; returns (stream, detector)."""
    if args.observe is None and not getattr(args, "auto_remediate", False):
        return None, None
    from repro.obs import AnomalyDetector, TelemetryStream, attach
    stream = TelemetryStream()
    attach(rt, stream)
    det = AnomalyDetector(stream, num_queues=rt.num_queues,
                          num_slots=num_slots,
                          hosts=getattr(rt, "hosts", 1))
    return stream, det


def _start_observer(rt, args, *, num_slots: int, stream=None, detector=None):
    """``--observe PORT``: serve the dashboard over the attached stream."""
    if args.observe is None:
        return None
    from repro.obs.server import ObsServer
    if stream is None:
        stream, detector = _make_detector(rt, args, num_slots=num_slots)
    srv = ObsServer(rt, stream, port=args.observe, detector=detector).start()
    print(f"observe: http://{srv.host}:{srv.port}/ "
          f"(/metrics /epochs /anomaly /stream /healthz)")
    return srv


def _finish_observer(srv, rt, args) -> None:
    """Write ``--epoch-log-json`` and wind down the observe server."""
    if args.epoch_log_json:
        from repro.obs import spans
        from repro.obs.server import _json_default
        with open(args.epoch_log_json, "w") as f:
            json.dump(spans.epoch_log_doc(rt), f, indent=2,
                      default=_json_default)
            f.write("\n")
        print(f"wrote {args.epoch_log_json}")
    if srv is not None:
        if args.observe_linger > 0:
            print(f"observe: lingering {args.observe_linger:.0f}s on "
                  f"port {srv.port}", flush=True)
            time.sleep(args.observe_linger)
        srv.stop()


def _replay_main(args) -> None:
    """``--trace replay PATH``: runtime shape comes from the trace."""
    trace = workloads.load(args.trace[1])
    meta = trace.meta
    hosts = int(meta.get("hosts", 1))
    queues = int(meta.get("queues_per_host", args.queues))
    print(f"replaying {args.trace[1]}: trace v{meta['version']} "
          f"{meta.get('name')!r} ({meta.get('kind', 'recorded')}), "
          f"{trace.total_packets} packets, "
          f"{len(trace.command_timeline())} command epoch(s), "
          f"{hosts} host(s) x {queues} queue(s)")
    rt = workloads.make_runtime(trace, audit=args.audit,
                                megastep_ticks=args.megastep_ticks)
    observer = _start_observer(rt, args,
                               num_slots=int(meta.get("num_slots") or 4))
    rep = workloads.replay(trace, rt)
    snap = _print_run_report(rt, rep["phases"], hosts, queues)
    dig = rep["digest"]
    print(f"replay: ok={rep['ok']} digest_ok={rep['digest_ok']}"
          + (f" sha256={dig['sha256'][:16]}..." if dig else ""))
    for m in rep["mismatches"]:
        print(f"  MISMATCH {m}")
    _finish_observer(observer, rt, args)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"replay": {k: rep[k] for k in
                                  ("ok", "mismatches", "phases", "totals",
                                   "digest", "digest_ok")},
                       "snapshot": snap}, f, indent=2, default=str)
            f.write("\n")
        print(f"wrote {args.json}")
    aud = snap["conservation"]
    if (not rep["ok"] or rep["digest_ok"] is False or not aud["ok"]
            or aud["wrong_verdict"] or not snap["continuity"]["ok"]):
        sys.exit(1)


class CacheChurnDriver:
    """Same-API facade (the ``DeployDriver`` precedent) that churns a
    ``SlotCache`` while traffic flows: every ``stride`` ticks it demands
    the next model of a rotating schedule wider than the resident bank,
    so the run exercises hits, misses, LRU evictions, and — with a
    prefetcher — flip-only prefetch promotions, all under the normal
    zero-wrong-verdict audit."""

    def __init__(self, inner, cache, schedule, *, stride: int = 4,
                 prefetcher=None):
        self._inner = inner
        self.cache = cache
        self.prefetcher = prefetcher
        self._schedule = list(schedule)
        self._stride = max(1, int(stride))
        self._ticks = 0
        self._i = 0

    def tick(self) -> int:
        n = self._inner.tick()
        self._ticks += 1
        if self._schedule and self._ticks % self._stride == 0:
            self.cache.ensure(self._schedule[self._i % len(self._schedule)])
            self._i += 1
            if self.prefetcher is not None:
                self.prefetcher.poll()
        return n

    def dispatch(self, packets_np, now=None, **kw):
        return self._inner.dispatch(packets_np, now=now, **kw)

    def drain(self, max_ticks: int = 100_000) -> int:
        return self._inner.drain(max_ticks)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def _make_slot_cache(rt, args, bank):
    """``--slot-cache N``: register N models (the bank's own slots first,
    then fresh inits) and return (cache, churn schedule, prefetcher)."""
    from repro.control import SlotCache, SlotMixPrefetcher
    from repro.core import bank as bank_lib
    n = args.slot_cache
    k = rt.num_slots
    names = [f"model{i:02d}" for i in range(n)]
    cache = SlotCache(rt, resident=names[:k])
    for i, name in enumerate(names):
        if i < k:
            cache.register(name, bank_lib.select_slot(bank, i))
        else:
            cache.register(name, executor.init_params(
                jax.random.PRNGKey(args.seed + 1000 + i)))
    prefetcher = SlotMixPrefetcher(cache) if args.prefetch else None
    return cache, names, prefetcher


def build_parser() -> argparse.ArgumentParser:
    """The launcher's argparse parser, exposed as a function so the CLI
    reference (docs/cli.md) and its parity test can introspect the live
    flag set."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--hosts", type=int, default=1,
                    help="mesh host shards (1 = single-host runtime)")
    ap.add_argument("--queues", type=int, default=4,
                    help="hardware queues per host")
    ap.add_argument("--slots", type=int, default=4,
                    help="resident bank size (models preloaded)")
    ap.add_argument("--strategy", default="fused",
                    choices=["fused", "grouped", "grouped_staged", "take",
                             "onehot"])
    ap.add_argument("--fanout", default="auto",
                    choices=["auto", "loop", "vmap", "shard_map"])
    ap.add_argument("--batch", type=int, default=128,
                    help="max rows drained per queue per tick")
    ap.add_argument("--ring-capacity", type=int, default=1024)
    ap.add_argument("--scenario", default="emergency",
                    choices=list(workloads.REGIME_NAMES),
                    help="workload regime from the generator library")
    ap.add_argument("--policy", default=None,
                    choices=["static", "least-depth", "drop-rate"],
                    help="closed-loop routing policy (default: none)")
    ap.add_argument("--megastep-ticks", type=int, default=1,
                    help="run N ticks on-device in one compiled scan "
                         "(deferred megastep mode, DESIGN.md §13); 1 = "
                         "the sequential per-tick loop.  Verdicts and "
                         "telemetry totals are bit-identical at any N")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="bounded in-flight tick window (1 = synchronous)")
    ap.add_argument("--scale", type=int, default=1,
                    help="burst-size multiplier for every phase")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--audit", action="store_true",
                    help="re-score every tick through the exact take path "
                         "and count wrong verdicts")
    ap.add_argument("--trace", nargs=2, metavar=("MODE", "PATH"),
                    default=None,
                    help="'record PATH' saves this run as a replayable "
                         "trace; 'replay PATH' replays a recorded trace "
                         "(runtime shape from the trace itself)")
    ap.add_argument("--fault-plan", metavar="FILE", default=None,
                    help="JSON fault plan to arm (overrides the "
                         "regime's built-in plan)")
    ap.add_argument("--lease-ticks", type=int, default=8,
                    help="mesh host-health lease: max ticks a straggler "
                         "may defer the barrier before degraded commit")
    ap.add_argument("--quorum", type=int, default=None,
                    help="hosts that must ack a commit "
                         "(default: majority)")
    ap.add_argument("--log-capacity", type=int, default=None,
                    help="bound the in-memory epoch log; evicted "
                         "records spill in trace-style chunks")
    ap.add_argument("--log-spill", metavar="PATH", default=None,
                    help="file to receive spilled epoch records")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the full report as JSON")
    ap.add_argument("--observe", type=int, metavar="PORT", default=None,
                    help="serve the live dashboard/API on this port "
                         "(0 = ephemeral) while the run executes")
    ap.add_argument("--observe-linger", type=float, metavar="SECS",
                    default=0.0,
                    help="keep the observe server up this long after "
                         "the run finishes")
    ap.add_argument("--epoch-log-json", metavar="PATH", default=None,
                    help="write the machine-readable epoch log (same "
                         "serializer as the /epochs endpoint)")
    ap.add_argument("--auto-remediate", action="store_true",
                    help="act on anomaly-detector proposals online: "
                         "ProgramReta/FailQueues epochs directly, retrain "
                         "triggers via fine-tune -> canary rollout")
    ap.add_argument("--deploy-demo", default=None,
                    choices=["promote", "rollback"],
                    help="script one end-to-end rollout: fine-tune on "
                         "sampled traffic, canary it, and require the "
                         "named terminal decision ('rollback' corrupts "
                         "the weights to force the auto-rollback path)")
    ap.add_argument("--deploy-bake-ticks", type=int, default=12,
                    help="canary bake window before promote/rollback")
    ap.add_argument("--deploy-warmup-ticks", type=int, default=16,
                    help="ticks of sampling before a scripted rollout "
                         "fine-tunes (--deploy-demo)")
    ap.add_argument("--deploy-steps", type=int, default=32,
                    help="SGD steps per online fine-tune")
    ap.add_argument("--deploy-share", type=float, default=0.125,
                    help="RETA bucket share steered at the canary queue")
    ap.add_argument("--checkpoint-dir", metavar="DIR", default=None,
                    help="where online fine-tunes commit checkpoints "
                         "(default: a fresh temp dir)")
    ap.add_argument("--slot-cache", type=int, metavar="N", default=None,
                    help="register N models behind the LRU slot-cache "
                         "(DESIGN.md §14) and churn residency during the "
                         "run; N may exceed --slots")
    ap.add_argument("--prefetch", action="store_true",
                    help="poll the telemetry-driven prefetcher during "
                         "slot-cache churn so predicted misses commit "
                         "flip-only (needs --slot-cache)")
    return ap


def main(argv=None) -> None:
    ap = build_parser()
    args = ap.parse_args(argv)
    if args.hosts < 1:
        ap.error("--hosts must be >= 1")
    if args.trace and args.trace[0] not in ("record", "replay"):
        ap.error("--trace MODE must be 'record' or 'replay'")
    if args.prefetch and not args.slot_cache:
        ap.error("--prefetch needs --slot-cache N")
    if args.slot_cache is not None and args.slot_cache < 1:
        ap.error("--slot-cache must be >= 1")

    if args.trace and args.trace[0] == "replay":
        _replay_main(args)
        return

    deploy_active = bool(args.auto_remediate or args.deploy_demo)
    if deploy_active and args.slots < 2:
        ap.error("--auto-remediate/--deploy-demo need --slots >= 2 "
                 "(a canary slot)")

    total_queues = args.hosts * args.queues
    print(f"== resident bank: {args.slots} slots (random init) ==")
    bank = executor.init_bank(jax.random.PRNGKey(args.seed), args.slots)
    workload = workloads.make_workload(
        args.scenario, num_slots=args.slots, num_queues=args.queues,
        scale=args.scale, hosts=args.hosts)
    pool, pool_labels = workload.payload_pool, None
    if deploy_active and pool is None:
        # synthetic regimes render random payloads with no ground truth;
        # deployment needs labeled traffic, so render from the corpus
        # pool instead (the oracle keys on payload words[1:])
        from repro.deploy import labeled_pool
        pool, pool_labels = labeled_pool(samples_per_group=512,
                                         seed=args.seed)
        print(f"deploy: labeled payload pool ({pool.shape[0]} examples, "
              f"{int(pool_labels.sum())} malicious)")
    trace = workloads.render(
        list(workload.phases), num_slots=args.slots, seed=args.seed,
        num_queues=total_queues, payload_pool=pool)
    chaos_epochs = sum(len(p.chaos) for p in workload.phases)
    print(f"scenario: {args.scenario}, {len(workload.phases)} phases, "
          f"{trace.total_packets} packets, {chaos_epochs} chaos event(s), "
          f"seed={args.seed} (replayable)")

    plan = (faults.load_plan(args.fault_plan) if args.fault_plan
            else workload.fault_plan)
    injector = faults.FaultInjector(plan) if plan is not None else None
    if injector is not None and injector.armed:
        kinds = ", ".join(sorted({type(f).__name__ for f in plan.faults}))
        print(f"fault plan: {plan.name!r}, {len(plan.faults)} fault(s) "
              f"armed ({kinds}), lease={args.lease_ticks} ticks")

    policy = make_policy(args.policy) if args.policy else None
    recording = bool(args.trace)
    kw = dict(strategy=args.strategy, fanout=args.fanout, batch=args.batch,
              ring_capacity=args.ring_capacity, audit=args.audit,
              pipeline_depth=args.pipeline_depth,
              megastep_ticks=args.megastep_ticks, policy=policy,
              record=recording, fault_injector=injector,
              log_capacity=args.log_capacity, log_spill=args.log_spill)
    if args.hosts > 1:
        rt = MeshDataplane(bank, hosts=args.hosts, num_queues=args.queues,
                           lease_ticks=args.lease_ticks, quorum=args.quorum,
                           **kw)
        shape = (f"{args.hosts} hosts x {args.queues} queues "
                 f"({total_queues} global)")
    else:
        rt = DataplaneRuntime(bank, num_queues=args.queues, **kw)
        shape = f"{args.queues} queues"
    print(f"runtime: {shape} x batch {args.batch}, "
          f"strategy={args.strategy}, "
          f"ring={args.ring_capacity}, depth={rt.pipeline_depth}, "
          f"policy={getattr(policy, 'name', None)}")

    stream, detector = _make_detector(rt, args, num_slots=args.slots)
    observer = _start_observer(rt, args, num_slots=args.slots,
                               stream=stream, detector=detector)
    driver = (workloads.record(rt, path=args.trace[1]) if recording
              else rt)
    sampler = None
    if deploy_active:
        from repro import deploy
        oracle = (deploy.LabelOracle(pool, pool_labels)
                  if pool_labels is not None else None)
        sampler = deploy.PacketSampler(oracle, num_slots=args.slots,
                                       seed=args.seed).attach(rt)
        ckpt_dir = args.checkpoint_dir or tempfile.mkdtemp(
            prefix="deploy-ckpt-")
        trainer = deploy.OnlineTrainer(checkpoint_dir=ckpt_dir,
                                       steps=args.deploy_steps,
                                       seed=args.seed)
        canary_kw = dict(canary_share=args.deploy_share,
                         bake_ticks=args.deploy_bake_ticks)
        driver = deploy.DeployDriver(driver)
        if args.deploy_demo:
            driver.add(deploy.ScheduledRollout(
                driver, sampler, trainer, target_slot=0,
                warmup_ticks=args.deploy_warmup_ticks,
                corrupt=args.deploy_demo == "rollback",
                canary_kw=canary_kw))
        if args.auto_remediate:
            driver.add(deploy.AutoRemediator(
                driver, detector, sampler=sampler, trainer=trainer,
                canary_kw=canary_kw))
        mode = args.deploy_demo or "auto-remediate"
        print(f"deploy: {mode}, labeled oracle="
              f"{'yes' if oracle is not None else 'no'}, "
              f"bake={args.deploy_bake_ticks} ticks, "
              f"share={args.deploy_share}, checkpoints -> {ckpt_dir}")
    cache = None
    if args.slot_cache:
        cache, schedule, prefetcher = _make_slot_cache(rt, args, bank)
        if prefetcher is not None and stream is None:
            # no observe/remediate stream attached; give the prefetcher
            # its own delta tail so slot-mix evidence still flows
            from repro.obs import TelemetryStream, attach
            stream = TelemetryStream()
            attach(rt, stream)
        if prefetcher is not None:
            prefetcher.stream = stream
        driver = CacheChurnDriver(driver, cache, schedule,
                                  prefetcher=prefetcher)
        print(f"slot-cache: {args.slot_cache} models over "
              f"{rt.num_slots} slots, prefetch="
              f"{'on' if prefetcher is not None else 'off'}")
    reports = workloads.play(driver, trace)
    if deploy_active:
        driver.flush_deploy()   # no canary may dangle past end of traffic
        sampler.detach()
    snap = _print_run_report(rt, reports, args.hosts, args.queues)
    if cache is not None:
        cs = cache.stats()
        hr = f"{cs['hit_rate']:.2f}" if cs["hit_rate"] is not None else "-"
        print(f"slot-cache: {cs['registered']} registered, "
              f"{cs['resident']}/{cs['num_slots']} resident, "
              f"hits={cs['hits']} misses={cs['misses']} hit_rate={hr} "
              f"evictions={cs['evictions']} "
              f"prefetch={cs['prefetch_hits']}/{cs['prefetch_issued']}")
        snap["slot_cache"] = cs

    if recording:
        saved = driver.finish(name=args.scenario, seed=args.seed)
        print(f"recorded trace: {saved.steps} steps, "
              f"{saved.total_packets} packets, "
              f"digest={'yes' if 'digest' in saved.expect else 'no'} "
              f"-> {saved.path} ({saved.nbytes} bytes, streamed)")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"phases": reports, "snapshot": snap,
                       "control_log": snap["control_log"],
                       "continuity": snap["continuity"]}, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json}")
    _finish_observer(observer, rt, args)
    ok = True
    if args.deploy_demo:
        want = ("promoted" if args.deploy_demo == "promote"
                else "rolled_back")
        events = [d["event"] for d in snap.get("deployments", [])]
        if want not in events:
            print(f"deploy-demo FAILED: expected a {want!r} decision, "
                  f"got {events}")
            ok = False
    aud = snap["conservation"]
    if (not ok or not aud["ok"] or aud["wrong_verdict"]
            or not snap["continuity"]["ok"]):
        sys.exit(1)


if __name__ == "__main__":
    main()
