"""Multi-queue data-plane driver: RSS -> rings -> sharded fused workers.

Runs a scenario from the traffic engine (``--scenario emergency`` |
``elephant-skew`` | ``cascading-failover``) through the multi-queue
runtime and reports per-phase throughput, per-queue telemetry, the
packet-conservation audit, and the control-plane epoch log.  ``--hosts``
lifts the run to the multi-host mesh data plane (``MeshDataplane``:
cross-host RSS over global queue ids, per-host rings, epoch-barrier
control fan-out); ``--policy`` installs a closed-loop routing policy
(RETA rebalances land as audited ``ProgramReta`` epochs);
``--pipeline-depth`` overlaps dispatch/device/retire.  Host-simulated
queues on CPU; device-spread via ``--fanout shard_map`` on real meshes.

    PYTHONPATH=src python -m repro.launch.dataplane --queues 4
    PYTHONPATH=src python -m repro.launch.dataplane \\
        --policy least-depth --scenario elephant-skew
    PYTHONPATH=src python -m repro.launch.dataplane \\
        --hosts 2 --scenario cascading-failover --audit
"""

from __future__ import annotations

import argparse
import json
import sys

import jax

from repro.control import make_policy
from repro.core import executor
from repro.dataplane import (DataplaneRuntime, MeshDataplane, make_scenario,
                             play, render, scenarios)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--hosts", type=int, default=1,
                    help="mesh host shards (1 = single-host runtime)")
    ap.add_argument("--queues", type=int, default=4,
                    help="hardware queues per host")
    ap.add_argument("--slots", type=int, default=4,
                    help="resident bank size (models preloaded)")
    ap.add_argument("--strategy", default="fused",
                    choices=["fused", "grouped", "grouped_staged", "take",
                             "onehot"])
    ap.add_argument("--fanout", default="auto",
                    choices=["auto", "loop", "vmap", "shard_map"])
    ap.add_argument("--batch", type=int, default=128,
                    help="max rows drained per queue per tick")
    ap.add_argument("--ring-capacity", type=int, default=1024)
    ap.add_argument("--scenario", default="emergency",
                    choices=["emergency", "elephant-skew",
                             "cascading-failover"])
    ap.add_argument("--policy", default=None,
                    choices=["static", "least-depth", "drop-rate"],
                    help="closed-loop routing policy (default: none)")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="bounded in-flight tick window (1 = synchronous)")
    ap.add_argument("--scale", type=int, default=1,
                    help="burst-size multiplier for every phase")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--audit", action="store_true",
                    help="re-score every tick through the exact take path "
                         "and count wrong verdicts")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the full report as JSON")
    args = ap.parse_args(argv)
    if args.hosts < 1:
        ap.error("--hosts must be >= 1")

    total_queues = args.hosts * args.queues
    print(f"== resident bank: {args.slots} slots (random init) ==")
    bank = executor.init_bank(jax.random.PRNGKey(args.seed), args.slots)
    phases = make_scenario(args.scenario, num_slots=args.slots,
                           num_queues=args.queues, scale=args.scale,
                           hosts=args.hosts)
    trace = render(phases, num_slots=args.slots, seed=args.seed,
                   num_queues=total_queues)
    print(f"scenario: {args.scenario}, {len(phases)} phases, "
          f"{trace.total_packets} packets, seed={args.seed} (replayable)")

    policy = make_policy(args.policy) if args.policy else None
    kw = dict(strategy=args.strategy, fanout=args.fanout, batch=args.batch,
              ring_capacity=args.ring_capacity, audit=args.audit,
              pipeline_depth=args.pipeline_depth, policy=policy)
    if args.hosts > 1:
        rt = MeshDataplane(bank, hosts=args.hosts, num_queues=args.queues,
                           **kw)
        shape = (f"{args.hosts} hosts x {args.queues} queues "
                 f"({total_queues} global)")
    else:
        rt = DataplaneRuntime(bank, num_queues=args.queues, **kw)
        shape = f"{args.queues} queues"
    print(f"runtime: {shape} x batch {args.batch}, "
          f"strategy={args.strategy}, "
          f"ring={args.ring_capacity}, depth={rt.pipeline_depth}, "
          f"policy={getattr(policy, 'name', None)}")

    reports = play(rt, trace, swap_delivery=scenarios.default_swap_delivery)
    print(f"{'phase':<16}{'offered':>9}{'done':>9}{'dropped':>9}"
          f"{'wrong':>7}{'kpps':>10}")
    for r in reports:
        print(f"{r['phase']:<16}{r['offered']:>9}{r['completed']:>9}"
              f"{r['dropped']:>9}{r['wrong_verdict']:>7}{r['kpps']:>10.1f}")

    snap = rt.snapshot()
    qph = args.queues
    for q in snap["queues"]:
        label = (f"host {q['queue'] // qph} queue {q['queue'] % qph}"
                 if args.hosts > 1 else f"queue {q['queue']}")
        print(f"{label}: completed={q['completed']} "
              f"pps_busy={q['pps_busy']:.0f} "
              f"lat p50/p99/max={q['latency_p50_us']:.0f}/"
              f"{q['latency_p99_us']:.0f}/{q['latency_max_us']:.0f}us "
              f"per_slot={q['per_slot_total']}")
    aud = snap["conservation"]
    print(f"conservation: offered={aud['totals']['offered']} = "
          f"completed={aud['totals']['completed']} + "
          f"dropped={aud['totals']['dropped']} "
          f"(+{aud['totals']['occupancy']} queued, "
          f"+{aud['totals']['in_flight']} in flight) "
          f"ok={aud['ok']} wrong_verdict={aud['wrong_verdict']}")
    if args.hosts > 1:
        for i, h in enumerate(aud["per_host"]):
            t = h["totals"]
            print(f"  host {i}: offered={t['offered']} "
                  f"completed={t['completed']} dropped={t['dropped']} "
                  f"ok={h['ok']}")

    log = rt.control.command_log()
    cont = rt.control.continuity_audit()
    print(f"control: api_v{rt.control.API_VERSION}, "
          f"{len(log)} epoch(s) applied, continuity ok={cont['ok']}")
    for rec in log:
        cmds = ", ".join(c["cmd"] for c in rec["commands"])
        barrier = (f" hosts@{rec['host_ticks']}"
                   if rec.get("host_ticks") else "")
        print(f"  epoch {rec['epoch']:>3} @tick {rec['applied_tick']:<6} "
              f"[{cmds}] apply={rec['apply_us']:.0f}us "
              f"latency={rec['apply_latency_us']:.0f}us{barrier}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"phases": reports, "snapshot": snap,
                       "control_log": log, "continuity": cont}, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json}")
    if not aud["ok"] or aud["wrong_verdict"] or not cont["ok"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
