import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede every other import (jax locks the device
count at first init); they give this process 512 placeholder CPU devices so
``jax.make_mesh`` can build the production meshes:

    single-pod:  (16, 16)    ("data", "model")       = 256 chips
    multi-pod:   (2, 16, 16) ("pod", "data", "model") = 512 chips

For each cell the step function (train / prefill / serve) is jitted with
explicit in/out shardings, ``.lower()``-ed on ShapeDtypeStructs (no
allocation) and ``.compile()``-d; we record ``memory_analysis()``,
``cost_analysis()`` and the loop-aware roofline terms parsed from the
optimized HLO (repro.distributed.roofline).

Usage:
    python -m repro.launch.dryrun --arch smollm-360m --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --out results/dryrun
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, ShapeConfig, shape_applicable
from repro.configs.registry import ARCH_IDS, get_config
from repro.distributed import roofline as rf
from repro.distributed import sharding as sh
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts_lib

# FSDP thresholds (param count): above these, weights/opt-state shard over
# the data axis too (ZeRO-3 semantics via GSPMD).
FSDP_TRAIN_THRESHOLD = 2e9
FSDP_SERVE_THRESHOLD = 50e9


def rules_for(cfg, kind: str, mesh, style: str = "1d") -> sh.ShardingRules:
    n = cfg.param_count()
    thresh = FSDP_TRAIN_THRESHOLD if kind == "train" else FSDP_SERVE_THRESHOLD
    dp = tuple(a for a in mesh.axis_names if a != "model")
    return sh.ShardingRules(
        tp_axis="model",
        fsdp_axis="data" if n > thresh else None,
        dp_axes=dp,
        style=style,
    )


# Perf-iteration variants (EXPERIMENTS.md §Perf).  "baseline" is the
# paper-faithful default; everything else is a beyond-paper optimization.
VARIANTS = {
    "baseline": {},
    "flashremat": {"cfg": {"flash_remat": True}},
    "seqshard": {"cfg": {"seq_shard_attention": True}},
    "flashremat+seqshard": {"cfg": {"flash_remat": True,
                                    "seq_shard_attention": True}},
    "serve2d": {"style": "2d"},
    "serve2d+seqshard": {"style": "2d", "cfg": {"seq_shard_attention": True}},
    "int8cache": {"cfg": {"cache_dtype": "int8"}},
    # Megatron-style sequence parallelism: the token stream itself is
    # sharded over the TP axis, so per-layer activation collectives move
    # (B, S/16, d) instead of (B, S, d)
    "seqpar": {"style": "2d", "batch_seq_shard": True,
               "cfg": {"seq_shard_attention": True}},
    # + explicit Megatron-SP constraints on the residual stream (GSPMD drops
    # the input-level seq sharding otherwise)
    "seqpar2": {"style": "2d", "batch_seq_shard": True,
                "cfg": {"seq_shard_attention": True,
                        "seq_shard_activations": True}},
}


def _legal_batch_specs(batch_sds, rules, mesh):
    specs = sh.batch_specs(batch_sds, rules)
    return sh.legalize(specs, batch_sds, mesh)


def _decode_cache_specs(cache_sds, rules, mesh):
    """KV caches: batch over dp, kv-heads over model (seq as fallback)."""
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = axis_size.get(rules.tp_axis, 1)
    dp = tuple(a for a in rules.dp_axes if a)
    dp_total = 1
    for a in dp:
        dp_total *= axis_size.get(a, 1)
    dp_entry = dp if len(dp) > 1 else (dp[0] if dp else None)

    def spec(path, leaf):
        name = sh._path_str(path)
        shape = leaf.shape
        nd = len(shape)
        entries = [None] * nd
        if re.search(r"(^|/)(k|v)(_scale)?$", name):
            # (L, B, G, Lc[, hd]) — scales lack the trailing hd dim
            if dp_entry and shape[1] % dp_total == 0:
                entries[1] = dp_entry
            if shape[2] % tp == 0:
                entries[2] = rules.tp_axis
            elif shape[3] % tp == 0:
                entries[3] = rules.tp_axis  # seq-dim fallback (glm/arctic/llava)
        else:
            bdim = nd - 4 if name.endswith("ssm") else nd - 3
            if dp_entry and shape[bdim] % dp_total == 0:
                entries[bdim] = dp_entry
        return P(*entries)

    return jax.tree_util.tree_map_with_path(spec, cache_sds)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None, variant: str = "baseline") -> dict:
    cfg = get_config(arch)
    vspec = VARIANTS[variant]
    v_over = dict(vspec.get("cfg", {}))
    if overrides:
        v_over.update(overrides)
    if v_over:
        cfg = dataclasses.replace(cfg, **v_over)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    cell_id = f"{arch}|{shape_name}|{mesh_name}"
    if variant != "baseline":
        cell_id += f"|{variant}"
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"cell": cell_id, "status": "skipped", "reason": why}

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = shape.kind
    rules = rules_for(cfg, kind, mesh, style=vspec.get("style", "1d"))
    cell = specs_lib.input_specs(cfg, shape)
    result = {"cell": cell_id, "arch": arch, "shape": shape_name,
              "mesh": mesh_name, "kind": kind, "variant": variant,
              "fsdp": rules.fsdp_axis is not None}

    with mesh:
        if kind == "train":
            state_sds, batch_sds = cell["state"], cell["batch"]
            pspecs = sh.param_specs(state_sds["params"], rules)
            pspecs, dropped = sh.legalize(pspecs, state_sds["params"], mesh)
            state_specs = {
                "params": pspecs,
                "opt": sh.opt_state_specs(pspecs, state_sds["opt"]),
                "step": P(),
            }
            bspecs, bdropped = _legal_batch_specs(batch_sds, rules, mesh)
            step = ts_lib.make_train_step(cfg, cell["opt_cfg"])
            jstep = jax.jit(
                step,
                in_shardings=(sh.named(mesh, state_specs), sh.named(mesh, bspecs)),
                donate_argnums=(0,),
            )
            lowered = jstep.lower(state_sds, batch_sds)
        elif kind == "prefill":
            params_sds, batch_sds = cell["params"], cell["batch"]
            pspecs, dropped = sh.legalize(
                sh.param_specs(params_sds, rules), params_sds, mesh)
            bspecs, bdropped = _legal_batch_specs(batch_sds, rules, mesh)
            if vspec.get("batch_seq_shard"):
                def seq_shard(spec, leaf):
                    if len(leaf.shape) >= 2 and leaf.shape[1] % 16 == 0:
                        return P(spec[0], rules.tp_axis,
                                 *spec[2:len(leaf.shape)])
                    return spec
                bspecs = jax.tree_util.tree_map(
                    seq_shard, bspecs, batch_sds,
                    is_leaf=lambda x: isinstance(x, P))
            step = ts_lib.make_prefill_step(cfg)
            jstep = jax.jit(
                step,
                in_shardings=(sh.named(mesh, pspecs), sh.named(mesh, bspecs)),
            )
            lowered = jstep.lower(params_sds, batch_sds)
        else:  # decode
            params_sds = cell["params"]
            pspecs, dropped = sh.legalize(
                sh.param_specs(params_sds, rules), params_sds, mesh)
            cache_specs = _decode_cache_specs(cell["cache"], rules, mesh)
            cache_specs, cdropped = sh.legalize(cache_specs, cell["cache"], mesh)
            tok_spec, tdropped = _legal_batch_specs(cell["tokens"], rules, mesh)
            step = ts_lib.make_serve_step(cfg)
            args = [cell["tokens"], cell["cache"], cell["cache_len"]]
            in_shard = [sh.named(mesh, pspecs), sh.named(mesh, tok_spec),
                        sh.named(mesh, cache_specs), sh.named(mesh, P())]
            if cell["slot_ids"] is not None:
                sspec, _ = _legal_batch_specs(cell["slot_ids"], rules, mesh)
                args.append(cell["slot_ids"])
                in_shard.append(sh.named(mesh, sspec))
            jstep = jax.jit(
                step, in_shardings=tuple(in_shard), donate_argnums=(2,)
            )
            lowered = jstep.lower(params_sds, *args)

        compiled = lowered.compile()

    result["dropped_shardings"] = [f"{p}[{d}]@{a}" for (p, d, a) in dropped]
    try:
        mem = compiled.memory_analysis()
        result["memory"] = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_total": (
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes
            ),
        }
    except Exception as e:  # pragma: no cover
        result["memory"] = {"error": str(e)}
    try:
        ca = rf.xla_cost_analysis(compiled)
        result["xla_cost"] = {
            "flops": ca.get("flops"), "bytes accessed": ca.get("bytes accessed")
        }
    except Exception as e:  # pragma: no cover
        result["xla_cost"] = {"error": str(e)}

    hlo = compiled.as_text()
    analysis = rf.analyze(hlo)
    result["analysis"] = {
        "dot_flops": analysis["dot_flops"],
        "hbm_bytes": analysis["hbm_bytes"],
        "collective_bytes": analysis["collective_bytes"],
        "collective_bytes_total": analysis["collective_bytes_total"],
    }
    result["roofline"] = rf.roofline_terms(analysis, result.get("xla_cost"))
    n_dev = mesh.devices.size
    mf = rf.model_flops(cfg, shape, kind)
    result["model_flops_global"] = mf
    global_dot = analysis["dot_flops"] * n_dev
    result["useful_flops_ratio"] = mf / global_dot if global_dot else None
    result["params"] = cfg.param_count()
    result["active_params"] = cfg.active_param_count()
    result["compile_seconds"] = time.time() - t0
    result["status"] = "ok"
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=[a for a in ARCH_IDS if a != "boundswitch-h32"])
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="directory for per-cell JSON")
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    args = ap.parse_args()

    cells = []
    archs = [a for a in ARCH_IDS if a != "boundswitch-h32"] if args.all else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    for a, s, m in cells:
        try:
            res = run_cell(a, s, multi_pod=(m == "multi"),
                           variant=args.variant)
        except Exception as e:
            res = {"cell": f"{a}|{s}|{m}|{args.variant}", "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
        line = {k: res.get(k) for k in ("cell", "status", "reason", "error")}
        print(json.dumps(line))
        if res.get("status") == "ok":
            r = res["roofline"]
            print(f"  compute={r['compute_s']*1e3:.3f}ms memory={r['memory_s']*1e3:.3f}ms "
                  f"collective={r['collective_s']*1e3:.3f}ms dominant={r['dominant']} "
                  f"mem/dev={res['memory'].get('per_device_total', 0)/2**30:.2f}GiB "
                  f"compile={res['compile_seconds']:.0f}s")
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            fname = res["cell"].replace("|", "_") + ".json"
            with open(os.path.join(args.out, fname), "w") as f:
                json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
