"""Mesh construction — the single source of truth for device layout.

Every mesh the system uses (production pod, host-local, data-plane queue
sharding) is built through the one ``_build`` funnel below, so axis names
and shapes cannot drift between the serving stack and the data plane.
All constructors are FUNCTIONS, not module-level constants — importing
this module never touches jax device state (the dry-run sets XLA_FLAGS
before first init).
"""

from __future__ import annotations

import math

import jax


def _build(shape: tuple[int, ...], axes: tuple[str, ...]):
    """The one funnel every mesh layout goes through."""
    if len(shape) != len(axes):
        raise ValueError(f"mesh shape {shape} does not match axes {axes}")
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _build(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever this process actually has (tests / examples / elastic)."""
    n = jax.device_count()
    model_parallel = min(model_parallel, n)
    return _build((n // model_parallel, model_parallel), ("data", "model"))


def make_queue_mesh(num_queues: int):
    """A mesh whose leading axis shards the data-plane queue dimension.

    Composes with ``make_host_mesh`` instead of re-deriving the layout:
    the host mesh is reused whenever its data axis divides the queue
    count; otherwise a dedicated 1-axis mesh is built over the largest
    device count that does.  Returns ``(mesh, axis_name)``.
    """
    m = make_host_mesh(1)
    if num_queues % m.devices.shape[0] == 0:
        return m, "data"
    d = math.gcd(num_queues, jax.device_count())
    return _build((d,), ("queues",)), "queues"
