"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever this process actually has (tests / examples / elastic)."""
    n = jax.device_count()
    model_parallel = min(model_parallel, n)
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))
