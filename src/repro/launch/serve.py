"""Serving launcher: batched requests with per-request model-slot routing."""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import api
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m",
                    choices=[a for a in ARCH_IDS if a != "boundswitch-h32"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(remat="none")
    params = api.init(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, max_batch=args.max_batch,
                         max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        prompt = list(rng.integers(0, cfg.vocab_size, int(rng.integers(4, 48))))
        slot = int(rng.integers(0, args.slots)) if cfg.bank_mode != "none" else 0
        engine.submit(Request(rid=i, prompt=prompt, slot_id=slot,
                              max_new_tokens=args.max_new_tokens))
    finished = engine.run_until_done()
    dt = time.perf_counter() - t0
    tokens = sum(len(f.output) for f in finished)
    print(f"served {len(finished)} requests, {tokens} tokens in {dt:.2f}s "
          f"({tokens/dt:.1f} tok/s), {engine.ticks} ticks, "
          f"rejected {engine.rejected_count}")
    lat = sorted(f.latency_s for f in finished if not f.rejected)
    if lat:
        print(f"latency p50={lat[len(lat)//2]*1e3:.1f}ms "
              f"p99={lat[int(len(lat)*0.99)]*1e3:.1f}ms")


if __name__ == "__main__":
    main()
