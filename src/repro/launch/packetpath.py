"""The paper's end-to-end driver: the BoundSwitch packet path.

Trains the two slot models (recall / precision oriented) on the synthetic
IoT-23-like workload, preloads them into the resident bank, and replays a
boundary stream through the shared forwarding pipeline — reporting the
paper's headline metrics (throughput, selection cost, continuity).

The default strategy is ``fused`` — the one-launch Pallas megakernel is
the hot path (PR 1); the exact per-row ``take`` baseline stays reachable
via ``--strategy take``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bank as bank_lib
from repro.core import packet as pkt
from repro.core import pipeline, switching
from repro.data import packets as pk
from repro.train import bnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--packets", type=int, default=8192)
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--samples-per-group", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--strategy", default="fused",
                    choices=["take", "onehot", "grouped", "grouped_staged",
                             "fused"],
                    help="fused (default) runs the one-launch megakernel "
                         "hot path; take is the exact per-row baseline")
    ap.add_argument("--stream", action="store_true",
                    help="streaming replay: async dispatch with a bounded "
                         "in-flight window instead of per-batch blocking")
    args = ap.parse_args()

    print("== training resident slot models (STE, pos_weight 4.0 / 0.5) ==")
    slot0, slot1 = bnn.train_slot_pair(
        epochs=args.epochs, samples_per_group=args.samples_per_group)
    bank = bank_lib.stack_bank([slot0, slot1])
    print(f"resident bank: 2 slots, {bank_lib.bank_bytes(bank)} bytes")

    xb, yb = pk.load_split("val", 1024, 0)
    w = pk.to_payload_words(xb)
    for name, slot in (("slot0", slot0), ("slot1", slot1)):
        m = bnn.evaluate(slot, w, yb)
        print(f"{name}: precision={m['precision']:.3f} recall={m['recall']:.3f} "
              f"f1={m['f1']:.3f}")

    print("== boundary replay ==")
    payload = w[np.arange(args.packets) % w.shape[0]]
    trace = switching.boundary_trace(args.packets, payload)
    t0 = time.perf_counter()
    res = pipeline.packet_step(
        bank, jnp.asarray(trace), num_slots=2, strategy=args.strategy)
    res.scores.block_until_ready()
    # batched-throughput measurement
    iters = 5
    t0 = time.perf_counter()
    for _ in range(iters):
        pipeline.packet_step(
            bank, jnp.asarray(trace), num_slots=2, strategy=args.strategy
        ).scores.block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    mpps = args.packets / dt / 1e6
    print(f"batched pipeline: {mpps:.3f} Mpps ({dt/args.packets*1e6:.3f} us/pkt), "
          f"{mpps * pkt.PAYLOAD_BYTES * 8 / 1e3:.2f} Gbps @1024B payload")

    rr = switching.replay_trace(bank, trace[:1024], num_slots=2,
                                strategy=args.strategy, stream=args.stream)
    g = rr.gap_stats_us()
    k = rr.rate_kpps()
    print(f"per-packet replay: wrong_slot={rr.wrong_slot} "
          f"wrong_verdict={rr.wrong_verdict} "
          f"median_gap={g['median_gap_us']:.2f}us boundary_gap={g['boundary_gap_us']:.2f}us "
          f"rate before/after boundary: {k['before_kpps']:.1f}/{k['after_kpps']:.1f} kpps")


if __name__ == "__main__":
    main()
