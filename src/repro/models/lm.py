"""Decoder-LM family: dense (llama-style), MoE, SSM (mamba2), hybrid (zamba2),
with optional VLM patch-embedding frontend stub and the model-bank technique
(adapter / head / full residency) integrated as a first-class feature.

One functional namespace serves all families; ``cfg.family`` selects the layer
stack.  Layer stacks are homogeneous and scanned (``lax.scan`` over stacked
params) so HLO size is O(1) in depth — required for 40-cell dry-run compiles.

Hybrid structure (zamba2): ``n_groups = L // attn_every`` groups, each =
``attn_every`` mamba layers followed by ONE application of a *shared*
attention block (single weight set referenced from every group — itself a
resident shared executor in the BoundSwitch sense), plus trailing mamba
layers.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.nn import modules as nn
from repro.nn import moe as moe_lib
from repro.nn import ssd as ssd_lib


# ---------------------------------------------------------------------------
# adapters (the banked technique at LM scale)
# ---------------------------------------------------------------------------

def adapter_init(key, cfg: ModelConfig, out_dim: int) -> dict:
    """Banked low-rank delta: K resident (d->r->out) adapters."""
    ka, kb = jax.random.split(key)
    k, r, d = cfg.bank_slots, cfg.adapter_rank, cfg.d_model
    dt = nn.cdtype(cfg)
    return {
        "a": nn._dense_init(ka, (k, d, r), dt),
        "b": jnp.zeros((k, r, out_dim), dt),  # zero-init: no-op at start
    }


def adapter_apply(params, x, slot_ids):
    """x: (B, S, d); slot_ids: (B,) -> (B, S, out).  Per-request gather is
    cheap because adapters are low-rank (the 'take' strategy)."""
    a = params["a"][slot_ids]  # (B, d, r)
    b = params["b"][slot_ids]  # (B, r, out)
    return jnp.einsum("bsd,bdr,bro->bso", x, a, b)


# ---------------------------------------------------------------------------
# layer definitions
# ---------------------------------------------------------------------------

def _dense_layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "ln1": nn.rmsnorm_init(cfg.d_model, nn.cdtype(cfg)),
        "attn": nn.attention_init(k1, cfg),
        "ln2": nn.rmsnorm_init(cfg.d_model, nn.cdtype(cfg)),
        "mlp": nn.mlp_init(k2, cfg),
    }
    if cfg.bank_mode == "adapter":
        p["adapter"] = adapter_init(k3, cfg, cfg.d_model)
    return p


def _moe_layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": nn.rmsnorm_init(cfg.d_model, nn.cdtype(cfg)),
        "attn": nn.attention_init(k1, cfg),
        "ln2": nn.rmsnorm_init(cfg.d_model, nn.cdtype(cfg)),
        "moe": moe_lib.moe_init(k2, cfg),
    }
    if cfg.moe_dense_residual:
        p["dense_mlp"] = nn.mlp_init(k3, cfg)
    if cfg.bank_mode == "adapter":
        p["adapter"] = adapter_init(k4, cfg, cfg.d_model)
    return p


def _ssm_layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": nn.rmsnorm_init(cfg.d_model, nn.cdtype(cfg)),
        "mamba": ssd_lib.mamba_init(k1, cfg),
    }
    if cfg.bank_mode == "adapter":
        p["adapter"] = adapter_init(k2, cfg, cfg.d_model)
    return p


def _dense_layer_apply(lp, x, cfg, *, positions, kv_cache=None, cache_len=None,
                       slot_ids=None, moe_capacity=None, pad_mask=None):
    h, new_kv = nn.attention_apply(
        lp["attn"], nn.rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg,
        positions=positions, kv_cache=kv_cache, cache_len=cache_len,
    )
    x = x + h
    xn = nn.rmsnorm(lp["ln2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe" and "moe" in lp:
        m, aux = moe_lib.moe_apply(lp["moe"], xn, cfg, capacity=moe_capacity,
                                   token_mask=pad_mask)
        if cfg.moe_dense_residual:
            m = m + nn.mlp_apply(lp["dense_mlp"], xn)
    else:
        m = nn.mlp_apply(lp["mlp"], xn)
    if "adapter" in lp and slot_ids is not None:
        m = m + adapter_apply(lp["adapter"], xn, slot_ids)
    out = x + m
    if cfg.seq_shard_activations and out.ndim == 3 and out.shape[1] % 16 == 0:
        out = jax.lax.with_sharding_constraint(
            out, jax.sharding.PartitionSpec(None, "model", None))
    return out, new_kv, aux


def _ssm_layer_apply(lp, x, cfg, *, ssm_state=None, conv_state=None,
                     slot_ids=None, pad_mask=None, last_valid=None):
    xn = nn.rmsnorm(lp["ln1"], x, cfg.norm_eps)
    h, new_ssm, new_conv = ssd_lib.mamba_apply(
        lp["mamba"], xn, cfg, ssm_state=ssm_state, conv_state=conv_state,
        pad_mask=pad_mask, last_valid=last_valid,
    )
    if "adapter" in lp and slot_ids is not None:
        h = h + adapter_apply(lp["adapter"], xn, slot_ids)
    return x + h, new_ssm, new_conv


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def _stack_init(layer_init_fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(layer_init_fn)(keys)


def lm_init(key, cfg: ModelConfig) -> dict:
    ke, kl, kh, ks, kf, kb = jax.random.split(key, 6)
    params: dict = {"embed": nn.embed_init(ke, cfg)}
    if cfg.family in ("dense", "moe"):
        init_fn = (
            functools.partial(_moe_layer_init, cfg=cfg)
            if cfg.family == "moe"
            else functools.partial(_dense_layer_init, cfg=cfg)
        )
        params["layers"] = _stack_init(lambda k: init_fn(k), kl, cfg.n_layers)
    elif cfg.family == "ssm":
        params["layers"] = _stack_init(
            lambda k: _ssm_layer_init(k, cfg), kl, cfg.n_layers
        )
    elif cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        trailing = cfg.n_layers - n_groups * cfg.attn_every
        kg, kt = jax.random.split(kl)
        group_keys = jax.random.split(kg, n_groups)
        params["groups"] = jax.vmap(
            lambda k: _stack_init(lambda kk: _ssm_layer_init(kk, cfg), k, cfg.attn_every)
        )(group_keys)
        if trailing:
            params["trailing"] = _stack_init(
                lambda k: _ssm_layer_init(k, cfg), kt, trailing
            )
        params["shared_attn"] = _dense_layer_init(ks, cfg)  # ONE shared block
    else:
        raise ValueError(f"lm_init does not handle family {cfg.family!r}")

    params["final_norm"] = nn.rmsnorm_init(cfg.d_model, nn.cdtype(cfg))
    params["head"] = nn.head_init(kh, cfg)
    if cfg.frontend == "patch":
        params["frontend_proj"] = {
            "w": nn._dense_init(kf, (cfg.d_model, cfg.d_model), nn.cdtype(cfg))
        }
    if cfg.bank_mode == "head":
        params["bank_head"] = {
            "w": nn._dense_init(kb, (cfg.bank_slots, cfg.d_model, cfg.padded_vocab),
                                nn.cdtype(cfg))
        }
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None) -> dict:
    """Decode cache pytree for a context of ``seq_len`` tokens."""
    quant = cfg.cache_dtype == "int8" and dtype is None
    dt = dtype or (jnp.int8 if quant else nn.cdtype(cfg))
    lc = cfg.kv_cache_len(seq_len)
    g, hd = cfg.n_kv_heads, cfg.head_dim or 0

    def kv(n_layers):
        c = {
            "k": jnp.zeros((n_layers, batch, g, lc, hd), dt),
            "v": jnp.zeros((n_layers, batch, g, lc, hd), dt),
        }
        if quant:
            c["k_scale"] = jnp.zeros((n_layers, batch, g, lc), jnp.float32)
            c["v_scale"] = jnp.zeros((n_layers, batch, g, lc), jnp.float32)
        return c

    def mamba_states(n, extra=()):
        di, h, nst, conv_dim = ssd_lib.ssm_dims(cfg)
        return {
            "ssm": jnp.zeros((*extra, n, batch, h, cfg.ssm_head_dim, nst), jnp.float32),
            "conv": jnp.zeros((*extra, n, batch, cfg.ssm_conv_width - 1, conv_dim), dt),
        }

    if cfg.family in ("dense", "moe"):
        return kv(cfg.n_layers)
    if cfg.family == "ssm":
        return mamba_states(cfg.n_layers)
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        trailing = cfg.n_layers - n_groups * cfg.attn_every
        cache = {
            "groups": mamba_states(cfg.attn_every, extra=(n_groups,)),
            "attn": kv(n_groups),
        }
        if trailing:
            cache["trailing"] = mamba_states(trailing)
        return cache
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat == "full" else fn


def _embed_inputs(params, batch, cfg: ModelConfig):
    x = nn.embed_apply(params["embed"], batch["tokens"])
    if cfg.frontend == "patch" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype) @ params["frontend_proj"]["w"]
        x = jnp.concatenate([pe, x], axis=1)
    return x


def _final_logits(params, x, cfg, slot_ids=None):
    x = nn.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.bank_mode == "head" and slot_ids is not None and "bank_head" in params:
        w = params["bank_head"]["w"][slot_ids]  # (B, d, V) banked head
        logits = jnp.einsum("bsd,bdv->bsv", x, w, preferred_element_type=jnp.float32)
        if cfg.padded_vocab != cfg.vocab_size:
            pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            logits = jnp.where(pad, jnp.finfo(jnp.float32).min, logits)
        return logits
    return nn.logits_apply(params["embed"], params.get("head", {}), x, cfg)


def lm_apply(params, batch, cfg: ModelConfig, *, return_cache: bool = False):
    """Full-sequence forward (train / prefill).

    batch: tokens (B, S) [+ patch_embeds (B, F, d)] [+ slot_ids (B,)].
    Returns (logits (B, S_total, V), aux_loss) and optionally the kv cache
    pytree holding the full-sequence keys/values (prefill).
    """
    slot_ids = batch.get("slot_ids")
    pad_mask = batch.get("pad_mask")  # (B, S): 1=real token, 0=right pad
    last_valid = (
        pad_mask.sum(axis=1).astype(jnp.int32) if pad_mask is not None else None
    )
    x = _embed_inputs(params, batch, cfg)
    bsz, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (bsz, s))
    moe_capacity = None
    if cfg.family == "moe":
        moe_capacity = int(
            cfg.moe_capacity_factor * bsz * s * cfg.experts_per_token / cfg.n_experts
        )
        moe_capacity = max(8, -(-moe_capacity // 8) * 8)

    aux_total = jnp.zeros((), jnp.float32)
    caches = None

    if cfg.family in ("dense", "moe"):
        def body(x, lp):
            y, kv, aux = _dense_layer_apply(
                lp, x, cfg, positions=positions, slot_ids=slot_ids,
                moe_capacity=moe_capacity, pad_mask=pad_mask,
            )
            return y, (kv, aux)

        x, (kvs, auxs) = lax.scan(
            lambda c, lp: _maybe_remat(body, cfg)(c, lp), x, params["layers"]
        )
        aux_total = auxs.sum()
        caches = kvs
    elif cfg.family == "ssm":
        def body(x, lp):
            y, ssm, conv = _ssm_layer_apply(
                lp, x, cfg, slot_ids=slot_ids,
                pad_mask=pad_mask, last_valid=last_valid,
            )
            return y, {"ssm": ssm, "conv": conv}

        x, states = lax.scan(
            lambda c, lp: _maybe_remat(body, cfg)(c, lp), x, params["layers"]
        )
        caches = states
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group_body(x, glp):
            def inner(x, lp):
                y, ssm, conv = _ssm_layer_apply(
                    lp, x, cfg, slot_ids=slot_ids,
                    pad_mask=pad_mask, last_valid=last_valid,
                )
                return y, {"ssm": ssm, "conv": conv}

            x, states = lax.scan(_maybe_remat(inner, cfg), x, glp)
            y, kv, _ = _dense_layer_apply(
                shared, x, cfg, positions=positions, slot_ids=slot_ids
            )
            return y, (states, kv)

        x, (gstates, kvs) = lax.scan(
            lambda c, g: _maybe_remat(group_body, cfg)(c, g), x, params["groups"]
        )
        caches = {"groups": gstates, "attn": kvs}
        if "trailing" in params:
            def inner(x, lp):
                y, ssm, conv = _ssm_layer_apply(
                    lp, x, cfg, slot_ids=slot_ids,
                    pad_mask=pad_mask, last_valid=last_valid,
                )
                return y, {"ssm": ssm, "conv": conv}

            x, tstates = lax.scan(_maybe_remat(inner, cfg), x, params["trailing"])
            caches["trailing"] = tstates
    else:
        raise ValueError(cfg.family)

    logits = _final_logits(params, x, cfg, slot_ids)
    if return_cache:
        return logits, aux_total, caches
    return logits, aux_total


def lm_decode_step(params, tokens, cache, cache_len, cfg: ModelConfig,
                   slot_ids=None):
    """One decode step.  tokens: (B, 1); cache from ``init_cache``;
    cache_len: scalar int32 — number of valid context tokens (synchronous
    stepping).  Returns (logits (B, 1, V), new_cache)."""
    x = nn.embed_apply(params["embed"], tokens)
    bsz = x.shape[0]
    positions = jnp.broadcast_to(
        jnp.atleast_1d(cache_len)[..., None], (bsz, 1)
    ).astype(jnp.int32)
    moe_capacity = None
    if cfg.family == "moe":
        # decode must never drop: worst case all rows route to one expert
        moe_capacity = max(8, -(-bsz // 8) * 8)

    if cfg.family in ("dense", "moe"):
        def body(x, inp):
            lp, kv = inp
            y, new_kv, _ = _dense_layer_apply(
                lp, x, cfg, positions=positions, kv_cache=kv,
                cache_len=cache_len, slot_ids=slot_ids,
                moe_capacity=moe_capacity,
            )
            return y, new_kv

        x, new_cache = lax.scan(body, x, (params["layers"], cache))
    elif cfg.family == "ssm":
        def body(x, inp):
            lp, st = inp
            y, ssm, conv = _ssm_layer_apply(
                lp, x, cfg, ssm_state=st["ssm"], conv_state=st["conv"],
                slot_ids=slot_ids,
            )
            return y, {"ssm": ssm, "conv": conv}

        x, new_cache = lax.scan(body, x, (params["layers"], cache))
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group_body(x, inp):
            glp, gst, kv = inp

            def inner(x, i2):
                lp, st = i2
                y, ssm, conv = _ssm_layer_apply(
                    lp, x, cfg, ssm_state=st["ssm"], conv_state=st["conv"],
                    slot_ids=slot_ids,
                )
                return y, {"ssm": ssm, "conv": conv}

            x, new_gst = lax.scan(inner, x, (glp, gst))
            y, new_kv, _ = _dense_layer_apply(
                shared, x, cfg, positions=positions, kv_cache=kv,
                cache_len=cache_len, slot_ids=slot_ids,
            )
            return y, (new_gst, new_kv)

        x, (new_gstates, new_kvs) = lax.scan(
            group_body, x, (params["groups"], cache["groups"], cache["attn"])
        )
        new_cache = {"groups": new_gstates, "attn": new_kvs}
        if "trailing" in params:
            def inner(x, i2):
                lp, st = i2
                y, ssm, conv = _ssm_layer_apply(
                    lp, x, cfg, ssm_state=st["ssm"], conv_state=st["conv"],
                    slot_ids=slot_ids,
                )
                return y, {"ssm": ssm, "conv": conv}

            x, new_t = lax.scan(inner, x, (params["trailing"], cache["trailing"]))
            new_cache["trailing"] = new_t
    else:
        raise ValueError(cfg.family)

    logits = _final_logits(params, x, cfg, slot_ids)
    return logits, new_cache
