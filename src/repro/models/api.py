"""Unified model API: one entry point per step kind, family-dispatched."""

from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models import encdec as _encdec
from repro.models import lm as _lm


def init(key, cfg: ModelConfig):
    if cfg.family == "encdec":
        return _encdec.encdec_init(key, cfg)
    return _lm.lm_init(key, cfg)


def apply(params, batch, cfg: ModelConfig, *, return_cache: bool = False):
    """Full-sequence forward -> (logits, aux[, cache])."""
    if cfg.family == "encdec":
        return _encdec.encdec_apply(params, batch, cfg, return_cache=return_cache)
    return _lm.lm_apply(params, batch, cfg, return_cache=return_cache)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    if cfg.family == "encdec":
        return _encdec.init_cache(cfg, batch, seq_len, dtype)
    return _lm.init_cache(cfg, batch, seq_len, dtype)


def decode_step(params, tokens, cache, cache_len, cfg: ModelConfig, slot_ids=None):
    if cfg.family == "encdec":
        return _encdec.encdec_decode_step(params, tokens, cache, cache_len, cfg,
                                          slot_ids)
    return _lm.lm_decode_step(params, tokens, cache, cache_len, cfg, slot_ids)
