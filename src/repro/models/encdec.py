"""Encoder-decoder backbone (seamless-m4t-medium).

The audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, S_enc, d).  Decoder layers carry causal
self-attention plus cross-attention into the encoder memory; at decode time
the per-layer cross K/V are precomputed once (prefill) and read-only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.nn import modules as nn


def _cross_attention_init(key, cfg: ModelConfig) -> dict:
    return nn.attention_init(key, cfg)  # same shapes; no RoPE at apply time


def _cross_attention_apply(params, x, memory_kv, cfg: ModelConfig):
    """x: (B, Sq, d); memory_kv: precomputed {"k","v"}: (B, G, Sm, D)."""
    bsz, sq, _ = x.shape
    hq, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    gq = hq // g
    q = (x @ params["wq"]).reshape(bsz, sq, g, gq, hd).transpose(0, 2, 3, 1, 4)
    k, v = memory_kv["k"], memory_kv["v"]
    scores = jnp.einsum(
        "bghqd,bgkd->bghqk", q, k, preferred_element_type=jnp.float32
    ) * (hd ** -0.5)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bghqk,bgkd->bghqd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)
    out = out.transpose(0, 3, 1, 2, 4).reshape(bsz, sq, hq * hd)
    return out @ params["wo"]


def cross_kv(params, memory, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder memory: (B, Sm, d)."""
    bsz, sm, _ = memory.shape
    g, hd = cfg.n_kv_heads, cfg.head_dim
    k = (memory @ params["wk"]).reshape(bsz, sm, g, hd).transpose(0, 2, 1, 3)
    v = (memory @ params["wv"]).reshape(bsz, sm, g, hd).transpose(0, 2, 1, 3)
    return {"k": k, "v": v}


def _enc_layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": nn.rmsnorm_init(cfg.d_model, nn.cdtype(cfg)),
        "attn": nn.attention_init(k1, cfg),
        "ln2": nn.rmsnorm_init(cfg.d_model, nn.cdtype(cfg)),
        "mlp": nn.mlp_init(k2, cfg),
    }


def _dec_layer_init(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": nn.rmsnorm_init(cfg.d_model, nn.cdtype(cfg)),
        "self_attn": nn.attention_init(k1, cfg),
        "ln2": nn.rmsnorm_init(cfg.d_model, nn.cdtype(cfg)),
        "cross_attn": _cross_attention_init(k2, cfg),
        "ln3": nn.rmsnorm_init(cfg.d_model, nn.cdtype(cfg)),
        "mlp": nn.mlp_init(k3, cfg),
    }


def encdec_init(key, cfg: ModelConfig) -> dict:
    ke, kenc, kdec, kin, kh, kb = jax.random.split(key, 6)
    enc_keys = jax.random.split(kenc, cfg.n_enc_layers)
    dec_keys = jax.random.split(kdec, cfg.n_dec_layers)
    params = {
        "frame_proj": {"w": nn._dense_init(kin, (cfg.d_model, cfg.d_model),
                                           nn.cdtype(cfg))},
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
        "enc_norm": nn.rmsnorm_init(cfg.d_model, nn.cdtype(cfg)),
        "embed": nn.embed_init(ke, cfg),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
        "final_norm": nn.rmsnorm_init(cfg.d_model, nn.cdtype(cfg)),
        "head": nn.head_init(kh, cfg),
    }
    if cfg.bank_mode == "head":
        params["bank_head"] = {
            "w": nn._dense_init(kb, (cfg.bank_slots, cfg.d_model, cfg.padded_vocab),
                                nn.cdtype(cfg))
        }
    return params


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat == "full" else fn


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, S_enc, d) stub frame embeddings -> encoder memory."""
    x = frames.astype(nn.cdtype(cfg)) @ params["frame_proj"]["w"]
    bsz, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (bsz, s))

    def body(x, lp):
        h, _ = nn.attention_apply(
            lp["attn"], nn.rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg,
            positions=positions, causal=False,
        )
        x = x + h
        return x + nn.mlp_apply(lp["mlp"], nn.rmsnorm(lp["ln2"], x, cfg.norm_eps)), None

    x, _ = lax.scan(lambda c, lp: _maybe_remat(body, cfg)(c, lp), x,
                    params["enc_layers"])
    return nn.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _final_logits(params, x, cfg, slot_ids=None):
    x = nn.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.bank_mode == "head" and slot_ids is not None and "bank_head" in params:
        w = params["bank_head"]["w"][slot_ids]
        logits = jnp.einsum("bsd,bdv->bsv", x, w, preferred_element_type=jnp.float32)
        if cfg.padded_vocab != cfg.vocab_size:
            pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            logits = jnp.where(pad, jnp.finfo(jnp.float32).min, logits)
        return logits
    return nn.logits_apply(params["embed"], params.get("head", {}), x, cfg)


def encdec_apply(params, batch, cfg: ModelConfig, *, return_cache=False):
    """Training / prefill forward.

    batch: frames (B, S_enc, d), tokens (B, S_dec) [+ slot_ids].
    Returns (decoder logits, aux=0) [+ cache {self, cross}].
    """
    slot_ids = batch.get("slot_ids")
    memory = encode(params, batch["frames"], cfg)
    x = nn.embed_apply(params["embed"], batch["tokens"])
    bsz, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (bsz, s))

    def body(x, lp):
        h, kv = nn.attention_apply(
            lp["self_attn"], nn.rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg,
            positions=positions,
        )
        x = x + h
        ckv = cross_kv(lp["cross_attn"], memory, cfg)
        x = x + _cross_attention_apply(
            lp["cross_attn"], nn.rmsnorm(lp["ln2"], x, cfg.norm_eps), ckv, cfg
        )
        x = x + nn.mlp_apply(lp["mlp"], nn.rmsnorm(lp["ln3"], x, cfg.norm_eps))
        return x, (kv, ckv)

    x, (kvs, ckvs) = lax.scan(
        lambda c, lp: _maybe_remat(body, cfg)(c, lp), x, params["dec_layers"]
    )
    logits = _final_logits(params, x, cfg, slot_ids)
    if return_cache:
        return logits, jnp.zeros((), jnp.float32), {"self": kvs, "cross": ckvs}
    return logits, jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None) -> dict:
    """Decoder cache: self-attn cache of seq_len + cross K/V of cross_len."""
    dt = dtype or nn.cdtype(cfg)
    g, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "self": {
            "k": jnp.zeros((cfg.n_dec_layers, batch, g, seq_len, hd), dt),
            "v": jnp.zeros((cfg.n_dec_layers, batch, g, seq_len, hd), dt),
        },
        "cross": {
            "k": jnp.zeros((cfg.n_dec_layers, batch, g, cfg.cross_len, hd), dt),
            "v": jnp.zeros((cfg.n_dec_layers, batch, g, cfg.cross_len, hd), dt),
        },
    }


def encdec_decode_step(params, tokens, cache, cache_len, cfg: ModelConfig,
                       slot_ids=None):
    """One decoder step against resident self/cross caches."""
    x = nn.embed_apply(params["embed"], tokens)
    bsz = x.shape[0]
    positions = jnp.broadcast_to(
        jnp.atleast_1d(cache_len)[..., None], (bsz, 1)
    ).astype(jnp.int32)

    def body(x, inp):
        lp, kv, ckv = inp
        h, new_kv = nn.attention_apply(
            lp["self_attn"], nn.rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg,
            positions=positions, kv_cache=kv, cache_len=cache_len,
        )
        x = x + h
        x = x + _cross_attention_apply(
            lp["cross_attn"], nn.rmsnorm(lp["ln2"], x, cfg.norm_eps), ckv, cfg
        )
        x = x + nn.mlp_apply(lp["mlp"], nn.rmsnorm(lp["ln3"], x, cfg.norm_eps))
        return x, new_kv

    x, new_self = lax.scan(
        body, x, (params["dec_layers"], cache["self"], cache["cross"])
    )
    logits = _final_logits(params, x, cfg, slot_ids)
    return logits, {"self": new_self, "cross": cache["cross"]}
