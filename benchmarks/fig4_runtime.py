"""Fig. 4 — runtime breakdown: slot selection, inline h32 inference, and
end-to-end packet-path latency; throughput in Mpps / Gbps.

Paper (x86 AVX-512, one pinned core): selection 0.005 us, inference
0.528 us, e2e 0.894 us, 1.894 Mpps.  This container measures the same
decomposition on its own CPU via the jitted JAX pipeline; absolute numbers
differ, the structure (selection << inference < e2e) is the claim.
"""

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_us, trained_bank, val_payload
from repro.core import bank as bank_lib, packet as pkt, pipeline


def main(batch: int = 4096):
    bank, s0, _ = trained_bank()
    payload, _ = val_payload(batch)
    slots = np.arange(batch) % 2
    packets = jnp.asarray(pkt.make_packets(slots, payload))
    pw = pkt.payload_of(packets)

    sel = lambda: pipeline.slot_select_only(packets, 2).block_until_ready()
    inf = lambda: pipeline.inference_only(s0, pw).block_until_ready()
    e2e = lambda: pipeline.packet_step(
        bank, packets, num_slots=2, strategy="take").scores.block_until_ready()

    t_sel = time_us(sel) / batch
    t_inf = time_us(inf) / batch
    t_e2e = time_us(e2e) / batch
    mpps = 1.0 / t_e2e
    gbps_payload = mpps * pkt.PAYLOAD_BYTES * 8 / 1e3
    gbps_1500 = mpps * 1500 * 8 / 1e3

    emit("fig4.slot_selection_us", t_sel, "paper=0.005")
    emit("fig4.inference_us", t_inf, "paper=0.528")
    emit("fig4.e2e_packet_path_us", t_e2e, "paper=0.894")
    emit("fig4.throughput_mpps", mpps, "paper=1.894")
    emit("fig4.gbps_1024B", gbps_payload, "paper=15.52")
    emit("fig4.gbps_1500B", gbps_1500, "paper=22.73")
    emit("fig4.selection_vs_inference_ratio", t_sel / t_inf,
         "selection<<inference")


if __name__ == "__main__":
    main()
