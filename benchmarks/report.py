"""Render the EXPERIMENTS.md roofline table and perf log from dry-run JSONs.

Usage:
    PYTHONPATH=src python -m benchmarks.report            # print tables
    PYTHONPATH=src python -m benchmarks.report --write    # splice into EXPERIMENTS.md
"""

import argparse
import json
import os

from benchmarks.roofline import load_cells

ROOT = os.path.join(os.path.dirname(__file__), "..")


def roofline_markdown(cells) -> str:
    out = []
    for mesh in ("single", "multi"):
        out.append(f"\n### {'Single-pod 16×16 (256 chips)' if mesh == 'single' else 'Multi-pod 2×16×16 (512 chips)'}\n")
        out.append("| arch | shape | compute_s | memory_s | collective_s | "
                   "dominant | useful_FLOPs | mem/dev GiB | what would move the dominant term |")
        out.append("|---|---|---|---|---|---|---|---|---|")
        for c in cells:
            if c.get("mesh") != mesh and c.get("status") == "ok":
                continue
            parts = c["cell"].split("|")
            if c.get("status") == "skipped":
                if parts[2] != mesh:
                    continue
                out.append(f"| {parts[0]} | {parts[1]} | — | — | — | *skipped* | — | — | "
                           f"full attention: no sub-quadratic 500k decode |")
                continue
            if c.get("status") != "ok" or c.get("variant", "baseline") != "baseline":
                continue
            r = c["roofline"]
            mem = c["memory"].get("per_device_total", 0) / 2**30
            ratio = c.get("useful_flops_ratio") or 0
            out.append(
                f"| {c['arch']} | {c['shape']} | {r['compute_s']:.4g} "
                f"| {r['memory_s']:.4g} | {r['collective_s']:.4g} "
                f"| **{r['dominant']}** | {ratio:.3f} | {mem:.2f} "
                f"| {_advice(c)} |")
    return "\n".join(out)


def _advice(c) -> str:
    r = c["roofline"]
    dom = r["dominant"]
    coll = c["analysis"]["collective_bytes"]
    if dom == "collective":
        top = max((k for k in coll), key=lambda k: coll[k])
        return f"cut {top} traffic (dominant collective class)"
    if dom == "memory":
        if c["kind"] == "decode":
            return "KV/state cache traffic: quantize cache or widen batch"
        return "fuse / remat flash inner scans; fewer fusion-boundary trips"
    return "MXU-align block shapes; remove masked-block waste"


def splice(path: str, marker: str, content: str):
    with open(path) as f:
        text = f.read()
    assert marker in text, marker
    text = text.replace(marker, marker + "\n" + content)
    with open(path, "w") as f:
        f.write(text)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true")
    ap.add_argument("--dir", default=os.path.join(ROOT, "results", "dryrun"))
    args = ap.parse_args()
    cells = load_cells(args.dir)
    md = roofline_markdown(cells)
    if args.write:
        splice(os.path.join(ROOT, "EXPERIMENTS.md"), "<!-- ROOFLINE_TABLE -->", md)
        print("spliced roofline table into EXPERIMENTS.md")
    else:
        print(md)


if __name__ == "__main__":
    main()
