"""Fig. 14 (repo extension) — continuous deployment under live traffic.

Three measurements over `repro.deploy` (DESIGN.md §12):

  * **sampler overhead** — the emergency regime played with and without
    a ``PacketSampler`` tapped into the retire/drop path (oracle
    labeling + reservoir upkeep on the host thread): kpps both ways,
    the per-tick sampling cost, and an ``expect=0`` audit that the
    overhead stays under the 5% budget (always-on sampling must not
    backpressure the tick loop);
  * **rollout latency** — one scripted fine-tune -> canary -> promote
    rollout and one forced (corrupted-weights) rollback, both under
    live emergency traffic with ``audit=True``: online fine-tune cost,
    canary-start-to-promote and canary-start-to-rollback wall time, and
    the retrain-to-promote total an operator would see;
  * **decision audits** — ``expect=0``: both rollouts reach exactly the
    expected terminal decision (promote resp. rollback), zero wrong
    verdicts across the bake windows, conservation and epoch-continuity
    intact — the "every deployment decision is a typed epoch" claim.

Run standalone with ``--json BENCH_8.json`` for the machine-readable
map, or through ``python -m benchmarks.run --only fig14``.
"""

from __future__ import annotations

import os
import sys
import time

if __package__ in (None, ""):  # invoked as `python benchmarks/fig14_deploy.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(1, os.path.join(_root, "src"))

import jax
import numpy as np

from benchmarks.common import emit, standalone_json_main
from repro import deploy
from repro.core import executor
from repro.dataplane import DataplaneRuntime, workloads

NUM_SLOTS = 2
NUM_QUEUES = 4
BATCH = 128
OVERHEAD_BUDGET_PCT = 5.0


def _labeled_trace(scale: int = 1):
    """Emergency regime rendered from the labeled corpus pool (the
    sampler's oracle needs ground truth for every payload)."""
    pool, labels = deploy.labeled_pool(samples_per_group=256, seed=0)
    w = workloads.make_workload("emergency", num_slots=NUM_SLOTS,
                                num_queues=NUM_QUEUES, scale=scale)
    trace = workloads.render(list(w.phases), num_slots=NUM_SLOTS, seed=0,
                             num_queues=NUM_QUEUES, payload_pool=pool)
    return trace, deploy.LabelOracle(pool, labels)


def _runtime(bank, **kw):
    kw.setdefault("batch", BATCH)
    kw.setdefault("ring_capacity", 4096)
    return DataplaneRuntime(bank, num_queues=NUM_QUEUES, **kw)


def bench_sampler_overhead(bank):
    """Emergency play with the retire/drop taps empty vs sampling.

    The tick-path cost is the tap alone (the retire tap enqueues batch
    references and returns; subsampling + labeling defer to ``flush()``
    on the consumer side, reported separately below).  It sits far below
    OS jitter on a single run; min over alternating reps is the robust
    estimator (jitter only adds time)."""
    trace, oracle = _labeled_trace(scale=2)

    def run(with_sampler: bool) -> tuple[float, int, int]:
        rt = _runtime(bank)
        sampler = (deploy.PacketSampler(oracle, num_slots=NUM_SLOTS)
                   .attach(rt) if with_sampler else None)
        t0 = time.perf_counter()
        workloads.play(rt, trace)
        dt = time.perf_counter() - t0
        if sampler is not None:
            sampler.detach()  # flushes the deferred labeling queue
            assert sampler.labeled > 0  # the tap actually did the work
        done = rt.telemetry.snapshot()["completed_total"]
        return dt, done, rt.telemetry.runtime_ticks

    run(False)  # warm the jit caches off the clock
    base, tapped = [], []
    ticks = done = 0
    for _ in range(5):  # alternate to keep drift out of the delta
        dt0, done, ticks = run(False)
        dt1, _, _ = run(True)
        base.append(dt0)
        tapped.append(dt1)
    dt0, dt1 = float(np.min(base)), float(np.min(tapped))
    overhead_pct = max(dt1 - dt0, 0.0) / dt0 * 100.0

    # the deferred consumer-side cost, accounted explicitly: one flush of
    # everything the whole play enqueued (subsample + label + reservoirs)
    rt = _runtime(bank)
    sampler = deploy.PacketSampler(oracle, num_slots=NUM_SLOTS).attach(rt)
    workloads.play(rt, trace)
    t0 = time.perf_counter()
    sampler.flush()
    flush_s = time.perf_counter() - t0
    sampler.detach()

    emit("fig14.sampler.kpps_untapped", done / dt0 / 1e3,
         f"{done} pkts emergency play, taps empty")
    emit("fig14.sampler.kpps_tapped", done / dt1 / 1e3,
         "same play, sampler labeling + reservoirs attached")
    emit("fig14.sampler.per_tick_us",
         max(dt1 - dt0, 0.0) * 1e6 / max(ticks, 1),
         f"per-tick tap cost over {ticks} ticks")
    emit("fig14.sampler.flush_us_per_krow",
         flush_s * 1e6 / max(sampler.sampled / 1e3, 1e-9),
         f"deferred label+file cost, {sampler.sampled} rows one flush")
    emit("fig14.audit.sampler_overhead_over_budget",
         int(overhead_pct > OVERHEAD_BUDGET_PCT),
         f"expect=0: overhead {overhead_pct:.2f}% within "
         f"{OVERHEAD_BUDGET_PCT:.0f}% budget")
    assert overhead_pct <= OVERHEAD_BUDGET_PCT, overhead_pct


def _run_rollout(bank, trace, oracle, *, corrupt: bool):
    """One scripted rollout under live traffic; returns (pilot, runtime)."""
    rt = _runtime(bank, audit=True)
    sampler = deploy.PacketSampler(oracle, num_slots=NUM_SLOTS).attach(rt)
    driver = deploy.DeployDriver(rt)
    pilot = deploy.ScheduledRollout(
        driver, sampler, deploy.OnlineTrainer(steps=24, seed=0),
        warmup_ticks=8, min_samples=48, corrupt=corrupt,
        canary_kw=dict(bake_ticks=8, min_samples=24))
    driver.add(pilot)
    workloads.play(driver, trace)
    driver.flush_deploy()
    sampler.detach()
    return pilot, rt


def bench_rollout_latency(bank):
    trace, oracle = _labeled_trace()
    bad_outcome = wrong = 0
    for corrupt, want in ((False, "promoted"), (True, "rolled_back")):
        pilot, rt = _run_rollout(bank, trace, oracle, corrupt=corrupt)
        rec = pilot.decision
        ok = rec is not None and rec["event"] == want
        bad_outcome += int(not ok)
        aud = rt.audit_conservation()
        wrong += int(rt.telemetry.wrong_verdict)
        bad_outcome += int(not aud["ok"])
        bad_outcome += int(not rt.control.continuity_audit()["ok"])
        if rec is None:
            continue
        bake_us = rec["metrics"]["elapsed_us"]
        if corrupt:
            emit("fig14.deploy.rollback_latency_us", bake_us,
                 f"canary start -> rolled_back "
                 f"({rec['metrics']['bake_window_ticks']} ticks bake, "
                 f"reason: {rec['reason']})")
        else:
            train_us = pilot.result.train_us
            emit("fig14.deploy.fine_tune_us", train_us,
                 f"{pilot.result.metrics['samples']} sampled examples, "
                 f"24 STE steps, holdout err "
                 f"{pilot.result.metrics['err']:.3f}")
            emit("fig14.deploy.promote_latency_us", bake_us,
                 f"canary start -> promoted "
                 f"({rec['metrics']['bake_window_ticks']} ticks bake)")
            emit("fig14.deploy.retrain_to_promote_us", train_us + bake_us,
                 "operator-visible: fine-tune + canary bake + promote epoch")
    emit("fig14.audit.rollout_outcome_mismatch", bad_outcome,
         "expect=0: promote run promoted, corrupted run rolled back, "
         "conservation + epoch continuity intact on both")
    emit("fig14.audit.deploy_wrong_verdict", wrong,
         "expect=0: zero wrong verdicts across both audited rollouts")
    assert bad_outcome == 0 and wrong == 0


def main() -> None:
    bank = executor.init_bank(jax.random.PRNGKey(0), NUM_SLOTS)
    bench_sampler_overhead(bank)
    bench_rollout_latency(bank)


if __name__ == "__main__":
    standalone_json_main(
        main, "fig14: continuous deployment — sampling, canary rollouts")
