"""Fig. 13 (repo extension) — observability pipeline cost + detection.

Three measurements over `repro.obs` and the streaming trace codec
(DESIGN.md §11):

  * **telemetry streaming overhead** — the fused fig8 hot path (replay
    of the emergency regime) with and without a delta-stream sink
    attached: kpps both ways, the per-tick delta-emission cost, and an
    ``expect=0`` audit that the overhead stays under the 5% budget
    (always-on observability must not tax the data plane);
  * **anomaly detection sweep** — every generator regime replayed with
    the delta stream attached and classified by ``AnomalyDetector``:
    detect-latency-in-ticks per regime (first tick of the stable
    correct classification) plus an ``expect=0`` misclassification
    count across all 11 regimes — the replay-testable detection claim;
  * **streaming trace codec** — the end-of-run save stall of a
    streamed recording vs the v1 monolithic codec that fig11 measured
    at ~177 ms (BENCH_5 ``fig11.trace.save_us``), bytes per packet
    under the payload-dictionary chunk encoding, and an ``expect=0``
    audit that streamed and buffered saves stay byte-identical and
    that the stall improves on the monolithic save by >= 5x.

Run standalone with ``--json BENCH_7.json`` for the machine-readable
map, or through ``python -m benchmarks.run --only fig13``.
"""

from __future__ import annotations

import os
import sys
import tempfile
import time

if __package__ in (None, ""):  # invoked as `python benchmarks/fig13_obs.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(1, os.path.join(_root, "src"))

import jax
import numpy as np

from benchmarks.common import emit, standalone_json_main
from repro.core import executor
from repro.dataplane import (DataplaneRuntime, MeshDataplane, faults,
                             workloads)
from repro.dataplane.workloads import generators
from repro.dataplane.workloads import trace as trace_mod
from repro.obs import AnomalyDetector, TelemetryStream, attach, detach

NUM_SLOTS = 2
BATCH = 128
OVERHEAD_BUDGET_PCT = 5.0
STREAM_SPEEDUP_FLOOR = 5.0

#: regimes the detector needs the mesh + armed fault plan for (health
#: transitions and degraded/rollback commits are the evidence)
_MESH_REGIMES = ("cascading-failover", "chaos-host-failover",
                 "barrier-straggler", "crash-mid-commit")


def _workload_trace(regime: str, scale: int = 1):
    hosts = 2 if regime in _MESH_REGIMES else 1
    queues = 2 if regime in _MESH_REGIMES else 4
    w = workloads.make_workload(
        regime, num_slots=NUM_SLOTS, num_queues=queues, hosts=hosts,
        scale=scale, corpus_root=generators.SYNTHETIC_CORPUS)
    trace = workloads.synthesize(
        w.phases, num_slots=NUM_SLOTS, num_queues=hosts * queues,
        seed=0, name=regime, payload_pool=w.payload_pool)
    return w, trace, hosts, queues


def _runtime_for(bank, w, hosts: int, queues: int, **kw):
    kw.setdefault("batch", BATCH)
    kw.setdefault("ring_capacity", 4096)
    if hosts > 1:
        injector = (faults.FaultInjector(w.fault_plan)
                    if w.fault_plan is not None else None)
        return MeshDataplane(bank, hosts=hosts, num_queues=queues,
                             fault_injector=injector, **kw)
    return DataplaneRuntime(bank, num_queues=queues, **kw)


def bench_stream_overhead(bank):
    """Emergency replay on the fused path, sink detached vs attached.

    The per-tick emission cost is ~30 us against multi-ms ticks, so the
    signal is far below OS scheduling jitter on any single run; min over
    alternating reps is the standard robust estimator here (jitter only
    ever adds time)."""
    w, trace, hosts, queues = _workload_trace("emergency", scale=2)

    def run(with_sink: bool) -> tuple[float, int, int]:
        rt = _runtime_for(bank, w, hosts, queues)
        if with_sink:
            attach(rt, TelemetryStream(capacity=1 << 16))
        t0 = time.perf_counter()
        rep = workloads.replay(trace, rt)
        dt = time.perf_counter() - t0
        if with_sink:
            detach(rt)
        return dt, rep["totals"]["completed"], rt.telemetry.runtime_ticks

    run(False)  # warm the jit caches off the clock
    base, sunk = [], []
    ticks = done = 0
    for _ in range(5):  # alternate to keep drift out of the delta
        dt0, done, ticks = run(False)
        dt1, _, _ = run(True)
        base.append(dt0)
        sunk.append(dt1)
    dt0, dt1 = float(np.min(base)), float(np.min(sunk))
    overhead_pct = max(dt1 - dt0, 0.0) / dt0 * 100.0
    emit("fig13.telemetry.kpps_nosink", done / dt0 / 1e3,
         f"{done} pkts fused replay, no delta sink")
    emit("fig13.telemetry.kpps_sink", done / dt1 / 1e3,
         "same replay, delta stream + spans attached")
    emit("fig13.telemetry.delta_emit_us",
         max(dt1 - dt0, 0.0) * 1e6 / max(ticks, 1),
         f"per-tick delta emission cost over {ticks} ticks")
    emit("fig13.audit.telemetry_overhead_over_budget",
         int(overhead_pct > OVERHEAD_BUDGET_PCT),
         f"expect=0: overhead {overhead_pct:.2f}% within "
         f"{OVERHEAD_BUDGET_PCT:.0f}% budget")
    assert overhead_pct <= OVERHEAD_BUDGET_PCT, overhead_pct


def bench_detector_sweep(bank):
    """Replay every regime through an attached detector; classification
    must land on the regime's own name, and stay there."""
    wrong = 0
    for regime in workloads.REGIME_NAMES:
        w, trace, hosts, queues = _workload_trace(regime)
        rt = _runtime_for(bank, w, hosts, queues, record=True)
        stream = TelemetryStream(capacity=1 << 16)
        attach(rt, stream)
        det = AnomalyDetector(stream, num_queues=hosts * queues,
                              num_slots=NUM_SLOTS, hosts=hosts)
        t0 = time.perf_counter()
        workloads.replay(trace, rt)
        det.poll()
        dt = time.perf_counter() - t0
        got = det.classify()
        label = regime.replace("-", "_")
        ok = got["regime"] == regime
        wrong += int(not ok)
        detect = det.detect_tick()
        emit(f"fig13.detector.{label}.detect_tick",
             -1 if detect is None else detect,
             f"classified {got['regime']!r} "
             f"({len(det.findings)} findings, "
             f"{dt * 1e3:.0f} ms replay+poll)")
        assert ok, (regime, got["regime"], got["evidence"])
    emit("fig13.audit.regime_misclassified", wrong,
         f"expect=0: all {len(workloads.REGIME_NAMES)} regimes named")


def bench_stream_codec(bank):
    """Streamed vs buffered vs v1-monolithic save of the same run."""
    w, rendered_trace, hosts, queues = _workload_trace("emergency")
    rendered = workloads.render(list(w.phases), num_slots=NUM_SLOTS,
                                seed=7, num_queues=queues,
                                payload_pool=w.payload_pool)

    def run_recorder(path=None):
        rt = _runtime_for(bank, w, hosts, queues, record=True)
        rec = workloads.record(rt, path=path)
        workloads.play(rec, rendered)
        return rec

    tmp = tempfile.mkdtemp(prefix="fig13_")
    buffered = run_recorder().finish(name="emergency", seed=7)
    v1_path = os.path.join(tmp, "v1.bswt")
    t0 = time.perf_counter()
    trace_mod._save_v1(buffered, v1_path)
    v1_save_us = (time.perf_counter() - t0) * 1e6
    v2_path = os.path.join(tmp, "v2.bswt")
    t0 = time.perf_counter()
    nbytes = workloads.save(buffered, v2_path)
    v2_save_us = (time.perf_counter() - t0) * 1e6

    stream_path = os.path.join(tmp, "streamed.bswt")
    rec = run_recorder(path=stream_path)
    t0 = time.perf_counter()
    streamed = rec.finish(name="emergency", seed=7)
    stall_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    loaded = workloads.load(stream_path)
    load_us = (time.perf_counter() - t0) * 1e6

    with open(v2_path, "rb") as a, open(stream_path, "rb") as b:
        identical = a.read() == b.read()
    rep = workloads.replay(loaded, workloads.make_runtime(loaded))
    speedup = v1_save_us / max(stall_us, 1.0)
    emit("fig13.trace.stream_save_stall_us", stall_us,
         f"end-of-run stall of a streamed recording "
         f"({streamed.nbytes} bytes already on disk)")
    emit("fig13.trace.chunked_save_us", v2_save_us,
         f"buffered v2 save, {nbytes} bytes "
         f"(v1 monolithic: {v1_save_us:.0f} us)")
    emit("fig13.trace.load_us", load_us, "chunked decode + dict expand")
    emit("fig13.trace.bytes_per_packet",
         streamed.nbytes / streamed.total_packets,
         f"payload-dictionary chunks, {streamed.total_packets} pkts")
    bad = sum((not identical, not rep["ok"], rep["digest_ok"] is not True,
               speedup < STREAM_SPEEDUP_FLOOR))
    emit("fig13.audit.stream_codec_mismatch", bad,
         f"expect=0: byte-identical={identical} replay_ok={rep['ok']} "
         f"digest_ok={rep['digest_ok']} stall speedup {speedup:.0f}x "
         f"(floor {STREAM_SPEEDUP_FLOOR:.0f}x vs v1 monolithic)")
    assert bad == 0, (identical, rep["ok"], rep["digest_ok"], speedup)


def main() -> None:
    bank = executor.init_bank(jax.random.PRNGKey(0), NUM_SLOTS)
    bench_stream_overhead(bank)
    bench_detector_sweep(bank)
    bench_stream_codec(bank)


if __name__ == "__main__":
    standalone_json_main(
        main, "fig13: observability pipeline cost + anomaly detection")
