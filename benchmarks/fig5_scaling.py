"""Fig. 5 — resident-bank scaling 2 -> 16 slots under fixed / round-robin /
random / hotspot slot-access traces.

Paper: selection cost flat (~0.0037 us) for both 2- and 16-slot banks;
select+inference 0.67-0.92 us dominated by access-pattern-dependent runtime.
"""

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bank_with_slots, emit, time_us, val_payload
from repro.core import packet as pkt, pipeline, switching


def main(batch: int = 2048):
    payload, _ = val_payload(batch)
    for n_slots in (2, 16):
        bank = bank_with_slots(n_slots)
        for trace_kind in ("fixed", "round_robin", "random", "hotspot"):
            slots = switching.access_trace(trace_kind, batch, n_slots)
            packets = jnp.asarray(pkt.make_packets(slots, payload))

            t_sel = time_us(
                lambda: pipeline.slot_select_only(packets, n_slots)
                .block_until_ready()) / batch
            t_both = time_us(
                lambda: pipeline.packet_step(
                    bank, packets, num_slots=n_slots, strategy="take"
                ).scores.block_until_ready()) / batch
            emit(f"fig5.select_us.{n_slots}slots.{trace_kind}", t_sel,
                 "paper~0.0037")
            emit(f"fig5.select_plus_infer_us.{n_slots}slots.{trace_kind}",
                 t_both, "paper=0.67-0.92")
            # correctness guard: all 16 slot ids resolve correctly
            res = pipeline.packet_step(bank, packets, num_slots=n_slots)
            assert (np.asarray(res.slots) == slots).all()


if __name__ == "__main__":
    main()
