"""Fig. 8 (repo extension) — multi-queue data-plane runtime scaling.

Sweeps queue count x strategy over the emergency scenario (steady ->
flash crowd -> link failover -> slot churn) and reports aggregate
throughput per configuration, plus three hard structural audits:

  * **one fused launch per queue-block** — the traced per-queue program
    (backend pinned to pallas) contains exactly ONE ``pallas_call``;
  * **packet conservation** — ``offered == completed + dropped`` per
    queue and in aggregate across every scenario phase (flash crowd is
    sized to force real tail-drops, so the dropped leg is non-trivial);
  * **swap continuity** — zero wrong-verdict packets while the slot-churn
    phase replaces a resident slot online (audit mode re-scores every
    tick through the exact ``take`` path).

Run standalone with ``--json BENCH_2.json`` for the machine-readable
map, or through ``python -m benchmarks.run --only fig8``.
"""

from __future__ import annotations

import os
import sys
import time

if __package__ in (None, ""):  # invoked as `python benchmarks/fig8_dataplane.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(1, os.path.join(_root, "src"))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, jaxpr_stats, standalone_json_main
from repro.core import executor, packet as pkt, pipeline
from repro.dataplane import (DataplaneRuntime, emergency_phases, play, render)

NUM_SLOTS = 4
BATCH = 128
BLOCK_B = 32


#: Scan-window length the fused sweep (and the megastep sweep's center
#: point) runs with — the ``--megastep-ticks`` default of the launch CLI.
MEGASTEP_TICKS = 8


def _run_scenario(bank, trace, num_queues: int, strategy: str,
                  *, ring_capacity: int = 1024, audit: bool = False,
                  megastep_ticks: int = 1, record: bool = False):
    rt = DataplaneRuntime(
        bank, num_queues=num_queues, strategy=strategy, batch=BATCH,
        block_b=BLOCK_B, ring_capacity=ring_capacity, audit=audit,
        megastep_ticks=megastep_ticks, record=record)
    t0 = time.perf_counter()
    reports = play(rt, trace)
    dt = time.perf_counter() - t0
    return rt, reports, dt


def main():
    bank = executor.init_bank(jax.random.PRNGKey(0), NUM_SLOTS)
    trace = render(emergency_phases(NUM_SLOTS), num_slots=NUM_SLOTS, seed=0)

    # -- queue-count x strategy throughput sweep --------------------------
    # best-of-2: the first run compiles the jitted per-queue programs (the
    # process-wide jit cache makes the second run warm), so the reported
    # number is steady-state throughput, not compile time.  The fused
    # strategy runs in deferred (megastep) mode — one compiled scan per
    # 8-tick window (DESIGN.md §13); ``take`` stays on the sequential
    # per-tick loop, so the pair also measures the megastep's win.
    best_by = {}
    for num_queues in (1, 2, 4):
        for strategy in ("fused", "take"):
            mt = MEGASTEP_TICKS if strategy == "fused" else 1
            best = 0.0
            # deferred mode gets a third rep: its first run compiles one
            # scan variant per window shape, and single-core CI runners
            # are noisy enough that one warm sample under-reports
            for _ in range(3 if strategy == "fused" else 2):
                rt, _, dt = _run_scenario(bank, trace, num_queues, strategy,
                                          ring_capacity=8192,
                                          megastep_ticks=mt)
                aud = rt.audit_conservation()
                assert aud["ok"], aud
                done = aud["totals"]["completed"]
                assert done == trace.total_packets, aud  # big rings: no drops
                best = max(best, done / dt / 1e3)
            best_by[(strategy, num_queues)] = best
            reps = 3 if strategy == "fused" else 2
            emit(f"fig8.{strategy}.q{num_queues}.kpps", best,
                 f"{done} pkts {rt.fanout}-fanout best-of-{reps}")
    losses = sum(best_by[("fused", q)] < best_by[("take", q)]
                 for q in (1, 2, 4))
    emit("fig8.audit.fused_beats_take", losses,
         "expect=0 queue counts where fused < take")
    assert losses == 0, best_by

    # -- structural audit: ONE fused launch per queue-block ---------------
    qpackets = jnp.asarray(pkt.make_packets(
        np.arange(BATCH) % NUM_SLOTS,
        np.random.default_rng(0).integers(
            0, 2**32, (BATCH, pkt.PAYLOAD_WORDS), dtype=np.uint32)))

    def queue_block_step(p):
        return pipeline.packet_step(
            bank, p, num_slots=NUM_SLOTS, strategy="fused",
            backend="pallas", block_b=BLOCK_B)

    stats = jaxpr_stats(
        queue_block_step, qpackets,
        payload_threshold=BATCH * pkt.PAYLOAD_WORDS * 4)
    emit("fig8.audit.launches_per_queue_block",
         stats["kernel_launches"], "expect=1")
    emit("fig8.audit.payload_roundtrip_bytes",
         stats["payload_roundtrip_bytes"], "expect=0")
    assert stats["kernel_launches"] == 1, stats
    assert stats["payload_roundtrip_bytes"] == 0, stats

    # -- conservation under backpressure + swap continuity ----------------
    # small rings force real tail-drops during the flash crowd; audit mode
    # cross-checks every verdict against the exact path, including across
    # the online slot swap in the slot_churn phase.
    rt, reports, _ = _run_scenario(bank, trace, 4, "fused",
                                   ring_capacity=512, audit=True,
                                   megastep_ticks=MEGASTEP_TICKS)
    aud = rt.audit_conservation()
    assert aud["ok"], aud
    t = aud["totals"]
    assert t["offered"] == t["completed"] + t["dropped"], t
    assert t["offered"] == trace.total_packets, t
    crowd = next(r for r in reports if r["phase"] == "flash_crowd")
    emit("fig8.audit.flash_crowd_dropped", crowd["dropped"],
         "counted tail-drops under backpressure")
    emit("fig8.audit.wrong_verdict_during_swap", aud["wrong_verdict"],
         "expect=0 across online slot swap")
    assert crowd["dropped"] > 0, crowd
    assert aud["wrong_verdict"] == 0, aud


def _digest(rt):
    """Order-sensitive digest of the per-queue completion streams."""
    out = []
    for q in range(rt.num_queues):
        out.append((tuple(rt.completed_seq[q]),
                    tuple(rt.completed_verdicts[q]),
                    tuple(rt.completed_slots[q])))
    return tuple(out)


def megastep_main():
    """Fig. 8m — megastep window-length sweep (BENCH_9.json).

    Reports the fused strategy's throughput as a function of the scan
    window (``--megastep-ticks``) at 4 queues, plus queue scaling at the
    default window, and one structural audit: the deferred window must
    reproduce the sequential per-tick loop's completion streams
    (sequence ids, verdicts, slots — order-sensitive, per queue) exactly.
    """
    bank = executor.init_bank(jax.random.PRNGKey(0), NUM_SLOTS)
    trace = render(emergency_phases(NUM_SLOTS), num_slots=NUM_SLOTS, seed=0)

    for ticks in (1, 8, 64):
        best = 0.0
        for _ in range(3):
            rt, _, dt = _run_scenario(bank, trace, 4, "fused",
                                      ring_capacity=8192,
                                      megastep_ticks=ticks)
            done = rt.audit_conservation()["totals"]["completed"]
            assert done == trace.total_packets
            best = max(best, done / dt / 1e3)
        emit(f"fig8m.fused.q4.t{ticks}.kpps", best,
             f"scan window {ticks} best-of-3")
    for num_queues in (1, 2):
        best = 0.0
        for _ in range(3):
            rt, _, dt = _run_scenario(bank, trace, num_queues, "fused",
                                      ring_capacity=8192,
                                      megastep_ticks=MEGASTEP_TICKS)
            done = rt.audit_conservation()["totals"]["completed"]
            assert done == trace.total_packets
            best = max(best, done / dt / 1e3)
        emit(f"fig8m.fused.q{num_queues}.t{MEGASTEP_TICKS}.kpps", best,
             f"scan window {MEGASTEP_TICKS} best-of-3")

    # -- structural audit: megastep == sequential, bit for bit ------------
    # same trace, same bank; the sequential run and the deferred run must
    # agree on every completed packet's (seq, verdict, slot) in order.
    rt_seq, _, _ = _run_scenario(bank, trace, 4, "fused",
                                 ring_capacity=8192, record=True)
    rt_meg, _, _ = _run_scenario(bank, trace, 4, "fused",
                                 ring_capacity=8192, record=True,
                                 audit=True, megastep_ticks=MEGASTEP_TICKS)
    mismatch = int(_digest(rt_seq) != _digest(rt_meg))
    emit("fig8m.audit.megastep_digest_mismatch", mismatch,
         "expect=0 deferred window == sequential loop")
    emit("fig8m.audit.wrong_verdict", rt_meg.telemetry.wrong_verdict,
         "expect=0 suffix-dedup forward vs exact per-row path")
    assert mismatch == 0
    assert rt_meg.telemetry.wrong_verdict == 0


if __name__ == "__main__":
    standalone_json_main(main, __doc__)
