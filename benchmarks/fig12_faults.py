"""Fig. 12 (repo extension) — fault-tolerant epoch barriers.

Runs the canonical one-fault ``demo_plan`` for every fault class
(DESIGN.md §10) on a 2-host audited mesh with a 4-tick lease and
measures, per class:

  * **detect_ticks** — ticks from fault onset to the health monitor's
    first transition away from HEALTHY for the victim host;
  * **failover_latency_ticks** — ticks from that detection to the
    synthesized ``FailQueues`` failover epoch committing (0 when the
    class resolves without failover, e.g. shard errors -> rollback);
  * **packets_at_risk** — peak packets stranded on a non-live host
    (queued + in flight) at any tick boundary during the run;

plus the structural ``expect=0`` audits: zero wrong verdicts across
every epoch window (degraded commits included), zero epochs whose
outcome is not exactly one of {atomic, degraded, rollback}, and a zero
mesh-wide conservation gap with stranded packets accounted.

All fig12 metrics are tick counts or packet counts — deterministic in
the plan and seed, so the CI guard compares them raw (no machine-speed
normalization applies, but none is needed).

Run standalone with ``--json BENCH_6.json`` or through
``python -m benchmarks.run --only fig12``.
"""

from __future__ import annotations

import os
import sys

if __package__ in (None, ""):  # invoked as `python benchmarks/fig12_faults.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(1, os.path.join(_root, "src"))

import jax

from benchmarks.common import emit, standalone_json_main
from repro.control import SwapSlot
from repro.core import executor
from repro.dataplane import MeshDataplane, Phase, faults, render, scenarios

NUM_SLOTS = 4
HOSTS = 2
QUEUES = 2
LEASE = 4
TICKS = 20
FAULT_TICK = 6


def _drive(mesh, bursts):
    """Dispatch + tick through ``bursts`` with a SwapSlot epoch every
    third tick (so commits land while the fault is live), sampling the
    peak stranded-packet count at every tick boundary."""
    at_risk = 0
    for t, burst in enumerate(bursts):
        if t % 3 == 1:
            slot = (t // 3) % NUM_SLOTS
            mesh.control.submit(
                SwapSlot(slot, scenarios.default_swap_delivery(slot)))
        mesh.dispatch(burst)
        mesh.tick()
        stranded = mesh.audit_conservation().get("stranded")
        if stranded:
            at_risk = max(at_risk, stranded["packets"])
    mesh.drain()
    return at_risk


def _outcome_violations(log) -> int:
    """Epochs that did not end in exactly one of the three legal
    outcomes: atomic commit, degraded quorum commit, atomic rollback."""
    bad = 0
    for rec in log:
        mode = rec.commit_mode
        if mode not in ("atomic", "degraded", "rollback"):
            bad += 1
        elif (mode == "rollback") != (rec.error is not None):
            bad += 1
    return bad


def bench_fault_class(bank, bursts, kind: str):
    plan = faults.demo_plan(kind, hosts=HOSTS, lease_ticks=LEASE,
                            at_tick=FAULT_TICK)
    mesh = MeshDataplane(bank, hosts=HOSTS, num_queues=QUEUES, batch=128,
                         ring_capacity=4096, audit=True, record=True,
                         lease_ticks=LEASE,
                         fault_injector=faults.FaultInjector(plan))
    at_risk = _drive(mesh, bursts)

    trans = mesh.health.transitions
    detect = next((t.tick for t in trans
                   if t.frm == "healthy" and t.to != "healthy"), None)
    onset = min(f.at_tick for f in plan.faults)
    emit(f"fig12.{kind}.detect_ticks",
         0 if detect is None else detect - onset,
         f"fault @tick {onset}, lease={LEASE}"
         + ("" if detect is not None else " (no health impact)"))

    failover_lat = 0
    if mesh.failover_epochs:
        first = mesh.failover_epochs[0]
        rec = next(r for r in mesh.control.log if r.epoch == first)
        failover_lat = rec.applied_tick - (detect
                                           if detect is not None
                                           else FAULT_TICK)
    emit(f"fig12.{kind}.failover_latency_ticks", failover_lat,
         f"{len(mesh.failover_epochs)} failover epoch(s) synthesized")
    emit(f"fig12.{kind}.packets_at_risk", at_risk,
         "peak packets stranded on a non-live host")

    cont = mesh.control.continuity_audit()
    aud = mesh.audit_conservation()
    t = aud["totals"]
    # totals already count dead-host queues/in-flight; "stranded" is the
    # informational subset of those sitting on non-live hosts
    gap = (t["offered"] - t["completed"] - t["dropped"]
           - t["occupancy"] - t["in_flight"])
    emit(f"fig12.audit.{kind}.wrong_verdict", cont["wrong_verdict_total"],
         f"expect=0 across {len(cont['epochs'])} epochs "
         f"(modes {cont['commit_modes']})")
    emit(f"fig12.audit.{kind}.outcome_violations",
         _outcome_violations(mesh.control.log),
         "expect=0: every epoch atomic, degraded, or rolled back")
    emit(f"fig12.audit.{kind}.conservation_gap", gap,
         "expect=0: mesh-wide conservation incl. stranded")
    assert cont["ok"], cont
    assert aud["ok"], aud
    assert gap == 0


def main():
    bank = executor.init_bank(jax.random.PRNGKey(0), NUM_SLOTS)
    uniform = (1.0 / NUM_SLOTS,) * NUM_SLOTS
    trace = render(
        [Phase("drive", ticks=TICKS, burst=96, flows=24, slot_mix=uniform)],
        num_slots=NUM_SLOTS, seed=0, num_queues=HOSTS * QUEUES)
    bursts = trace.bursts[0]
    for kind in faults.FAULT_CLASSES:
        bench_fault_class(bank, bursts, kind)


if __name__ == "__main__":
    standalone_json_main(main, __doc__)
