"""Fig. 6 — slot-conditioned behavior: precision / recall / F1 of the
recall-oriented (slot 0, pos_weight 4.0) vs precision-oriented (slot 1,
pos_weight 0.5) resident models on the same forwarding path, plus the
paper's single-sample score-flip demonstration."""

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, trained_bank, val_payload
from repro.core import packet as pkt, pipeline
from repro.train import bnn


def main():
    bank, s0, s1 = trained_bank()
    payload, labels = val_payload(2048)

    for name, slot in (("slot0_recall_oriented", s0),
                       ("slot1_precision_oriented", s1)):
        m = bnn.evaluate(slot, payload, labels)
        emit(f"fig6.{name}.precision", m["precision"] * 100, "percent")
        emit(f"fig6.{name}.recall", m["recall"] * 100, "percent")
        emit(f"fig6.{name}.f1", m["f1"] * 100, "percent")

    # single-sample flip: same payload, only reg0 differs (paper: 1.98715
    # under slot 0 -> -0.0181384 under slot 1)
    from repro.core import executor
    sc0 = np.asarray(executor.forward(s0, jnp.asarray(payload))[:, 0])
    sc1 = np.asarray(executor.forward(s1, jnp.asarray(payload))[:, 0])
    flip = (sc0 > 0) != (sc1 > 0)
    idx = int(np.argmax(np.abs(sc0 - sc1) * flip)) if flip.any() else \
        int(np.argmax(np.abs(sc0 - sc1)))
    p0 = jnp.asarray(pkt.make_packets(np.zeros(1), payload[idx:idx + 1]))
    p1 = jnp.asarray(pkt.make_packets(np.ones(1), payload[idx:idx + 1]))
    y0 = float(pipeline.packet_step(bank, p0, num_slots=2).scores[0])
    y1 = float(pipeline.packet_step(bank, p1, num_slots=2).scores[0])
    emit("fig6.single_sample.slot0_score", y0, "paper=1.98715")
    emit("fig6.single_sample.slot1_score", y1, "paper=-0.0181384")
    emit("fig6.single_sample.verdict_flipped", float((y0 > 0) != (y1 > 0)),
         "1.0=behavior altered by slot choice alone")


if __name__ == "__main__":
    main()
