"""Fig. 15 (repo extension) — zero-copy model switching (DESIGN.md §14).

Three measurements over the double-buffered device bank:

  * **commit latency: flip vs re-stage** — the barrier-apply cost of one
    ``SwapSlot`` epoch on the double-buffered runtime (params prestaged
    into the shadow bank at submit time, commit = pointer flip) against
    the legacy single-bank runtime (commit = ``update_slot`` re-stage,
    fig9's 2023.966 us baseline).  The audit key asserts the flip path
    is at least 10x cheaper;
  * **flip/re-stage equivalence** — the full emergency scenario run
    through both commit paths under audit mode, with the verdict streams
    compared bit-for-bit (expect 0 mismatches, 0 wrong verdicts);
  * **LRU slot-cache churn** — 16 resident slots serving a rotating
    working set of 16/32/48 registered models: every demanded model is
    ``ensure``d through the cache (hits are host-side, misses become
    flip-commit ``SwapSlot`` epochs), traffic for that model flows the
    same tick, and the audit re-scores every packet.  Reports end-to-end
    churn throughput, the cache hit/miss economics, and the wall cost of
    a hit, a cold miss, and a prefetched miss.

Run standalone with ``--json BENCH_10.json`` for the machine-readable
map, or through ``python -m benchmarks.run --only fig15``.
"""

from __future__ import annotations

import os
import statistics
import sys
import time

if __package__ in (None, ""):  # invoked as `python benchmarks/fig15_swap.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(1, os.path.join(_root, "src"))

import jax
import numpy as np

from benchmarks.common import emit, standalone_json_main, time_us
from repro.control import SlotCache, SwapSlot
from repro.core import bank as bank_lib, executor, packet as pkt
from repro.dataplane import (DataplaneRuntime, emergency_phases, play,
                             render, scenarios)

NUM_SLOTS = 4       # commit-latency section mirrors fig9's shape
NUM_QUEUES = 4
BATCH = 128

CACHE_SLOTS = 16    # churn section: the paper's max resident bank
CACHE_MODELS = (16, 32, 48)
CHURN_STEPS = 96
CHURN_BURST = 64


def _fresh_runtime(bank, **kw):
    kw.setdefault("num_queues", NUM_QUEUES)
    kw.setdefault("strategy", "fused")
    kw.setdefault("batch", BATCH)
    kw.setdefault("ring_capacity", 1024)
    return DataplaneRuntime(bank, **kw)


def _swap_apply_us(rt, params, trials: int = 9, warmup: int = 3) -> float:
    """Median barrier-apply cost of a fresh single-SwapSlot epoch.

    A new command object per trial keeps the prestage honest (staging
    tokens key on command identity); warmup trials absorb the staging
    jit compiles so the median sees the steady state."""
    samples = []
    for i in range(warmup + trials):
        rt.control.submit(SwapSlot(1, params))
        rt.flush_control()
        if i >= warmup:
            samples.append(rt.control.log[-1].apply_us)
    return float(statistics.median(samples))


def bench_commit_latency(bank):
    delivered = scenarios.default_swap_delivery(1)
    flip_rt = _fresh_runtime(bank)                        # double-buffered
    restage_rt = _fresh_runtime(bank, double_buffer=False)  # legacy path
    flip_us = _swap_apply_us(flip_rt, delivered)
    restage_us = _swap_apply_us(restage_rt, delivered)
    speedup = restage_us / max(flip_us, 1e-9)
    emit("fig15.commit.flip_us", flip_us,
         "shadow prestaged at submit; barrier commit = pointer flip")
    emit("fig15.commit.restage_us", restage_us,
         f"legacy update_slot at the barrier; flip is {speedup:.1f}x faster")
    emit("fig15.audit.flip_not_10x_faster", int(flip_us * 10 > restage_us),
         f"expect=0 (flip {flip_us:.1f}us vs re-stage {restage_us:.1f}us)")


def bench_flip_restage_equivalence(bank):
    """Same scenario, both commit paths, bit-identical verdict streams."""
    trace = render(emergency_phases(NUM_SLOTS), num_slots=NUM_SLOTS, seed=0)
    streams = {}
    wrong = 0
    for name, db in (("flip", True), ("restage", False)):
        rt = _fresh_runtime(bank, ring_capacity=8192, audit=True,
                            record=True, double_buffer=db)
        play(rt, trace)
        aud = rt.audit_conservation()
        assert aud["ok"], aud
        wrong += aud["wrong_verdict"]
        streams[name] = (rt.completed_seq, rt.completed_verdicts,
                         rt.completed_slots)
    mismatch = int(streams["flip"] != streams["restage"])
    emit("fig15.audit.flip_vs_restage_verdict_mismatch", mismatch,
         "expect=0: pointer-flip commits change nothing observable")
    emit("fig15.audit.flip_wrong_verdict", wrong,
         "expect=0 across both commit paths, audit mode")


def _demand_sequence(n_models: int, steps: int, seed: int = 0) -> list[int]:
    """Deterministic skewed working set: a hot third revisits often, the
    cold tail returns periodically (the diurnal/flash-crowd shape the
    prefetcher is built for)."""
    rng = np.random.default_rng(seed)
    hot = max(1, n_models // 3)
    out = []
    for i in range(steps):
        if rng.random() < 0.7:
            out.append(int(rng.integers(hot)))
        else:
            out.append(hot + (i % max(1, n_models - hot)))
    return out


def _register_models(cache, n_models: int):
    src = executor.init_bank(jax.random.PRNGKey(7), n_models)
    names = [f"m{i:02d}" for i in range(n_models)]
    for i, name in enumerate(names):
        cache.register(name, bank_lib.select_slot(src, i))
    return names


def bench_cache_churn(payload):
    wrong_total = 0
    for n_models in CACHE_MODELS:
        bank = executor.init_bank(jax.random.PRNGKey(3), CACHE_SLOTS)
        rt = DataplaneRuntime(bank, num_queues=2, strategy="fused",
                              batch=CHURN_BURST, ring_capacity=2048,
                              audit=True)
        cache = SlotCache(rt)
        names = _register_models(cache, n_models)
        demand = _demand_sequence(n_models, CHURN_STEPS)
        done = 0
        t0 = time.perf_counter()
        for step, m in enumerate(demand):
            slot = cache.ensure(names[m])
            burst = pkt.make_packets(
                np.full(CHURN_BURST, slot),
                payload[(step * CHURN_BURST) % len(payload):]
                [:CHURN_BURST])
            rt.dispatch(burst)
            done += rt.tick()
        done += rt.drain()
        dt = time.perf_counter() - t0
        aud = rt.audit_conservation()
        assert aud["ok"], aud
        wrong_total += aud["wrong_verdict"]
        s = cache.stats()
        emit(f"fig15.cache.models{n_models}.kpps", done / dt / 1e3,
             f"{done} pkts, {CACHE_SLOTS} slots, hit_rate="
             f"{s['hit_rate']:.2f}, misses={s['misses']}, "
             f"evictions={s['evictions']}")
    emit("fig15.audit.cache_wrong_verdict", wrong_total,
         f"expect=0 over {len(CACHE_MODELS)} churn sweeps, audit mode")


def bench_cache_op_costs():
    """Wall cost of the three cache outcomes: resident hit (host-only),
    cold miss (stage+flip), prefetched miss (flip-only commit)."""
    bank = executor.init_bank(jax.random.PRNGKey(3), CACHE_SLOTS)
    rt = DataplaneRuntime(bank, num_queues=2, strategy="fused",
                          batch=CHURN_BURST, ring_capacity=2048)
    cache = SlotCache(rt)
    names = _register_models(cache, CACHE_SLOTS + 8)
    for n in names[:CACHE_SLOTS]:      # fill the resident set
        cache.ensure(n)
    emit("fig15.cache.hit_us",
         time_us(lambda: cache.ensure(names[0]), iters=200),
         "resident hit: pure host bookkeeping")

    cold = list(names[CACHE_SLOTS:])

    def miss(prefetch):
        m = cold.pop(0)
        cold.append(m)  # rotate so each trial is a genuine miss
        if prefetch:
            cache.prefetch(m)
        t0 = time.perf_counter()
        cache.ensure(m)
        rt.flush_control()
        return (time.perf_counter() - t0) * 1e6

    for _ in range(3):  # absorb staging-jit compiles
        miss(False), miss(True)
    emit("fig15.cache.miss_us",
         float(statistics.median([miss(False) for _ in range(9)])),
         "cold miss: submit-time stage + flip commit")
    emit("fig15.cache.prefetched_miss_us",
         float(statistics.median([miss(True) for _ in range(9)])),
         "predicted miss: shadow pre-staged, commit flip-only")


def main():
    bank = executor.init_bank(jax.random.PRNGKey(0), NUM_SLOTS)
    rng = np.random.default_rng(11)
    payload = rng.integers(0, 2**32, size=(4096, pkt.PAYLOAD_WORDS),
                           dtype=np.uint32)
    bench_commit_latency(bank)
    bench_flip_restage_equivalence(bank)
    bench_cache_churn(payload)
    bench_cache_op_costs()


if __name__ == "__main__":
    standalone_json_main(main, __doc__)
