# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import sys
import traceback


def main() -> None:
    from benchmarks import (fig4_runtime, fig5_scaling, fig6_slot_behavior,
                            roofline, table4_continuity, table5_controlplane)

    benches = [
        ("fig4", fig4_runtime.main),
        ("fig5", fig5_scaling.main),
        ("fig6", fig6_slot_behavior.main),
        ("table4", table4_continuity.main),
        ("table5", table5_controlplane.main),
        ("roofline", roofline.main),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        try:
            fn()
        except Exception as e:  # keep the suite running
            failures += 1
            print(f"{name}.ERROR,0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
