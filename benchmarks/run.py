# One function per paper table. Print ``name,us_per_call,derived`` CSV.
# ``--json PATH`` additionally writes a machine-readable name -> us_per_call
# map (e.g. BENCH_1.json) so the perf trajectory across PRs is diffable.
import argparse
import contextlib
import io
import json
import sys
import traceback


def _parse_rows(text: str) -> dict:
    rows = {}
    for line in text.splitlines():
        parts = line.split(",")
        if len(parts) >= 2:
            try:
                rows[parts[0]] = float(parts[1])
            except ValueError:
                continue
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write results as a name -> us_per_call JSON "
                         "map (convention: BENCH_<pr>.json)")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names to run (default: all)")
    args = ap.parse_args(argv)

    from benchmarks import (fig4_runtime, fig5_scaling, fig6_slot_behavior,
                            fig7_fused, roofline, table4_continuity,
                            table5_controlplane)

    benches = [
        ("fig4", fig4_runtime.main),
        ("fig5", fig5_scaling.main),
        ("fig6", fig6_slot_behavior.main),
        ("fig7", fig7_fused.main),
        ("table4", table4_continuity.main),
        ("table5", table5_controlplane.main),
        ("roofline", roofline.main),
    ]
    if args.only:
        wanted = set(args.only.split(","))
        unknown = wanted - {n for n, _ in benches}
        if unknown:
            ap.error(f"unknown bench name(s): {sorted(unknown)} "
                     f"(known: {[n for n, _ in benches]})")
        benches = [(n, f) for n, f in benches if n in wanted]

    print("name,us_per_call,derived")
    results: dict = {}
    failures = 0
    for name, fn in benches:
        buf = io.StringIO()
        try:
            with contextlib.redirect_stdout(buf):
                fn()
        except Exception as e:  # keep the suite running
            failures += 1
            buf.write(f"{name}.ERROR,0,{type(e).__name__}: {e}\n")
            traceback.print_exc(file=sys.stderr)
        text = buf.getvalue()
        sys.stdout.write(text)
        sys.stdout.flush()
        results.update(_parse_rows(text))

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {len(results)} entries to {args.json}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
