# One function per paper table. Print ``name,us_per_call,derived`` CSV.
# ``--json PATH`` additionally writes a machine-readable name -> us_per_call
# map (e.g. BENCH_1.json) so the perf trajectory across PRs is diffable;
# ``--compare BASELINE.json`` exits nonzero on >25% regression of any key
# shared with the baseline (the CI regression guard).
import argparse
import contextlib
import io
import json
import os
import statistics
import sys
import traceback

REGRESSION_THRESHOLD = 0.25

# keys where larger is better (throughput); everything else is
# us/bytes/launch-count style where smaller is better.
_HIGHER_BETTER = ("kpps", "mpps", "pps")


def _parse_rows(text: str) -> dict:
    from benchmarks.common import parse_csv_rows
    return parse_csv_rows(text)


def _is_throughput(key: str) -> bool:
    return any(key.endswith(suf) for suf in _HIGHER_BETTER)


_MIN_NORMALIZE_KEYS = 4


def _speed_factor(results: dict, baseline: dict, shared) -> float:
    """Median uniform slowdown of this machine vs the baseline machine,
    estimated over the non-structural (timing/throughput) shared keys.
    1.0 = same speed; 1.4 = everything uniformly 40% slower.

    With fewer than ``_MIN_NORMALIZE_KEYS`` samples the median IS the keys
    under test (a regression would normalize itself away), so we fall
    back to raw comparison (factor 1.0)."""
    ratios = []
    for key in shared:
        if ".audit." in key or baseline[key] <= 0 or results[key] <= 0:
            continue
        r = results[key] / baseline[key]
        ratios.append(1.0 / r if _is_throughput(key) else r)
    if len(ratios) < _MIN_NORMALIZE_KEYS:
        return 1.0
    return statistics.median(ratios)


def compare_results(results: dict, baseline: dict,
                    threshold: float = REGRESSION_THRESHOLD,
                    normalize: bool = False) -> list[str]:
    """Regressions of ``results`` vs ``baseline`` over their shared keys.

    Throughput-style keys (``*pps``) regress by dropping; cost-style keys
    (us/bytes/counts) regress by growing.  A zero-cost baseline (e.g. the
    structural ``expect=0`` audits) regresses on ANY nonzero value.

    ``normalize=True`` divides out the median machine-speed factor before
    applying the threshold, so a uniformly slower machine (a different CI
    runner class) does not flag every key — only keys that regress
    *relative to the rest of the suite* do.  Structural ``.audit.`` keys
    are never normalized.  The trade-off: a change that slows every path
    by the same factor is invisible under normalization; with fewer than
    ``_MIN_NORMALIZE_KEYS`` shared timing keys normalization disables
    itself and the comparison is raw.
    """
    shared = sorted(set(results) & set(baseline))
    speed = _speed_factor(results, baseline, shared) if normalize else 1.0
    regressions = []
    for key in shared:
        base, new = baseline[key], results[key]
        adj = speed if (normalize and ".audit." not in key) else 1.0
        if _is_throughput(key):
            if base > 0 and new * adj < base * (1 - threshold):
                regressions.append(
                    f"{key}: {new:.4g} < {base:.4g} "
                    f"(-{(1 - new * adj / base) * 100:.0f}% at speed "
                    f"factor {speed:.2f})")
        elif base == 0:
            if new > 0:
                regressions.append(f"{key}: {new:.4g} > 0 (baseline 0)")
        elif new / adj > base * (1 + threshold):
            regressions.append(
                f"{key}: {new:.4g} > {base:.4g} "
                f"(+{(new / adj / base - 1) * 100:.0f}% at speed "
                f"factor {speed:.2f})")
    return regressions


def write_step_summary(path: str, results: dict, baseline: dict,
                       regressions: list[str], *, label: str,
                       normalize: bool) -> None:
    """Append a per-key comparison table (GitHub-flavored markdown) to
    ``path`` — the ``$GITHUB_STEP_SUMMARY`` report CI publishes."""
    shared = sorted(set(results) & set(baseline))
    speed = _speed_factor(results, baseline, shared) if normalize else 1.0
    flagged = {r.split(":", 1)[0] for r in regressions}
    lines = [
        f"### Benchmark comparison vs `{label}`",
        "",
        f"{len(shared)} shared keys, speed factor {speed:.2f}, "
        f"{len(regressions)} regression(s)",
        "",
        "| key | baseline | current | Δ | |",
        "|---|---:|---:|---:|---|",
    ]
    for key in shared:
        base, new = baseline[key], results[key]
        if base > 0:
            delta = (new / base - 1.0) * 100.0
            delta_s = f"{delta:+.0f}%"
        else:
            delta_s = "=" if new == base else f"{new:.4g} vs 0"
        good = _is_throughput(key)
        mark = ("🔴" if key in flagged else
                ("⚪" if ".audit." in key else
                 ("🟢" if (base > 0 and ((new > base) == good or new == base))
                  else "—")))
        lines.append(f"| `{key}` | {base:.4g} | {new:.4g} "
                     f"| {delta_s} | {mark} |")
    lines.append("")
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write results as a name -> us_per_call JSON "
                         "map (convention: BENCH_<pr>.json)")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names to run (default: all)")
    ap.add_argument("--compare", metavar="BASELINE", default=None,
                    help="baseline JSON (e.g. BENCH_1.json); exit nonzero on "
                         f">{REGRESSION_THRESHOLD:.0%}".replace("%", "%%")
                         + " regression of any shared key")
    ap.add_argument("--compare-normalize", action="store_true",
                    help="divide out the median machine-speed factor before "
                         "thresholding (for baselines recorded on different "
                         "hardware, e.g. CI runners)")
    ap.add_argument("--summary", metavar="PATH",
                    default=os.environ.get("GITHUB_STEP_SUMMARY"),
                    help="append a markdown per-key comparison table here "
                         "(default: $GITHUB_STEP_SUMMARY when set)")
    args = ap.parse_args(argv)

    from benchmarks import (fig4_runtime, fig5_scaling, fig6_slot_behavior,
                            fig7_fused, fig8_dataplane, fig9_control,
                            fig10_mesh, fig11_workloads, fig12_faults,
                            fig13_obs, fig14_deploy, fig15_swap,
                            roofline, table4_continuity,
                            table5_controlplane)

    benches = [
        ("fig4", fig4_runtime.main),
        ("fig5", fig5_scaling.main),
        ("fig6", fig6_slot_behavior.main),
        ("fig7", fig7_fused.main),
        ("fig8", fig8_dataplane.main),
        ("fig8m", fig8_dataplane.megastep_main),
        ("fig9", fig9_control.main),
        ("fig10", fig10_mesh.main),
        ("fig11", fig11_workloads.main),
        ("fig12", fig12_faults.main),
        ("fig13", fig13_obs.main),
        ("fig14", fig14_deploy.main),
        ("fig15", fig15_swap.main),
        ("table4", table4_continuity.main),
        ("table5", table5_controlplane.main),
        ("roofline", roofline.main),
    ]
    if args.only:
        wanted = set(args.only.split(","))
        unknown = wanted - {n for n, _ in benches}
        if unknown:
            ap.error(f"unknown bench name(s): {sorted(unknown)} "
                     f"(known: {[n for n, _ in benches]})")
        benches = [(n, f) for n, f in benches if n in wanted]

    print("name,us_per_call,derived")
    results: dict = {}
    failures = 0
    for name, fn in benches:
        buf = io.StringIO()
        try:
            with contextlib.redirect_stdout(buf):
                fn()
        except Exception as e:  # keep the suite running
            failures += 1
            buf.write(f"{name}.ERROR,0,{type(e).__name__}: {e}\n")
            traceback.print_exc(file=sys.stderr)
        text = buf.getvalue()
        sys.stdout.write(text)
        sys.stdout.flush()
        results.update(_parse_rows(text))

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {len(results)} entries to {args.json}", file=sys.stderr)
    if args.compare:
        with open(args.compare) as f:
            baseline = json.load(f)
        regressions = compare_results(results, baseline,
                                      normalize=args.compare_normalize)
        shared = len(set(results) & set(baseline))
        print(f"# compared {shared} shared keys vs {args.compare}: "
              f"{len(regressions)} regression(s)", file=sys.stderr)
        for r in regressions:
            print(f"# REGRESSION {r}", file=sys.stderr)
        if args.summary:
            write_step_summary(args.summary, results, baseline, regressions,
                               label=args.compare,
                               normalize=args.compare_normalize)
        if regressions:
            sys.exit(2)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
