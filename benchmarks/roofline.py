"""Roofline report: aggregates the dry-run JSONs (results/dryrun) into the
EXPERIMENTS.md table — per (arch x shape x mesh): three terms, dominant
bottleneck, MODEL_FLOPS ratio, per-device memory."""

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells(results_dir: str = RESULTS) -> list[dict]:
    cells = []
    if not os.path.isdir(results_dir):
        return cells
    for name in sorted(os.listdir(results_dir)):
        if name.endswith(".json"):
            with open(os.path.join(results_dir, name)) as f:
                cells.append(json.load(f))
    return cells


def fmt_table(cells: list[dict], mesh: str = "single") -> str:
    rows = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
            "| useful_flops | mem/dev GiB |",
            "|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c.get("mesh") != mesh:
            continue
        if c.get("status") == "skipped":
            rows.append(f"| {c['cell'].split('|')[0]} | {c['cell'].split('|')[1]} "
                        f"| — | — | — | skipped | — | — |")
            continue
        if c.get("status") != "ok":
            continue
        r = c["roofline"]
        mem = c["memory"].get("per_device_total", 0) / 2**30
        ratio = c.get("useful_flops_ratio")
        rows.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']:.4f} "
            f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} "
            f"| {r['dominant']} | {ratio:.3f} | {mem:.2f} |")
    return "\n".join(rows)


def main():
    cells = load_cells()
    ok = [c for c in cells if c.get("status") == "ok"]
    if not ok:
        print("roofline.cells,0,no dry-run results found — run "
              "`python -m repro.launch.dryrun --all --mesh both --out results/dryrun`")
        return
    print(f"roofline.cells,{len(ok)},compiled cells")
    by_dom = {}
    for c in ok:
        by_dom.setdefault(c["roofline"]["dominant"], []).append(c["cell"])
    for dom, cs in sorted(by_dom.items()):
        print(f"roofline.dominant.{dom},{len(cs)},e.g. {cs[0]}")
    worst = min(
        (c for c in ok if c["kind"] == "train"),
        key=lambda c: c.get("useful_flops_ratio") or 0)
    print(f"roofline.worst_useful_flops,{worst.get('useful_flops_ratio'):.4f},"
          f"{worst['cell']}")
    most_coll = max(
        ok, key=lambda c: c["roofline"]["collective_s"]
        / max(c["roofline"]["step_s_lower_bound"], 1e-12))
    print(f"roofline.most_collective_bound,"
          f"{most_coll['roofline']['collective_s']:.4f},{most_coll['cell']}")


if __name__ == "__main__":
    main()
