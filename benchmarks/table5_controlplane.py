"""Table V — lightweight resident switching vs online control-plane
replacement on the same boundary workload.

Paper: resident 0.005 us / 0 wrong packets; control-plane 484.896 us switch
latency, 8479 us boundary-to-effective window, 99 wrong-model and 99
wrong-verdict events."""

import numpy as np

from benchmarks.common import emit, trained_bank, val_payload
from repro.core import bank as bank_lib, switching


def main(n_packets: int = 2048, pacing_us: float = 10.0):
    bank, s0, s1 = trained_bank()
    payload, _ = val_payload(n_packets)
    trace = switching.boundary_trace(n_packets, payload)

    # resident switching: per-packet slot resolution cost + correctness
    res = switching.replay_trace(bank, trace[:1024], num_slots=2, batch=1)
    cost = switching.resident_switch_cost_us(bank, trace[:1024], 2)
    emit("table5.resident.switch_latency_us", cost, "paper=0.005")
    emit("table5.resident.wrong_packets", float(res.wrong_verdict), "paper=0")

    # control-plane replacement: slot-1 weights delivered after boundary
    cp = switching.control_plane_replay(s0, s1, trace, pacing_us=pacing_us)
    emit("table5.controlplane.switch_latency_us", cp.switch_latency_us,
         "paper=484.896")
    emit("table5.controlplane.boundary_to_effective_us",
         cp.boundary_to_effective_us, "paper=8479.45")
    emit("table5.controlplane.wrong_model_packets",
         float(cp.wrong_model_packets), "paper=99")
    emit("table5.controlplane.wrong_verdict_packets",
         float(cp.wrong_verdict_packets), "paper=99")
    ratio = cp.switch_latency_us / max(cost, 1e-9)
    emit("table5.latency_ratio_controlplane_over_resident", ratio,
         "paper~97000x")


if __name__ == "__main__":
    main()
