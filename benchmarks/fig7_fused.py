"""Fig. 7 (repo extension) — fused megakernel vs staged forwarding path.

Sweeps block_b x num_slots x strategy and reports us/packet for:

  * ``fused``          — ONE Pallas launch: DMA-gather prologue + parse +
                         XNOR layer 1 + sign + layer 2 + Pi, all in VMEM.
  * ``grouped``        — zero-copy fused executor (payload view upstream).
  * ``grouped_staged`` — the pre-fused layout: scatter_padded -> kernel ->
                         gather_padded, with HBM round trips between stages.
  * ``take``           — exact per-row gather baseline.

Also audits the traced program structure of the fused vs staged paths:
kernel launches per batch and payload-sized scatter/gather round-trip bytes
(the fused path must show exactly one launch and zero round-trip bytes),
plus the streaming replay engine vs per-batch blocking replay.

On CPU the Pallas path runs under ``interpret=True`` (audit only; timings
use ``backend="auto"`` so CPU times the oracle and TPU times the kernel).
"""

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, jaxpr_stats, time_us
from repro.core import executor, packet as pkt, pipeline, switching


def audit_path(bank, packets, num_slots, strategy, block_b):
    """Count kernel launches and payload-sized scatter/gather bytes in the
    traced forwarding program (backend pinned to pallas)."""

    def step(p):
        return pipeline.packet_step(
            bank, p, num_slots=num_slots, strategy=strategy,
            backend="pallas", block_b=block_b,
        )

    threshold = packets.shape[0] * pkt.PAYLOAD_WORDS * 4
    return jaxpr_stats(step, packets, payload_threshold=threshold)


def main(batch: int = 512):
    bank16 = executor.init_bank(jax.random.PRNGKey(0), 16)
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 2**32, (batch, pkt.PAYLOAD_WORDS),
                           dtype=np.uint32)

    # -- us/packet sweep: block_b x num_slots x strategy ------------------
    for num_slots in (4, 16):
        slots = switching.access_trace("random", batch, num_slots, seed=2)
        packets = jnp.asarray(pkt.make_packets(slots, payload))
        for strategy in ("fused", "grouped", "grouped_staged"):
            for block_b in (32, 128):
                fn = lambda: pipeline.packet_step(
                    bank16, packets, num_slots=num_slots, strategy=strategy,
                    block_b=block_b,
                ).scores.block_until_ready()
                t = time_us(fn, iters=10) / batch
                emit(f"fig7.{strategy}.K{num_slots}.bb{block_b}.us_per_packet",
                     t, "one-launch" if strategy == "fused" else "staged")
        fn = lambda: pipeline.packet_step(
            bank16, packets, num_slots=num_slots, strategy="take",
        ).scores.block_until_ready()
        emit(f"fig7.take.K{num_slots}.us_per_packet",
             time_us(fn, iters=10) / batch, "per-row gather baseline")

    # -- structural audit: one launch, zero payload round trips -----------
    slots = switching.access_trace("hotspot", batch, 16, seed=3)
    packets = jnp.asarray(pkt.make_packets(slots, payload))
    fused = audit_path(bank16, packets, 16, "fused", 128)
    staged = audit_path(bank16, packets, 16, "grouped_staged", 128)
    emit("fig7.audit.fused.kernel_launches",
         fused["kernel_launches"], "expect=1")
    emit("fig7.audit.fused.payload_roundtrip_bytes",
         fused["payload_roundtrip_bytes"], "expect=0")
    emit("fig7.audit.staged.kernel_launches",
         staged["kernel_launches"], "plus XLA stages")
    emit("fig7.audit.staged.payload_roundtrip_bytes",
         staged["payload_roundtrip_bytes"], "scatter/gather HBM traffic")
    assert fused["kernel_launches"] == 1, fused
    assert fused["payload_roundtrip_bytes"] == 0, fused
    assert staged["payload_roundtrip_bytes"] > 0, staged

    # -- streaming replay engine vs per-batch blocking --------------------
    n = 2048
    pay = payload[np.arange(n) % batch]
    trace = switching.boundary_trace(n, pay)
    bank2 = executor.init_bank(jax.random.PRNGKey(1), 2)

    def kpps(stream):
        best = 0.0
        for _ in range(3):  # best-of-3: single replays are timing-noisy
            res = switching.replay_trace(bank2, trace, num_slots=2, batch=256,
                                         stream=stream)
            assert res.wrong_verdict == 0
            best = max(best, n / res.timestamps_us[-1] * 1e3)
        return best

    emit("fig7.replay.sync_kpps", kpps(False), "block per batch")
    emit("fig7.replay.stream_kpps", kpps(True), "bounded in-flight window")


if __name__ == "__main__":
    main()
