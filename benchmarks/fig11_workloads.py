"""Fig. 11 (repo extension) — trace-driven workload engine.

Three measurements over `repro.dataplane.workloads` (DESIGN.md §9):

  * **regime sweep** — every generator regime synthesized into a
    versioned trace and replayed through an audited runtime (mesh for
    the host-addressed regimes): replay kpps per regime, plus an
    ``expect=0`` wrong-verdict count and an ``expect=0`` invariant-
    mismatch count per regime — the zero-wrong-verdict continuity claim
    checked across the whole demand space, not just one storyline;
  * **record -> replay bit-exactness** — a live emergency run recorded
    through ``TraceRecorder``, saved, loaded, and replayed on a fresh
    runtime: the verdict-stream digest and the raw per-queue
    (seq, verdict, slot) streams must match bit-exactly (``expect=0``
    mismatch count), the acceptance criterion of ISSUE 5;
  * **trace codec cost** — save + load round-trip time and compressed
    bytes-per-packet for a recorded trace (the control-channel cost of
    shipping a scenario corpus around).

Run standalone with ``--json BENCH_5.json`` for the machine-readable
map, or through ``python -m benchmarks.run --only fig11``.
"""

from __future__ import annotations

import os
import sys
import tempfile
import time

if __package__ in (None, ""):  # invoked as `python benchmarks/fig11_workloads.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(1, os.path.join(_root, "src"))

import jax

from benchmarks.common import emit, standalone_json_main
from repro.core import executor
from repro.dataplane import DataplaneRuntime, MeshDataplane, workloads
from repro.dataplane.workloads import generators

NUM_SLOTS = 2
BATCH = 128

#: regimes whose phases address hosts (global queue ids over 2 hosts)
_MESH_REGIMES = ("cascading-failover", "chaos-host-failover")


def _runtime_for(bank, regime: str):
    kw = dict(batch=BATCH, ring_capacity=4096, record=True, audit=True)
    if regime in _MESH_REGIMES:
        return MeshDataplane(bank, hosts=2, num_queues=2, **kw), 2, 2
    return DataplaneRuntime(bank, num_queues=4, **kw), 1, 4


def bench_regime_sweep(bank):
    """Synthesize + replay every regime; kpps and audit counters each."""
    for regime in workloads.REGIME_NAMES:
        hosts = 2 if regime in _MESH_REGIMES else 1
        queues = 2 if regime in _MESH_REGIMES else 4
        w = workloads.make_workload(
            regime, num_slots=NUM_SLOTS, num_queues=queues, hosts=hosts,
            # pin file-replay to the synthetic corpus: baselines must not
            # depend on which file sets exist on the measuring machine
            corpus_root=generators.SYNTHETIC_CORPUS)
        trace = workloads.synthesize(
            w.phases, num_slots=NUM_SLOTS, num_queues=hosts * queues,
            seed=0, name=regime, payload_pool=w.payload_pool)
        rt, _, _ = _runtime_for(bank, regime)
        t0 = time.perf_counter()
        rep = workloads.replay(trace, rt)
        dt = time.perf_counter() - t0
        done = rep["totals"]["completed"]
        cont = rt.control.continuity_audit()
        label = regime.replace("-", "_")
        emit(f"fig11.{label}.kpps", done / dt / 1e3,
             f"{done}/{trace.total_packets} pkts {hosts}h x {queues}q "
             f"{len(rt.control.log)} epochs audited replay")
        emit(f"fig11.audit.{label}.wrong_verdict",
             rt.telemetry.wrong_verdict,
             "expect=0: zero-wrong-verdict continuity under this regime")
        bad = len(rep["mismatches"]) + (0 if cont["ok"] else 1)
        emit(f"fig11.audit.{label}.invariant_mismatch", bad,
             "expect=0: per-phase invariants + epoch continuity hold")
        assert rt.telemetry.wrong_verdict == 0, regime
        assert bad == 0, (regime, rep["mismatches"])


def bench_record_replay(bank):
    """Record a live run, save/load, replay: must be bit-exact."""
    w = workloads.make_workload("emergency", num_slots=NUM_SLOTS,
                                num_queues=4)
    rendered = workloads.render(list(w.phases), num_slots=NUM_SLOTS,
                                seed=7, num_queues=4)
    rt = DataplaneRuntime(bank, num_queues=4, batch=BATCH,
                          ring_capacity=2048, record=True)
    rec = workloads.record(rt)
    workloads.play(rec, rendered)
    trace = rec.finish(name="emergency", seed=7)

    path = os.path.join(tempfile.mkdtemp(prefix="fig11_"), "emergency.bswt")
    t0 = time.perf_counter()
    nbytes = workloads.save(trace, path)
    save_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    loaded = workloads.load(path)
    load_s = time.perf_counter() - t0
    emit("fig11.trace.save_us", save_s * 1e6,
         f"{nbytes} bytes, {trace.total_packets} pkts")
    emit("fig11.trace.load_us", load_s * 1e6, "zlib+msgpack decode")
    emit("fig11.trace.bytes_per_packet", nbytes / trace.total_packets,
         "compressed trace size amortized")

    rt2 = workloads.make_runtime(loaded)
    rep = workloads.replay(loaded, rt2)
    mismatch = len(rep["mismatches"])
    mismatch += sum((
        rep["digest_ok"] is not True,
        rt2.completed_seq != rt.completed_seq,
        rt2.completed_verdicts != rt.completed_verdicts,
        rt2.completed_slots != rt.completed_slots,
        sorted(rt2.dropped_seq) != sorted(rt.dropped_seq),
    ))
    emit("fig11.audit.record_replay_mismatch", mismatch,
         "expect=0: replay of a recorded trace is bit-identical "
         "(digest + raw per-queue seq/verdict/slot streams)")
    assert mismatch == 0, rep["mismatches"]
    os.unlink(path)


def main():
    bank = executor.init_bank(jax.random.PRNGKey(0), NUM_SLOTS)
    bench_regime_sweep(bank)
    bench_record_replay(bank)


if __name__ == "__main__":
    standalone_json_main(
        main, "fig11: trace-driven workload engine (replay kpps + audits)")
