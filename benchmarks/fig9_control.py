"""Fig. 9 (repo extension) — control-plane epochs and adaptive routing.

Three measurements over the epoch-stamped control plane (DESIGN.md §7):

  * **epoch apply latency** — wall-clock cost of applying one epoch of
    each command kind (SwapSlot / ProgramReta / FailQueues /
    RestoreQueues / SetPolicy) at a tick boundary, median over trials;
    the epoch-native successor of ``switching.measure_update_latency_us``;
  * **adaptive-policy rebalance** — the elephant-flow skew scenario (a
    few heavy flows hash to one queue) under ``StaticReta`` vs
    ``LeastDepth`` vs ``DropRateRebalance``: max-queue drop count (the
    imbalance the policy must fix — asserted to shrink) and the time
    from skew onset to the last rebalance epoch;
  * **pipelined ticks** — scenario throughput at pipeline depth 1
    (synchronous) vs 4 (bounded in-flight window), plus the continuity
    audit proving zero wrong-verdict packets across a run that exercises
    every command kind.

Run standalone with ``--json BENCH_3.json`` for the machine-readable
map, or through ``python -m benchmarks.run --only fig9``.
"""

from __future__ import annotations

import os
import statistics
import sys
import time

if __package__ in (None, ""):  # invoked as `python benchmarks/fig9_control.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(1, os.path.join(_root, "src"))

import jax
import numpy as np

from benchmarks.common import emit, standalone_json_main
from repro.control import (DropRateRebalance, FailQueues, LeastDepth,
                           ProgramReta, RestoreQueues, SetPolicy, StaticReta,
                           SwapSlot)
from repro.core import executor
from repro.dataplane import (DataplaneRuntime, elephant_skew_phases,
                             emergency_phases, play, render, rss, scenarios)

NUM_SLOTS = 4
NUM_QUEUES = 4
BATCH = 128


def _fresh_runtime(bank, **kw):
    kw.setdefault("num_queues", NUM_QUEUES)
    kw.setdefault("strategy", "fused")
    kw.setdefault("batch", BATCH)
    kw.setdefault("ring_capacity", 1024)
    return DataplaneRuntime(bank, **kw)


def _apply_us(rt, cmd, trials: int = 7) -> float:
    """Median apply cost of one single-command epoch at a tick boundary."""
    samples = []
    for _ in range(trials):
        rt.control.submit(cmd)
        rt.flush_control()
        samples.append(rt.control.log[-1].apply_us)
    return float(statistics.median(samples))


def bench_epoch_latency(bank):
    rt = _fresh_runtime(bank)
    delivered = scenarios.default_swap_delivery(1)
    reta = tuple(rss.indirection_table(NUM_QUEUES))
    kinds = [
        ("swap_slot", SwapSlot(1, delivered)),
        ("program_reta", ProgramReta(reta)),
        ("fail_queues", FailQueues((0,))),
        ("restore_queues", RestoreQueues()),
        ("set_policy", SetPolicy(LeastDepth())),
    ]
    for name, cmd in kinds:
        emit(f"fig9.epoch.{name}.apply_us", _apply_us(rt, cmd),
             "single-command epoch at tick boundary")


def bench_policy_rebalance(bank, trace):
    results = {}
    for policy in (StaticReta(), LeastDepth(), DropRateRebalance()):
        rt = _fresh_runtime(bank, ring_capacity=256, batch=64,
                            policy=policy)
        t0 = time.perf_counter()
        reports = play(rt, trace)
        aud = rt.audit_conservation()
        assert aud["ok"], aud
        dropped = [q["dropped"] for q in aud["per_queue"]]
        rebalances = [r for r in rt.control.log
                      if any(isinstance(c, ProgramReta) for c in r.commands)]
        # skew onset = end of the warmup phase (which also absorbed JIT
        # compile); convergence = last rebalance epoch becoming effective
        skew_start = t0 + reports[0]["elapsed_s"]
        rebalance_us = (max(0.0, rebalances[-1].submitted_s - skew_start)
                        * 1e6 + rebalances[-1].apply_latency_us
                        if rebalances else 0.0)
        key = policy.name.replace("-", "_")
        results[policy.name] = max(dropped)
        emit(f"fig9.policy.{key}.max_queue_dropped", max(dropped),
             f"elephant skew, {len(rebalances)} rebalance epoch(s)")
        emit(f"fig9.policy.{key}.total_dropped", sum(dropped),
             "all queues")
        if rebalances:
            emit(f"fig9.policy.{key}.rebalance_us", rebalance_us,
                 "skew onset -> last rebalance effective")
    assert results["least-depth"] < results["static"], results
    assert results["drop-rate"] < results["static"], results


def bench_pipeline_and_continuity(bank):
    trace = render(emergency_phases(NUM_SLOTS), num_slots=NUM_SLOTS, seed=0)
    verdicts = {}
    for depth in (1, 4):
        best = 0.0
        for _ in range(2):  # warm best-of-2 (first run pays compile)
            rt = _fresh_runtime(bank, ring_capacity=8192,
                                pipeline_depth=depth, record=True)
            t0 = time.perf_counter()
            play(rt, trace)
            dt = time.perf_counter() - t0
            aud = rt.audit_conservation()
            assert aud["ok"], aud
            done = aud["totals"]["completed"]
            assert done == trace.total_packets, aud
            best = max(best, done / dt / 1e3)
        verdicts[depth] = (rt.completed_seq, rt.completed_verdicts,
                           rt.completed_slots)
        emit(f"fig9.pipeline.depth{depth}.kpps", best,
             f"{done} pkts best-of-2")
    assert verdicts[1] == verdicts[4], "pipelined ticks changed results"

    # continuity across EVERY command kind: the emergency trace covers
    # RestoreQueues / FailQueues / SwapSlot; a mid-run SetPolicy installs
    # LeastDepth whose rebalances add ProgramReta epochs.
    rt = _fresh_runtime(bank, ring_capacity=512, audit=True,
                        pipeline_depth=2)
    rt.control.submit(SetPolicy(LeastDepth()))
    play(rt, trace)
    cont = rt.control.continuity_audit()
    kinds = {c for e in cont["epochs"] for c in e["commands"]}
    assert kinds >= {"restore_queues", "fail_queues", "swap_slot",
                     "set_policy", "program_reta"}, kinds
    assert cont["ok"], cont
    emit("fig9.audit.wrong_verdict_all_commands",
         cont["wrong_verdict_total"],
         f"expect=0 across {len(cont['epochs'])} epochs, "
         f"{len(kinds)} command kinds")


def main():
    bank = executor.init_bank(jax.random.PRNGKey(0), NUM_SLOTS)
    skew = render(elephant_skew_phases(NUM_SLOTS, NUM_QUEUES),
                  num_slots=NUM_SLOTS, seed=0, num_queues=NUM_QUEUES)
    bench_epoch_latency(bank)
    bench_policy_rebalance(bank, skew)
    bench_pipeline_and_continuity(bank)


if __name__ == "__main__":
    standalone_json_main(main, __doc__)
