"""Shared benchmark utilities: timing, workload setup, CSV emission."""

from __future__ import annotations

import functools
import time

import jax
import numpy as np

from repro.core import bank as bank_lib, executor, packet as pkt
from repro.data import packets as pk
from repro.train import bnn


def time_us(fn, iters: int = 50, warmup: int = 3) -> float:
    """Median-of-means wall time per call in microseconds."""
    for _ in range(warmup):
        fn()
    reps = []
    for _ in range(5):
        t0 = time.perf_counter()
        for _ in range(max(iters // 5, 1)):
            fn()
        reps.append((time.perf_counter() - t0) / max(iters // 5, 1))
    return float(np.median(reps)) * 1e6


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.4f},{derived}")


def parse_csv_rows(text: str) -> dict:
    """``name,value,...`` CSV lines -> {name: float} (non-numeric skipped)."""
    rows = {}
    for line in text.splitlines():
        parts = line.split(",")
        if len(parts) >= 2:
            try:
                rows[parts[0]] = float(parts[1])
            except ValueError:
                continue
    return rows


@functools.lru_cache(maxsize=1)
def trained_bank():
    """Train the paper's two slots once per process (cached)."""
    s0, s1 = bnn.train_slot_pair(seed=0, epochs=2, samples_per_group=512)
    return bank_lib.stack_bank([s0, s1]), s0, s1


@functools.lru_cache(maxsize=1)
def val_payload(n: int = 4096):
    xb, yb = pk.load_split("val", max(n // 2, 256), 0)
    w = pk.to_payload_words(xb)
    reps = -(-n // w.shape[0])
    return np.tile(w, (reps, 1))[:n], np.tile(yb, reps)[:n]


def bank_with_slots(num_slots: int):
    """The paper's scaling setup: the same two weight sets alternated."""
    _, s0, s1 = trained_bank()
    return bank_lib.stack_bank(
        [s0 if i % 2 == 0 else s1 for i in range(num_slots)])


# ---------------------------------------------------------------------------
# traced-program structural audit (shared by fig7 / fig8)
# ---------------------------------------------------------------------------

PAYLOAD_SIZED_PRIMS = ("scatter", "scatter-add", "gather")


def walk_jaxpr(jaxpr, counts: dict, threshold: int) -> None:
    """Count ``pallas_call`` launches and payload-sized scatter/gather bytes
    in a (possibly nested) jaxpr."""
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "pallas_call":
            counts["kernel_launches"] += 1
        if name in PAYLOAD_SIZED_PRIMS:
            nbytes = sum(
                int(np.prod(v.aval.shape)) * v.aval.dtype.itemsize
                for v in eqn.outvars
            )
            if nbytes >= threshold:
                counts["payload_roundtrip_bytes"] += nbytes
        for param in eqn.params.values():
            for sub in param if isinstance(param, (list, tuple)) else [param]:
                closed = getattr(sub, "jaxpr", None)
                if closed is not None and hasattr(sub, "eqns"):
                    walk_jaxpr(sub, counts, threshold)  # raw Jaxpr
                elif closed is not None and hasattr(closed, "eqns"):
                    walk_jaxpr(closed, counts, threshold)  # ClosedJaxpr


def jaxpr_stats(fn, *args, payload_threshold: int = 0) -> dict:
    """Trace ``fn(*args)`` and return its structural launch/traffic counts."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    counts = {"kernel_launches": 0, "payload_roundtrip_bytes": 0}
    walk_jaxpr(jaxpr.jaxpr, counts, payload_threshold)
    return counts


def standalone_json_main(main_fn, description, argv=None):
    """Shared ``--json PATH`` standalone entry for per-figure benchmarks.

    Runs ``main_fn`` capturing its ``name,value,derived`` CSV stdout and
    additionally writes the parsed name -> value map as sorted JSON (the
    BENCH_<pr>.json convention consumed by ``benchmarks.run --compare``).
    """
    import argparse
    import contextlib
    import io
    import json
    import sys

    ap = argparse.ArgumentParser(description=description)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write name -> value JSON "
                         "(e.g. BENCH_<pr>.json)")
    args = ap.parse_args(argv)
    if args.json is None:
        main_fn()
        return
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        main_fn()
    text = buf.getvalue()
    sys.stdout.write(text)
    rows = parse_csv_rows(text)
    with open(args.json, "w") as f:
        json.dump(rows, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {len(rows)} entries to {args.json}", file=sys.stderr)
