"""Shared benchmark utilities: timing, workload setup, CSV emission."""

from __future__ import annotations

import functools
import time

import jax
import numpy as np

from repro.core import bank as bank_lib, executor, packet as pkt
from repro.data import packets as pk
from repro.train import bnn


def time_us(fn, iters: int = 50, warmup: int = 3) -> float:
    """Median-of-means wall time per call in microseconds."""
    for _ in range(warmup):
        fn()
    reps = []
    for _ in range(5):
        t0 = time.perf_counter()
        for _ in range(max(iters // 5, 1)):
            fn()
        reps.append((time.perf_counter() - t0) / max(iters // 5, 1))
    return float(np.median(reps)) * 1e6


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.4f},{derived}")


@functools.lru_cache(maxsize=1)
def trained_bank():
    """Train the paper's two slots once per process (cached)."""
    s0, s1 = bnn.train_slot_pair(seed=0, epochs=2, samples_per_group=512)
    return bank_lib.stack_bank([s0, s1]), s0, s1


@functools.lru_cache(maxsize=1)
def val_payload(n: int = 4096):
    xb, yb = pk.load_split("val", max(n // 2, 256), 0)
    w = pk.to_payload_words(xb)
    reps = -(-n // w.shape[0])
    return np.tile(w, (reps, 1))[:n], np.tile(yb, reps)[:n]


def bank_with_slots(num_slots: int):
    """The paper's scaling setup: the same two weight sets alternated."""
    _, s0, s1 = trained_bank()
    return bank_lib.stack_bank(
        [s0 if i % 2 == 0 else s1 for i in range(num_slots)])
