"""Fig. 10 (repo extension) — multi-host mesh data plane.

Four measurements over ``MeshDataplane`` (DESIGN.md §8):

  * **hosts x queues sweep** — aggregate kpps over the emergency
    scenario for every (hosts, queues-per-host) cell, the mesh analogue
    of fig8's queue-count sweep;
  * **hosts=1 degeneracy** — ``MeshDataplane(hosts=1)`` replays the
    fig8-style trace bit-identically to ``DataplaneRuntime`` (same
    completed sequence stamps, verdicts, slots, and drops) — asserted,
    emitted as an ``expect=0`` mismatch count;
  * **epoch broadcast latency** — apply cost of one epoch of each
    queue-addressed kind on a 2-host mesh (stage on every host + barrier
    commit) vs the single-host runtime, median over trials;
  * **failover continuity** — the cascading host failover scenario
    (host dies -> its buckets remap -> second host degrades) replayed in
    audit mode: zero wrong verdicts across every epoch window, mesh-wide
    conservation, a drained dead host, and a barrier-tick spread of 0.

Run standalone with ``--json BENCH_4.json`` for the machine-readable
map, or through ``python -m benchmarks.run --only fig10``.
"""

from __future__ import annotations

import os
import statistics
import sys
import time

if __package__ in (None, ""):  # invoked as `python benchmarks/fig10_mesh.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(1, os.path.join(_root, "src"))

import jax
import numpy as np

from benchmarks.common import emit, standalone_json_main
from repro.control import FailQueues, ProgramReta, RestoreQueues, SwapSlot
from repro.core import executor
from repro.dataplane import (DataplaneRuntime, MeshDataplane,
                             cascading_failover_phases, emergency_phases,
                             play, render, rss, scenarios)

NUM_SLOTS = 4
BATCH = 128


def bench_mesh_sweep(bank, trace):
    """hosts x queues-per-host throughput over the emergency scenario."""
    for hosts in (1, 2):
        for queues in (2, 4):
            best = 0.0
            for _ in range(2):  # warm best-of-2 (first run pays compile)
                mesh = MeshDataplane(bank, hosts=hosts, num_queues=queues,
                                     batch=BATCH, ring_capacity=8192)
                t0 = time.perf_counter()
                play(mesh, trace)
                dt = time.perf_counter() - t0
                aud = mesh.audit_conservation()
                assert aud["ok"], aud
                done = aud["totals"]["completed"]
                assert done == trace.total_packets, aud  # big rings: no drops
                best = max(best, done / dt / 1e3)
            emit(f"fig10.mesh.h{hosts}q{queues}.kpps", best,
                 f"{done} pkts over {hosts * queues} global queues "
                 "best-of-2")


def bench_hosts1_degeneracy(bank, trace):
    """MeshDataplane(hosts=1) must be bit-identical to DataplaneRuntime."""
    kw = dict(strategy="fused", batch=BATCH, ring_capacity=512, record=True)
    rt = DataplaneRuntime(bank, num_queues=4, **kw)
    play(rt, trace)
    m1 = MeshDataplane(bank, hosts=1, num_queues=4, **kw)
    play(m1, trace)
    mismatch = sum((
        m1.completed_seq != rt.completed_seq,
        m1.completed_verdicts != rt.completed_verdicts,
        m1.completed_slots != rt.completed_slots,
        m1.dropped_seq != rt.dropped_seq,
        not np.array_equal(m1.reta, rt.reta),
    ))
    emit("fig10.audit.hosts1_mismatch", mismatch,
         "expect=0: hosts=1 mesh bit-identical to DataplaneRuntime")
    assert mismatch == 0


def _apply_us(rt, cmd, trials: int = 7) -> float:
    samples = []
    for _ in range(trials):
        rt.control.submit(cmd)
        rt.flush_control()
        samples.append(rt.control.log[-1].apply_us)
    return float(statistics.median(samples))


def bench_epoch_broadcast(bank):
    """Barrier broadcast (2 hosts) vs single-host apply, per command kind."""
    delivered = scenarios.default_swap_delivery(1)
    single = DataplaneRuntime(bank, num_queues=4, batch=BATCH)
    mesh = MeshDataplane(bank, hosts=2, num_queues=4, batch=BATCH)
    kinds = [
        ("swap_slot", SwapSlot(1, delivered)),
        ("program_reta", lambda rt: ProgramReta(
            tuple(rss.indirection_table(rt.num_queues)))),
        ("fail_queues", FailQueues((0,))),
        ("restore_queues", RestoreQueues()),
    ]
    for name, cmd in kinds:
        for label, rt in (("single_host", single), ("broadcast_h2", mesh)):
            c = cmd(rt) if callable(cmd) else cmd
            emit(f"fig10.epoch.{name}.{label}.apply_us", _apply_us(rt, c),
                 "stage + barrier commit" if label != "single_host"
                 else "single-host apply")


def bench_cascading_failover(bank):
    """Cascading host failover under audit: continuity at mesh scale."""
    hosts, queues = 2, 4
    phases = cascading_failover_phases(NUM_SLOTS, hosts=hosts,
                                       queues_per_host=queues)
    trace = render(phases, num_slots=NUM_SLOTS, seed=0,
                   num_queues=hosts * queues)
    mesh = MeshDataplane(bank, hosts=hosts, num_queues=queues, batch=BATCH,
                         ring_capacity=512, audit=True, record=True)
    reports = play(mesh, trace)
    aud = mesh.audit_conservation()
    assert aud["ok"], aud
    t = aud["totals"]
    assert t["offered"] == t["completed"] + t["dropped"] == \
        trace.total_packets, t
    cont = mesh.control.continuity_audit()
    assert cont["ok"], cont
    down = next(r for r in reports if r["phase"] == "host_down")
    spread = max(max(r.host_ticks) - min(r.host_ticks)
                 for r in mesh.control.log if r.applied)
    emit("fig10.audit.wrong_verdict_cascading_failover",
         cont["wrong_verdict_total"],
         f"expect=0 across {len(cont['epochs'])} epochs")
    emit("fig10.audit.barrier_tick_spread", spread,
         "expect=0: every host applies each epoch at one tick")
    emit("fig10.audit.failover_unaccounted_packets",
         t["offered"] - t["completed"] - t["dropped"],
         "expect=0: mesh-wide conservation")
    emit("fig10.failover.host_down_kpps", down["kpps"],
         "throughput while surviving host absorbs remapped buckets")
    assert cont["wrong_verdict_total"] == 0 and spread == 0


def main():
    bank = executor.init_bank(jax.random.PRNGKey(0), NUM_SLOTS)
    trace = render(emergency_phases(NUM_SLOTS), num_slots=NUM_SLOTS, seed=0)
    bench_mesh_sweep(bank, trace)
    bench_hosts1_degeneracy(bank, trace)
    bench_epoch_broadcast(bank)
    bench_cascading_failover(bank)


if __name__ == "__main__":
    standalone_json_main(main, __doc__)
