"""Table IV — switching continuity on the paced 8192-packet run.

Paper: 10 us pacing; boundary gap 95.58 us vs median 93.03 us; forwarding
rate 10.49 kpps before / 10.85 kpps after in a 512-packet window; zero
wrong-slot and zero wrong-verdict packets; all 4096 slot-1 packets in the
sink phase delivered."""

import numpy as np

from benchmarks.common import emit, trained_bank, val_payload
from repro.core import switching


def main(n_packets: int = 8192, pacing_us: float = 10.0):
    bank, _, _ = trained_bank()
    payload, _ = val_payload(n_packets)
    trace = switching.boundary_trace(n_packets, payload)
    res = switching.replay_trace(bank, trace, num_slots=2,
                                 pacing_us=pacing_us, batch=1)
    g = res.gap_stats_us()
    k = res.rate_kpps(window=512)
    emit("table4.median_gap_us", g["median_gap_us"], "paper=93.03")
    emit("table4.boundary_gap_us", g["boundary_gap_us"], "paper=95.58")
    emit("table4.rate_before_kpps", k["before_kpps"], "paper=10.49")
    emit("table4.rate_after_kpps", k["after_kpps"], "paper=10.85")
    emit("table4.wrong_slot", float(res.wrong_slot), "paper=0")
    emit("table4.wrong_verdict", float(res.wrong_verdict), "paper=0")
    sink = res.slots[res.boundary_index:]
    emit("table4.sink_phase_delivered", float((sink == 1).sum()),
         f"paper=4096 (of {n_packets // 2})")


if __name__ == "__main__":
    main()
