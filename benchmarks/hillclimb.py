"""§Perf hillclimb driver: run optimization variants for the three selected
cells and report deltas against the baseline dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.hillclimb --out results/perf
"""

# NOTE: must run in a fresh process; sets the device count before jax init.
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

CELLS = {
    # worst useful-FLOPs ratio: 15 heads % 16 != 0 -> attention replicated
    # across the TP axis; flash residuals blow memory
    "A": ("smollm-360m", "train_4k",
          ["flashremat", "seqshard", "flashremat+seqshard"]),
    # most collective-bound: FSDP contraction-dim sharding makes GSPMD emit
    # partial-sum all-reduces of (B, 32k, d) activations
    "B": ("arctic-480b", "prefill_32k", ["serve2d", "serve2d+seqshard"]),
    # most technique-representative: adapter-banked decode (per-request slot
    # routing) against a 32k cache
    "C": ("glm4-9b", "decode_32k", ["int8cache"]),
}


def main():
    from repro.launch import dryrun

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/perf")
    ap.add_argument("--baseline-dir", default="results/dryrun")
    ap.add_argument("--cells", default="ABC")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    for key in args.cells:
        arch, shape, variants = CELLS[key]
        base_file = os.path.join(args.baseline_dir,
                                 f"{arch}_{shape}_single.json")
        with open(base_file) as f:
            base = json.load(f)
        br = base["roofline"]
        print(f"\n=== cell {key}: {arch} | {shape} | single ===")
        print(f"baseline: compute={br['compute_s']:.4f}s "
              f"memory={br['memory_s']:.4f}s collective={br['collective_s']:.4f}s "
              f"dominant={br['dominant']} bound={br['step_s_lower_bound']:.4f}s")
        for variant in variants:
            try:
                res = dryrun.run_cell(arch, shape, multi_pod=False,
                                      variant=variant)
            except Exception as e:
                print(f"  {variant}: ERROR {type(e).__name__}: {e}")
                continue
            if res["status"] != "ok":
                print(f"  {variant}: {res['status']} {res.get('error','')[:200]}")
                continue
            r = res["roofline"]
            speedup = br["step_s_lower_bound"] / max(r["step_s_lower_bound"], 1e-12)
            print(f"  {variant}: compute={r['compute_s']:.4f}s "
                  f"memory={r['memory_s']:.4f}s collective={r['collective_s']:.4f}s "
                  f"dominant={r['dominant']} bound={r['step_s_lower_bound']:.4f}s "
                  f"speedup={speedup:.2f}x "
                  f"mem/dev={res['memory'].get('per_device_total',0)/2**30:.1f}GiB")
            fname = f"{arch}_{shape}_single_{variant.replace('+','_')}.json"
            with open(os.path.join(args.out, fname), "w") as f:
                json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
