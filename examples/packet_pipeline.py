"""The paper's full system, end to end: train both resident slot models on
the synthetic IoT-23-like workload, preload the bank, replay a boundary
stream, and report the headline numbers (Fig. 4 / Table IV analogues).

Runs the ``fused`` strategy (the one-launch megakernel hot path) by
default, like the driver it wraps; pass a trailing ``--strategy take``
to fall back to the exact per-row baseline.

Run:  PYTHONPATH=src python examples/packet_pipeline.py
(equivalent to: python -m repro.launch.packetpath --packets 2048)
"""

from repro.launch import packetpath
import sys

sys.argv = [sys.argv[0], "--packets", "2048", "--epochs", "2",
            "--samples-per-group", "512", *sys.argv[1:]]
packetpath.main()
