"""Quickstart: the BoundSwitch mechanism in ~40 lines.

Build a resident bank of two BNN models, assemble fixed-format packets whose
reg0 metadata selects the slot, and run them through the shared forwarding
path — switching models at packet granularity with no pipeline change.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bank as bank_lib
from repro.core import executor, packet as pkt, pipeline

# 1. preload K=2 resident models (paper Eq. 2-3): one bank, fixed HBM layout
bank = executor.init_bank(jax.random.PRNGKey(0), num_slots=2)
print(f"resident bank: {bank_lib.bank_size(bank)} slots, "
      f"{bank_lib.bank_bytes(bank)} bytes "
      f"(paper Table II: 2 slots = 65864 B)")

# 2. make packets: 1088 B = reg0 metadata + 1024 B payload (paper §II-B)
rng = np.random.default_rng(0)
payload = rng.integers(0, 2**32, (8, pkt.PAYLOAD_WORDS), dtype=np.uint32)
slots = np.array([0, 1, 0, 1, 0, 0, 1, 1])   # the 4-byte Model Slot ID field
packets = jnp.asarray(pkt.make_packets(slots, payload))

# 3. one shared pipeline: parse -> sigma -> resident slot -> BNN -> Pi
result = pipeline.packet_step(bank, packets, num_slots=2, strategy="take")
for i in range(8):
    print(f"packet {i}: slot={int(result.slots[i])} "
          f"score={float(result.scores[i]):+8.3f} "
          f"action={'DROP' if int(result.actions[i]) else 'FORWARD'}")

# 4. the paper's single-sample demo: same payload, different reg0 ->
#    different verdict, same compiled program
p = pkt.make_packets(np.array([0]), payload[:1])
s0 = float(pipeline.packet_step(bank, jnp.asarray(p), num_slots=2).scores[0])
p[:, pkt.SLOT_WORD] = 1
s1 = float(pipeline.packet_step(bank, jnp.asarray(p), num_slots=2).scores[0])
print(f"\nslot flip on identical payload: {s0:+.4f} -> {s1:+.4f} "
      f"(paper: +1.98715 -> -0.01814)")
