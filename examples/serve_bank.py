"""End-to-end serving driver (the paper's kind: serve a small model with
batched requests) — BoundSwitch's technique lifted to LLM serving.

A smollm-family model carries a K=2 resident adapter bank; each request's
metadata selects its slot, and the engine routes every prefill/decode step
through the bank at request granularity with zero engine reconfiguration.

Run:  PYTHONPATH=src python examples/serve_bank.py
"""

import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.models import api
from repro.serve.engine import Request, ServeEngine

cfg = get_config("smollm-360m").reduced(
    bank_mode="adapter", bank_slots=2, remat="none", dtype="float32",
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256)
params = api.init(jax.random.PRNGKey(0), cfg)

# give slot 1 a distinct behavior (in production: per-tenant finetuned deltas)
def bump(t):
    if isinstance(t, dict):
        if "a" in t and "b" in t:
            t["b"] = t["b"].at[1].set(
                jax.random.normal(jax.random.PRNGKey(7), t["b"].shape[1:]) * 0.3)
        return {k: bump(v) for k, v in t.items()}
    return t
params = bump(params)

engine = ServeEngine(params, cfg, max_batch=4, max_seq=128,
                     prefill_buckets=(16, 64))
rng = np.random.default_rng(0)
t0 = time.perf_counter()
for i in range(12):
    engine.submit(Request(
        rid=i,
        prompt=list(rng.integers(0, cfg.vocab_size, int(rng.integers(4, 16)))),
        slot_id=i % 2,                    # the reg0 analogue
        max_new_tokens=8,
    ))
finished = engine.run_until_done()
dt = time.perf_counter() - t0

tokens = sum(len(f.output) for f in finished)
print(f"served {len(finished)} requests / {tokens} tokens in {dt:.2f}s "
      f"({engine.ticks} engine ticks)")
by_slot = {0: [], 1: []}
for f in sorted(finished, key=lambda f: f.rid):
    by_slot[f.rid % 2].append(tuple(f.output[:4]))
    print(f"  rid={f.rid} slot={f.rid % 2} out={f.output}")
print("\ndistinct slot behaviors on the shared engine:",
      set(by_slot[0]) != set(by_slot[1]))
