"""Train a small LM end-to-end with the full production loop: AdamW,
microbatched grad accumulation, checkpointing, preemption-safe resume.

Run:  PYTHONPATH=src python examples/train_lm.py  (~2 min on CPU)
"""

import tempfile

from repro.configs.registry import get_config
from repro.data.tokens import SyntheticTokens, TokenPipelineConfig
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import OptimizerConfig

cfg = get_config("smollm-360m").reduced(
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=1024, remat="none")
print(f"model: {cfg.name}, {cfg.param_count()/1e6:.2f}M params")

opt = OptimizerConfig(learning_rate=1e-3, warmup_steps=20, total_steps=300)
data = SyntheticTokens(TokenPipelineConfig(
    vocab_size=cfg.vocab_size, seq_len=64, global_batch=16))

with tempfile.TemporaryDirectory() as ckpt_dir:
    trainer = Trainer(
        cfg, opt,
        TrainerConfig(total_steps=300, checkpoint_every=100, log_every=25,
                      checkpoint_dir=ckpt_dir, num_microbatches=2),
        data,
    )
    out = trainer.run()
    print(out)
    first, last = trainer.metrics_log[0], trainer.metrics_log[-1]
    print(f"loss: {first['loss']:.3f} (step {first['step']}) -> "
          f"{last['loss']:.3f} (step {last['step']})")
    assert last["loss"] < first["loss"], "training did not reduce loss"
