"""Zero-copy model switching (DESIGN.md §14): DoubleBufferedBank
staging/flip/rollback semantics, the kernel-level (2K,...) double-bank
view, SlotCache LRU/pinning/prefetch, and the property that any
swap/traffic interleaving under the cache yields verdicts bit-identical
to the re-staging commit path with zero wrong-verdict packets."""

import jax
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.control import CacheError, SlotCache, SlotMixPrefetcher, SwapSlot
from repro.core import bank as bank_lib, executor, packet as pkt
from repro.dataplane import DataplaneRuntime
from repro.kernels.banked_matmul import (banked_matmul, flip_slots,
                                         stack_double_bank)


@pytest.fixture(scope="module")
def bank4():
    return executor.init_bank(jax.random.PRNGKey(0), 4)


@pytest.fixture(scope="module")
def params_pool():
    return [executor.init_params(jax.random.PRNGKey(100 + i))
            for i in range(6)]


def banks_equal(a, b) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def host_copy(tree):
    return jax.tree_util.tree_map(lambda l: np.asarray(l).copy(), tree)


# ---------------------------------------------------------------------------
# DoubleBufferedBank unit semantics
# ---------------------------------------------------------------------------

def test_stage_commit_matches_update_slot(bank4, params_pool):
    dbb = bank_lib.DoubleBufferedBank(bank4)
    assert dbb.stage(2, params_pool[0], token="t", epoch=1)
    assert dbb.has_staged
    new = dbb.commit()
    assert not dbb.has_staged and dbb.committed("t")
    assert banks_equal(new, bank_lib.update_slot(bank4, 2, params_pool[0]))


def test_sequential_swaps_resync_dirty_slots(bank4, params_pool):
    """The second flip's demoted buffer is dirty at the first swap's
    slot; stage() must resync it so only the staged slot differs."""
    dbb = bank_lib.DoubleBufferedBank(bank4)
    dbb.stage(1, params_pool[0], token="a", epoch=1)
    dbb.commit()
    dbb.stage(3, params_pool[1], token="b", epoch=2)
    new = dbb.commit()
    want = bank_lib.update_slot(
        bank_lib.update_slot(bank4, 1, params_pool[0]), 3, params_pool[1])
    assert banks_equal(new, want)


def test_one_staged_epoch_policy(bank4, params_pool):
    dbb = bank_lib.DoubleBufferedBank(bank4)
    assert dbb.stage(0, params_pool[0], token="a", epoch=1)
    # a different epoch scope is refused without force
    assert not dbb.stage(1, params_pool[1], token="b", epoch=2)
    # apply-time wins: force discards the earlier staged entry
    assert dbb.stage(1, params_pool[1], token="b", epoch=2, force=True)
    new = dbb.commit()
    assert banks_equal(new, bank_lib.update_slot(bank4, 1, params_pool[1]))
    assert dbb.committed("b") and not dbb.committed("a")


def test_mark_restore_rolls_back_a_flip(bank4, params_pool):
    dbb = bank_lib.DoubleBufferedBank(bank4)
    before = host_copy(dbb.active)
    m = dbb.mark()
    dbb.stage(2, params_pool[0], token="x", epoch=1)
    dbb.commit()
    dbb.restore(m)
    dbb.discard_staged()
    assert banks_equal(dbb.active, before)
    # the buffer dirtied by the rollback is resynced on the next stage
    dbb.stage(0, params_pool[1], token="y", epoch=2)
    assert banks_equal(dbb.commit(),
                       bank_lib.update_slot(bank4, 0, params_pool[1]))


def test_pin_forces_copy_on_write(bank4, params_pool):
    """A pinned buffer that becomes the staging shadow after a flip must
    be un-aliased, not mutated — its holder (the megastep window) may
    still read it."""
    dbb = bank_lib.DoubleBufferedBank(bank4)
    handle = dbb.pin_active()
    snapshot = host_copy(handle.tree)
    dbb.stage(1, params_pool[0], token="a", epoch=1)
    dbb.commit()                       # pinned buffer is now the shadow
    dbb.stage(2, params_pool[1], token="b", epoch=2)
    dbb.commit()
    assert banks_equal(handle.tree, snapshot)
    assert dbb.unalias_copies >= 1
    dbb.unpin(handle)


def test_runtime_flip_equals_restage(bank4, params_pool):
    banks = {}
    for db in (True, False):
        rt = DataplaneRuntime(bank4, num_queues=2, strategy="take",
                              batch=32, double_buffer=db)
        rt.control.submit(SwapSlot(1, params_pool[0]))
        rt.flush_control()
        banks[db] = rt.bank
    assert banks_equal(banks[True], banks[False])
    assert banks_equal(banks[True],
                       bank_lib.update_slot(bank4, 1, params_pool[0]))


# ---------------------------------------------------------------------------
# kernel-level (2K, ...) double-bank view
# ---------------------------------------------------------------------------

def test_stack_double_bank_flip_selects_halves():
    key = jax.random.PRNGKey(3)
    k, d, h, bsz, bb = 3, 16, 8, 64, 16
    kf, kb, kx = jax.random.split(key, 3)
    wf = jax.random.normal(kf, (k, d, h), np.float32)
    bf = jax.random.normal(kf, (k, h), np.float32)
    wb = jax.random.normal(kb, (k, d, h), np.float32)
    bb_ = jax.random.normal(kb, (k, h), np.float32)
    x = jax.random.normal(kx, (bsz, d), np.float32)
    slots = np.asarray([0, 2, 1, 0], np.int32)
    both_w = stack_double_bank(wf, wb)
    both_b = stack_double_bank(bf, bb_)
    assert both_w.shape == (2 * k, d, h)
    for active, (w, b) in enumerate(((wf, bf), (wb, bb_))):
        want = banked_matmul(x, w, b, slots, block_b=bb, interpret=True)
        got = banked_matmul(x, both_w, both_b,
                            flip_slots(slots, active, k),
                            block_b=bb, interpret=True)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_double_buffered_forward_equivalence(bank4):
    from repro.kernels.fused_forward import (double_buffered_forward,
                                             fused_forward)
    back = executor.init_bank(jax.random.PRNGKey(9), 4)
    rng = np.random.default_rng(5)
    w_words = bank4["w1p"].shape[-1]
    x = rng.integers(0, 2**32, (64, w_words), dtype=np.uint32)
    slots = np.asarray([1, 3], np.int32)
    for active, src in ((0, bank4), (1, back)):
        want = fused_forward(x, src["w1p"], src["b1"], src["w2"],
                             src["b2"], slots, block_b=32, interpret=True)
        got = double_buffered_forward(x, bank4, back, active, slots,
                                      block_b=32, interpret=True)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


# ---------------------------------------------------------------------------
# SlotCache: LRU, pinning, prefetch
# ---------------------------------------------------------------------------

def _cache_rt(num_slots=2, **kw):
    bank = executor.init_bank(jax.random.PRNGKey(1), num_slots)
    kw.setdefault("num_queues", 2)
    kw.setdefault("strategy", "take")
    kw.setdefault("batch", 32)
    return DataplaneRuntime(bank, **kw)


def _registered_cache(rt, n_models):
    cache = SlotCache(rt)
    for i in range(n_models):
        cache.register(f"m{i}", executor.init_params(
            jax.random.PRNGKey(50 + i)))
    return cache


def test_cache_lru_eviction_order():
    rt = _cache_rt(2)
    cache = _registered_cache(rt, 4)
    s0 = cache.ensure("m0")
    s1 = cache.ensure("m1")
    assert {s0, s1} == {0, 1} and cache.misses == 2
    assert cache.ensure("m0") == s0 and cache.hits == 1
    # m1 is now least-recently used -> m2 takes its slot
    assert cache.ensure("m2") == s1
    assert not cache.is_resident("m1") and cache.evictions == 1
    rt.flush_control()
    aud = rt.audit_conservation()
    assert aud["ok"] and aud["wrong_verdict"] == 0


def test_evict_pinned_slot_rejected():
    rt = _cache_rt(2)
    cache = _registered_cache(rt, 4)
    cache.ensure("m0")
    cache.ensure("m1")
    cache.pin("m0")
    with pytest.raises(CacheError):
        cache.evict("m0")
    cache.pin("m1")
    with pytest.raises(CacheError):   # miss with every slot pinned
        cache.ensure("m2")
    cache.unpin("m1")
    assert cache.ensure("m2") == 1    # m1's slot, the only evictable one
    cache.unpin("m0")
    assert cache.evict("m0") == 0
    with pytest.raises(CacheError):
        cache.evict("m0")             # no longer resident


def test_prefetch_promotes_to_flip_only_miss():
    rt = _cache_rt(2)
    cache = _registered_cache(rt, 4)
    cache.ensure("m0")
    cache.ensure("m1")
    rt.flush_control()                      # commit the fills; shadow free
    assert cache.prefetch("m2") is True     # staged into the shadow
    reserved_slot = cache._prefetched["m2"][0]
    assert cache.ensure("m2") == reserved_slot
    assert cache.prefetch_hits == 1
    rt.flush_control()
    assert banks_equal(
        bank_lib.select_slot(rt.bank, reserved_slot),
        cache._models["m2"])


def test_prefetcher_predicts_periodic_demand():
    rt = _cache_rt(2)
    cache = _registered_cache(rt, 3)
    pf = SlotMixPrefetcher(cache, horizon=8)
    for m in ("m0", "m1", "m2", "m0", "m1", "m2", "m0"):
        cache.ensure(m)
    rt.flush_control()        # commit pending swaps; shadow free to stage
    issued = pf.poll()
    # m1/m2 are the non-resident models with a learned period; the one
    # due back soonest is pre-staged before its miss arrives
    assert issued and issued[0] in ("m1", "m2")
    assert cache.prefetch_issued >= 1


# ---------------------------------------------------------------------------
# property: cache churn is bit-identical across flip vs re-stage commits
# ---------------------------------------------------------------------------

_OP = st.sampled_from(["dispatch", "tick", "ensure", "prefetch", "pinflip"])


def _drive(ops, seed, bank4, params_pool, double_buffer):
    rng = np.random.default_rng(seed)
    rt = DataplaneRuntime(bank4, num_queues=2, strategy="take", batch=32,
                          ring_capacity=4096, record=True, audit=True,
                          double_buffer=double_buffer)
    cache = SlotCache(rt)
    names = [f"m{i}" for i in range(len(params_pool))]
    for n, p in zip(names, params_pool):
        cache.register(n, p)
    pinned = None
    for op in ops:
        if op == "dispatch":
            burst = pkt.make_packets(
                rng.integers(0, 4, 16),
                rng.integers(0, 2**32, (16, pkt.PAYLOAD_WORDS),
                             dtype=np.uint32))
            rt.dispatch(burst)
        elif op == "tick":
            rt.tick()
        elif op == "ensure":
            try:
                cache.ensure(names[rng.integers(len(names))])
            except CacheError:
                pass                      # every slot pinned: rejected
        elif op == "prefetch":
            cache.prefetch(names[rng.integers(len(names))])
        elif op == "pinflip":
            m = names[rng.integers(len(names))]
            if pinned == m:
                cache.unpin(m)
                pinned = None
            elif pinned is None and cache.is_resident(m):
                cache.pin(m)
                pinned = m
    rt.drain()
    aud = rt.audit_conservation()
    assert aud["ok"] and aud["wrong_verdict"] == 0, aud
    stats = cache.stats()
    # prefetch_hits counts actual shadow staging, which only exists on
    # the double-buffered stack — every packet-observable quantity and
    # the hit/miss/eviction economics must still match exactly
    stats.pop("prefetch_hits")
    return (rt.completed_seq, rt.completed_verdicts, rt.completed_slots,
            [cache.model_at(i) for i in range(rt.num_slots)],
            stats)


@settings(max_examples=8, deadline=None)
@given(st.lists(_OP, min_size=4, max_size=20), st.integers(0, 2**31))
def test_cache_interleaving_flip_equals_restage(ops, seed, bank4,
                                                params_pool):
    """Any interleaving of traffic with cache hits, misses, evictions,
    prefetches, and pin churn scores every packet bit-identically
    whether swaps commit by pointer flip or by re-staging — and neither
    path ever produces a wrong verdict."""
    flip = _drive(ops, seed, bank4, params_pool, double_buffer=True)
    restage = _drive(ops, seed, bank4, params_pool, double_buffer=False)
    assert flip == restage
