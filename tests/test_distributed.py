"""Distribution layer: sharding rules, legalization, multi-device subprocess
tests (compressed psum, sharded train step)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_config
from repro.distributed import roofline as rf
from repro.distributed import sharding as sh
from repro.launch import specs as specs_lib
from repro.train import optimizer as opt_lib

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _subproc(body: str, devices: int = 8) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import sys; sys.path.insert(0, {repr(SRC)})
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_param_rules_cover_all_archs():
    """Every parameter leaf of every arch matches a rule (or is replicated
    deliberately); matrices bigger than 1M params must not silently
    replicate."""
    rules = sh.ShardingRules(tp_axis="model", fsdp_axis=None, dp_axes=("data",))
    for arch in ("smollm-360m", "olmoe-1b-7b", "zamba2-7b",
                 "seamless-m4t-medium", "mamba2-130m", "llava-next-34b"):
        cfg = get_config(arch)
        params = specs_lib.param_shape_specs(cfg)
        specs = sh.param_specs(params, rules)
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        sflat = jax.tree_util.tree_structure(params).flatten_up_to(specs)
        for (path, leaf), spec in zip(flat, sflat):
            n = int(np.prod(leaf.shape))
            if n > 4_000_000:
                assert any(e is not None for e in spec), \
                    f"{arch}: {sh._path_str(path)} ({n} params) replicated"


def test_legalize_drops_indivisible():
    mesh = jax.make_mesh((1,), ("model",))  # 1 device: everything divisible
    # synthetic: mesh with model=16 can't shard dim of 15
    import unittest.mock as mock
    fake_mesh = mock.Mock()
    fake_mesh.axis_names = ("model",)
    fake_mesh.devices = np.empty((16,))
    spec_tree = {"w": P(None, "model")}
    shapes = {"w": jax.ShapeDtypeStruct((4, 15), jnp.float32)}
    legal, dropped = sh.legalize(spec_tree, shapes, fake_mesh)
    assert legal["w"] == P(None, None)
    assert len(dropped) == 1


def test_compressed_psum_matches_exact():
    out = _subproc("""
        from repro.distributed.compress import compressed_psum
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
        exact = x * 4  # psum over data of replicated x = 4x
        got = compressed_psum(x, mesh, "data")
        rel = float(jnp.abs(got - exact).max() / jnp.abs(exact).max())
        assert rel < 0.02, rel
        print("PSUM_OK", rel)
    """)
    assert "PSUM_OK" in out


@pytest.mark.slow
def test_sharded_train_step_multidevice():
    """Small sharded train step on a 4x2 mesh runs and is finite."""
    out = _subproc("""
        from repro.configs.registry import get_config
        from repro.distributed import sharding as sh
        from repro.train import optimizer as opt_lib, train_step as ts_lib
        from jax.sharding import PartitionSpec as P
        cfg = get_config("smollm-360m").reduced(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab_size=256, vocab_pad_multiple=32,
            dtype="float32", remat="none")
        opt_cfg = opt_lib.OptimizerConfig(warmup_steps=0, total_steps=5)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = sh.ShardingRules(tp_axis="model", fsdp_axis=None,
                                 dp_axes=("data",))
        state = ts_lib.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
        pspecs, _ = sh.legalize(sh.param_specs(state["params"], rules),
                                state["params"], mesh)
        sspecs = {"params": pspecs,
                  "opt": sh.opt_state_specs(pspecs, state["opt"]),
                  "step": P()}
        batch = {
            "tokens": jnp.zeros((8, 16), jnp.int32),
            "labels": jnp.zeros((8, 16), jnp.int32),
            "loss_mask": jnp.ones((8, 16), jnp.float32),
        }
        bspecs, _ = sh.legalize(sh.batch_specs(batch, rules), batch, mesh)
        step = jax.jit(ts_lib.make_train_step(cfg, opt_cfg),
                       in_shardings=(sh.named(mesh, sspecs),
                                     sh.named(mesh, bspecs)),
                       donate_argnums=(0,))
        with mesh:
            state = jax.device_put(state, sh.named(mesh, sspecs))
            batch = jax.device_put(batch, sh.named(mesh, bspecs))
            state, metrics = step(state, batch)
        loss = float(metrics["loss"])
        assert np.isfinite(loss)
        print("SHARDED_OK", loss)
    """)
    assert "SHARDED_OK" in out


def test_roofline_analyzer_counts_loops():
    """The loop-aware analyzer must multiply while bodies by trip count."""
    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)
    compiled = jax.jit(scanned).lower(x, ws).compile()
    res = rf.analyze(compiled.as_text())
    want = 2 * 64 * 64 * 64 * 12
    assert abs(res["dot_flops"] - want) / want < 0.05, res["dot_flops"]
    # and the body-once xla number really is ~12x smaller
    xla = rf.xla_cost_analysis(compiled)["flops"]
    assert res["dot_flops"] > 8 * xla


def test_roofline_terms_and_dominance():
    a = {"dot_flops": 197e12, "hbm_bytes": 819e9 / 2,
         "collective_bytes": {}, "collective_bytes_total": 50e9 * 2}
    t = rf.roofline_terms(a)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(0.5)
    assert t["collective_s"] == pytest.approx(2.0)
    assert t["dominant"] == "collective"
