"""Serving engine: greedy-exactness vs no-cache reference, slot routing,
deadline rejection."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import api
from repro.serve.engine import Finished, Request, ServeEngine


def _greedy_ref(params, cfg, prompt, n_new, slot=None):
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        batch = {"tokens": jnp.asarray([toks])}
        if slot is not None:
            batch["slot_ids"] = jnp.asarray([slot], jnp.int32)
        logits, _ = api.apply(params, batch, cfg)
        t = int(jnp.argmax(logits[0, -1]))
        toks.append(t)
        out.append(t)
    return out


@pytest.mark.parametrize("arch", ["smollm-360m", "mamba2-130m", "zamba2-7b"])
def test_engine_matches_reference(arch, rng):
    cfg = get_config(arch).reduced(bank_mode="none", remat="none",
                                   dtype="float32")
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompts = [list(rng.integers(0, cfg.vocab_size, int(n)))
               for n in (5, 9, 17)]
    eng = ServeEngine(params, cfg, max_batch=4, max_seq=64,
                      prefill_buckets=(8, 32))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    fins = eng.run_until_done()
    assert len(fins) == 3
    for f in fins:
        assert f.output == _greedy_ref(params, cfg, prompts[f.rid], 5), f.rid


def test_engine_moe_with_ample_capacity(rng):
    cfg = get_config("olmoe-1b-7b").reduced(
        bank_mode="none", remat="none", dtype="float32",
        moe_capacity_factor=16.0)
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in (6, 11)]
    eng = ServeEngine(params, cfg, max_batch=2, max_seq=64,
                      prefill_buckets=(16,))
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    for f in eng.run_until_done():
        assert f.output == _greedy_ref(params, cfg, prompts[f.rid], 4)


def test_slot_routing_changes_behavior(rng):
    """The paper's property at LLM scale: same prompt, different slot ->
    different output, same engine, same compiled step."""
    cfg = get_config("smollm-360m").reduced(bank_mode="adapter", bank_slots=2,
                                            remat="none", dtype="float32")
    params = api.init(jax.random.PRNGKey(0), cfg)
    # make the banked adapters actually differ (b init is zeros)
    params = jax.tree_util.tree_map(lambda x: x, params)

    def bump(p):
        if isinstance(p, dict) and "a" in p and "b" in p:
            p["b"] = p["b"].at[1].set(
                jax.random.normal(jax.random.PRNGKey(7), p["b"].shape[1:]) * 0.5)
        return p
    def walk(t):
        if isinstance(t, dict):
            return bump({k: walk(v) for k, v in t.items()})
        return t
    params = walk(params)

    prompt = list(rng.integers(0, cfg.vocab_size, 8))
    outs = {}
    eng = ServeEngine(params, cfg, max_batch=2, max_seq=64,
                      prefill_buckets=(8,))
    eng.submit(Request(rid=0, prompt=prompt, slot_id=0, max_new_tokens=6))
    eng.submit(Request(rid=1, prompt=prompt, slot_id=1, max_new_tokens=6))
    for f in eng.run_until_done():
        outs[f.rid] = f.output
    assert outs[0] != outs[1], "slots did not induce distinct behaviors"
    # and each matches its per-slot reference
    assert outs[0] == _greedy_ref(params, cfg, prompt, 6, slot=0)
    assert outs[1] == _greedy_ref(params, cfg, prompt, 6, slot=1)


def test_deadline_rejection(rng):
    cfg = get_config("smollm-360m").reduced(bank_mode="none", remat="none",
                                            dtype="float32")
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, max_batch=2, max_seq=64,
                      prefill_buckets=(8,))
    past = time.monotonic() - 1.0
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=4,
                       deadline_s=past))
    eng.submit(Request(rid=1, prompt=[1, 2, 3], max_new_tokens=4))
    fins = eng.run_until_done()
    by_rid = {f.rid: f for f in fins}
    assert by_rid[0].rejected and not by_rid[1].rejected
    assert eng.rejected_count == 1
    assert len(by_rid[1].output) == 4
