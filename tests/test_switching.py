"""Switching harnesses: boundary continuity (Table IV semantics) and the
control-plane replacement baseline (Table V semantics)."""

import jax
import numpy as np
import pytest

from repro.core import bank as bank_lib
from repro.core import executor, packet as pkt, switching


@pytest.fixture(scope="module")
def setup():
    bank = executor.init_bank(jax.random.PRNGKey(0), 2)
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 2**32, (256, pkt.PAYLOAD_WORDS), dtype=np.uint32)
    return bank, payload


def test_boundary_trace_structure(setup):
    _, payload = setup
    tr = switching.boundary_trace(64, payload)
    assert (tr[:32, pkt.SLOT_WORD] == 0).all()
    assert (tr[32:, pkt.SLOT_WORD] == 1).all()


def test_replay_zero_wrong_verdicts(setup):
    """Paper: online switching completes with zero wrong-slot and zero
    wrong-verdict packets (64-packet deterministic stream)."""
    bank, payload = setup
    tr = switching.boundary_trace(64, payload[:64])
    res = switching.replay_trace(bank, tr, num_slots=2)
    assert res.wrong_slot == 0
    assert res.wrong_verdict == 0
    assert res.boundary_index == 32
    g = res.gap_stats_us()
    assert np.isfinite(g["median_gap_us"]) and np.isfinite(g["boundary_gap_us"])


def test_access_traces(setup):
    for kind in ("fixed", "round_robin", "random", "hotspot"):
        tr = switching.access_trace(kind, 128, 16)
        assert tr.shape == (128,)
        assert tr.min() >= 0 and tr.max() < 16
    assert (switching.access_trace("fixed", 64, 16) == 0).all()
    rr = switching.access_trace("round_robin", 64, 16)
    assert (rr == np.arange(64) % 16).all()
    hot = switching.access_trace("hotspot", 1000, 16)
    assert (hot == 0).mean() > 0.8


def test_control_plane_produces_wrong_window(setup):
    """The heavyweight baseline must show a non-zero stale-model window."""
    bank, payload = setup
    slot0 = bank_lib.select_slot(bank, 0)
    slot1 = bank_lib.select_slot(bank, 1)
    slot0 = {k: np.asarray(v) for k, v in slot0.items()}
    slot1 = {k: np.asarray(v) for k, v in slot1.items()}
    tr = switching.boundary_trace(128, payload[:128])
    res = switching.control_plane_replay(slot0, slot1, tr, pacing_us=50.0)
    assert res.switch_latency_us > 1.0          # update >> resident switch
    assert res.wrong_model_packets > 0          # stale window exists
    assert res.boundary_to_effective_us >= res.switch_latency_us * 0.5
    assert res.wrong_verdict_packets <= res.wrong_model_packets


def test_resident_switch_cost_is_small(setup):
    bank, payload = setup
    tr = switching.boundary_trace(256, payload)
    cost = switching.resident_switch_cost_us(bank, tr, num_slots=2, iters=50)
    # per-packet slot resolution must be far below one inference (~us scale)
    assert cost < 5.0
