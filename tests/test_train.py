"""Training substrate: optimizer math, microbatch equivalence, compression,
loop fault-tolerance semantics."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs.registry import get_config
from repro.data.tokens import SyntheticTokens, TokenPipelineConfig
from repro.distributed import compress
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts_lib
from repro.train.loop import StragglerMonitor, Trainer, TrainerConfig


def test_adamw_converges_quadratic():
    cfg = opt_lib.OptimizerConfig(learning_rate=0.1, warmup_steps=0,
                                  total_steps=200, weight_decay=0.0)
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = opt_lib.adamw_init(params, cfg)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = opt_lib.adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 1e-3


def test_lr_schedule_shape():
    cfg = opt_lib.OptimizerConfig(learning_rate=1.0, warmup_steps=10,
                                  total_steps=100, min_lr_ratio=0.1)
    lrs = [float(opt_lib.lr_schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0 and abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert 0.1 < lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-6  # floor


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
    assert abs(float(opt_lib.global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) == pytest.approx(200.0)


def test_microbatch_equivalence(rng):
    """1 vs 4 microbatches produce (near-)identical updates."""
    cfg = get_config("smollm-360m").reduced(n_layers=2, dtype="float32",
                                            remat="none")
    opt_cfg = opt_lib.OptimizerConfig(warmup_steps=0, total_steps=10)
    state0 = ts_lib.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16))),
        "loss_mask": jnp.ones((8, 16), jnp.float32),
    }
    s1, m1 = jax.jit(ts_lib.make_train_step(cfg, opt_cfg))(state0, batch)
    state0b = ts_lib.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    s4, m4 = jax.jit(ts_lib.make_train_step(cfg, opt_cfg,
                                            num_microbatches=4))(state0b, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-4)
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        s1["params"], s4["params"])
    assert max(jax.tree_util.tree_leaves(d)) < 5e-4


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10**6), st.floats(0.1, 1e4))
def test_quantize_roundtrip_error_bound(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    y = compress.quantize_dequantize(x)
    amax = float(jnp.abs(x).max())
    assert float(jnp.abs(x - y).max()) <= amax / 127.0 + 1e-6


def test_compressed_train_step_close_to_exact(rng):
    cfg = get_config("smollm-360m").reduced(n_layers=2, dtype="float32",
                                            remat="none")
    opt_cfg = opt_lib.OptimizerConfig(warmup_steps=0, total_steps=10)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16))),
        "loss_mask": jnp.ones((4, 16), jnp.float32),
    }
    s_exact, _ = jax.jit(ts_lib.make_train_step(cfg, opt_cfg))(
        ts_lib.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg), batch)
    s_comp, _ = jax.jit(ts_lib.make_train_step(
        cfg, opt_cfg, compress_gradients=True))(
        ts_lib.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg), batch)
    rel = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()
                           / (jnp.abs(a.astype(jnp.float32)).max() + 1e-9)),
        s_exact["params"], s_comp["params"])
    assert max(jax.tree_util.tree_leaves(rel)) < 0.2


def test_trainer_preemption_resume_exact():
    cfg = get_config("smollm-360m").reduced(n_layers=1, dtype="float32",
                                            remat="none")
    opt_cfg = opt_lib.OptimizerConfig(warmup_steps=0, total_steps=50,
                                      learning_rate=1e-3)
    dcfg = TokenPipelineConfig(vocab_size=cfg.vocab_size, seq_len=16,
                               global_batch=4)
    with tempfile.TemporaryDirectory() as d:
        tc = lambda n: TrainerConfig(total_steps=n, checkpoint_every=100,
                                     checkpoint_dir=d)
        # uninterrupted run to 8
        t_full = Trainer(cfg, opt_cfg, tc(8), SyntheticTokens(dcfg))
        t_full.run()
        full_params = t_full.state["params"]
        # preempted at 4, resumed to 8
        with tempfile.TemporaryDirectory() as d2:
            tc2 = lambda n: TrainerConfig(total_steps=n, checkpoint_every=100,
                                          checkpoint_dir=d2)
            t1 = Trainer(cfg, opt_cfg, tc2(4), SyntheticTokens(dcfg))
            t1.run()
            t2 = Trainer(cfg, opt_cfg, tc2(8), SyntheticTokens(dcfg))
            assert t2.try_restore()
            assert int(t2.state["step"]) == 4 and t2.data.cursor == 4
            t2.run()
            diff = jax.tree_util.tree_map(
                lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)).max()),
                full_params, t2.state["params"])
            assert max(jax.tree_util.tree_leaves(diff)) < 1e-5


def test_straggler_monitor():
    mon = StragglerMonitor(n_hosts=4, factor=1.5)
    for _ in range(5):
        flagged = mon.observe(np.asarray([1.0, 1.0, 1.0, 5.0]))
    assert flagged == [3]
    plan = mon.reassignment_plan(flagged, n_shards=4)
    assert 3 in plan and plan[3] != 3
