"""Fault-tolerant epoch barriers (DESIGN.md §10): host-health leases,
degraded quorum commit, typed fault injection, bounded epoch logs, and
the property that every fault class ends in exactly one of {atomic
commit, atomic rollback, degraded quorum commit + failover epoch} with
conservation and zero wrong verdicts intact."""

import jax
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.control import (API_VERSION, HealthMonitor, NonFatalControlError,
                           SwapSlot, load_epoch_spill)
from repro.core import executor
from repro.dataplane import (DataplaneRuntime, MeshDataplane, Phase, faults,
                             render, scenarios, workloads)

LEASE = 4


@pytest.fixture(scope="module")
def bank2():
    return executor.init_bank(jax.random.PRNGKey(0), 2)


def small_phases(total_queues=4, ticks=10, burst=64):
    return [Phase("drive", ticks=ticks, burst=burst, flows=16,
                  slot_mix=(0.5, 0.5))]


def make_mesh(bank, *, hosts=2, num_queues=2, plan=None, **kw):
    kw.setdefault("strategy", "take")
    kw.setdefault("batch", 32)
    kw.setdefault("ring_capacity", 4096)
    kw.setdefault("lease_ticks", LEASE)
    if plan is not None:
        kw.setdefault("fault_injector", faults.FaultInjector(plan))
    return MeshDataplane(bank, hosts=hosts, num_queues=num_queues, **kw)


def drive(mesh, *, ticks=14, swap_every=3, seed=3, burst=64):
    """Dispatch + tick with a SwapSlot epoch every ``swap_every`` ticks."""
    total = mesh.hosts * mesh.num_queues_per_host
    trace = render(small_phases(total, ticks=ticks, burst=burst),
                   num_slots=2, seed=seed, num_queues=total)
    for t, b in enumerate(trace.bursts[0]):
        if swap_every and t % swap_every == 1:
            slot = (t // swap_every) % 2
            mesh.control.submit(
                SwapSlot(slot, scenarios.default_swap_delivery(slot)))
        mesh.dispatch(b)
        mesh.tick()
    mesh.drain()
    return trace


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------

def test_fault_plan_json_roundtrip(tmp_path):
    plan = faults.FaultPlan(
        faults=(faults.StallHost(1, 4, 3), faults.CrashHost(2, 9),
                faults.ShardError(0, 5, "stage"), faults.DropAck(1, 7, 2),
                faults.DelayRetire(1, 3, 6)),
        name="kitchen-sink", seed=7)
    path = str(tmp_path / "plan.json")
    faults.save_plan(plan, path)
    loaded = faults.load_plan(path)
    assert loaded == plan
    assert loaded.to_dict() == plan.to_dict()


def test_demo_plan_covers_every_fault_class():
    for kind in faults.FAULT_CLASSES:
        for hosts in (1, 2, 4):
            plan = faults.demo_plan(kind, hosts=hosts, lease_ticks=LEASE)
            assert plan.faults, (kind, hosts)
            # host 0 must survive whenever there is a host to fail over to
            if hosts > 1:
                assert all(f.host != 0 for f in plan.faults)
    with pytest.raises(ValueError, match="unknown fault class"):
        faults.demo_plan("nope", hosts=2)


def test_random_plan_deterministic_and_spares_host0():
    a = faults.random_plan(11, hosts=3)
    assert a == faults.random_plan(11, hosts=3)
    assert all(f.host != 0 for f in a.faults)
    crashed = [f.host for f in a.faults if isinstance(f, faults.CrashHost)]
    assert len(set(crashed)) == len(crashed) <= 2  # a survivor always exists


# ---------------------------------------------------------------------------
# health monitor state machine
# ---------------------------------------------------------------------------

def test_health_monitor_lease_lifecycle():
    hm = HealthMonitor(2, lease_ticks=4, suspect_after=2)
    alive = False
    for t in range(4):
        hm.heartbeat(0, t)
        hm.miss(1, t)
        hm.miss(1, t)                       # deduped per (host, tick)
        hm.observe(t, probe=lambda h: alive)
    assert hm.total_misses == 4
    assert [(tr.to, tr.tick) for tr in hm.transitions] == \
        [("suspect", 1), ("dead", 3)]
    assert hm.dead_hosts() == (1,) and hm.live_hosts() == (0,)
    # exponential backoff: probes at died_at+2, then +4 after a failure
    probed = []
    for t in range(4, 12):
        hm.heartbeat(0, t)
        before = hm.total_probes
        hm.observe(t, probe=lambda h: probed.append(t) or alive)
        assert hm.total_probes - before in (0, 1)
    assert probed == [5, 9]
    alive = True                            # next probe due at tick 17
    for t in range(12, 20):
        hm.heartbeat(0, t)
        hm.observe(t, probe=lambda h: alive)
        if hm.state(1).value == "recovering":
            hm.heartbeat(1, t)              # caller resyncs, host serves
    assert hm.state(1).value == "healthy"
    assert [tr.to for tr in hm.transitions] == \
        ["suspect", "dead", "recovering", "healthy"]


def test_health_monitor_miss_beats_heartbeat_same_tick():
    hm = HealthMonitor(1, lease_ticks=2, suspect_after=1)
    for t in range(2):
        hm.miss(0, t)
        hm.heartbeat(0, t)                  # ignored: miss already recorded
        hm.observe(t)
    assert hm.is_dead(0)


def test_health_monitor_validates_config():
    with pytest.raises(ValueError, match="must not exceed"):
        HealthMonitor(2, lease_ticks=2, suspect_after=3)


# ---------------------------------------------------------------------------
# barrier outcomes per fault class
# ---------------------------------------------------------------------------

def test_stall_within_lease_defers_then_commits_atomic(bank2):
    plan = faults.FaultPlan(faults=(faults.StallHost(1, 4, 2),), name="blip")
    mesh = make_mesh(bank2, plan=plan, suspect_after=3, lease_ticks=6)
    drive(mesh, ticks=12)
    cont = mesh.control.continuity_audit()
    assert cont["ok"], cont
    assert cont["commit_modes"]["degraded"] == 0
    assert cont["commit_modes"]["rollback"] == 0
    assert mesh.failover_epochs == []
    assert mesh.health.dead_hosts() == ()
    # the swap submitted during the stall waited for the straggler
    stalled_epochs = [r for r in mesh.control.log
                      if r.applied and r.applied_tick >= 4]
    assert stalled_epochs and all(r.commit_mode == "atomic"
                                  for r in stalled_epochs)


def test_lease_expiry_degrades_then_recovers(bank2):
    plan = faults.demo_plan("stall", hosts=2, lease_ticks=LEASE, at_tick=4)
    mesh = make_mesh(bank2, plan=plan)
    drive(mesh, ticks=20)
    cont = mesh.control.continuity_audit()
    assert cont["ok"], cont
    assert cont["commit_modes"]["degraded"] > 0
    assert mesh.failover_epochs and mesh.restore_epochs
    tos = [t.to for t in mesh.health.transitions]
    assert tos[:2] == ["suspect", "dead"]
    assert mesh.health.state(1).value == "healthy"       # rejoined
    aud = mesh.audit_conservation()
    assert aud["ok"], aud
    assert aud["stranded"]["packets"] == 0               # backlog resynced
    # the mesh never stalled longer than the lease on the dead host
    dead_tick = next(t.tick for t in mesh.health.transitions
                     if t.to == "dead")
    miss_start = dead_tick - mesh.health.lease_ticks
    blocked = [r for r in mesh.control.log
               if r.applied and miss_start <= r.applied_tick <= dead_tick]
    assert all(r.applied_tick - miss_start <= mesh.health.lease_ticks + 1
               for r in blocked)


def test_crash_strands_packets_and_drain_converges(bank2):
    plan = faults.demo_plan("crash", hosts=2, lease_ticks=LEASE, at_tick=5)
    mesh = make_mesh(bank2, plan=plan)
    drive(mesh, ticks=16)                   # drain() inside must terminate
    aud = mesh.audit_conservation()
    assert aud["ok"], aud
    assert aud["stranded"]["hosts"] == [1]
    assert aud["stranded"]["packets"] > 0
    t = aud["totals"]
    assert t["offered"] == (t["completed"] + t["dropped"]
                            + t["occupancy"] + t["in_flight"])
    cont = mesh.control.continuity_audit()
    assert cont["ok"], cont
    assert cont["commit_modes"]["degraded"] > 0
    assert mesh.failover_epochs and not mesh.restore_epochs
    assert mesh.health.is_dead(1)


@pytest.mark.parametrize("point", ["stage", "apply"])
def test_shard_error_rolls_back_atomically(bank2, point):
    plan = faults.FaultPlan(
        faults=(faults.ShardError(1, 4, point),), name=f"err-{point}")
    mesh = make_mesh(bank2, plan=plan)
    reta_before = mesh.reta.copy()
    drive(mesh, ticks=10)
    log = mesh.control.log
    rolled = [r for r in log if r.commit_mode == "rollback"]
    assert len(rolled) == 1
    assert "injected shard error" in rolled[0].error
    assert not rolled[0].applied and rolled[0].apply_us is None
    # the fault is non-fatal: later epochs still commit
    assert any(r.applied and r.epoch > rolled[0].epoch for r in log)
    cont = mesh.control.continuity_audit()
    assert cont["ok"], cont
    assert mesh.audit_conservation()["ok"]
    assert np.array_equal(mesh.reta, reta_before)        # nothing leaked
    assert mesh.health.dead_hosts() == ()                # not a health event


def test_drop_ack_degrades_then_restores(bank2):
    plan = faults.FaultPlan(faults=(faults.DropAck(1, 4),), name="lost-ack")
    mesh = make_mesh(bank2, plan=plan)
    drive(mesh, ticks=16)
    cont = mesh.control.continuity_audit()
    assert cont["ok"], cont
    assert cont["commit_modes"]["degraded"] >= 1
    assert mesh.failover_epochs and mesh.restore_epochs  # suspected, rejoined
    assert mesh.health.state(1).value == "healthy"
    assert [t.to for t in mesh.health.transitions][0] == "suspect"
    assert mesh.audit_conservation()["ok"]


def test_quorum_lost_rolls_back_not_commits(bank2):
    plan = faults.FaultPlan(
        faults=(faults.CrashHost(1, 3), faults.CrashHost(2, 3)),
        name="two-down")
    mesh = make_mesh(bank2, hosts=3, plan=plan)       # quorum = 2, 1 lives
    drive(mesh, ticks=14)
    log = mesh.control.log
    rolled = [r for r in log if r.commit_mode == "rollback"]
    assert rolled and all("quorum" in r.error for r in rolled)
    assert not any(r.commit_mode == "degraded" for r in log
                   if r.epoch > rolled[0].epoch)
    cont = mesh.control.continuity_audit()
    assert cont["ok"], cont
    assert mesh.audit_conservation()["ok"]
    assert sorted(mesh.audit_conservation()["stranded"]["hosts"]) == [1, 2]


# ---------------------------------------------------------------------------
# bounded epoch log
# ---------------------------------------------------------------------------

def test_log_capacity_spills_and_audit_folds_in(bank2, tmp_path):
    spill = str(tmp_path / "epochs.bswel")
    mesh = make_mesh(bank2, log_capacity=2, log_spill=spill)
    drive(mesh, ticks=14, swap_every=2)
    stats = mesh.control.stats()
    assert len(mesh.control.log) == 2
    assert stats["epochs_spilled"] >= 2
    spilled = load_epoch_spill(spill)
    assert [d["epoch"] for d in spilled] == \
        list(range(1, stats["epochs_spilled"] + 1))
    assert all(d["commit_mode"] == "atomic" for d in spilled)
    assert all("wrong_verdict_in_window" in d for d in spilled)
    cont = mesh.control.continuity_audit()
    assert cont["ok"], cont
    assert cont["spilled_epochs"] == stats["epochs_spilled"]
    assert cont["spilled_wrong_verdict"] == 0


def test_log_capacity_validates():
    with pytest.raises(ValueError, match="log_capacity"):
        DataplaneRuntime(executor.init_bank(jax.random.PRNGKey(1), 2),
                         num_queues=2, log_capacity=0)


# ---------------------------------------------------------------------------
# single-host runtime injection points
# ---------------------------------------------------------------------------

def test_single_host_stall_and_stage_error_nonfatal(bank2):
    plan = faults.FaultPlan(
        faults=(faults.StallHost(0, 3, 2), faults.ShardError(0, 7, "stage")),
        name="single")
    rt = DataplaneRuntime(bank2, num_queues=2, batch=32, ring_capacity=4096,
                          strategy="take",
                          fault_injector=faults.FaultInjector(plan))
    trace = render(small_phases(2, ticks=12), num_slots=2, seed=5,
                   num_queues=2)
    for t, b in enumerate(trace.bursts[0]):
        if t % 3 == 1:
            rt.control.submit(
                SwapSlot(t % 2, scenarios.default_swap_delivery(t % 2)))
        rt.dispatch(b)
        rt.tick()
    rt.drain()
    rolled = [r for r in rt.control.log if r.commit_mode == "rollback"]
    assert len(rolled) == 1 and "injected" in rolled[0].error
    assert rt.control.continuity_audit()["ok"]
    assert rt.audit_conservation()["ok"]


# ---------------------------------------------------------------------------
# API surface
# ---------------------------------------------------------------------------

def test_api_v3_and_commit_mode_in_records(bank2):
    assert API_VERSION == 3
    mesh = make_mesh(bank2, plan=faults.demo_plan("crash", hosts=2,
                                                  lease_ticks=LEASE))
    drive(mesh, ticks=12)
    for rec in mesh.control.command_log():
        assert rec["commit_mode"] in ("atomic", "degraded", "rollback")
    assert isinstance(NonFatalControlError("x"), Exception)
    snap = mesh.snapshot()
    assert snap["degraded_commits"] > 0
    assert snap["health"]["hosts"][1]["state"] == "dead"
    assert snap["fault_events"]


def test_faulted_trace_replays_bit_exactly(bank2, tmp_path):
    wl = workloads.make_workload("crash-mid-commit", num_slots=2,
                                 num_queues=2, hosts=2)
    rendered = render(list(wl.phases), num_slots=2, seed=9, num_queues=4)
    mesh = make_mesh(bank2, plan=wl.fault_plan, record=True, audit=True)
    rec = workloads.record(mesh)
    workloads.play(rec, rendered)
    trace = rec.finish(name=wl.name, seed=9)
    assert trace.meta["fault_plan"]["faults"]
    assert trace.meta["lease_ticks"] == LEASE
    path = str(tmp_path / "crash.bswt")
    workloads.save(trace, path)
    loaded = workloads.load(path)
    rt2 = workloads.make_runtime(loaded, bank=bank2, audit=True)
    rep = workloads.replay(loaded, rt2)
    assert rep["ok"] and rep["digest_ok"]
    assert (rt2.control.continuity_audit()["commit_modes"]
            == mesh.control.continuity_audit()["commit_modes"])
    assert (rt2.audit_conservation().get("stranded")
            == mesh.audit_conservation().get("stranded"))


# ---------------------------------------------------------------------------
# property: any random fault plan x regime keeps every invariant
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(plan_seed=st.integers(min_value=0, max_value=10_000),
       regime=st.sampled_from(["emergency", "flash-crowd", "slot-thrash"]))
def test_random_faults_preserve_invariants(bank2, plan_seed, regime):
    plan = faults.random_plan(plan_seed, hosts=2, horizon=16)
    wl = workloads.make_workload(regime, num_slots=2, num_queues=2, hosts=2)
    rendered = render(list(wl.phases), num_slots=2, seed=plan_seed,
                      num_queues=4)
    mesh = make_mesh(bank2, plan=plan, audit=True, record=True,
                     ring_capacity=256)
    workloads.play(mesh, rendered)
    aud = mesh.audit_conservation()
    assert aud["ok"], aud                   # conservation incl. stranded
    t = aud["totals"]
    assert t["offered"] == (t["completed"] + t["dropped"]
                            + t["occupancy"] + t["in_flight"])
    cont = mesh.control.continuity_audit()
    assert cont["ok"], cont                 # zero wrong verdicts anywhere
    assert cont["wrong_verdict_total"] == 0
    for e in cont["epochs"]:
        assert e["commit_mode"] in ("atomic", "degraded", "rollback")
    for shard in mesh.shards:               # per-queue FIFO survives faults
        for seqs in shard.completed_seq:
            assert (np.diff(np.asarray(seqs)) > 0).all()
