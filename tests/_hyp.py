"""Hypothesis import guard for the tier-1 suite.

``from _hyp import given, settings, st`` works whether or not hypothesis is
installed.  When it is missing, property-based tests are skipped
individually and every example-based test in the same module still collects
and runs (a bare ``pytest.importorskip("hypothesis")`` would skip whole
modules and lose that coverage).
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _StrategyExpr:
        """Inert strategy value: absorbs chained calls (``.map``,
        ``.filter``, ...).  Nothing is ever drawn — the test skips."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: _StrategyExpr()

        def __call__(self, *args, **kwargs):
            return _StrategyExpr()

    class _StrategyStub:
        """Stands in for ``hypothesis.strategies``."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: _StrategyExpr()

    st = _StrategyStub()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        return lambda fn: fn
