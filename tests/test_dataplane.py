"""Multi-queue dataplane: RSS determinism, ring/runtime packet
conservation, per-queue ordering, fan-out parity, and zero-wrong-verdict
continuity across online slot swaps (DESIGN.md §6)."""

import jax
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import bank as bank_lib, executor, packet as pkt, switching
from repro.dataplane import (DataplaneRuntime, PacketRing, Phase,
                             emergency_phases, play, render, rss, scenarios)


@pytest.fixture(scope="module")
def bank2():
    return executor.init_bank(jax.random.PRNGKey(0), 2)


def small_phases(num_slots=2):
    """A fast 3-phase scenario exercising backpressure, failover and churn."""
    uniform = tuple(1.0 / num_slots for _ in range(num_slots))
    return [
        Phase("steady", ticks=2, burst=64, flows=16, slot_mix=uniform),
        Phase("crowd", ticks=2, burst=192, flows=4, slot_mix=uniform),
        Phase("churn", ticks=2, burst=64, flows=16, slot_mix=uniform,
              failed_queues=(0,), swap_slot=1),
    ]


def small_trace(num_slots=2, seed=0):
    return render(small_phases(num_slots), num_slots=num_slots, seed=seed)


# ---------------------------------------------------------------------------
# RSS dispatch
# ---------------------------------------------------------------------------

def _toeplitz_naive(words, key=rss.DEFAULT_KEY):
    """Independent per-bit reference implementation."""
    data = b"".join(int(w).to_bytes(4, "big") for w in words)
    keyval = int.from_bytes(key, "big")
    kbits = len(key) * 8
    out = 0
    for i, byte in enumerate(data):
        for b in range(8):
            if byte & (0x80 >> b):
                j = i * 8 + b
                out ^= (keyval >> (kbits - 32 - j)) & 0xFFFFFFFF
    return out


def test_toeplitz_matches_reference(rng):
    fw = rng.integers(0, 2**32, (32, rss.FLOW_WORDS), dtype=np.uint32)
    h = rss.toeplitz_hash(fw)
    for i in range(fw.shape[0]):
        assert int(h[i]) == _toeplitz_naive(fw[i])


def test_rss_deterministic_and_flow_affine(rng):
    fw = rng.integers(0, 2**32, (256, rss.FLOW_WORDS), dtype=np.uint32)
    pkts = pkt.make_packets(
        np.zeros(256, np.int64),
        rng.integers(0, 2**32, (256, pkt.PAYLOAD_WORDS), dtype=np.uint32))
    pkts[:, rss.FLOW_WORD_LO : rss.FLOW_WORD_LO + rss.FLOW_WORDS] = fw
    q1 = rss.queue_of(pkts, 4)
    q2 = rss.queue_of(pkts, 4)
    assert (q1 == q2).all()                     # stable across calls
    assert q1.min() >= 0 and q1.max() < 4
    assert len(np.unique(q1)) > 1               # flows actually spread
    # queue depends ONLY on the flow tuple: rewrite slot/payload words
    pkts2 = pkts.copy()
    pkts2[:, pkt.SLOT_WORD] = 1
    pkts2[:, pkt.META_WORDS :] = 0
    assert (rss.queue_of(pkts2, 4) == q1).all()
    # two packets sharing a flow tuple share a queue
    pkts3 = pkts.copy()
    pkts3[:, rss.FLOW_WORD_LO : rss.FLOW_WORD_LO + rss.FLOW_WORDS] = fw[0]
    assert len(np.unique(rss.queue_of(pkts3, 4))) == 1
    # non-power-of-two RETA: every bucket stays reachable (modulo, not mask)
    reta96 = np.arange(96, dtype=np.int32) % 4
    q96 = rss.queue_of(pkts, 4, reta=reta96)
    assert q96.min() >= 0 and q96.max() < 4
    h = rss.toeplitz_hash(fw)
    assert (q96 == reta96[h % np.uint32(96)]).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31), st.integers(1, 8))
def test_rss_property_stable_in_range(seed, num_queues):
    rng = np.random.default_rng(seed)
    fw = rng.integers(0, 2**32, (64, rss.FLOW_WORDS), dtype=np.uint32)
    h = rss.toeplitz_hash(fw)
    assert (h == rss.toeplitz_hash(fw.copy())).all()
    reta = rss.indirection_table(num_queues)
    q = reta[h & np.uint32(rss.RETA_SIZE - 1)]
    assert q.min() >= 0 and q.max() < num_queues


def test_failover_table_moves_only_dead_buckets():
    reta = rss.indirection_table(4)
    fo = rss.failover_table(reta, (0,))
    assert not (fo == 0).any()                  # dead queue fully drained
    live = reta != 0
    assert (fo[live] == reta[live]).all()       # survivors keep affinity
    with pytest.raises(ValueError):
        rss.failover_table(rss.indirection_table(1), (0,))


# ---------------------------------------------------------------------------
# rings
# ---------------------------------------------------------------------------

def test_ring_fifo_tail_drop_and_conservation(rng):
    ring = PacketRing(8, packet_words=4)
    rows = np.arange(12, dtype=np.uint32).reshape(12, 1) * np.ones(
        (1, 4), np.uint32)
    admitted = ring.push(rows)
    assert admitted == 8 and ring.counters.dropped == 4
    out, _ = ring.pop(5)
    assert (out[:, 0] == np.arange(5)).all()    # FIFO, prefix admitted
    ring.mark_completed(5)
    # wraparound: push into freed space
    assert ring.push(rows[:4]) == 4
    out2, _ = ring.pop(100)
    assert (out2[:, 0] == np.r_[np.arange(5, 8), np.arange(4)]).all()
    ring.mark_completed(out2.shape[0])
    s = ring.conservation()
    assert s["producer_ok"] and s["consumer_ok"]
    assert s["offered"] == 16 and s["dropped"] == 4 and s["completed"] == 12


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 32), st.lists(st.integers(0, 20), min_size=1,
                                    max_size=30))
def test_ring_property_conservation(capacity, burst_sizes):
    ring = PacketRing(capacity, packet_words=1)
    seq = 0
    popped = []
    for i, n in enumerate(burst_sizes):
        rows = np.arange(seq, seq + n, dtype=np.uint32)[:, None]
        seq += n
        ring.push(rows)
        if i % 2:
            out, _ = ring.pop(capacity // 2 + 1)
            ring.mark_completed(out.shape[0])
            popped.extend(out[:, 0].tolist())
    out, _ = ring.pop(capacity)
    ring.mark_completed(out.shape[0])
    popped.extend(out[:, 0].tolist())
    s = ring.conservation()
    assert s["producer_ok"] and s["consumer_ok"] and s["occupancy"] == 0
    assert s["offered"] == seq and s["completed"] == len(popped)
    assert sorted(popped) == popped             # FIFO never reorders
    assert len(set(popped)) == len(popped)      # never duplicates


# ---------------------------------------------------------------------------
# runtime: conservation, ordering, fan-out parity
# ---------------------------------------------------------------------------

def run_trace(bank, trace, **kw):
    kw.setdefault("num_queues", 4)
    kw.setdefault("batch", 32)
    kw.setdefault("ring_capacity", 128)
    kw.setdefault("record", True)
    rt = DataplaneRuntime(bank, **kw)
    play(rt, trace)
    return rt


def test_runtime_conservation_and_per_queue_order(bank2):
    trace = small_trace()
    rt = run_trace(bank2, trace, strategy="fused", ring_capacity=64)
    aud = rt.audit_conservation()
    assert aud["ok"], aud
    t = aud["totals"]
    assert t["offered"] == t["completed"] + t["dropped"] == trace.total_packets
    assert t["dropped"] > 0                     # crowd phase forced drops
    # within a queue: sequence stamps strictly increase (no reorder/dup)
    for seqs in rt.completed_seq:
        assert (np.diff(np.asarray(seqs)) > 0).all()
    # across queues + drops: every offered packet accounted exactly once
    completed = [s for qs in rt.completed_seq for s in qs]
    allseq = completed + rt.dropped_seq
    assert len(allseq) == len(set(allseq)) == trace.total_packets


def test_runtime_fanout_parity(bank2):
    trace = small_trace(seed=7)
    kw = dict(ring_capacity=4096)               # no drops: exact comparison
    base = run_trace(bank2, trace, strategy="take", fanout="loop", **kw)
    for strategy, fanout in [("take", "vmap"), ("take", "shard_map"),
                             ("fused", "loop"), ("fused", "vmap"),
                             ("fused", "shard_map")]:
        rt = run_trace(bank2, trace, strategy=strategy, fanout=fanout, **kw)
        assert rt.completed_seq == base.completed_seq, (strategy, fanout)
        assert rt.completed_verdicts == base.completed_verdicts, (
            strategy, fanout)
        assert rt.completed_slots == base.completed_slots, (strategy, fanout)


def test_runtime_failover_drains_dead_queue(bank2):
    trace = small_trace(seed=1)
    rt = DataplaneRuntime(bank2, num_queues=4, strategy="take", batch=32,
                          ring_capacity=4096)
    rt.fail_queues((0,))
    for burst in trace.bursts[0]:
        rt.dispatch(burst)
    assert rt.rings[0].counters.offered == 0
    assert sum(r.counters.offered for r in rt.rings) > 0
    # skewed RETA: failing the only *referenced* queue must still remap
    # onto the live-but-unreferenced queues, not raise
    rt2 = DataplaneRuntime(bank2, num_queues=4, strategy="take", batch=32,
                           ring_capacity=4096)
    rt2.set_reta(np.zeros(rss.RETA_SIZE, np.int32))
    rt2.fail_queues((0,))
    assert not (rt2.reta == 0).any()
    assert set(rt2.reta) <= {1, 2, 3}


def test_telemetry_snapshot(bank2):
    rt = run_trace(bank2, small_trace(seed=2), strategy="fused",
                   ring_capacity=4096)
    snap = rt.snapshot()
    assert snap["completed_total"] == sum(
        q["completed"] for q in snap["queues"])
    assert snap["slot_swaps"] == 1 and snap["reta_updates"] >= 2
    busy = [q for q in snap["queues"] if q["completed"]]
    assert busy
    for q in busy:
        assert q["pps_busy"] > 0
        assert q["latency_p50_us"] <= q["latency_p99_us"]
        assert sum(q["per_slot_total"]) == q["completed"]
        acts = q["actions"]
        assert acts["forward"] + acts["drop"] + acts["flag"] == q["completed"]


# ---------------------------------------------------------------------------
# continuity: online slot swap under multi-queue churn
# ---------------------------------------------------------------------------

def test_zero_wrong_verdict_across_online_swap(bank2):
    """Multi-queue extension of the replay_trace zero-wrong-verdict
    regression: audit mode re-scores every tick through the exact take
    path while the slot-churn phase swaps a resident slot online, with
    the replacement weights delivered through the control-plane
    serialize -> deserialize channel."""
    trace = small_trace(seed=4)
    rt = DataplaneRuntime(bank2, num_queues=4, strategy="fused", batch=32,
                          ring_capacity=64, audit=True, record=True)

    def delivery(slot):
        fresh = executor.init_params(jax.random.PRNGKey(100 + slot))
        return switching._deserialize(switching._serialize(fresh), fresh)

    play(rt, trace, swap_delivery=delivery)
    aud = rt.audit_conservation()
    assert aud["ok"], aud
    assert aud["wrong_verdict"] == 0
    assert rt.telemetry.slot_swaps == 1


def test_swap_leaves_other_slots_verdicts_unchanged(bank2, rng):
    """Packets of the untouched slot get identical verdicts before and
    after another slot is hot-swapped (resident continuity)."""
    payload = rng.integers(0, 2**32, (64, pkt.PAYLOAD_WORDS), dtype=np.uint32)
    rows = pkt.make_packets(np.zeros(64, np.int64), payload)
    rows[:, rss.FLOW_WORD_LO : rss.FLOW_WORD_LO + rss.FLOW_WORDS] = \
        rng.integers(0, 2**32, (64, rss.FLOW_WORDS), dtype=np.uint32)
    rows[:, scenarios.SEQ_WORD] = np.arange(64, dtype=np.uint32)

    rt = DataplaneRuntime(bank2, num_queues=2, strategy="fused", batch=64,
                          ring_capacity=256, record=True)
    rt.dispatch(rows)
    rt.drain()
    before = {s: v for qs, qv in zip(rt.completed_seq, rt.completed_verdicts)
              for s, v in zip(qs, qv)}
    rt.swap_slot(1, executor.init_params(jax.random.PRNGKey(99)))
    rows2 = rows.copy()
    rows2[:, scenarios.SEQ_WORD] += 64
    rt.dispatch(rows2)
    rt.drain()
    after = {s - 64: v
             for qs, qv in zip(rt.completed_seq, rt.completed_verdicts)
             for s, v in zip(qs, qv) if s >= 64}
    assert before == after


# ---------------------------------------------------------------------------
# scenario engine
# ---------------------------------------------------------------------------

def test_scenarios_replayable_and_stamped():
    t1 = render(emergency_phases(2), num_slots=2, seed=5)
    t2 = render(emergency_phases(2), num_slots=2, seed=5)
    flat1 = [b for ph in t1.bursts for b in ph]
    flat2 = [b for ph in t2.bursts for b in ph]
    assert all((a == b).all() for a, b in zip(flat1, flat2))
    seqs = np.concatenate([b[:, scenarios.SEQ_WORD] for b in flat1])
    assert (seqs == np.arange(t1.total_packets)).all()
    t3 = render(emergency_phases(2), num_slots=2, seed=6)
    assert any((a != b).any()
               for a, b in zip(flat1, [b for ph in t3.bursts for b in ph]))


def test_emergency_phase_shapes():
    phases = emergency_phases(4, scale=2)
    names = [p.name for p in phases]
    assert names == ["steady", "flash_crowd", "link_failover", "slot_churn"]
    crowd = phases[1]
    assert crowd.burst > phases[0].burst        # surge
    assert crowd.flows < phases[0].flows        # elephant flows
    assert phases[2].failed_queues == (0,)
    assert phases[3].swap_slot is not None
    for p in phases:
        assert abs(sum(p.slot_mix) - 1.0) < 1e-9


# ---------------------------------------------------------------------------
# structural audit: one fused launch per queue-block
# ---------------------------------------------------------------------------

def test_one_fused_launch_per_queue_block(bank2, rng):
    common = pytest.importorskip("benchmarks.common")
    from repro.core import pipeline

    packets = pkt.make_packets(
        np.arange(32) % 2,
        rng.integers(0, 2**32, (32, pkt.PAYLOAD_WORDS), dtype=np.uint32))

    def queue_block_step(p):
        return pipeline.packet_step(bank2, p, num_slots=2, strategy="fused",
                                    backend="pallas", block_b=16)

    import jax.numpy as jnp
    stats = common.jaxpr_stats(
        queue_block_step, jnp.asarray(packets),
        payload_threshold=32 * pkt.PAYLOAD_WORDS * 4)
    assert stats["kernel_launches"] == 1
    assert stats["payload_roundtrip_bytes"] == 0
