"""Per-arch smoke tests: reduced config of the same family, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment
requirement).  Full configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import api
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts_lib

ARCHS = [a for a in ARCH_IDS if a != "boundswitch-h32"]


def _batch(cfg, rng, b=2, s=32):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))}
    labels_len = s
    if cfg.frontend == "patch":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend_len, cfg.d_model)), cfg.dtype)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), cfg.dtype)
    if cfg.bank_mode in ("adapter", "head"):
        batch["slot_ids"] = jnp.asarray(
            rng.integers(0, cfg.bank_slots, (b,)), jnp.int32)
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, labels_len)))
    batch["loss_mask"] = jnp.ones((b, labels_len), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = get_config(arch).reduced()
    params = api.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 32
    batch = _batch(cfg, rng, b, s)
    logits, aux = api.apply(params, batch, cfg)
    s_total = s + (cfg.frontend_len if cfg.frontend == "patch" else 0)
    assert logits.shape == (b, s_total, cfg.padded_vocab)
    real = np.asarray(logits[..., :cfg.vocab_size], np.float32)
    assert np.isfinite(real).all(), f"{arch}: NaN/inf in logits"
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, rng):
    cfg = get_config(arch).reduced(remat="none")
    opt_cfg = opt_lib.OptimizerConfig(
        warmup_steps=1, total_steps=10,
        moments_dtype=cfg.moments_dtype, master_weights=cfg.master_weights)
    state = ts_lib.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    step = jax.jit(ts_lib.make_train_step(cfg, opt_cfg))
    batch = _batch(cfg, rng)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: loss not finite"
    assert float(metrics["grad_norm"]) > 0, f"{arch}: zero gradients"
    assert int(new_state["step"]) == 1
    # params actually moved
    delta = jax.tree_util.tree_reduce(
        lambda acc, xy: acc + float(jnp.abs(xy[0].astype(jnp.float32)
                                            - xy[1].astype(jnp.float32)).sum()),
        jax.tree_util.tree_map(lambda a, b: (a, b),
                               state["params"], new_state["params"]),
        0.0, is_leaf=lambda x: isinstance(x, tuple))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch, rng):
    cfg = get_config(arch).reduced(remat="none")
    params = api.init(jax.random.PRNGKey(0), cfg)
    b = 2
    cache = api.init_cache(cfg, b, 64)
    slot_ids = (jnp.zeros((b,), jnp.int32)
                if cfg.bank_mode in ("adapter", "head") else None)
    logits, new_cache = api.decode_step(
        params, jnp.zeros((b, 1), jnp.int32), cache, jnp.int32(3), cfg, slot_ids)
    assert logits.shape == (b, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits[..., :cfg.vocab_size], np.float32)).all()
    assert jax.tree_util.tree_structure(new_cache) == \
        jax.tree_util.tree_structure(cache)


def test_param_counts_match_published_scale():
    """Analytic N within expected range of each arch's nameplate size."""
    expected = {
        "h2o-danube-3-4b": (3.0e9, 5.0e9),
        "smollm-360m": (0.30e9, 0.45e9),
        "deepseek-7b": (6e9, 8e9),
        "glm4-9b": (8e9, 11e9),
        "zamba2-7b": (6e9, 9e9),
        "olmoe-1b-7b": (5.5e9, 8e9),       # 6.9B total
        "arctic-480b": (4.0e11, 5.4e11),
        "llava-next-34b": (3.0e10, 4.0e10),
        "seamless-m4t-medium": (0.5e9, 1.5e9),
        "mamba2-130m": (0.10e9, 0.17e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: N={n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]B"


def test_moe_active_params_much_smaller():
    cfg = get_config("arctic-480b")
    assert cfg.active_param_count() < 0.15 * cfg.param_count()
