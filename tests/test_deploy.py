"""Continuous deployment subsystem (DESIGN.md §12): telemetry-attached
sampling, online fine-tuning, canary SwapSlot rollouts with
promote/rollback, auto-remediation, and the end-to-end audit story."""

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro import deploy
from repro.checkpoint import store
from repro.core import bank as bank_lib
from repro.core import executor
from repro.core import packet as pkt
from repro.dataplane import DataplaneRuntime, MeshDataplane, workloads
from repro.obs import AnomalyDetector, TelemetryStream
from repro.obs import spans


@pytest.fixture(scope="module")
def bank2():
    return executor.init_bank(jax.random.PRNGKey(0), 2)


@functools.lru_cache(maxsize=1)
def _pool():
    return deploy.labeled_pool(samples_per_group=96, seed=0)


@pytest.fixture(scope="module")
def corpus():
    pool, labels = _pool()
    return pool, labels, deploy.LabelOracle(pool, labels)


@pytest.fixture(scope="module")
def trained(corpus):
    pool, labels, _ = corpus
    return deploy.OnlineTrainer(steps=24, seed=0).fine_tune(pool, labels)


@functools.lru_cache(maxsize=None)
def _rendered(regime, seed=0, queues=2):
    pool, _labels = _pool()
    w = workloads.make_workload(regime, num_slots=2, num_queues=queues)
    return workloads.render(list(w.phases), num_slots=2, seed=seed,
                            num_queues=queues, payload_pool=pool)


def _drive(driver, pool, rng, ticks, *, controller=None, n=192):
    """Feed pool-payload packets through dispatch/tick for ``ticks``."""
    for _ in range(ticks):
        idx = rng.integers(0, pool.shape[0], n)
        pkts = pkt.make_packets(rng.integers(0, 2, n), pool[idx])
        driver.dispatch(pkts)
        driver.tick()
        if controller is not None:
            controller.step()


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        bool(jnp.array_equal(x, y)) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# runtime taps
# ---------------------------------------------------------------------------

def test_runtime_taps_account_for_every_row(bank2):
    rng = np.random.default_rng(0)
    rt = DataplaneRuntime(bank2, num_queues=2, batch=64, ring_capacity=128)
    retired, dropped = [], []
    rt.on_retire = lambda q, rows, s, v, a, t: retired.append(rows.shape[0])
    rt.on_drop = lambda q, rows: dropped.append(rows.shape[0])
    pool, _ = _pool()
    for _ in range(6):  # tiny rings: tail drops exercised too
        idx = rng.integers(0, pool.shape[0], 300)
        rt.dispatch(pkt.make_packets(rng.integers(0, 2, 300), pool[idx]))
        rt.tick()
    rt.drain()
    snap = rt.telemetry.snapshot()
    assert sum(retired) == snap["completed_total"] > 0
    assert sum(dropped) == snap["dropped_total"] > 0


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------

def test_label_oracle_survives_word0_twist(corpus):
    pool, labels, oracle = corpus
    twisted = pool.copy()
    twisted[:, 0] ^= np.arange(pool.shape[0], dtype=np.uint32) * 2654435761
    got = oracle.lookup(twisted[:64])
    np.testing.assert_array_equal(got, labels[:64])
    unknown = np.random.default_rng(0).integers(
        0, 2**32, (4, 256), dtype=np.uint32)
    assert (oracle.lookup(unknown) == -1).all()


def test_reservoir_bounded_over_unbounded_stream():
    r = deploy.Reservoir(64, 4, np.random.default_rng(0))
    for i in range(10):
        words = np.full((100, 4), i, np.uint32)
        r.add(words, np.ones(100, np.int8), np.zeros(100, np.int8), i)
    assert r.count == 64 and r.seen == 1000
    words, labels, verdicts = r.rows()
    assert words.shape == (64, 4) and (labels == 1).all()
    # late batches must actually displace early ones (uniform-ish sample)
    assert len(np.unique(words[:, 0])) > 3


def test_sampler_is_bounded_and_does_not_mutate_the_stream(bank2, corpus):
    pool, _labels, oracle = corpus
    trace = _rendered("emergency")
    kw = dict(num_queues=2, batch=128, ring_capacity=4096, record=True)

    rt_plain = DataplaneRuntime(bank2, **kw)
    workloads.play(rt_plain, trace)

    rt = DataplaneRuntime(bank2, **kw)
    sampler = deploy.PacketSampler(oracle, num_slots=2,
                                   capacity=256).attach(rt)
    workloads.play(rt, trace)
    sampler.detach()
    assert rt.on_retire is None and rt.on_drop is None

    # verdict/slot streams are bit-identical with the sampler attached
    assert rt.completed_verdicts == rt_plain.completed_verdicts
    assert rt.completed_slots == rt_plain.completed_slots
    st_ = sampler.stats()
    assert st_["seen"] == rt.telemetry.snapshot()["completed_total"]
    assert st_["labeled"] > 0 and st_["unknown"] == 0
    assert all(c <= 256 for c in st_["reservoir_rows"])
    words, labels = sampler.training_batch()
    assert words.shape[0] == labels.shape[0] > 0
    assert set(np.unique(labels)) <= {0, 1}


def test_sampler_harvests_ring_edge_drops(bank2, corpus):
    pool, _labels, oracle = corpus
    rng = np.random.default_rng(1)
    rt = DataplaneRuntime(bank2, num_queues=2, batch=32, ring_capacity=64)
    sampler = deploy.PacketSampler(oracle, num_slots=2).attach(rt)
    for _ in range(4):  # overrun the tiny rings without ticking
        idx = rng.integers(0, pool.shape[0], 512)
        rt.dispatch(pkt.make_packets(rng.integers(0, 2, 512), pool[idx]))
    rt.drain()
    sampler.detach()
    assert sampler.drops_seen > 0
    assert 0 < sampler.drop_reservoir.count <= sampler.drop_reservoir.capacity
    _words, labels = sampler.training_batch()
    assert labels.size > 0


def test_sampler_window_filters_by_tick(bank2, corpus):
    pool, _labels, oracle = corpus
    rng = np.random.default_rng(2)
    rt = DataplaneRuntime(bank2, num_queues=2, batch=128, ring_capacity=1024)
    sampler = deploy.PacketSampler(oracle, num_slots=2).attach(rt)
    _drive(rt, pool, rng, 4)
    cut = rt._tick_count
    _drive(rt, pool, rng, 3)
    rt.drain()
    sampler.detach()
    _w, _l, _v, _s = sampler.window_since(0)
    w2, l2, _v2, _s2 = sampler.window_since(cut)
    assert 0 < l2.size < _l.size
    assert (oracle.lookup(w2) == l2).all()


def test_double_attach_rejected(bank2):
    rt = DataplaneRuntime(bank2, num_queues=2)
    s1 = deploy.PacketSampler(None, num_slots=2).attach(rt)
    with pytest.raises(RuntimeError, match="already has a sampler tap"):
        deploy.PacketSampler(None, num_slots=2).attach(rt)
    s1.detach()


# ---------------------------------------------------------------------------
# trainer
# ---------------------------------------------------------------------------

def test_trainer_learns_and_checkpoints(corpus, tmp_path):
    pool, labels, _ = corpus
    trainer = deploy.OnlineTrainer(checkpoint_dir=str(tmp_path), steps=24,
                                   seed=0, keep_last=2)
    res = trainer.fine_tune(pool, labels)
    assert res.metrics["err"] <= 0.35          # beats coin-flip clearly
    assert res.metrics["f1"] > 0.5
    assert res.checkpoint_path and os.path.isdir(res.checkpoint_path)
    back, extra = store.restore(str(tmp_path), res.step, res.latent)
    assert _tree_equal(back, res.latent)
    assert "metrics" in extra and extra["metrics"]["samples"] == pool.shape[0]
    # successive fine-tunes advance the step and GC old checkpoints
    for _ in range(3):
        res = trainer.fine_tune(pool, labels, warm_latent=res.latent)
    assert store.list_steps(str(tmp_path)) == [2, 3]


def test_corrupt_params_invert_the_model(corpus, trained):
    pool, labels, _ = corpus
    good = deploy.paired_err(trained.params, pool, labels)
    bad = deploy.paired_err(deploy.corrupt_params(trained.params),
                            pool, labels)
    assert good < 0.35 and bad > 0.65 and abs(good + bad - 1.0) < 1e-6


# ---------------------------------------------------------------------------
# canary lifecycle
# ---------------------------------------------------------------------------

def test_canary_promote_installs_weights_and_restores_routing(
        bank2, corpus, trained):
    pool, _labels, oracle = corpus
    rng = np.random.default_rng(3)
    rt = DataplaneRuntime(bank2, num_queues=4, batch=128,
                          ring_capacity=2048, audit=True)
    sampler = deploy.PacketSampler(oracle, num_slots=2).attach(rt)
    ctl = deploy.CanaryController(rt, sampler, target_slot=0, bake_ticks=5,
                                  min_samples=16)
    prior_reta = np.asarray(rt.reta).copy()
    slot1_before = bank_lib.select_slot(rt.bank, 1)
    _drive(rt, pool, rng, 2)
    ctl.start(trained.params, reason="test")
    assert ctl.state == ctl.BAKING
    assert not np.array_equal(np.asarray(rt.reta), prior_reta)  # steered
    _drive(rt, pool, rng, 6, controller=ctl)
    rt.drain()
    assert ctl.state == ctl.IDLE and len(ctl.decisions) == 1
    rec = ctl.decisions[0]
    assert rec["event"] == "promoted", rec
    assert _tree_equal(bank_lib.select_slot(rt.bank, 0), trained.params)
    assert _tree_equal(bank_lib.select_slot(rt.bank, 1), slot1_before)
    assert np.array_equal(np.asarray(rt.reta), prior_reta)
    # both transitions are typed epochs in the control log
    kinds = [tuple(c["cmd"] for c in e["commands"])
             for e in rt.control.command_log()]
    assert ("swap_slot", "program_reta") in kinds            # canary_start
    assert ("swap_slot", "swap_slot", "program_reta") in kinds  # promote
    aud = rt.audit_conservation()
    assert aud["ok"] and aud["wrong_verdict"] == 0
    assert rt.control.continuity_audit()["ok"]
    sampler.detach()


def test_canary_rolls_back_a_regression_bit_exactly(bank2, corpus, trained):
    pool, _labels, oracle = corpus
    rng = np.random.default_rng(4)
    rt = DataplaneRuntime(bank2, num_queues=4, batch=128,
                          ring_capacity=2048, audit=True)
    sampler = deploy.PacketSampler(oracle, num_slots=2).attach(rt)
    ctl = deploy.CanaryController(rt, sampler, target_slot=0, bake_ticks=5,
                                  min_samples=16)
    slot0_before = bank_lib.select_slot(rt.bank, 0)
    slot1_before = bank_lib.select_slot(rt.bank, 1)
    prior_reta = np.asarray(rt.reta).copy()
    _drive(rt, pool, rng, 2)
    ctl.start(deploy.corrupt_params(trained.params), reason="test")
    _drive(rt, pool, rng, 6, controller=ctl)
    rt.drain()
    rec = ctl.decisions[0]
    assert rec["event"] == "rolled_back"
    assert rec["metrics"]["err_new"] > rec["metrics"]["err_base"]
    assert _tree_equal(bank_lib.select_slot(rt.bank, 0), slot0_before)
    assert _tree_equal(bank_lib.select_slot(rt.bank, 1), slot1_before)
    assert np.array_equal(np.asarray(rt.reta), prior_reta)
    aud = rt.audit_conservation()
    assert aud["ok"] and aud["wrong_verdict"] == 0
    assert rt.control.continuity_audit()["ok"]
    sampler.detach()


def test_canary_flush_forces_exactly_one_conservative_decision(
        bank2, trained):
    rt = DataplaneRuntime(bank2, num_queues=2)
    ctl = deploy.CanaryController(rt, None, target_slot=0, bake_ticks=50)
    ctl.start(trained.params)
    rec = ctl.flush()               # end of traffic mid-bake
    assert rec["event"] == "rolled_back"
    assert "insufficient" in rec["reason"]
    assert ctl.flush() is None and ctl.step() is None
    assert len(ctl.decisions) == 1
    events = [d["event"] for d in rt.deploy_log]
    assert events == ["canary_start", "rolled_back"]


def test_canary_guards(bank2, trained):
    bank1 = executor.init_bank(jax.random.PRNGKey(1), 1)
    with pytest.raises(ValueError, match=">= 2 resident slots"):
        deploy.CanaryController(DataplaneRuntime(bank1, num_queues=2), None)
    rt = DataplaneRuntime(bank2, num_queues=2)
    with pytest.raises(ValueError, match="must differ"):
        deploy.CanaryController(rt, None, target_slot=0, canary_slot=0)
    ctl = deploy.CanaryController(rt, None)
    ctl.start(trained.params)
    with pytest.raises(RuntimeError, match="already baking"):
        ctl.start(trained.params)
    ctl.flush()


def test_canary_on_mesh_promotes_mesh_wide(bank2, corpus, trained):
    pool, _labels, oracle = corpus
    rng = np.random.default_rng(5)
    mesh = MeshDataplane(bank2, hosts=2, num_queues=2, batch=128,
                         ring_capacity=2048)
    sampler = deploy.PacketSampler(oracle, num_slots=2).attach(mesh)
    ctl = deploy.CanaryController(mesh, sampler, target_slot=0,
                                  bake_ticks=4, min_samples=16)
    _drive(mesh, pool, rng, 2)
    ctl.start(trained.params)
    _drive(mesh, pool, rng, 5, controller=ctl)
    mesh.drain()
    assert ctl.decisions and ctl.decisions[0]["event"] == "promoted"
    for shard in mesh.shards:   # mesh-wide: every shard's bank updated
        assert _tree_equal(bank_lib.select_slot(shard.bank, 0),
                           trained.params)
    assert mesh.audit_conservation()["ok"]
    assert mesh.control.continuity_audit()["ok"]
    sampler.detach()


# ---------------------------------------------------------------------------
# auto-remediation
# ---------------------------------------------------------------------------

def _mix_shift_stream(ticks=16, flip=8):
    """Crafted delta stream whose slot mix flips halfway (detector fuel)."""
    stream = TelemetryStream()
    for tick in range(ticks):
        per_slot = [64, 0] if tick < flip else [0, 64]
        stream.push({"kind": "delta", "seq": tick, "tick": tick, "t_s": None,
                     "host": 0,
                     "queues": [{"queue": 0, "completed": 64, "dropped": 0,
                                 "per_slot": per_slot,
                                 "actions": [64, 0, 0], "depth": 0},
                                {"queue": 1, "completed": 60, "dropped": 0,
                                 "per_slot": per_slot,
                                 "actions": [60, 0, 0], "depth": 0}],
                     "events": {}})
    return stream


def test_auto_remediator_runs_retrain_canary_pipeline(bank2, corpus):
    pool, _labels, oracle = corpus
    rng = np.random.default_rng(6)
    rt = DataplaneRuntime(bank2, num_queues=2, batch=128,
                          ring_capacity=2048, audit=True)
    sampler = deploy.PacketSampler(oracle, num_slots=2).attach(rt)
    det = AnomalyDetector(_mix_shift_stream(), num_queues=2, num_slots=2,
                          window=4)
    rem = deploy.AutoRemediator(
        rt, det, sampler=sampler,
        trainer=deploy.OnlineTrainer(steps=16, seed=0),
        canary_kw=dict(bake_ticks=4, min_samples=16),
        min_retrain_samples=32)
    _drive(rt, pool, rng, 3)          # fill the reservoirs first
    rem.step()                        # proposal -> fine-tune -> canary
    events = [d["event"] for d in rt.deploy_log]
    assert events[:2] == ["retrain", "canary_start"]
    retrain = rt.deploy_log[0]
    assert retrain["reason"] == "slot_mix_shift" and retrain["slot"] == 1
    for _ in range(5):
        _drive(rt, pool, rng, 1)
        rem.step()
    rem.flush()
    rt.drain()
    events = [d["event"] for d in rt.deploy_log]
    assert sum(e in ("promoted", "rolled_back") for e in events) == 1
    # dedup: the same proposal never retrains twice
    rem.step()
    assert sum(e == "retrain" for e in
               [d["event"] for d in rt.deploy_log]) == 1
    aud = rt.audit_conservation()
    assert aud["ok"] and aud["wrong_verdict"] == 0
    assert rt.control.continuity_audit()["ok"]
    sampler.detach()


def test_auto_remediator_submits_routing_proposals_as_epochs(bank2, corpus):
    pool, _labels, oracle = corpus
    rng = np.random.default_rng(7)
    rt = DataplaneRuntime(bank2, num_queues=4, batch=128,
                          ring_capacity=4096, audit=True)
    stream = TelemetryStream()
    from repro.obs import attach, detach
    attach(rt, stream)
    det = AnomalyDetector(stream, num_queues=4, num_slots=2)
    rem = deploy.AutoRemediator(rt, det)
    driver = deploy.DeployDriver(rt, rem)
    trace = _rendered("elephant-skew", 0, queues=4)
    workloads.play(driver, trace)
    driver.flush_deploy()
    detach(rt)
    acts = [d for d in rt.deploy_log if d["event"] == "auto_remediate"]
    assert acts and acts[0]["command"]["cmd"] == "program_reta"
    assert acts[0]["epoch"] is not None
    aud = rt.audit_conservation()
    assert aud["ok"] and aud["wrong_verdict"] == 0
    assert rt.control.continuity_audit()["ok"]


# ---------------------------------------------------------------------------
# epoch-log provenance + record/replay
# ---------------------------------------------------------------------------

def test_epoch_log_doc_carries_deployments(bank2, trained):
    rt = DataplaneRuntime(bank2, num_queues=2)
    ctl = deploy.CanaryController(rt, None, bake_ticks=3)
    ctl.start(trained.params)
    ctl.flush()
    doc = spans.epoch_log_doc(rt)
    assert [d["event"] for d in doc["deployments"]] == \
        ["canary_start", "rolled_back"]
    assert doc["continuity"]["ok"]
    applied = {e["epoch"] for e in doc["epochs"]}
    for d in doc["deployments"]:
        assert d["epoch"] in applied   # every decision is a typed epoch


def test_recorded_deploy_run_replays_bit_exact(bank2, corpus):
    pool, _labels, oracle = corpus
    trace = _rendered("emergency")
    rt = DataplaneRuntime(bank2, num_queues=2, batch=128,
                          ring_capacity=4096, record=True)
    rec = workloads.record(rt)
    driver = deploy.DeployDriver(rec)
    sampler = deploy.PacketSampler(oracle, num_slots=2).attach(rt)
    pilot = deploy.ScheduledRollout(
        driver, sampler, deploy.OnlineTrainer(steps=8, seed=0),
        warmup_ticks=4, min_samples=24,
        canary_kw=dict(bake_ticks=4, min_samples=16))
    driver.add(pilot)
    workloads.play(driver, trace)
    driver.flush_deploy()
    sampler.detach()
    assert pilot.decision is not None
    saved = rec.finish(name="deploy-promote", seed=0)
    swap_epochs = [s for s in saved.steps if s["kind"] == "commands"
                   and any(type(c).__name__ == "SwapSlot"
                           for c in s["commands"])]
    assert len(swap_epochs) >= 2       # canary_start + decision recorded
    rep = workloads.replay(saved, workloads.make_runtime(saved))
    assert rep["ok"] and rep["digest_ok"]


# ---------------------------------------------------------------------------
# the canary-lifecycle property (ISSUE 8 satellite): every rollout ends
# in exactly one of promoted/rolled-back, with zero wrong verdicts and
# conservation intact across the bake window
# ---------------------------------------------------------------------------

PROPERTY_REGIMES = ("emergency", "flash-crowd", "slot-thrash")


@settings(max_examples=6, deadline=None)
@given(st.sampled_from(PROPERTY_REGIMES), st.booleans(), st.integers(0, 2))
def test_canary_rollout_property(bank2, corpus, regime, corrupt, seed):
    _pool_words, _labels, oracle = corpus
    trace = _rendered(regime, seed)
    rt = DataplaneRuntime(bank2, num_queues=2, batch=128,
                          ring_capacity=4096, audit=True)
    sampler = deploy.PacketSampler(oracle, num_slots=2, seed=seed).attach(rt)
    driver = deploy.DeployDriver(rt)
    pilot = deploy.ScheduledRollout(
        driver, sampler, deploy.OnlineTrainer(steps=12, seed=seed),
        warmup_ticks=4, min_samples=24, corrupt=corrupt,
        canary_kw=dict(bake_ticks=6, min_samples=16))
    driver.add(pilot)
    workloads.play(driver, trace)
    driver.flush_deploy()
    sampler.detach()

    events = [d["event"] for d in rt.deploy_log]
    terminal = [e for e in events if e in ("promoted", "rolled_back")]
    if pilot.canary is not None:          # a rollout actually started
        assert len(terminal) == 1, events
        if corrupt:
            assert terminal == ["rolled_back"], rt.deploy_log
    else:                                 # not enough labeled traffic
        assert terminal == []
    aud = rt.audit_conservation()
    assert aud["ok"] and aud["wrong_verdict"] == 0
    assert rt.control.continuity_audit()["ok"]


# ---------------------------------------------------------------------------
# launch CLI end-to-end (--deploy-demo)
# ---------------------------------------------------------------------------

def test_cli_deploy_demo_promote(tmp_path):
    import json
    from repro.launch import dataplane as launch
    out = tmp_path / "epochs.json"
    launch.main(["--scenario", "emergency", "--queues", "2", "--slots", "2",
                 "--ring-capacity", "4096", "--deploy-demo", "promote",
                 "--deploy-warmup-ticks", "6", "--deploy-bake-ticks", "6",
                 "--deploy-steps", "8",
                 "--checkpoint-dir", str(tmp_path / "ckpt"),
                 "--epoch-log-json", str(out)])
    doc = json.loads(out.read_text())
    events = [d["event"] for d in doc["deployments"]]
    assert "promoted" in events and "retrain" in events
    assert doc["continuity"]["ok"]
    assert store.list_steps(str(tmp_path / "ckpt"))
