"""Quantized (int8) KV cache: decode numerics within tolerance of the
full-precision path (beyond-paper optimization, EXPERIMENTS.md §Perf C2)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import api


@pytest.mark.parametrize("arch", ["glm4-9b", "smollm-360m"])
def test_int8_cache_decode_close(arch, rng):
    cfg = get_config(arch).reduced(bank_mode="none", remat="none",
                                   dtype="float32")
    cfg8 = dataclasses.replace(cfg, cache_dtype="int8")
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = rng.integers(0, cfg.vocab_size, 10)
    c_bf = api.init_cache(cfg, 2, 32)
    c_i8 = api.init_cache(cfg8, 2, 32)
    assert c_i8["k"].dtype == jnp.int8 and "k_scale" in c_i8
    for i, t in enumerate(toks):
        tt = jnp.asarray([[int(t)], [int(t)]])
        lg1, c_bf = api.decode_step(params, tt, c_bf, jnp.int32(i), cfg)
        lg2, c_i8 = api.decode_step(params, tt, c_i8, jnp.int32(i), cfg8)
        rel = float(jnp.abs(lg1 - lg2).max() / (jnp.abs(lg1).max() + 1e-9))
        assert rel < 0.05, f"step {i}: rel err {rel}"


def test_int8_cache_halves_bytes():
    cfg = get_config("glm4-9b").reduced()
    cfg8 = dataclasses.replace(cfg, cache_dtype="int8")
    c = api.init_cache(cfg, 4, 64)
    c8 = api.init_cache(cfg8, 4, 64)
    kv = c["k"].nbytes + c["v"].nbytes
    kv8 = c8["k"].nbytes + c8["v"].nbytes
    scales = c8["k_scale"].nbytes + c8["v_scale"].nbytes
    assert kv8 == kv // 2
    # one f32 scale per head_dim int8 values: overhead = 4/head_dim
    assert scales <= kv8 * 4 / cfg8.head_dim
