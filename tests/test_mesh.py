"""Multi-host mesh data plane: global-queue-id RETA, cross-host failover
affinity, hosts=1 bit-identity, mesh-wide conservation + per-host FIFO,
epoch-barrier fan-out with atomic cross-host rollback, mesh policies,
and telemetry merge (DESIGN.md §8)."""

import jax
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.control import (FailQueues, LeastDepth, ProgramReta, RestoreQueues,
                           SetPolicy, StaticReta, SwapSlot)
from repro.core import executor
from repro.dataplane import (DataplaneRuntime, MeshDataplane, Phase,
                             cascading_failover_phases, emergency_phases,
                             make_scenario, play, render, rss, scenarios,
                             telemetry)
from repro.launch import mesh as mesh_lib


@pytest.fixture(scope="module")
def bank2():
    return executor.init_bank(jax.random.PRNGKey(0), 2)


@pytest.fixture(scope="module")
def spare_params():
    return executor.init_params(jax.random.PRNGKey(41))


def small_phases(num_slots=2, total_queues=4):
    """Fast mesh storyline: backpressure, whole-host failover, churn."""
    uniform = tuple(1.0 / num_slots for _ in range(num_slots))
    half = tuple(range(total_queues // 2))      # host 0 on a 2-host mesh
    return [
        Phase("steady", ticks=2, burst=64, flows=16, slot_mix=uniform),
        Phase("crowd", ticks=2, burst=192, flows=4, slot_mix=uniform),
        Phase("churn", ticks=2, burst=64, flows=16, slot_mix=uniform,
              failed_queues=half, swap_slot=1),
    ]


def make_mesh(bank, *, hosts=2, num_queues=2, **kw):
    kw.setdefault("strategy", "take")
    kw.setdefault("batch", 32)
    kw.setdefault("ring_capacity", 4096)
    return MeshDataplane(bank, hosts=hosts, num_queues=num_queues, **kw)


# ---------------------------------------------------------------------------
# global-queue-id RETA
# ---------------------------------------------------------------------------

def test_global_queue_id_roundtrip():
    gids = rss.global_queue_id(np.array([0, 1, 2]), np.array([3, 0, 1]), 4)
    assert gids.tolist() == [3, 4, 9]
    host, queue = rss.split_host_queue(gids, 4)
    assert host.tolist() == [0, 1, 2] and queue.tolist() == [3, 0, 1]


def test_mesh_indirection_degenerates_to_single_host():
    assert (rss.mesh_indirection_table(1, 4)
            == rss.indirection_table(4)).all()
    t = rss.mesh_indirection_table(2, 4)
    host, queue = rss.split_host_queue(t, 4)
    assert set(host.tolist()) == {0, 1}         # both hosts referenced
    assert set(queue.tolist()) == {0, 1, 2, 3}


def test_mesh_queue_of_spreads_hosts(rng):
    from repro.core import packet as pkt
    pkts = pkt.make_packets(
        np.zeros(256, np.int64),
        rng.integers(0, 2**32, (256, pkt.PAYLOAD_WORDS), dtype=np.uint32))
    pkts[:, rss.FLOW_WORD_LO : rss.FLOW_WORD_LO + rss.FLOW_WORDS] = \
        rng.integers(0, 2**32, (256, rss.FLOW_WORDS), dtype=np.uint32)
    host, queue = rss.mesh_queue_of(pkts, 2, 4)
    assert set(host.tolist()) == {0, 1}
    assert queue.min() >= 0 and queue.max() < 4
    # mesh dispatch at hosts=1 IS single-host dispatch
    h1, q1 = rss.mesh_queue_of(pkts, 1, 4)
    assert (h1 == 0).all()
    assert (q1 == rss.queue_of(pkts, 4)).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31), st.integers(1, 4), st.integers(1, 4),
       st.integers(1, 6))
def test_property_cross_host_failover_preserves_survivor_affinity(
        seed, hosts, queues, n_failed):
    """Cross-host RETA failover never remaps a flow whose (host, queue)
    both survive: buckets pointing at surviving global ids keep their
    exact (host, queue), only dead buckets move — and they move onto
    survivors."""
    rng = np.random.default_rng(seed)
    total = hosts * queues
    reta = rng.integers(0, total, rss.RETA_SIZE).astype(np.int32)
    failed = tuple(sorted(rng.choice(total, size=min(n_failed, total - 1),
                                     replace=False).tolist()))
    if not failed:
        return
    fo = rss.mesh_failover_table(reta, failed, num_hosts=hosts,
                                 num_queues=queues)
    dead = np.isin(reta, failed)
    assert (fo[~dead] == reta[~dead]).all()     # survivors never remapped
    assert not np.isin(fo, failed).any()        # dead pairs fully drained
    # flows: any flow hashing to a surviving bucket keeps its (host, queue)
    fw = rng.integers(0, 2**32, (64, rss.FLOW_WORDS), dtype=np.uint32)
    b = rss.bucket_index(rss.toeplitz_hash(fw), len(reta))
    survives = ~dead[b]
    h0, q0 = rss.split_host_queue(reta[b], queues)
    h1, q1 = rss.split_host_queue(fo[b], queues)
    assert (h1[survives] == h0[survives]).all()
    assert (q1[survives] == q0[survives]).all()


# ---------------------------------------------------------------------------
# hosts=1 is the degenerate mesh: bit-identical to DataplaneRuntime
# ---------------------------------------------------------------------------

def test_hosts1_bit_identical_to_runtime(bank2):
    trace = render(small_phases(), num_slots=2, seed=3)
    kw = dict(strategy="fused", batch=32, ring_capacity=64, record=True)
    rt = DataplaneRuntime(bank2, num_queues=4, **kw)
    play(rt, trace)
    m1 = MeshDataplane(bank2, hosts=1, num_queues=4, **kw)
    play(m1, trace)
    assert m1.completed_seq == rt.completed_seq
    assert m1.completed_verdicts == rt.completed_verdicts
    assert m1.completed_slots == rt.completed_slots
    assert m1.dropped_seq == rt.dropped_seq
    assert (m1.reta == rt.reta).all()
    a, b = rt.audit_conservation(), m1.audit_conservation()
    assert a["totals"] == b["totals"] and b["ok"]
    sa, sb = rt.snapshot(), m1.snapshot()
    assert sa["completed_total"] == sb["completed_total"]
    assert sa["slot_swaps"] == sb["slot_swaps"] == 1
    assert sa["reta_updates"] == sb["reta_updates"]


# ---------------------------------------------------------------------------
# mesh conservation + per-host FIFO
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hosts,queues", [(2, 2), (3, 2)])
def test_mesh_conservation_and_per_host_fifo(bank2, hosts, queues):
    total = hosts * queues
    trace = render(small_phases(total_queues=total), num_slots=2, seed=11)
    mesh = make_mesh(bank2, hosts=hosts, num_queues=queues,
                     ring_capacity=64, record=True)
    play(mesh, trace)
    aud = mesh.audit_conservation()
    assert aud["ok"], aud
    t = aud["totals"]
    # offered == admitted + dropped summed across hosts, nothing vanishes
    assert t["offered"] == t["admitted"] + t["dropped"]
    assert t["offered"] == t["completed"] + t["dropped"] == trace.total_packets
    assert t["dropped"] > 0                     # crowd forced real drops
    for h in aud["per_host"]:
        assert h["ok"]
    # per-queue FIFO per host: sequence stamps strictly increase
    for shard in mesh.shards:
        for seqs in shard.completed_seq:
            assert (np.diff(np.asarray(seqs)) > 0).all()
    # every offered packet accounted exactly once across the whole mesh
    done = [s for qs in mesh.completed_seq for s in qs]
    allseq = done + mesh.dropped_seq
    assert len(allseq) == len(set(allseq)) == trace.total_packets


def test_dispatch_rejects_out_of_range_precomputed_queues(bank2, rng):
    """A global id handed to a shard must raise, not vanish silently
    past the conservation audit."""
    from repro.core import packet as pkt
    rt = DataplaneRuntime(bank2, num_queues=2, batch=8, ring_capacity=64)
    rows = pkt.make_packets(
        np.zeros(4, np.int64),
        rng.integers(0, 2**32, (4, pkt.PAYLOAD_WORDS), dtype=np.uint32))
    with pytest.raises(ValueError, match="out of range"):
        rt.dispatch(rows, queues=np.array([0, 1, 2, 3]))
    rt.dispatch(rows, queues=np.array([0, 1, 1, 0]))    # in range: fine
    assert rt.rings[0].counters.offered == 2
    assert rt.rings[1].counters.offered == 2


def test_mesh_failover_drains_dead_host(bank2):
    trace = render(small_phases(), num_slots=2, seed=1)
    mesh = make_mesh(bank2, hosts=2, num_queues=2)
    host0 = tuple(range(mesh.num_queues_per_host))
    mesh.control.submit(FailQueues(host0))
    mesh.flush_control()
    hostpart, _ = rss.split_host_queue(mesh.reta, mesh.num_queues_per_host)
    assert not (hostpart == 0).any()            # no bucket points at host 0
    for burst in trace.bursts[0]:
        mesh.dispatch(burst)
    assert all(r.counters.offered == 0 for r in mesh.shards[0].rings)
    assert sum(r.counters.offered for r in mesh.shards[1].rings) > 0
    mesh.drain()
    assert mesh.audit_conservation()["ok"]


# ---------------------------------------------------------------------------
# epoch barrier: same tick on every host, atomic cross-host rollback
# ---------------------------------------------------------------------------

def test_epoch_barrier_applies_at_same_tick_on_all_hosts(bank2, spare_params):
    trace = render(small_phases(), num_slots=2, seed=6)
    bursts = [b for ph in trace.bursts for b in ph]
    mesh = make_mesh(bank2, hosts=3, num_queues=2, pipeline_depth=2)
    for i, burst in enumerate(bursts):
        mesh.dispatch(burst)
        mesh.tick()
        if i == 1:
            mesh.control.submit(SwapSlot(1, spare_params),
                                ProgramReta(tuple(np.roll(mesh.reta, 1))))
        if i == 3:
            mesh.control.submit(FailQueues((0,)))
    mesh.drain()
    assert len(mesh.control.log) >= 2
    for rec in mesh.control.log:
        assert rec.applied
        assert rec.host_ticks is not None and len(rec.host_ticks) == 3
        assert len(set(rec.host_ticks)) == 1    # the barrier: one tick
        assert rec.host_ticks[0] == rec.applied_tick
    assert [b["host_ticks"] for b in mesh.barrier_log] == \
        [[r.applied_tick] * 3 for r in mesh.control.log]
    # serialized log carries the barrier proof too
    logged = mesh.control.command_log()
    assert all(rec["host_ticks"] == [rec["applied_tick"]] * 3
               for rec in logged)


def test_epoch_rejected_by_one_host_stages_nothing(bank2, spare_params,
                                                   monkeypatch):
    """Stage phase: if any single host rejects its projection, the epoch
    is rejected before ANY host mutates."""
    mesh = make_mesh(bank2, hosts=2, num_queues=2)
    banks_before = [s.bank for s in mesh.shards]
    orig = mesh.shards[1]._validate_command

    def veto(cmd):
        if isinstance(cmd, SwapSlot):
            raise ValueError("host 1 refuses delivery")
        orig(cmd)

    monkeypatch.setattr(mesh.shards[1], "_validate_command", veto)
    mesh.control.submit(SwapSlot(1, spare_params))
    with pytest.raises(ValueError, match="host 1 refuses"):
        mesh.flush_control()
    assert [s.bank for s in mesh.shards] == banks_before
    assert all(s.telemetry.slot_swaps == 0 for s in mesh.shards)
    rec = mesh.control.log[-1]
    assert rec.error and not rec.applied
    assert not mesh.barrier_log                 # no barrier was crossed


def test_epoch_commit_failure_rolls_back_every_host(bank2, spare_params):
    """Commit phase: an epoch that passes staging but fails mid-commit
    (apply-time conflict) rolls back ALL hosts — including ones that
    already applied earlier commands of the epoch."""
    mesh = make_mesh(bank2, hosts=2, num_queues=2)
    banks_before = [s.bank for s in mesh.shards]
    reta_before = mesh.reta.copy()
    # SwapSlot applies on both hosts first; failing every global queue
    # then raises at apply time (zero survivors) -> everything rolls back
    mesh.control.submit(SwapSlot(1, spare_params),
                        FailQueues(tuple(range(mesh.num_queues))))
    with pytest.raises(ValueError):
        mesh.flush_control()
    assert [s.bank for s in mesh.shards] == banks_before
    assert all(s.telemetry.slot_swaps == 0 for s in mesh.shards)
    assert (mesh.reta == reta_before).all()
    assert mesh.failed_queues == set()
    assert mesh.telemetry.slot_swaps == 0 and mesh.telemetry.reta_updates == 0
    rec = mesh.control.log[-1]
    assert rec.error and not rec.applied


def test_applied_epoch_keeps_barrier_stamp_when_later_epoch_rejects(
        bank2, spare_params):
    """An epoch that committed before a later pending epoch was rejected
    in the same flush still carries its host_ticks barrier proof."""
    mesh = make_mesh(bank2, hosts=2, num_queues=2)
    good = mesh.control.submit(SwapSlot(1, spare_params))
    mesh.control.submit(FailQueues(tuple(range(mesh.num_queues))))
    with pytest.raises(ValueError):
        mesh.flush_control()
    recs = {r.epoch: r for r in mesh.control.log}
    assert recs[good].applied
    assert recs[good].host_ticks == (0, 0)      # stamped despite the raise
    assert [b["epoch"] for b in mesh.barrier_log] == [good]
    assert mesh.telemetry.slot_swaps == 1       # the good epoch stuck
    bad = recs[max(recs)]
    assert bad.error and not bad.applied and bad.host_ticks is None


def test_mesh_continuity_audit_across_cascading_failover(bank2):
    phases = cascading_failover_phases(2, hosts=2, queues_per_host=2)
    trace = render(phases, num_slots=2, seed=0, num_queues=4)
    mesh = make_mesh(bank2, hosts=2, num_queues=2, strategy="fused",
                     ring_capacity=256, audit=True, pipeline_depth=2)
    play(mesh, trace)
    cont = mesh.control.continuity_audit()
    kinds = {c for e in cont["epochs"] for c in e["commands"]}
    assert kinds >= {"restore_queues", "fail_queues", "swap_slot"}, kinds
    assert cont["ok"], cont
    assert mesh.telemetry.wrong_verdict == 0
    aud = mesh.audit_conservation()
    assert aud["ok"]
    assert aud["totals"]["offered"] == trace.total_packets


# ---------------------------------------------------------------------------
# mesh policies: the single-host loop, unchanged at mesh scale
# ---------------------------------------------------------------------------

def test_mesh_policy_rebalances_with_global_ids(bank2):
    phases = scenarios.elephant_skew_phases(2, 4, ticks=6)
    trace = render(phases, num_slots=2, seed=0, num_queues=4)
    drops = {}
    for policy in (StaticReta(), LeastDepth()):
        mesh = make_mesh(bank2, hosts=2, num_queues=2, batch=64,
                         ring_capacity=256, policy=policy)
        play(mesh, trace)
        aud = mesh.audit_conservation()
        assert aud["ok"]
        drops[policy.name] = max(q["dropped"] for q in aud["per_queue"])
        if policy.name == "least-depth":
            rebalances = [r for r in mesh.control.log
                          if any(isinstance(c, ProgramReta)
                                 for c in r.commands)]
            assert rebalances                   # proposals became epochs
            assert all(len(set(r.host_ticks)) == 1 for r in rebalances)
    assert drops["static"] > 0                  # skew hurts one (host, queue)
    assert drops["least-depth"] < drops["static"]


def test_mesh_policy_never_routes_onto_failed_pairs(bank2):
    phases = scenarios.elephant_skew_phases(2, 4, ticks=4)
    trace = render(phases, num_slots=2, seed=1, num_queues=4)
    mesh = make_mesh(bank2, hosts=2, num_queues=2, batch=64,
                     ring_capacity=256, policy=LeastDepth())
    mesh.control.submit(FailQueues((3,)))       # host 1, queue 1
    for phase_bursts in trace.bursts:
        for burst in phase_bursts:
            mesh.dispatch(burst)
            mesh.tick()
    mesh.drain()
    assert 3 not in set(mesh.reta.tolist())
    assert mesh.audit_conservation()["ok"]


# ---------------------------------------------------------------------------
# telemetry merge
# ---------------------------------------------------------------------------

def test_telemetry_merge_aggregates_hosts():
    t0, t1 = telemetry.Telemetry(2, 2), telemetry.Telemetry(2, 2)
    t0.record_tick(0, np.array([0, 1]), np.array([True, False]),
                   np.array([0, 1]), latency_us=np.array([10.0, 20.0]),
                   tick_s=0.5)
    t1.record_tick(1, np.array([1, 1, 0]), np.array([True, True, False]),
                   np.array([2, 0, 0]), latency_us=np.array([5.0, 6.0, 7.0]),
                   tick_s=0.25)
    t0.slot_swaps, t1.wrong_verdict = 2, 3
    merged = telemetry.merge([t0, t1])
    assert len(merged.queues) == 4              # host-major global order
    assert [q.queue for q in merged.queues] == [0, 1, 2, 3]
    assert merged.queues[0].completed == 2      # host 0, queue 0
    assert merged.queues[3].completed == 3      # host 1, queue 1
    assert merged.slot_swaps == 2 and merged.wrong_verdict == 3
    snap = merged.snapshot()
    assert snap["completed_total"] == 5
    assert merged.queues[3].latency_hist.sum() == 3
    # deep copy: mutating the merge never touches the inputs
    merged.queues[0].per_slot_total[0] = 99
    assert t0.queues[0].per_slot_total[0] != 99
    with pytest.raises(ValueError):
        telemetry.merge([])
    with pytest.raises(ValueError):
        telemetry.merge([t0, telemetry.Telemetry(1, 3)])


# ---------------------------------------------------------------------------
# scenario registry + device-layout helper
# ---------------------------------------------------------------------------

def test_cascading_failover_phase_shapes():
    phases = cascading_failover_phases(2, hosts=2, queues_per_host=4)
    assert [p.name for p in phases] == ["steady", "host_down", "cascade",
                                        "recovery"]
    assert phases[1].failed_queues == (0, 1, 2, 3)       # all of host 0
    assert set(phases[2].failed_queues) >= {0, 1, 2, 3, 4, 5}
    assert phases[3].failed_queues == () and phases[3].swap_slot is not None
    with pytest.raises(ValueError, match="zero live"):
        cascading_failover_phases(2, hosts=1, queues_per_host=2)
    via_registry = make_scenario("cascading-failover", num_slots=2,
                                 num_queues=4, hosts=2)
    assert [p.name for p in via_registry] == [p.name for p in phases]


def test_queue_mesh_single_source_of_truth():
    from repro.dataplane import queue_mesh
    m1, ax1 = queue_mesh(4)
    m2, ax2 = mesh_lib.make_queue_mesh(4)
    assert ax1 == ax2
    assert m1.devices.shape == m2.devices.shape
    assert m1.axis_names == m2.axis_names
    with pytest.raises(ValueError):
        mesh_lib._build((2, 2), ("only-one-axis",))
