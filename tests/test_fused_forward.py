"""Fused forwarding megakernel: bit-exact parity vs the ref oracle across
slot counts, ragged traces, and both input modes; streaming replay
regression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bank as bank_lib
from repro.core import executor, packet as pkt, pipeline, switching
from repro.kernels import fused_forward as ff
from repro.kernels import ops, ref

CFG = executor.BNNConfig(d_bits=64 * 32, hidden=16, n_out=1)  # small h16


def _bank(num_slots):
    return executor.init_bank(jax.random.PRNGKey(7), num_slots, CFG)


def _payload(rng, b, words=CFG.d_bits // 32):
    return jnp.asarray(rng.integers(0, 2**32, (b, words), dtype=np.uint32))


@pytest.mark.parametrize("num_slots", [1, 4, 16])
def test_fused_gather_bit_exact_vs_oracle(num_slots):
    """interpret=True kernel output == pure-jnp oracle, bit for bit."""
    rng = np.random.default_rng(num_slots)
    bank = _bank(num_slots)
    b, bb = 48, 8
    x = _payload(rng, b)
    slots = jnp.asarray(rng.integers(0, num_slots, b), jnp.int32)
    g = bank_lib.group_by_slot_padded(slots, num_slots, bb)

    got = ops.bnn_forward_fused(bank, x, g.block_slots, g.row_ids,
                                block_b=bb, backend="pallas")
    want = ops.bnn_forward_fused(bank, x, g.block_slots, g.row_ids,
                                 block_b=bb, backend="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and the de-padded result matches the per-row oracle exactly
    back = np.asarray(jnp.take(got, g.result_rows, axis=0))
    oracle = ref.banked_xnor_forward_ref(
        bank["w1p"], bank["b1"], bank["w2"], bank["b2"], x, slots)
    np.testing.assert_array_equal(back, np.asarray(oracle))


@pytest.mark.parametrize("kind", ["hotspot", "random", "round_robin"])
def test_fused_ragged_traces(kind):
    """Ragged slot distributions from the paper's access traces."""
    num_slots, b, bb = 8, 64, 8
    bank = _bank(num_slots)
    rng = np.random.default_rng(3)
    x = _payload(rng, b)
    slots = jnp.asarray(
        switching.access_trace(kind, b, num_slots, seed=1), jnp.int32)
    g = bank_lib.group_by_slot_padded(slots, num_slots, bb)
    got = ops.bnn_forward_fused(bank, x, g.block_slots, g.row_ids,
                                block_b=bb, backend="pallas")
    oracle = ref.banked_xnor_forward_ref(
        bank["w1p"], bank["b1"], bank["w2"], bank["b2"], x, slots)
    np.testing.assert_array_equal(
        np.asarray(jnp.take(got, g.result_rows, axis=0)), np.asarray(oracle))


def test_fused_contiguous_mode_matches_grouped_kernel():
    """row_ids=None path (pre-grouped rows) == staged grouped kernel entry."""
    num_slots, b, bb = 4, 32, 8
    bank = _bank(num_slots)
    rng = np.random.default_rng(5)
    slots = jnp.asarray(rng.integers(0, num_slots, b), jnp.int32)
    x = _payload(rng, b)
    g = bank_lib.group_by_slot_padded(slots, num_slots, bb)
    x_pad = bank_lib.scatter_padded(x, g)
    fused = ops.bnn_forward_grouped(bank, x_pad, g.block_slots,
                                    block_b=bb, backend="pallas")
    want = ops.bnn_forward_grouped(bank, x_pad, g.block_slots,
                                   block_b=bb, backend="ref")
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(want))


def test_packet_forward_fused_inline_actions():
    """The megakernel's in-kernel parse + Pi matches the staged pipeline,
    including the monitor-only control bit."""
    num_slots, b = 4, 48
    bank = executor.init_bank(jax.random.PRNGKey(0), num_slots)
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 2**32, (b, pkt.PAYLOAD_WORDS), dtype=np.uint32)
    slots = rng.integers(0, num_slots, b)
    for control in (0, int(pkt.CTRL_MONITOR_ONLY)):
        p = jnp.asarray(pkt.make_packets(slots, payload, control=control))
        base = pipeline.packet_step(bank, p, num_slots=num_slots,
                                    strategy="take")
        for backend in ("pallas", "ref"):
            res = pipeline.packet_step(bank, p, num_slots=num_slots,
                                       strategy="fused", backend=backend,
                                       block_b=8)
            np.testing.assert_array_equal(np.asarray(res.slots),
                                          np.asarray(base.slots))
            np.testing.assert_array_equal(np.asarray(res.scores),
                                          np.asarray(base.scores))
            np.testing.assert_array_equal(np.asarray(res.verdicts),
                                          np.asarray(base.verdicts))
            np.testing.assert_array_equal(np.asarray(res.actions),
                                          np.asarray(base.actions))


@pytest.mark.parametrize("strategy", ["grouped", "grouped_staged"])
def test_executor_grouped_strategies_agree(strategy):
    num_slots, b = 16, 64
    bank = _bank(num_slots)
    rng = np.random.default_rng(9)
    x = _payload(rng, b)
    slots = jnp.asarray(rng.integers(0, num_slots, b), jnp.int32)
    base = executor.forward_banked(bank, x, slots, strategy="take")
    got = executor.forward_banked(bank, x, slots, strategy=strategy,
                                  block_b=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def test_actions_ref_constants_mirror_packet_layout():
    assert ff.CTRL_WORD == pkt.CONTROL_WORD_LO
    assert ff.CTRL_MONITOR_ONLY == int(pkt.CTRL_MONITOR_ONLY)
    assert (ff.ACTION_FORWARD, ff.ACTION_DROP, ff.ACTION_FLAG) == (
        pkt.ACTION_FORWARD, pkt.ACTION_DROP, pkt.ACTION_FLAG)


def test_fused_rejects_bad_shapes():
    bank = _bank(2)
    rng = np.random.default_rng(1)
    x = _payload(rng, 16)
    with pytest.raises(ValueError, match="row_ids"):
        ff.fused_forward(x, bank["w1p"], bank["b1"], bank["w2"], bank["b2"],
                         jnp.zeros(2, jnp.int32), jnp.zeros(5, jnp.int32),
                         block_b=8, interpret=True)
    with pytest.raises(ValueError, match="with_actions"):
        ff.fused_forward(x, bank["w1p"], bank["b1"], bank["w2"], bank["b2"],
                         jnp.zeros(2, jnp.int32), block_b=8, interpret=True,
                         with_actions=True)


def test_streaming_replay_boundary_regression():
    """Streaming replay engine must preserve exact continuity semantics:
    zero wrong slots / verdicts on the boundary trace."""
    bank = executor.init_bank(jax.random.PRNGKey(0), 2)
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 2**32, (64, pkt.PAYLOAD_WORDS), dtype=np.uint32)
    tr = switching.boundary_trace(64, payload)
    res = switching.replay_trace(bank, tr, num_slots=2, batch=8,
                                 stream=True, stream_window=4)
    assert res.wrong_slot == 0
    assert res.wrong_verdict == 0
    assert res.boundary_index == 32
    assert np.all(np.diff(res.timestamps_us) >= 0)  # retire order is monotone


def test_streaming_replay_fused_strategy():
    bank = executor.init_bank(jax.random.PRNGKey(0), 2)
    rng = np.random.default_rng(0)
    payload = rng.integers(0, 2**32, (32, pkt.PAYLOAD_WORDS), dtype=np.uint32)
    tr = switching.boundary_trace(32, payload)
    res = switching.replay_trace(bank, tr, num_slots=2, batch=8,
                                 strategy="fused", stream=True)
    assert res.wrong_slot == 0 and res.wrong_verdict == 0
