"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes/dtypes, plus hypothesis properties of the bit packing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import banked_matmul as bm
from repro.kernels import bnn_xnor, ops, ref


def _rand_packed(rng, shape):
    return jnp.asarray(rng.integers(0, 2**32, shape, dtype=np.uint32))


@pytest.mark.parametrize("b,h,w,bb,bh,chunk", [
    (8, 8, 8, 8, 8, 8),
    (16, 32, 256, 8, 16, 64),     # paper h32 layout (1024B payload)
    (32, 32, 256, 32, 32, 32),
    (64, 16, 64, 16, 8, 16),
    (8, 8, 32, 4, 4, 8),
])
def test_xnor_kernel_matches_ref(rng, b, h, w, bb, bh, chunk):
    x = _rand_packed(rng, (b, w))
    wts = _rand_packed(rng, (h, w))
    got = bnn_xnor.xnor_matmul(x, wts, block_b=bb, block_h=bh, chunk=chunk,
                               interpret=True)
    want = ref.xnor_matmul_ref(x, wts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_xnor_equals_float_dot(rng):
    """Binary dot via popcount == dense +-1 matmul."""
    x = _rand_packed(rng, (8, 16))
    w = _rand_packed(rng, (4, 16))
    d = 16 * 32
    xf = ref.unpack_bits(x, d).astype(np.float32)
    wf = ref.unpack_bits(w, d).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(ref.xnor_matmul_ref(x, w)), (xf @ wf.T).astype(np.int32))


def test_mxu_path_matches_bitwise(rng):
    x = _rand_packed(rng, (8, 32))
    w = _rand_packed(rng, (16, 32))
    np.testing.assert_array_equal(
        np.asarray(ref.xnor_matmul_mxu_ref(x, w)),
        np.asarray(ref.xnor_matmul_ref(x, w)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,d,hid,k,bb", [
    (8, 16, 8, 3, 4), (16, 32, 16, 2, 8), (32, 64, 8, 5, 8),
])
def test_banked_matmul_kernel(rng, dtype, b, d, hid, k, bb):
    x = jnp.asarray(rng.normal(size=(b, d)), dtype)
    w = jnp.asarray(rng.normal(size=(k, d, hid)), dtype)
    bias = jnp.asarray(rng.normal(size=(k, hid)), dtype)
    block_slots = jnp.asarray(rng.integers(0, k, b // bb), jnp.int32)
    got = bm.banked_matmul(x, w, bias, block_slots, block_b=bb, interpret=True)
    slots = jnp.repeat(block_slots, bb)
    want = ref.banked_matmul_ref(x, w, bias, slots)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5, atol=1e-2)


@pytest.mark.parametrize("b,h,w,k,bb,chunk", [
    (16, 8, 32, 2, 8, 16), (32, 32, 256, 16, 16, 64),
])
def test_banked_xnor_layer1_kernel(rng, b, h, w, k, bb, chunk):
    x = _rand_packed(rng, (b, w))
    bank_w1 = _rand_packed(rng, (k, h, w))
    bank_b1 = jnp.asarray(rng.normal(size=(k, h)), jnp.float32)
    block_slots = jnp.asarray(rng.integers(0, k, b // bb), jnp.int32)
    got = bm.banked_xnor_layer1(x, bank_w1, bank_b1, block_slots,
                                block_b=bb, chunk=chunk, interpret=True)
    slots = np.repeat(np.asarray(block_slots), bb)
    d = w * 32
    want = np.stack([
        np.asarray(ref.xnor_matmul_ref(x[i:i+1], bank_w1[slots[i]]))[0]
        + np.asarray(bank_b1[slots[i]])
        for i in range(b)
    ])
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 6), st.integers(1, 8), st.data())
def test_pack_unpack_roundtrip(rows, words, data):
    d = words * 32
    bits = data.draw(st.lists(
        st.lists(st.sampled_from([-1, 1]), min_size=d, max_size=d),
        min_size=rows, max_size=rows))
    x = jnp.asarray(np.asarray(bits, np.int8))
    packed = ref.pack_bits(x)
    back = ref.unpack_bits(packed, d)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_ops_backends_agree(rng):
    key = jax.random.PRNGKey(0)
    params = ref.random_bnn_params(key, 1024, 16)
    x = _rand_packed(rng, (16, 32))
    y_ref = ops.bnn_forward(params, x, backend="ref")
    y_mxu = ops.bnn_forward(params, x, backend="mxu")
    y_pal = ops.bnn_forward(params, x, backend="pallas")
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_mxu), atol=1e-3)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal), atol=1e-5)
