"""Control-plane API: epoch semantics, deprecation shims, command
interleaving invariants, pipelined-tick parity, and adaptive routing
policies (DESIGN.md §7)."""

import json
import warnings

import jax
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.control import (ControlPlane, DropRateRebalance, FailQueues,
                           LeastDepth, PolicyView, ProgramReta, RestoreQueues,
                           SetPolicy, StaticReta, SwapSlot, make_policy)
from repro.core import executor, packet as pkt
from repro.dataplane import (DataplaneRuntime, Phase, elephant_skew_phases,
                             emergency_phases, phase_commands, play, render,
                             rss, scenarios)


@pytest.fixture(scope="module")
def bank2():
    return executor.init_bank(jax.random.PRNGKey(0), 2)


@pytest.fixture(scope="module")
def spare_params():
    return (executor.init_params(jax.random.PRNGKey(41)),
            executor.init_params(jax.random.PRNGKey(42)))


def small_phases(num_slots=2):
    uniform = tuple(1.0 / num_slots for _ in range(num_slots))
    return [
        Phase("steady", ticks=2, burst=64, flows=16, slot_mix=uniform),
        Phase("crowd", ticks=2, burst=192, flows=4, slot_mix=uniform),
        Phase("churn", ticks=2, burst=64, flows=16, slot_mix=uniform,
              failed_queues=(0,), swap_slot=1),
    ]


def make_rt(bank, **kw):
    kw.setdefault("num_queues", 4)
    kw.setdefault("strategy", "take")
    kw.setdefault("batch", 32)
    kw.setdefault("ring_capacity", 4096)
    return DataplaneRuntime(bank, **kw)


# ---------------------------------------------------------------------------
# epoch semantics
# ---------------------------------------------------------------------------

def test_epoch_applies_only_at_tick_boundary(bank2):
    rt = make_rt(bank2)
    before = rt.reta.copy()
    new = tuple(np.roll(rss.indirection_table(4), 1))
    epoch = rt.control.submit(ProgramReta(new))
    # submit never touches the runtime
    assert (rt.reta == before).all()
    assert [r.epoch for r in rt.control.pending] == [epoch]
    assert rt.telemetry.reta_updates == 0
    rt.tick()  # boundary (empty rings still cross it)
    assert (rt.reta == np.asarray(new)).all()
    assert not rt.control.pending
    rec = rt.control.log[-1]
    assert rec.epoch == epoch and rec.applied
    assert rec.apply_us > 0 and rec.apply_latency_us >= rec.apply_us


def test_epoch_is_atomic_and_ordered(bank2, spare_params):
    rt = make_rt(bank2)
    # two epochs: the first fails a queue and swaps a slot atomically,
    # the second restores — applied in submission order at one boundary
    e1 = rt.control.submit(FailQueues((0,)), SwapSlot(1, spare_params[0]))
    e2 = rt.control.submit(RestoreQueues())
    rt.flush_control()
    assert [r.epoch for r in rt.control.log] == [e1, e2]
    assert rt.telemetry.slot_swaps == 1
    assert rt.telemetry.reta_updates == 2       # failover then restore
    assert (rt.reta == rss.indirection_table(4)).all()
    assert rt.failed_queues == set()


def test_command_log_is_serializable(bank2, spare_params):
    rt = make_rt(bank2)
    rt.control.submit(SwapSlot(0, spare_params[0]),
                      ProgramReta(tuple(rss.indirection_table(4))))
    rt.control.submit(SetPolicy(LeastDepth()))
    rt.flush_control()
    log = rt.control.command_log()
    blob = json.dumps(log)  # must round-trip as JSON
    assert json.loads(blob) == log
    swap = log[0]["commands"][0]
    assert swap["cmd"] == "swap_slot" and swap["delta_bytes"] > 0
    assert log[1]["commands"][0]["policy"] == "least-depth"
    assert all(rec["api_version"] == ControlPlane.API_VERSION for rec in log)


def test_invalid_commands_rejected_atomically(bank2, spare_params):
    rt = make_rt(bank2)
    with pytest.raises(ValueError):
        rt.control.submit()
    with pytest.raises(TypeError):
        rt.control.submit("swap please")
    # a rejected epoch is atomic: the valid SwapSlot ahead of the bad
    # ProgramReta must NOT apply, and the rejection lands in the log
    rt.control.submit(SwapSlot(1, spare_params[0]),
                      ProgramReta(tuple([7] * rss.RETA_SIZE)))
    with pytest.raises(ValueError):
        rt.flush_control()
    assert rt.telemetry.slot_swaps == 0
    rec = rt.control.log[-1]
    assert rec.error and not rec.applied
    assert rt.control.command_log()[-1]["error"] == rec.error
    with pytest.raises(ValueError):  # failing every queue is unservable
        rt.control.submit(FailQueues((0, 1, 2, 3)))
        rt.flush_control()
    assert rt.failed_queues == set()


def test_conflicting_epoch_rolls_back_atomically(bank2):
    """Commands that are individually valid but conflict with each other
    fail at apply time; the state snapshot rolls EVERYTHING back."""
    rt = make_rt(bank2)
    rt.control.submit(FailQueues((0,)), FailQueues((1, 2, 3)))
    with pytest.raises(ValueError):
        rt.flush_control()
    assert rt.failed_queues == set()            # first command rolled back
    assert (rt.reta == rss.indirection_table(4)).all()
    assert rt.telemetry.reta_updates == 0
    assert rt.control.log[-1].error
    # phantom queue ids are rejected up front, not absorbed forever
    rt.control.submit(FailQueues((4,)))
    with pytest.raises(ValueError):
        rt.flush_control()
    assert rt.failed_queues == set()


def test_sequentially_valid_epoch_applies(bank2):
    """An epoch whose commands are only valid in order (restore one queue,
    then fail another) must apply — commands see their predecessors."""
    rt = make_rt(bank2)
    rt.control.submit(FailQueues((1, 2, 3)))
    rt.flush_control()
    rt.control.submit(RestoreQueues((1,)), FailQueues((0,)))
    rt.flush_control()                          # must not raise
    assert rt.failed_queues == {0, 2, 3}
    assert set(rt.reta.tolist()) == {1}         # queue 1 carries everything
    assert rt.control.log[-1].error is None


def test_render_rejects_bad_elephant_phases():
    bad_queue = [Phase("skew", ticks=1, burst=8, flows=8, slot_mix=(1.0,),
                       elephant_flows=2, elephant_queue=7)]
    with pytest.raises(ValueError, match="out of range"):
        render(bad_queue, num_slots=1, seed=0, num_queues=4)
    all_elephants = [Phase("skew", ticks=1, burst=8, flows=2, slot_mix=(1.0,),
                           elephant_flows=2, elephant_queue=0)]
    with pytest.raises(ValueError, match="elephant_flows"):
        render(all_elephants, num_slots=1, seed=0, num_queues=4)


def test_log_does_not_pin_swap_payloads(bank2, spare_params):
    rt = make_rt(bank2)
    rt.control.submit(SwapSlot(1, spare_params[0]))
    rt.flush_control()
    rec = rt.control.log[-1]
    assert rec.commands[0].params is None       # payload dropped after apply
    assert rec.summaries[0]["delta_bytes"] > 0  # but the delta size is kept
    assert rt.control.command_log()[-1]["commands"][0]["delta_bytes"] > 0


def test_policy_survives_reta_resize(bank2):
    """Installing a RETA of a different size must not crash the policy's
    delta tracking (the deltas restart instead)."""
    trace = render(small_phases(), num_slots=2, seed=9)
    bursts = [b for ph in trace.bursts for b in ph]
    rt = make_rt(bank2, policy=LeastDepth())
    rt.dispatch(bursts[0])
    rt.tick()                                   # seeds _last_load (len 128)
    rt.control.submit(ProgramReta(tuple(rss.indirection_table(4, 64))))
    rt.dispatch(bursts[1])                      # resize applies here
    rt.tick()                                   # must not raise
    rt.drain()
    assert len(rt.reta) == 64
    assert rt.audit_conservation()["ok"]


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------

def _drive(rt, bursts):
    for b in bursts:
        rt.dispatch(b)
        rt.tick()
    rt.drain()


def test_shims_warn_and_match_explicit_epochs(bank2, spare_params):
    trace = render(small_phases(), num_slots=2, seed=3)
    bursts = [b for ph in trace.bursts for b in ph]
    mid = len(bursts) // 2

    def run(mutate):
        rt = make_rt(bank2, record=True, audit=True)
        _drive(rt, bursts[:mid])
        mutate(rt)
        _drive(rt, bursts[mid:])
        return rt

    def via_shims(rt):
        with pytest.warns(DeprecationWarning):
            rt.swap_slot(1, spare_params[1])
        with pytest.warns(DeprecationWarning):
            rt.fail_queues((2,))
        with pytest.warns(DeprecationWarning):
            rt.set_reta(rss.failover_table(rt.reta, (3,), num_queues=4))
        with pytest.warns(DeprecationWarning):
            rt.reset_reta()

    def via_epochs(rt):
        rt.control.submit(SwapSlot(1, spare_params[1]))
        rt.control.submit(FailQueues((2,)))
        rt.control.submit(ProgramReta(
            tuple(rss.failover_table(
                rss.failover_table(rt.reta, (2,), num_queues=4),
                (3,), num_queues=4))))
        rt.control.submit(RestoreQueues())

    a, b = run(via_shims), run(via_epochs)
    assert a.completed_seq == b.completed_seq
    assert a.completed_verdicts == b.completed_verdicts
    assert a.completed_slots == b.completed_slots
    assert (a.reta == b.reta).all()
    assert a.telemetry.wrong_verdict == b.telemetry.wrong_verdict == 0
    # the shim path went through the control plane: everything is logged
    assert len(a.control.log) >= 4


# ---------------------------------------------------------------------------
# property: epoch interleavings preserve conservation + per-queue FIFO
# ---------------------------------------------------------------------------

_OP = st.sampled_from(
    ["dispatch", "tick", "fail", "restore", "reta", "swap", "policy"])


@settings(max_examples=12, deadline=None)
@given(st.lists(_OP, min_size=4, max_size=24), st.integers(0, 2**31))
def test_epoch_interleaving_invariants(ops, seed, bank2, spare_params):
    """Any interleaving of valid command epochs with traffic keeps the
    ring conservation invariants and per-queue FIFO ordering;
    ``audit_conservation`` holds after every single epoch."""
    rng = np.random.default_rng(seed)
    trace = render(small_phases(), num_slots=2, seed=seed % 97)
    bursts = [b for ph in trace.bursts for b in ph]
    rt = make_rt(bank2, ring_capacity=64, record=True,
                 pipeline_depth=1 + seed % 3)
    sent = 0
    for op in ops:
        if op == "dispatch":
            if sent < len(bursts):  # each burst once: seq stamps stay unique
                rt.dispatch(bursts[sent])
                sent += 1
        elif op == "tick":
            rt.tick()
        elif op == "fail":
            rt.control.submit(FailQueues((1 + rng.integers(3),)))
        elif op == "restore":
            rt.control.submit(RestoreQueues())
        elif op == "reta":
            rt.control.submit(ProgramReta(
                tuple(rng.integers(0, 4, rss.RETA_SIZE))))
        elif op == "swap":
            rt.control.submit(SwapSlot(int(rng.integers(2)),
                                       spare_params[rng.integers(2)]))
        elif op == "policy":
            rt.control.submit(SetPolicy(
                [None, StaticReta(), LeastDepth()][rng.integers(3)]))
        aud = rt.audit_conservation()
        assert aud["ok"], (op, aud)
    rt.drain()
    aud = rt.audit_conservation()
    assert aud["ok"] and aud["totals"]["occupancy"] == 0
    assert aud["totals"]["in_flight"] == 0
    for seqs in rt.completed_seq:            # FIFO within every queue
        assert (np.diff(np.asarray(seqs)) > 0).all()
    done = [s for qs in rt.completed_seq for s in qs]
    assert len(done) == len(set(done))       # no duplication across queues
    assert len(done) + len(rt.dropped_seq) == aud["totals"]["offered"]


# ---------------------------------------------------------------------------
# pipelined ticks: bit-identical to the synchronous loop
# ---------------------------------------------------------------------------

def test_pipelined_ticks_bit_identical_on_emergency(bank2):
    trace = render(emergency_phases(2), num_slots=2, seed=0)
    runs = {}
    for depth in (1, 4):
        rt = make_rt(bank2, batch=128, record=True, pipeline_depth=depth)
        play(rt, trace)
        aud = rt.audit_conservation()
        assert aud["ok"] and aud["totals"]["completed"] == trace.total_packets
        runs[depth] = (rt.completed_seq, rt.completed_verdicts,
                       rt.completed_slots)
    assert runs[1] == runs[4]


def test_pipeline_window_accounts_in_flight(bank2, rng):
    rt = make_rt(bank2, num_queues=2, batch=16, pipeline_depth=3)
    rows = pkt.make_packets(
        np.zeros(64, np.int64),
        rng.integers(0, 2**32, (64, pkt.PAYLOAD_WORDS), dtype=np.uint32))
    rows[:, rss.FLOW_WORD_LO : rss.FLOW_WORD_LO + rss.FLOW_WORDS] = \
        rng.integers(0, 2**32, (64, rss.FLOW_WORDS), dtype=np.uint32)
    rt.dispatch(rows)
    rt.tick()
    rt.tick()
    aud = rt.audit_conservation()
    assert aud["ok"]                          # holds mid-pipeline
    assert aud["totals"]["in_flight"] > 0     # window actually open
    rt.drain()
    aud = rt.audit_conservation()
    assert aud["ok"] and aud["totals"]["in_flight"] == 0
    assert aud["totals"]["completed"] == 64


# ---------------------------------------------------------------------------
# continuity: zero wrong verdicts across EVERY command kind
# ---------------------------------------------------------------------------

def test_zero_wrong_verdict_across_all_command_kinds(bank2):
    phases = small_phases() + elephant_skew_phases(2, 4, ticks=4)
    trace = render(phases, num_slots=2, seed=5, num_queues=4)
    rt = make_rt(bank2, ring_capacity=128, audit=True, pipeline_depth=2)
    rt.control.submit(SetPolicy(LeastDepth()))
    play(rt, trace)
    cont = rt.control.continuity_audit()
    kinds = {c for e in cont["epochs"] for c in e["commands"]}
    assert kinds >= {"set_policy", "restore_queues", "fail_queues",
                     "swap_slot", "program_reta"}, kinds
    assert cont["ok"], cont
    assert all(e["wrong_verdict_in_window"] == 0 for e in cont["epochs"])
    assert rt.audit_conservation()["wrong_verdict"] == 0


# ---------------------------------------------------------------------------
# routing policies
# ---------------------------------------------------------------------------

def test_elephant_skew_targets_one_queue():
    t1 = render(elephant_skew_phases(2, 4), num_slots=2, seed=0, num_queues=4)
    t2 = render(elephant_skew_phases(2, 4), num_slots=2, seed=0, num_queues=4)
    for a, b in zip(t1.bursts[1], t2.bursts[1]):
        assert (a == b).all()                 # replayable
    skew_rows = np.concatenate(t1.bursts[1])
    q = rss.queue_of(skew_rows, 4)
    share = (q == 0).mean()
    assert share > 0.7                        # elephants crush queue 0
    with pytest.raises(ValueError):           # elephants need num_queues
        render(elephant_skew_phases(2, 4), num_slots=2, seed=0)


def test_adaptive_policy_beats_static_on_elephant_skew(bank2):
    trace = render(elephant_skew_phases(2, 4), num_slots=2, seed=0,
                   num_queues=4)
    max_drop = {}
    for policy in (StaticReta(), LeastDepth(), DropRateRebalance()):
        rt = make_rt(bank2, batch=64, ring_capacity=256, policy=policy)
        play(rt, trace)
        aud = rt.audit_conservation()
        assert aud["ok"]
        max_drop[policy.name] = max(q["dropped"] for q in aud["per_queue"])
        if policy.name != "static":           # rebalances are real epochs
            assert any(isinstance(c, ProgramReta)
                       for r in rt.control.log for c in r.commands)
    assert max_drop["static"] > 0             # skew actually hurts
    assert max_drop["least-depth"] < max_drop["static"]
    assert max_drop["drop-rate"] < max_drop["static"]


def test_policy_respects_failed_queues(bank2):
    trace = render(elephant_skew_phases(2, 4), num_slots=2, seed=1,
                   num_queues=4)
    rt = make_rt(bank2, batch=64, ring_capacity=256, policy=LeastDepth())
    rt.control.submit(FailQueues((3,)))
    for phase_bursts in trace.bursts:         # no play(): its per-phase
        for burst in phase_bursts:            # RestoreQueues would undo
            rt.dispatch(burst)                # the failover under test
            rt.tick()
    rt.drain()
    assert 3 not in set(rt.reta.tolist())     # never rebalanced onto a dead queue
    assert rt.audit_conservation()["ok"]


def test_make_policy_registry():
    assert make_policy("least-depth").name == "least-depth"
    assert make_policy("drop-rate").name == "drop-rate"
    assert make_policy("static").propose(
        PolicyView(tick=0, num_queues=2, reta=rss.indirection_table(2),
                   queue_depth=np.zeros(2, np.int64),
                   queue_dropped=np.zeros(2, np.int64),
                   bucket_load=np.zeros(rss.RETA_SIZE, np.int64))) is None
    with pytest.raises(ValueError):
        make_policy("hrl-someday")
