"""Trace-driven workload engine (DESIGN.md §9): generator registry,
chaos phases, and the recordable/replayable trace format — in particular
the ISSUE 5 acceptance criterion that ``record()`` -> ``replay()`` is
bit-identical on verdicts and telemetry."""

import os

import jax
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.control import FailQueues, ProgramReta, RestoreQueues
from repro.core import executor
from repro.dataplane import DataplaneRuntime, MeshDataplane, workloads
from repro.dataplane.workloads import generators
from repro.dataplane.workloads.phases import ChaosEvent, Phase


@pytest.fixture(scope="module")
def bank2():
    return executor.init_bank(jax.random.PRNGKey(0), 2)


def small_chaos_phases(num_slots=2, num_queues=3):
    """A compact storyline with mid-phase chaos: surge, a queue dies at
    tick 2 while rings are loaded, restored at tick 4, swap at exit."""
    uniform = tuple(1.0 / num_slots for _ in range(num_slots))
    victim = num_queues - 1
    chaos = (ChaosEvent(at_tick=2, commands=(FailQueues((victim,)),)),
             ChaosEvent(at_tick=4, commands=(RestoreQueues((victim,)),)))
    return [
        Phase("calm", ticks=2, burst=48, flows=16, slot_mix=uniform),
        Phase("surge", ticks=6, burst=128, flows=8, slot_mix=uniform,
              chaos=chaos),
        Phase("after", ticks=2, burst=48, flows=16, slot_mix=uniform,
              swap_slot=1 % num_slots),
    ]


def _rt(bank, num_queues=3, **kw):
    kw.setdefault("batch", 64)
    kw.setdefault("ring_capacity", 256)
    kw.setdefault("record", True)
    return DataplaneRuntime(bank, num_queues=num_queues, **kw)


# ---------------------------------------------------------------------------
# compatibility shims
# ---------------------------------------------------------------------------

def test_scenarios_shim_reexports_workloads():
    from repro.dataplane import scenarios

    assert scenarios.Phase is workloads.Phase
    assert scenarios.render is workloads.render
    assert scenarios.play is workloads.play
    assert scenarios.SEQ_WORD == workloads.SEQ_WORD
    phases = scenarios.make_scenario(
        "emergency", num_slots=2, num_queues=4)
    assert [p.name for p in phases] == [
        "steady", "flash_crowd", "link_failover", "slot_churn"]


def test_registry_serves_every_regime():
    for name in workloads.REGIME_NAMES:
        w = workloads.make_workload(
            name, num_slots=2, num_queues=2, hosts=2,
            corpus_root=generators.SYNTHETIC_CORPUS)
        assert w.phases, name
        for p in w.phases:
            assert len(p.slot_mix) == 2, name
    with pytest.raises(ValueError, match="unknown workload"):
        workloads.make_workload("nope", num_slots=2, num_queues=2)


# ---------------------------------------------------------------------------
# trace round-trip: record -> save -> load -> replay, bit-identical
# ---------------------------------------------------------------------------

def test_record_replay_bit_identical(bank2, tmp_path):
    rendered = workloads.render(small_chaos_phases(), num_slots=2, seed=11,
                                num_queues=3)
    rt = _rt(bank2)
    rec = workloads.record(rt)
    reports = workloads.play(rec, rendered)
    trace = rec.finish(name="small-chaos", seed=11)
    assert [r["phase"] for r in reports] == ["calm", "surge", "after"]
    # the command timeline holds phase entries AND chaos epochs in order
    kinds = [type(c).__name__ for _, cmds in trace.command_timeline()
             for c in cmds]
    assert kinds.count("FailQueues") == 1
    assert kinds.count("SwapSlot") == 1

    path = str(tmp_path / "small.bswt")
    nbytes = workloads.save(trace, path)
    assert nbytes == os.path.getsize(path)
    loaded = workloads.load(path)
    assert loaded.meta["name"] == "small-chaos"
    assert loaded.total_packets == rendered.total_packets

    rt2 = workloads.make_runtime(loaded)
    rep = workloads.replay(loaded, rt2)
    assert rep["ok"], rep["mismatches"]
    assert rep["digest_ok"] is True
    # bit-identical verdict/telemetry streams, not just matching digests
    assert rt2.completed_seq == rt.completed_seq
    assert rt2.completed_verdicts == rt.completed_verdicts
    assert rt2.completed_slots == rt.completed_slots
    assert sorted(rt2.dropped_seq) == sorted(rt.dropped_seq)
    assert (rt2.telemetry.wrong_verdict, rt2.telemetry.slot_swaps) == \
        (rt.telemetry.wrong_verdict, rt.telemetry.slot_swaps)


def test_record_replay_with_routing_policy(bank2, tmp_path):
    """Policy rebalance epochs are NOT in the recorded command timeline
    (they regenerate from the replaying runtime's own policy loop), so
    the trace must carry the policy name and replay must reinstall it."""
    from repro.control import make_policy

    w = workloads.make_workload("elephant-skew", num_slots=2, num_queues=3)
    rendered = workloads.render(list(w.phases), num_slots=2, seed=4,
                                num_queues=3)
    rt = _rt(bank2, policy=make_policy("least-depth"))
    rec = workloads.record(rt)
    workloads.play(rec, rendered)
    trace = rec.finish(name="skew-policy", seed=4)
    assert trace.meta["policy"] == "least-depth"
    rebalances = [r for r in rt.control.log
                  if any(isinstance(c, ProgramReta) for c in r.commands)]
    assert rebalances  # the policy really acted during the recording

    path = str(tmp_path / "pol.bswt")
    workloads.save(trace, path)
    rt2 = workloads.make_runtime(workloads.load(path))
    assert rt2.policy is not None and rt2.policy.name == "least-depth"
    rep = workloads.replay(workloads.load(path), rt2)
    assert rep["ok"], rep["mismatches"]
    assert rep["digest_ok"] is True
    # an anonymous policy cannot be recorded faithfully -> loud failure
    class Anon:
        def propose(self, view):
            return None

    rec2 = workloads.record(_rt(bank2, policy=Anon()))
    with pytest.raises(ValueError, match="non-registry policy"):
        rec2.finish()


def test_replay_detects_tampered_invariants(bank2, tmp_path):
    rendered = workloads.render(small_chaos_phases(), num_slots=2, seed=3,
                                num_queues=3)
    rec = workloads.record(_rt(bank2))
    workloads.play(rec, rendered)
    trace = rec.finish()
    for step in trace.steps:
        if step["kind"] == "phase":
            step["expect"]["completed"] += 1  # lie about one phase
            break
    rep = workloads.replay(trace, _rt(bank2))
    assert not rep["ok"]
    assert any("completed" in m for m in rep["mismatches"])
    with pytest.raises(AssertionError):
        workloads.replay(trace, _rt(bank2), strict=True)


def test_trace_rejects_bad_magic_and_version(tmp_path):
    bad = tmp_path / "bad.bswt"
    bad.write_bytes(b"NOTATRACE")
    with pytest.raises(ValueError, match="bad magic"):
        workloads.load(str(bad))
    t = workloads.synthesize(small_chaos_phases(), num_slots=2,
                             num_queues=3, seed=0)
    path = tmp_path / "v.bswt"
    workloads.save(t, str(path))
    from repro.dataplane.workloads import trace as trace_mod

    blob = bytearray(path.read_bytes())
    blob[len(trace_mod.MAGIC)] = 99  # bump the version byte
    path.write_bytes(bytes(blob))
    with pytest.raises(ValueError, match="version"):
        workloads.load(str(path))


def test_synthesized_replay_deterministic_on_mesh(bank2, tmp_path):
    w = workloads.make_workload("chaos-host-failover", num_slots=2,
                                num_queues=2, hosts=2)
    trace = workloads.synthesize(w.phases, num_slots=2, num_queues=4,
                                 seed=5, name=w.name)
    path = str(tmp_path / "mesh.bswt")
    workloads.save(trace, path)
    trace = workloads.load(path)

    def run():
        rt = MeshDataplane(bank2, hosts=2, num_queues=2, batch=64,
                           ring_capacity=256, record=True, audit=True)
        rep = workloads.replay(trace, rt)
        return rt, rep

    rt1, rep1 = run()
    rt2, rep2 = run()
    assert rep1["ok"], rep1["mismatches"]
    assert rep1["digest"]["sha256"] == rep2["digest"]["sha256"]
    assert rt1.telemetry.wrong_verdict == 0
    assert rt1.control.continuity_audit()["ok"]
    # the host-loss epoch really failed a whole host's queues and the
    # barrier stamps agree on every applied epoch
    fails = [r for r in rt1.control.log
             if any(isinstance(c, FailQueues) for c in r.commands)]
    assert fails and fails[0].host_ticks is not None
    assert len(set(fails[0].host_ticks)) == 1


# ---------------------------------------------------------------------------
# chaos + adversarial regimes keep the zero-wrong-verdict guarantee
# ---------------------------------------------------------------------------

def test_slot_thrash_storm_zero_wrong_verdicts(bank2):
    w = workloads.make_workload("slot-thrash", num_slots=2, num_queues=2)
    storm = [ev for p in w.phases for ev in p.chaos]
    assert len(storm) >= 8  # one epoch per storm tick
    assert any(isinstance(c, ProgramReta) for ev in storm
               for c in ev.commands)
    trace = workloads.synthesize(w.phases, num_slots=2, num_queues=2,
                                 seed=2, name=w.name)
    rt = _rt(bank2, num_queues=2, audit=True)
    rep = workloads.replay(trace, rt)
    assert rep["ok"], rep["mismatches"]
    assert rt.telemetry.wrong_verdict == 0
    assert rt.telemetry.slot_swaps >= 4
    cont = rt.control.continuity_audit()
    assert cont["ok"]
    assert len(cont["epochs"]) >= len(storm)


def test_chaos_event_fires_mid_phase_not_at_entry(bank2):
    rendered = workloads.render(small_chaos_phases(), num_slots=2, seed=1,
                                num_queues=3)
    rt = _rt(bank2, audit=True)
    workloads.play(rt, rendered)
    assert rt.telemetry.wrong_verdict == 0
    fail_epochs = [r for r in rt.control.log
                   if any(isinstance(c, FailQueues) for c in r.commands)]
    assert len(fail_epochs) == 1
    # phase entry applies at the surge's first tick; the chaos failover
    # applies strictly later (mid-surge), while the rings are loaded
    entry_tick = rt.control.log[1].applied_tick
    assert fail_epochs[0].applied_tick > entry_tick


# ---------------------------------------------------------------------------
# generator library
# ---------------------------------------------------------------------------

def test_diurnal_curve_rises_and_falls():
    phases = generators.diurnal_phases(2, steps=8)
    bursts = [p.burst for p in phases]
    assert len(bursts) == 8
    assert bursts[0] == min(bursts)          # starts at the nightly minimum
    peak = bursts.index(max(bursts))
    assert 2 <= peak <= 6                    # peaks mid-period
    assert max(bursts) > 2 * min(bursts)     # a real swing, not noise
    day_mix = phases[peak].slot_mix
    night_mix = phases[0].slot_mix
    assert day_mix[0] > night_mix[0]         # day leans on the triage slot


def test_file_replay_deterministic_and_fallback(tmp_path):
    # explicit corpus: bytes drive the pool and phase shapes
    (tmp_path / "a.bin").write_bytes(bytes(range(256)) * 64)
    (tmp_path / "b.bin").write_bytes(b"emergency" * 4096)
    p1, pool1 = generators.file_replay_workload(2, root=str(tmp_path))
    p2, pool2 = generators.file_replay_workload(2, root=str(tmp_path))
    assert [ph.name for ph in p1] == [ph.name for ph in p2]
    assert np.array_equal(pool1, pool2)
    assert len(p1) == 2 and pool1.dtype == np.uint32
    # the pool really carries the corpus bytes
    assert pool1.tobytes().startswith(bytes(range(256)))
    # no corpus anywhere -> deterministic synthetic fallback
    synth1 = generators.file_corpus(generators.SYNTHETIC_CORPUS)
    synth2 = generators.file_corpus(generators.SYNTHETIC_CORPUS)
    assert [n for n, _ in synth1] == [n for n, _ in synth2]
    assert all(d1 == d2 for (_, d1), (_, d2) in zip(synth1, synth2))


def test_render_and_synthesize_are_seed_deterministic(bank2):
    w = workloads.make_workload("flash-crowd", num_slots=2, num_queues=2)
    t1 = workloads.synthesize(w.phases, num_slots=2, num_queues=2, seed=9)
    t2 = workloads.synthesize(w.phases, num_slots=2, num_queues=2, seed=9)
    b1 = [s["rows"] for s in t1.steps if s["kind"] == "burst"]
    b2 = [s["rows"] for s in t2.steps if s["kind"] == "burst"]
    assert len(b1) == len(b2) and all(
        np.array_equal(x, y) for x, y in zip(b1, b2))
    t3 = workloads.synthesize(w.phases, num_slots=2, num_queues=2, seed=10)
    b3 = [s["rows"] for s in t3.steps if s["kind"] == "burst"]
    assert not all(np.array_equal(x, y) for x, y in zip(b1, b3))


# ---------------------------------------------------------------------------
# hypothesis: replay determinism over generated regimes
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(
    regime=st.sampled_from(["flash-crowd", "slot-thrash",
                            "chaos-queue-surge"]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_property_generated_regime_replay_is_deterministic(regime, seed):
    bank = executor.init_bank(jax.random.PRNGKey(0), 2)
    w = workloads.make_workload(regime, num_slots=2, num_queues=2)
    trace = workloads.synthesize(w.phases, num_slots=2, num_queues=2,
                                 seed=seed, name=regime)

    def run():
        rt = DataplaneRuntime(bank, num_queues=2, batch=64,
                              ring_capacity=256, record=True)
        rep = workloads.replay(trace, rt)
        return rt, rep

    rt1, rep1 = run()
    rt2, rep2 = run()
    assert rep1["ok"], rep1["mismatches"]
    assert rep1["digest"]["sha256"] == rep2["digest"]["sha256"]
    assert rt1.completed_verdicts == rt2.completed_verdicts
    assert sorted(rt1.dropped_seq) == sorted(rt2.dropped_seq)
